"""Paper Table III reproduction: resource / utilization / performance /
power for the six (n, m) configurations, from the compiled SPD LBM PE +
the calibrated platform model, diffed against the paper's measurements."""

from __future__ import annotations

import time

from repro.apps import lbm
from repro.core.dse import (
    FPGAModel,
    StreamWorkload,
    TABLE3_MEASURED,
    render_table,
)


def run() -> list[str]:
    out = []
    t0 = time.time()
    prob = lbm.LBMProblem(300, 720, mode="wrap")
    sim = lbm.LBMSimulation(prob)
    rep = sim.hardware_report
    w = StreamWorkload.from_report(rep, elems=720 * 300, grid_w=720)
    model = FPGAModel()
    build_us = (time.time() - t0) * 1e6

    out.append("## Paper Table III reproduction (compiled SPD PE -> model)")
    out.append(f"PE: {rep.flops} FP ops ({rep.census}), depth {rep.depth}")
    pts = []
    for (n, m), meas in sorted(TABLE3_MEASURED.items()):
        pt = model.evaluate(w, n, m, rep.census)
        pts.append(pt)
        du = abs(pt.utilization - meas[4])
        dp = abs(pt.sustained_gflops - meas[5]) / meas[5]
        dw = abs(pt.power_w - meas[6]) / meas[6]
        out.append(
            f"(n={n},m={m}): sustained {pt.sustained_gflops:6.1f} GF/s "
            f"(paper {meas[5]:6.1f}, d={dp*100:4.1f}%)  u {pt.utilization:.3f} "
            f"(paper {meas[4]:.3f}, d={du:.3f})  {pt.power_w:5.1f} W "
            f"(paper {meas[6]:5.1f}, d={dw*100:4.1f}%)"
        )
    out.append(render_table(pts))
    best = max((p for p in pts if p.feasible), key=lambda p: p.perf_per_watt)
    out.append(
        f"best perf/W: (n={best.n},m={best.m}) {best.perf_per_watt:.3f} "
        f"GF/sW -- paper: (1,4) 2.416 GF/sW"
    )
    out.append(f"table3,{build_us:.0f},best=({best.n}-{best.m})")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
