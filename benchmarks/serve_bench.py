"""Load generator for the simulation-serving engine (DESIGN.md §13,
docs/pipeline.md §serve): open-loop Poisson arrivals over a multi-tenant
mix — 2-D diffusion at two grid sizes plus the uLBM core — driven
through :class:`repro.serve.sim.SimEngine` end to end:

1. **Cold start** — a fresh study directory: every context autotunes on
   first request through the budgeted non-blocking stepper
   (``live_timings`` > 0, one measurement per engine tick, interleaved
   with serving the already-warm tenants).
2. **Warm start** — a second engine over the *same* study directory and
   measurement cache: the journals replay into the runners' dedupe
   tables and every plan pins with **zero** live timings
   (``live_timings == 0``); the cold-vs-warm latency gap is the
   recorded price of first-request tuning.
3. **Batching win** — the same arrival schedule served by a resolver
   restricted to ``b_values=(1,)`` (sequential per-tenant launches):
   steady-state aggregate member-steps/s of the batched configuration
   must exceed it (``batched_wins``), the acceptance fact for the batch
   axis. Launch wall clock only — tuning time is excluded from
   ``steps_per_s`` on both sides.
4. **Backpressure** — a burst into a tiny admission queue: rejects are
   counted and *every accepted request completes* (no silent drops,
   ``accepted == completed``).

Reported per phase: steady-state aggregate steps/s, p50/p95/p99
submit→retire latency, the batch-occupancy histogram, tuning-tick and
live-timing counts, and the pinned per-context plans. Invoked as a
script this writes ``BENCH_serve.json`` next to the repo root (the
PR-over-PR trajectory file); ``--check`` re-runs the bench and
hard-fails against the committed baseline (warm p99 regression > 2x,
non-backpressure drops, a lost batching win, or a warm start that
timed anything live) — the CI ``serve`` job's gate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.core.measure import MeasurementCache
from repro.serve.sim import PlanResolver, SimEngine, SimRequest

#: Hard cap on live measurements per trial context (autotune-on-first-
#: request): the cold phase must never exceed ``n_contexts * BUDGET``.
BUDGET = 4

#: Requests per tenant and fused steps per request — small enough that
#: the whole bench (four phases, interpret mode) stays inside the CI
#: smoke window, large enough that per-launch overhead dominates noise.
REQUESTS_PER_TENANT = 8
STEPS_PER_REQUEST = 16

#: Open-loop arrival intensity: expected requests per engine tick. The
#: engine never paces the generator (rejects are counted, not retried).
#: Deliberately *saturating* — a group retires at most one batched
#: launch per tick, so arrivals outpacing the tick loop build the
#: backlog that lets the batch axis engage at full width (an idle
#: engine serves width-1 launches and batching is moot).
ARRIVAL_RATE = 8.0

BENCH_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)


# --------------------------------------------------------------------------
# Tenant mix + arrival schedule
# --------------------------------------------------------------------------


def make_tenants() -> list[dict]:
    """The tenant mix: one entry per (core, grid, regs) trial context.

    Kernels are built once per tenant and shared across its requests so
    the engine's per-object kernel cache sees one fingerprinting per
    context — the realistic serving shape.
    """
    tenants = []
    for h, w, alpha in ((32, 32, 0.2), (64, 64, 0.1)):
        sim = dif.DiffusionSimulation(h, w, alpha=alpha)
        u0, _ = dif.sine_init(h, w)
        tenants.append({
            "name": f"diffusion-{h}x{w}",
            "core": sim.kernel,
            "state": sim.state(u0),
            "regs": (sim.alpha,),
        })
    lsim = lbm.LBMSimulation(lbm.LBMProblem(32, 32, mode="wrap"))
    f0, attr, _ = lbm.taylor_green_init(32, 32)
    tenants.append({
        "name": "lbm-32x32",
        "core": lsim.stream_kernel(),
        "state": lsim.stream_state(f0, attr),
        "regs": lsim.stream_regs(),
    })
    return tenants


def make_schedule(tenants, *, seed: int = 0,
                  rate: float = ARRIVAL_RATE,
                  per_tenant: int = REQUESTS_PER_TENANT) -> list[tuple]:
    """Open-loop Poisson arrivals: ``(arrival_tick, tenant_index)``.

    Inter-arrival gaps are exponential in *ticks* (the engine's clock),
    tenant assignment is a seeded uniform draw constrained to exactly
    ``per_tenant`` requests each — the same seed reproduces the same
    trace for every phase, so cold/warm/b=1 comparisons see identical
    offered load.
    """
    rng = np.random.default_rng(seed)
    total = per_tenant * len(tenants)
    gaps = rng.exponential(1.0 / rate, size=total)
    ticks = np.floor(np.cumsum(gaps)).astype(int)
    order = rng.permutation(
        np.repeat(np.arange(len(tenants)), per_tenant)
    )
    return list(zip(ticks.tolist(), order.tolist()))


def drive(engine: SimEngine, tenants, schedule, *, rid_base: int = 0,
          max_ticks: int = 5_000):
    """Feed the schedule open-loop and tick until drained.

    Arrivals whose tick has come are submitted before each tick;
    rejected submissions (queue full) are dropped and counted by the
    engine — open-loop means the generator never retries or paces.
    Arrival ticks are relative to the engine's clock at entry, so
    repeated passes over the same schedule offer identical load (and
    hence identical launch shapes) regardless of prior ticks.
    """
    completions = []
    base = engine.tick_count
    rid = rid_base
    i = 0
    while i < len(schedule) or engine.queue or engine._active_count():
        while (i < len(schedule)
               and schedule[i][0] + base <= engine.tick_count):
            t = tenants[schedule[i][1]]
            engine.submit(SimRequest(
                rid=rid, core=t["core"], state=t["state"],
                steps=STEPS_PER_REQUEST, regs=t["regs"],
            ))
            rid += 1
            i += 1
        completions.extend(engine.step())
        if engine.tick_count - base > max_ticks:
            raise RuntimeError(
                f"load generator hit max_ticks={max_ticks} with "
                f"{len(schedule) - i} arrival(s) unsubmitted"
            )
    return completions


def steady_state(engine: SimEngine, tenants, schedule) -> dict:
    """Two-pass steady-state measurement: a warmup pass absorbs tuning
    and the one-time per-launch-shape trace/lower cost, then the
    accounting window resets and an identical measured pass reports
    pure launch work (throughput, latency, occupancy)."""
    drive(engine, tenants, schedule)
    engine.reset_counters()
    completions = drive(engine, tenants, schedule,
                        rid_base=len(schedule))
    return _phase_report(engine, completions)


def _phase_report(engine: SimEngine, completions) -> dict:
    """One phase's record: engine stats + latency percentiles."""
    stats = engine.stats()
    lat = np.array([c.latency_s for c in completions], dtype=float)
    waits = np.array([c.queue_wait_ticks for c in completions])
    stats["latency"] = {
        "p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "p95_s": float(np.percentile(lat, 95)) if lat.size else 0.0,
        "p99_s": float(np.percentile(lat, 99)) if lat.size else 0.0,
        "mean_s": float(lat.mean()) if lat.size else 0.0,
        "max_queue_wait_ticks": int(waits.max()) if waits.size else 0,
    }
    return stats


# --------------------------------------------------------------------------
# The benchmark
# --------------------------------------------------------------------------


def run(bench: dict | None = None, *, seed: int = 0) -> list[str]:
    """Run the four phases; fill ``bench`` (if given) for the JSON."""
    out = []
    t0 = time.time()
    tenants = make_tenants()
    schedule = make_schedule(tenants, seed=seed)
    out.append(
        f"## serve bench: {len(schedule)} requests over "
        f"{len(tenants)} tenant context(s) "
        f"({', '.join(t['name'] for t in tenants)}), "
        f"open-loop Poisson rate {ARRIVAL_RATE}/tick, "
        f"{STEPS_PER_REQUEST} steps/request, tuning budget {BUDGET}"
    )

    with tempfile.TemporaryDirectory(prefix="serve-bench-") as tmp:
        study_dir = os.path.join(tmp, "studies")
        cache = MeasurementCache(os.path.join(tmp, "measurements.json"))

        def resolver(**kw):
            kw.setdefault("budget", BUDGET)
            kw.setdefault("b_values", (1, 2, 4, 8))
            kw.setdefault("bh_values", (8, 16, 32))
            kw.setdefault("m_values", (1, 2, 4))
            kw.setdefault("study_dir", study_dir)
            kw.setdefault("cache", cache)
            return PlanResolver(**kw)

        # ---- phase 1: cold start --------------------------------------
        cold_eng = SimEngine(resolver())
        cold = _phase_report(cold_eng, drive(cold_eng, tenants, schedule))
        out.append(
            f"\n## phase 1: cold start — {cold['live_timings']} live "
            f"timing(s) across {len(cold['plans'])} context(s), "
            f"{cold['tuning_ticks']} tuning tick(s), "
            f"{cold['steps_per_s']:.1f} member-steps/s steady state"
        )
        for key, plan in sorted(cold["plans"].items()):
            out.append(
                f"  {key}: block_h={plan['block_h']} m={plan['m']} "
                f"b={plan['b']} db={plan['double_buffer']} "
                f"[{plan['source']}, {plan['budget_spent']} timed, "
                f"{plan['replayed']} replayed]"
            )

        # ---- phase 2: warm start (same studies + cache) ----------------
        warm_eng = SimEngine(resolver())
        warm = steady_state(warm_eng, tenants, schedule)
        out.append(
            f"\n## phase 2: warm start — {warm['live_timings']} live "
            f"timing(s) (study replay pins every plan), "
            f"{warm['steps_per_s']:.1f} member-steps/s steady state, "
            f"p99 latency {warm['latency']['p99_s']*1e3:.1f} ms "
            f"(cold p99 {cold['latency']['p99_s']*1e3:.1f} ms — the "
            f"price of first-request tuning + tracing)"
        )
        out.append(
            "  occupancy: " + ", ".join(
                f"b={k}: {v} launch(es)"
                for k, v in warm["occupancy"].items()
            )
        )

        # ---- phase 3: b=1 sequential baseline --------------------------
        b1_eng = SimEngine(resolver(
            b_values=(1,),
            study_dir=os.path.join(tmp, "studies-b1"),
        ))
        b1 = steady_state(b1_eng, tenants, schedule)
        batched_wins = warm["steps_per_s"] > b1["steps_per_s"]
        out.append(
            f"\n## phase 3: batching win — batched "
            f"{warm['steps_per_s']:.1f} vs b=1 sequential "
            f"{b1['steps_per_s']:.1f} member-steps/s "
            f"({warm['steps_per_s'] / b1['steps_per_s']:.2f}x, "
            f"{warm['launches']} vs {b1['launches']} launches) -> "
            f"{'WIN' if batched_wins else 'LOSS'}"
        )

        # ---- phase 4: backpressure burst -------------------------------
        bp_eng = SimEngine(resolver(), max_queue=4, max_active=4)
        bp_completions = []
        accepted = 0
        for rid, t in enumerate(tenants * 4):  # burst, no pacing
            accepted += bp_eng.submit(SimRequest(
                rid=1000 + rid, core=t["core"], state=t["state"],
                steps=STEPS_PER_REQUEST, regs=t["regs"],
            ))
        bp_completions = bp_eng.run_until_drained()
        bp = _phase_report(bp_eng, bp_completions)
        out.append(
            f"\n## phase 4: backpressure — burst of "
            f"{accepted + bp['rejected']} into max_queue=4: "
            f"{bp['rejected']} rejected at submit, {accepted} accepted, "
            f"{bp['completed']} completed (no silent drops)"
        )

    out.append(
        f"\nserve_bench,{(time.time() - t0) * 1e6:.0f},"
        f"batched={warm['steps_per_s']:.1f};b1={b1['steps_per_s']:.1f};"
        f"warm_live={warm['live_timings']}"
    )

    if bench is not None:
        bench["mix"] = {
            "tenants": [t["name"] for t in tenants],
            "requests": len(schedule),
            "steps_per_request": STEPS_PER_REQUEST,
            "arrival_rate_per_tick": ARRIVAL_RATE,
            "budget": BUDGET,
            "seed": seed,
        }
        bench["cold"] = cold
        bench["warm"] = warm
        bench["b1"] = b1
        bench["backpressure"] = {
            "accepted": int(accepted),
            "rejected": int(bp["rejected"]),
            "completed": int(bp["completed"]),
        }
        bench["batching"] = {
            "batched_steps_per_s": float(warm["steps_per_s"]),
            "b1_steps_per_s": float(b1["steps_per_s"]),
            "speedup": float(warm["steps_per_s"] / b1["steps_per_s"]),
            "batched_wins": bool(batched_wins),
        }
    return out


# --------------------------------------------------------------------------
# Gates (the CI serve job's hard checks)
# --------------------------------------------------------------------------


def check(bench: dict, baseline: dict | None = None) -> list[str]:
    """The acceptance gates; raises ``RuntimeError`` on any violation.

    ``bench`` is a fresh run's record; ``baseline`` the committed
    ``BENCH_serve.json`` (p99 regression is only checkable against it).
    """
    errors = []
    if bench["warm"]["live_timings"] != 0:
        errors.append(
            f"warm start timed {bench['warm']['live_timings']} "
            f"point(s) live (study replay must pin every plan)"
        )
    if not bench["batching"]["batched_wins"]:
        errors.append(
            f"batching win lost: batched "
            f"{bench['batching']['batched_steps_per_s']:.1f} <= b=1 "
            f"{bench['batching']['b1_steps_per_s']:.1f} member-steps/s"
        )
    bp = bench["backpressure"]
    if bp["completed"] != bp["accepted"]:
        errors.append(
            f"non-backpressure drop: {bp['accepted']} accepted but "
            f"{bp['completed']} completed"
        )
    for phase in ("cold", "warm", "b1"):
        ph = bench[phase]
        if ph["completed"] != ph["submitted"]:
            errors.append(
                f"{phase}: {ph['submitted']} accepted but "
                f"{ph['completed']} completed"
            )
    max_live = bench["mix"]["budget"] * len(bench["cold"]["plans"])
    if bench["cold"]["live_timings"] > max_live:
        errors.append(
            f"cold start overspent: {bench['cold']['live_timings']} "
            f"live timing(s) > budget x contexts = {max_live}"
        )
    if baseline is not None:
        base_p99 = baseline["warm"]["latency"]["p99_s"]
        fresh_p99 = bench["warm"]["latency"]["p99_s"]
        if base_p99 > 0 and fresh_p99 > 2.0 * base_p99:
            errors.append(
                f"warm p99 regression: {fresh_p99*1e3:.1f} ms > 2x "
                f"committed baseline {base_p99*1e3:.1f} ms"
            )
    if errors:
        raise RuntimeError(
            "serve bench gate failure:\n  - " + "\n  - ".join(errors)
        )
    return [
        "## gates: warm-zero-tuning OK, batching-win OK, "
        "no-silent-drops OK, budget OK"
        + (", p99-vs-baseline OK" if baseline is not None else "")
    ]


def write_bench(path: str = BENCH_PATH, *, seed: int = 0) -> list[str]:
    """Run the load generator and record ``BENCH_serve.json``."""
    bench: dict = {}
    out = run(bench, seed=seed)
    out.extend(check(bench))
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
        fh.write("\n")
    out.append(f"[wrote {path}]")
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="run the bench and hard-fail against the "
                         "committed BENCH_serve.json instead of "
                         "rewriting it (the CI serve job's gate)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.check:
        with open(BENCH_PATH, encoding="utf-8") as fh:
            baseline = json.load(fh)
        bench: dict = {}
        out = run(bench, seed=args.seed)
        try:
            out.extend(check(bench, baseline))
        except RuntimeError as e:
            print("\n".join(out))
            print(f"\nFAIL: {e}", file=sys.stderr)
            raise SystemExit(1)
        print("\n".join(out))
    else:
        print("\n".join(write_bench(seed=args.seed)))


if __name__ == "__main__":
    main()
