"""Design-space exploration sweeps (the paper's §III carried further):

1. FPGA target: the full (n, m) grid, not just the paper's six points.
2. TPU v5e target: temporal-blocking (block_h, m) sweep for the LBM kernel
   — the hardware-adapted analogue.
3. LM mesh planner: (dp, tp, pp) ranking for a transformer arch — the
   paper's spatial/temporal trade lifted to the fleet (DESIGN.md §4).
"""

from __future__ import annotations

import time

from repro.apps import lbm
from repro.core.dse import FPGAModel, StreamWorkload, TPUModel, render_table
from repro.core.planner import ArchStats, plan, render_plans
from repro.configs import get_arch


def run() -> list[str]:
    out = []
    t0 = time.time()
    prob = lbm.LBMProblem(300, 720, mode="wrap")
    sim = lbm.LBMSimulation(prob)
    w = StreamWorkload.from_report(sim.hardware_report, elems=720 * 300,
                                   grid_w=720)

    out.append("## DSE sweep 1: FPGA (n, m) grid (feasible + infeasible)")
    pts = FPGAModel().explore(w, n_values=(1, 2, 4, 8),
                              m_values=(1, 2, 4, 8),
                              census=sim.hardware_report.census)
    out.append(render_table(pts[:10]))

    out.append("\n## DSE sweep 2: TPU v5e temporal blocking (block_h, m)")
    tpts = TPUModel().explore(w)
    out.append(render_table(tpts[:10]))
    best = tpts[0]
    out.append(
        f"best: block_h={best.detail['block_rows']} m={best.m} -> "
        f"{best.sustained_gflops:.0f} GF/s "
        f"({best.utilization*100:.0f}% of VPU roof), "
        f"AI={best.detail['arithmetic_intensity']:.1f} flop/B"
    )

    out.append("\n## DSE sweep 3: LM mesh planner (granite-34b, 256 chips)")
    g = get_arch("granite-34b")
    stats = ArchStats(
        name=g.name, params=g.num_params(), active_params=g.active_params(),
        n_layers=g.n_layers, d_model=g.d_model, global_batch=256,
        seq_len=4096,
    )
    plans = plan(stats, 256)
    out.append(render_plans(plans, top=8))
    out.append(f"dse_sweep,{(time.time()-t0)*1e6:.0f},"
               f"tpu_best_m={best.m}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
