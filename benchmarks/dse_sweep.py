"""Design-space exploration sweeps (the paper's §III carried further),
driven end-to-end by ``repro.core.explorer``:

1. FPGA target: the full (n, m) lattice evaluated in one batched call,
   Pareto frontier over (throughput, perf/W, resources), and the paper's
   winning configuration (n, m) = (1, 4) recovered by ``best()``.
2. TPU v5e target: the (block_h, m) temporal-blocking lattice, its
   frontier, and — the model<->measurement loop — the top-k frontier
   points *executed* through the real ``lbm_stream`` Pallas kernel with
   predicted-vs-measured error per point. Off-TPU this runs the Pallas
   interpreter, so the error column mostly reflects host-vs-TPU speed;
   on real hardware pass interpret=False for a meaningful diff.
3. LM mesh planner: (dp, tp, pp) ranking for a transformer arch — the
   paper's spatial/temporal trade lifted to the fleet (DESIGN.md §4).
"""

from __future__ import annotations

import time

from repro.apps import lbm
from repro.core.explorer import execute_frontier, render_executed
from repro.core.planner import ArchStats, plan, render_plans
from repro.configs import get_arch

# Interpret-mode execution is host-speed; measure on a small lattice so the
# whole benchmark stays in seconds. The kernel numerics are unchanged.
MEASURE_H, MEASURE_W = 64, 128


def run(topk: int = 3, interpret: bool = True) -> list[str]:
    out = []
    t0 = time.time()
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()

    out.append("## DSE sweep 1: FPGA (n, m) lattice -> Pareto frontier")
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    out.append(sweep.table(k=10))
    frontier = sweep.frontier()
    out.append(
        f"frontier ({len(frontier)} of {len(sweep)} points): "
        + " ".join(f"(n={p.n},m={p.m})" for p in frontier)
    )
    best = sweep.best("perf_per_watt")
    out.append(
        f"best perf/W: (n={best.n},m={best.m}) -> "
        f"{best.perf_per_watt:.3f} GF/sW (paper: (1,4) -> 2.416)"
    )

    out.append("\n## DSE sweep 2: TPU v5e temporal blocking (block_h, m)")
    tsweep = ex.sweep_tpu()
    out.append(tsweep.table(k=10))
    tbest = tsweep.best("sustained_gflops")
    out.append(
        f"best: block_h={tbest.detail['block_rows']} m={tbest.m} -> "
        f"{tbest.sustained_gflops:.0f} GF/s "
        f"({tbest.utilization*100:.0f}% of VPU roof), "
        f"AI={tbest.detail['arithmetic_intensity']:.1f} flop/B"
    )

    out.append(
        f"\n## DSE sweep 2b: top-{topk} frontier points through the "
        f"Pallas kernel ({MEASURE_H}x{MEASURE_W}, "
        f"{'interpret' if interpret else 'tpu'} mode)"
    )
    mex = lbm.LBMSimulation(
        lbm.LBMProblem(MEASURE_H, MEASURE_W, mode="wrap")
    ).explorer()
    msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8))
    f0, attr, _ = lbm.taylor_green_init(MEASURE_H, MEASURE_W)
    runs = execute_frontier(
        msweep, f0, attr, one_tau=1 / 0.8, k=topk, interpret=interpret
    )
    out.append(render_executed(runs))
    if interpret:
        out.append(
            "(interpret mode: measured == host interpreter speed; the "
            "predicted column is the TPU model — run on TPU with "
            "interpret=False to close the loop on hardware)"
        )

    out.append(
        "\n## DSE sweep 2c: second SPD app (2-D diffusion) through the "
        "generic SPD->Pallas codegen"
    )
    from repro.apps import diffusion as dif

    dsim = dif.DiffusionSimulation(MEASURE_H, MEASURE_W, alpha=0.2)
    dex = dsim.explorer()
    dsweep = dex.sweep_tpu(bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8))
    u0, _ = dif.sine_init(MEASURE_H, MEASURE_W)
    druns = dex.execute_frontier(
        dsweep, dsim.state(u0), (dsim.alpha,), k=topk, interpret=interpret
    )
    out.append(render_executed(druns))
    out.append(
        f"(no hand-written kernel: {len(dsim.kernel.summary.offsets)} "
        f"stencil offsets inferred from the DFG, halo = "
        f"{dsim.kernel.summary.halo_y} row/step — docs/pipeline.md)"
    )

    out.append("\n## DSE sweep 3: LM mesh planner (granite-34b, 256 chips)")
    g = get_arch("granite-34b")
    stats = ArchStats(
        name=g.name, params=g.num_params(), active_params=g.active_params(),
        n_layers=g.n_layers, d_model=g.d_model, global_batch=256,
        seq_len=4096,
    )
    plans = plan(stats, 256)
    out.append(render_plans(plans, top=8))
    out.append(
        f"dse_sweep,{(time.time()-t0)*1e6:.0f},"
        f"fpga_best=({best.n};{best.m});tpu_best_m={tbest.m};"
        f"measured_mlups={runs[0].measured_mlups:.2f}"
    )
    return out


if __name__ == "__main__":
    print("\n".join(run()))
