"""Design-space exploration sweeps (the paper's §III carried further),
driven end-to-end by ``repro.core.explorer``:

1. FPGA target: the full (n, m) lattice evaluated in one batched call,
   Pareto frontier over (throughput, perf/W, resources), and the paper's
   winning configuration (n, m) = (1, 4) recovered by ``best()``.
2. TPU v5e target: the (block_h, m, d) temporal-blocking lattice — d is
   the device axis (y-sharding with halo exchange,
   ``repro.core.distribute``) — its frontier, and the model<->measurement
   loop: the top-k frontier points *executed* through the codegen'd uLBM
   Pallas kernel via the search subsystem's single measurement engine
   (``Explorer.search``, docs/pipeline.md §search); d > 1 points run
   sharded when the platform has the devices and are skipped otherwise.
   Measurements use the honest policy of ``repro.core.measure``
   (docs/pipeline.md §measure): median-of-reps timing with per-rep
   synchronization, *backend-calibrated* predictions — off-TPU the
   calibration anchors the model to the Pallas interpreter's measured
   throughput, so ``rel_error`` is a model-fidelity signal instead of
   the old meaningless host-vs-TPU speed ratio (≈ 0.9999 on every
   point) — and the persistent measurement cache, whose hit/miss stats
   land in the JSON (a repeated benchmark run re-times nothing).
   An **autotune smoke** then runs the budgeted strategies (LocalRefine,
   SuccessiveHalving, and the surrogate TPESearch) under a hard budget
   of ≤ 12 measurements each and hard-fails if a strategy overspends.
   The TPE pass journals into a durable named study
   (docs/pipeline.md §study) whose convergence/Pareto report is written
   next to the JSON as ``BENCH_study.html`` / ``BENCH_study.txt`` —
   the CI bench job uploads it as an artifact.
   A **stream-program sweep** (2h, docs/pipeline.md §program) then
   clocks every fusion partition of the two program apps — fused vs
   pipelined vs the unfused host-round-trip baseline — and hard-fails
   if the calibrated model's partition pick measures >10% worse than
   the best measured partition.
   A **2-D mesh sweep** (2i, DESIGN.md §15) measures every legal
   ``(dy, dx)`` factorization of a fixed device count on a wide and a
   tall diffusion grid through the search runner — block_h swept
   jointly so each mesh runs at its own best block — records
   best-mesh-per-aspect in the JSON's ``mesh`` section, and hard-fails
   if the calibrated model's mesh pick measures >10% worse than the
   best measured mesh (the §2h contract applied to the mesh axis).
3. LM mesh planner: (dp, tp, pp) ranking for a transformer arch — the
   paper's spatial/temporal trade lifted to the fleet (DESIGN.md §4).

Invoked as a script this also writes ``BENCH_dse.json`` next to the repo
root — best point, sustained GFLOPS, calibrated predicted-vs-measured
error, search ``strategy``/``budget_spent`` metadata and cache stats per
app — so the performance trajectory stays comparable across PRs.
"""

from __future__ import annotations

import json
import os
import time

from repro.apps import lbm
from repro.core.explorer import render_executed
from repro.core.measure import MeasurementCache, calibrate_backend
from repro.core.planner import ArchStats, plan, render_plans
from repro.core.search import ExhaustiveSearch
from repro.configs import get_arch

#: Hard cap on live measurements for the autotune smoke (sweep 2e): the
#: budgeted strategies must stay within it or the benchmark fails.
AUTOTUNE_BUDGET = 12

# Interpret-mode execution is host-speed; measure on a small lattice so the
# whole benchmark stays in seconds — but tall enough (256 rows) that the
# model puts d > 1 points on the frontier (on a short grid the halo
# exchange dominates and sharding is correctly dominated).
MEASURE_H, MEASURE_W = 256, 128

BENCH_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_dse.json",
)


def run(topk: int = 3, interpret: bool = True, reps: int = 3,
        bench: dict | None = None,
        cache: MeasurementCache | None = None) -> list[str]:
    """Print the sweep sections; fill ``bench`` (if given) for the JSON."""
    out = []
    t0 = time.time()
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()

    out.append("## DSE sweep 1: FPGA (n, m) lattice -> Pareto frontier")
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    out.append(sweep.table(k=10))
    frontier = sweep.frontier()
    out.append(
        f"frontier ({len(frontier)} of {len(sweep)} points): "
        + " ".join(f"(n={p.n},m={p.m})" for p in frontier)
    )
    best = sweep.best("perf_per_watt")
    out.append(
        f"best perf/W: (n={best.n},m={best.m}) -> "
        f"{best.perf_per_watt:.3f} GF/sW (paper: (1,4) -> 2.416)"
    )

    out.append("\n## DSE sweep 2: TPU v5e temporal blocking (block_h, m, d)")
    tsweep = ex.sweep_tpu()
    out.append(tsweep.table(k=10))
    tbest = tsweep.best("sustained_gflops")
    out.append(
        f"best: block_h={tbest.detail['block_rows']} m={tbest.m} "
        f"d={tbest.n} -> {tbest.sustained_gflops:.0f} GF/s "
        f"({tbest.utilization*100:.0f}% of the {tbest.n}-chip VPU roof), "
        f"AI={tbest.detail['arithmetic_intensity']:.1f} flop/B"
    )

    # The measured sweep only proposes device counts the platform can
    # actually run: on a tall grid the model (correctly) drops d=1 off
    # the frontier entirely, which would leave a single-device machine
    # with nothing executable.
    import jax

    from repro.core.distribute import device_axis_values

    exec_d = device_axis_values(min(4, jax.device_count()))
    out.append(
        f"\n## DSE sweep 2b: top-{topk} frontier points through the "
        f"codegen'd uLBM Pallas kernel ({MEASURE_H}x{MEASURE_W}, "
        f"{'interpret' if interpret else 'tpu'} mode; d swept over "
        f"{exec_d}, d>1 sharded)"
    )
    msim = lbm.LBMSimulation(
        lbm.LBMProblem(MEASURE_H, MEASURE_W, mode="wrap")
    )
    mex = msim.explorer()
    msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8),
                           d_values=exec_d)
    f0, attr, _ = lbm.taylor_green_init(MEASURE_H, MEASURE_W)
    mstate, mregs = msim.stream_state(f0, attr), msim.stream_regs()
    mres = mex.search(
        msweep, mstate, mregs,
        strategy=ExhaustiveSearch(k=topk, frontier_only=True),
        interpret=interpret, reps=reps, calibrate=True, cache=cache,
    )
    runs = mres.executed
    out.append(render_executed(runs))
    out.append(
        f"(strategy={mres.strategy}: {mres.budget_spent} live "
        f"measurement(s) spent)"
    )
    if interpret:
        out.append(
            "(interpret mode: the calib column anchors the model to the "
            "measured Pallas-interpreter throughput, so rel err is "
            "model fidelity, not host-vs-TPU speed; run on TPU with "
            "interpret=False to close the loop on hardware)"
        )

    out.append(
        "\n## DSE sweep 2c: second SPD app (2-D diffusion) through the "
        "generic SPD->Pallas codegen"
    )
    from repro.apps import diffusion as dif

    dsim = dif.DiffusionSimulation(MEASURE_H, MEASURE_W, alpha=0.2)
    dex = dsim.explorer()
    dsweep = dex.sweep_tpu(bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8),
                           d_values=exec_d)
    u0, _ = dif.sine_init(MEASURE_H, MEASURE_W)
    dres = dex.search(
        dsweep, dsim.state(u0), (dsim.alpha,),
        strategy=ExhaustiveSearch(k=topk, frontier_only=True),
        interpret=interpret, reps=reps, calibrate=True, cache=cache,
    )
    druns = dres.executed
    out.append(render_executed(druns))
    out.append(
        f"(no hand-written kernel: {len(dsim.kernel.summary.offsets)} "
        f"stencil offsets inferred from the DFG, halo = "
        f"{dsim.kernel.summary.halo_y} row/step — docs/pipeline.md)"
    )

    # Measurement-cache verification pass: the same frontier again — every
    # point (and the calibration anchor) must come back from the cache
    # without recompiling or retiming (docs/pipeline.md §measure).
    pass2_hits = 0
    if cache is not None:
        hits_before = cache.hits
        reruns = mex.execute_frontier(
            msweep, mstate, mregs, k=topk, interpret=interpret, reps=reps,
            calibrate=True, cache=cache,
        )
        pass2_hits = cache.hits - hits_before
        # Hard check, not just a printout (and not a stripped-under--O
        # assert): an identical sweep in the same process must re-time
        # nothing (fingerprint/key stability).
        retimed = [(e.block_h, e.m, e.d) for e in reruns if not e.cached]
        if retimed:
            raise RuntimeError(
                f"measurement-cache regression: repeated frontier pass "
                f"re-timed {retimed}"
            )
        out.append(
            f"\n## DSE sweep 2d: repeated uLBM frontier pass — "
            f"{pass2_hits} measurement-cache hit(s), "
            f"{sum(1 for e in reruns if e.cached)}/{len(reruns)} points "
            "served from cache"
        )

    # Autotune smoke (docs/pipeline.md §search): the budgeted strategies
    # search the same uLBM lattice measured-in-the-loop under a hard cap
    # of AUTOTUNE_BUDGET live measurements each. Overspending is a
    # regression, not a printout. Sharing the measurement cache with the
    # frontier pass above is the intended composition: plans the
    # exhaustive walk already timed are free, so the strategies' budget
    # goes to the plans only they propose.
    out.append(
        f"\n## DSE sweep 2e: autotune smoke — measured-in-the-loop "
        f"search, hard budget {AUTOTUNE_BUDGET} measurements/strategy"
    )
    from repro.core.search import Study, TPESearch

    exhaustive_best = max(e.measured_gflops for e in runs) if runs else 0.0
    autotune: dict = {"budget": AUTOTUNE_BUDGET}
    # The TPE pass journals into a durable named study: a re-run of the
    # benchmark replays completed trials from it (and from the cache)
    # instead of re-measuring (docs/pipeline.md §study).
    study_name = "bench-dse"
    specs = (
        ("refine", "refine", {}),
        ("halving", "halving", {}),
        ("tpe", TPESearch(seed=0), {"study": study_name}),
    )
    for label, strat, extra in specs:
        sres = mex.search(
            msweep, mstate, mregs, strategy=strat, budget=AUTOTUNE_BUDGET,
            interpret=interpret, reps=reps, calibrate=True, cache=cache,
            **extra,
        )
        if sres.budget_spent > AUTOTUNE_BUDGET:
            raise RuntimeError(
                f"autotune budget regression: strategy {label!r} spent "
                f"{sres.budget_spent} > {AUTOTUNE_BUDGET} measurements"
            )
        b = sres.best
        ratio = (
            b.measured_gflops / exhaustive_best
            if b is not None and exhaustive_best else 0.0
        )
        out.append(
            f"  {label}: best "
            + (f"(block_h={b.block_h}, m={b.m}, d={b.d}) "
               f"{b.measured_gflops:.4g} GF/s measured"
               if b is not None else "n/a")
            + f" ({ratio:.2f}x the exhaustive frontier best), "
            f"{sres.budget_spent}/{AUTOTUNE_BUDGET} budget spent, "
            f"{len(sres.executed)} point(s) measured"
            + (f", {sres.replayed} replayed from study {sres.study!r}"
               if sres.study else "")
        )
        # One schema for every search section: SearchResult.as_dict
        # (SEARCH_RESULT_FIELDS) — the derived ratio rides along.
        autotune[label] = {
            **sres.as_dict(), "vs_exhaustive_best": float(ratio),
        }

    # Overlapped halo exchange (docs/pipeline.md §overlap): time each
    # app's sharded kernel with the exchange overlapped against interior
    # compute vs the monolithic launch, same plan, same honest harness.
    # Wall clock only — the bitwise contract is tests/test_distribute.py's.
    overlap_bench: dict = {}
    if jax.device_count() >= 2:
        from repro.core.measure import time_run

        out.append(
            "\n## DSE sweep 2g: overlapped vs monolithic halo exchange "
            "(d=2, per app)"
        )
        ov_bh, ov_m = 16, 2  # 128-row shards -> nblk=8 >= 3: overlap engages
        for name, kern, state, regs in (
            ("lbm", msim.stream_kernel(), mstate, mregs),
            ("diffusion", dsim.kernel, dsim.state(u0), (dsim.alpha,)),
        ):
            sk = kern.sharded(2)
            walls = {}
            for overlap in (True, False):
                timing = time_run(
                    lambda: sk.run_blocked(
                        state, regs, steps=ov_m, m=ov_m, block_h=ov_bh,
                        overlap=overlap, interpret=interpret,
                    ),
                    reps=reps, warmup=1,
                )
                walls["on" if overlap else "off"] = float(timing.wall_s)
            overlap_bench[name] = {
                "d": 2, "block_h": ov_bh, "m": ov_m,
                "overlap_on_s": walls["on"], "overlap_off_s": walls["off"],
            }
            out.append(
                f"  {name}: overlap on {walls['on']*1e3:.2f} ms vs "
                f"off {walls['off']*1e3:.2f} ms per {ov_m}-step launch "
                f"(block_h={ov_bh}, d=2)"
            )
        if interpret:
            out.append(
                "(interpret mode serializes the would-be concurrent "
                "launches; the split is recorded so the TPU run shows "
                "the real hiding)"
            )
    else:
        out.append(
            "\n## DSE sweep 2g: overlapped halo exchange skipped — "
            "needs >= 2 devices (XLA_FLAGS="
            "--xla_force_host_platform_device_count=8)"
        )

    # 2h --------------------------------------------------------------
    # Stream programs: the fusion partition as a measured axis
    # (docs/pipeline.md §program, DESIGN.md §14). For each program app,
    # clock every partition of the chain — fused (one pallas_call per
    # m-step block), pipelined (chained on-device launches), and the
    # naive unfused baseline (host round-trip per cluster) — then ask
    # the calibrated model to pick a partition and hard-fail if its
    # pick measures >10% worse than the best measured partition. The
    # calibration gives the model this platform's throughput *and* its
    # per-launch dispatch overhead (TPUTarget.launch_overhead_s, backed
    # out of a tiny-grid probe where launches dominate the wall).
    import dataclasses

    from repro.apps.advection_diffusion import (
        AdvectionDiffusionSimulation, blob_init,
    )
    from repro.core import measure as measure_mod
    from repro.core.dse import TPUModel
    from repro.core.measure import time_run
    from repro.core.program import fusion_partitions, program_run_factory

    out.append(
        "\n## DSE sweep 2h: stream programs — fused vs pipelined vs "
        "unfused (per app)"
    )
    program_bench: dict = {}
    pg_h, pg_w = 128, 128
    pg_bh, pg_m, pg_steps = 16, 2, 16
    psim = lbm.LBMSimulation(lbm.LBMProblem(pg_h, pg_w, mode="wrap"))
    pf, pattr, _ = lbm.taylor_green_init(pg_h, pg_w)
    asim = AdvectionDiffusionSimulation(pg_h, pg_w)
    for pname, prog, pstate, pregs in (
        ("lbm_program", psim.program(), psim.stream_state(pf, pattr),
         psim.stream_regs()),
        ("advection_diffusion", asim.program,
         asim.state(blob_init(pg_h, pg_w)), asim.regs()),
    ):
        specs = fusion_partitions(prog.nstages)
        wl = prog.workload(pg_h * pg_w, grid_w=pg_w)
        prf = program_run_factory(prog, pstate, pregs, interpret)
        cal2h = measure_mod.calibrate_execution(
            prf, workload=wl, grid_shape=(pg_h, pg_w), width=pg_w,
            words=prog.P, interpret=interpret, reps=reps, warmup=1,
        )
        # Launch-overhead probe: the fully pipelined partition on a
        # 16-row slab — per-launch dispatch dominates the wall there.
        split = specs[-1]
        nclusters = split.count("+") + 1
        tiny = pstate[..., :16, :]
        tiny_steps = 8
        tp = time_run(
            lambda: prog.kernel(split).run_blocked(
                tiny, pregs, steps=tiny_steps, m=1, block_h=8,
                interpret=interpret,
            ),
            reps=reps, warmup=1,
        )
        ovh = float(tp.wall_s) / (tiny_steps * nclusters)
        model2h = TPUModel(dataclasses.replace(
            cal2h.target(d=1), launch_overhead_s=ovh
        ))
        walls: dict = {}
        for spec in specs:
            pk = prog.kernel(spec)
            timing = time_run(
                lambda: pk.run_blocked(
                    pstate, pregs, steps=pg_steps, m=pg_m, block_h=pg_bh,
                    interpret=interpret,
                ),
                reps=reps, warmup=1,
            )
            walls[spec] = float(timing.wall_s)
        unfused_t = time_run(
            lambda: prog.kernel(split).run_unfused(
                pstate, pregs, steps=pg_steps, block_h=pg_bh,
                interpret=interpret,
            ),
            reps=reps, warmup=1,
        )
        pick = max(
            specs,
            key=lambda s: model2h.evaluate(
                wl, pg_bh, pg_m, fusion=s
            ).sustained_gflops,
        )
        best_measured = min(walls, key=walls.get)
        for spec in specs:
            tag = ("fused" if "+" not in spec else
                   ("pipelined" if spec == split else "partial"))
            out.append(
                f"  {pname}: fusion={spec:<8s} {walls[spec]*1e3:8.2f} ms "
                f"/{pg_steps} steps ({tag})"
            )
        out.append(
            f"  {pname}: unfused  {float(unfused_t.wall_s)*1e3:8.2f} ms "
            f"(host round-trip per cluster); model pick {pick!r}, best "
            f"measured {best_measured!r} "
            f"(launch overhead {ovh*1e6:.1f} us/launch)"
        )
        if walls[pick] > 1.10 * walls[best_measured]:
            raise RuntimeError(
                f"program sweep 2h: model-picked partition {pick!r} "
                f"measured {walls[pick]*1e3:.2f} ms — more than 10% "
                f"worse than the best measured partition "
                f"{best_measured!r} at {walls[best_measured]*1e3:.2f} ms "
                f"({pname})"
            )
        # Machine-independent trajectory record: the raw-model lattice
        # best over the full fusion axis (same convention as the lbm/
        # diffusion "best" blocks — measurements stay platform-bound).
        pex = prog.explorer(pg_h * pg_w, grid_w=pg_w)
        psw = pex.sweep_tpu(
            bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8),
            fusion_values=specs,
        )
        pbest = psw.best("sustained_gflops")
        program_bench[pname] = {
            "grid": [pg_h, pg_w],
            "block_h": pg_bh, "m": pg_m, "steps": pg_steps,
            "partitions_s": walls,
            "fused_s": walls[specs[0]],
            "pipelined_s": walls[split],
            "unfused_s": float(unfused_t.wall_s),
            "model_pick": pick,
            "best_measured": best_measured,
            "launch_overhead_s": ovh,
            "best": {
                "fusion": str(pbest.detail["fusion"]),
                "m": int(pbest.m),
                "block_h": int(pbest.detail["block_rows"]),
                "sustained_gflops": float(pbest.sustained_gflops),
            },
        }

    # 2i --------------------------------------------------------------
    # 2-D device mesh (DESIGN.md §15): wide vs tall grids at one fixed
    # total device count, every legal (dy, dx) factorization measured
    # through the search runner, and the calibrated model's mesh pick
    # gated against the best measured mesh — the §2h contract applied
    # to the mesh axis. A wide grid should pick a column-heavy mesh
    # (short shards make the row ring recompute-bound), a tall grid the
    # row ring; the recorded best-(dy, dx)-per-aspect is the committed
    # evidence.
    mesh_bench: dict = {}
    mesh_d = min(8, jax.device_count())
    if mesh_d >= 2:
        # block_h is swept *jointly* with the mesh: a dy-heavy ring on a
        # short grid caps the legal block at the shard height H/dy (more
        # stripes, worse halo-recompute fraction), while a column mesh
        # keeps full-height blocks at the price of 2·m·halo_x guard
        # columns — that trade is the measurable mesh signal, and it
        # only exists if each mesh runs at its own best block_h.
        mesh_bhs, mesh_m, mesh_steps = (16, 32, 64, 128), 2, 8
        out.append(
            f"\n## DSE sweep 2i: 2-D device mesh (dy x dx) — every "
            f"factorization of d={mesh_d}, wide vs tall diffusion grid"
        )
        for aspect, (gh, gw) in (("wide", (128, 512)), ("tall", (512, 128))):
            gsim = dif.DiffusionSimulation(gh, gw, alpha=0.2)
            gu0, _ = dif.sine_init(gh, gw)
            gex = gsim.explorer()
            dxs = tuple(
                x for x in (1, 2, 4, 8, 16)
                if x <= mesh_d and mesh_d % x == 0
                and gw % x == 0 and gh % (mesh_d // x) == 0
            )
            gsw = gex.sweep_tpu(bh_values=mesh_bhs, m_values=(mesh_m,),
                                d_values=(mesh_d,), dx_values=dxs)
            gres = gex.search(
                gsw, gsim.state(gu0), (gsim.alpha,),
                strategy=ExhaustiveSearch(
                    k=len(dxs) * len(mesh_bhs), frontier_only=False,
                ),
                steps=mesh_steps, interpret=interpret, reps=reps,
                calibrate=True, cache=cache,
            )
            # Mesh-level records: each (dy, dx) is represented by its
            # best-measured block_h; the model's pick is the mesh of its
            # best-calibrated executed point. Comparing meshes (not raw
            # points) keeps the gate about the axis under test.
            per: dict = {}
            model_best: dict = {}
            for e in gres.executed:
                dy = e.d // max(int(e.dx), 1)
                key = f"{dy}x{e.dx}"
                cg = (None if e.calibrated_gflops is None
                      else float(e.calibrated_gflops))
                rec = {
                    "dy": int(dy), "dx": int(e.dx),
                    "block_h": int(e.block_h),
                    "wall_s": float(e.wall_s),
                    "steps": int(e.steps),
                    "steps_per_s": float(e.steps / e.wall_s),
                    "measured_gflops": float(e.measured_gflops),
                    "calibrated_gflops": cg,
                }
                if key not in per or rec["wall_s"] < per[key]["wall_s"]:
                    per[key] = rec
                score = cg if cg is not None else float(e.measured_gflops)
                if key not in model_best or score > model_best[key]:
                    model_best[key] = score
            if not per:
                mesh_bench[aspect] = {"skipped": "no executable mesh"}
                continue
            pick = max(model_best, key=model_best.get)
            best_meas = max(per, key=lambda k: per[k]["steps_per_s"])
            rings = [k for k, v in per.items() if v["dx"] == 1]
            cols = [k for k, v in per.items() if v["dx"] > 1]
            best_ring = (max(rings, key=lambda k: per[k]["steps_per_s"])
                         if rings else None)
            best_col = (max(cols, key=lambda k: per[k]["steps_per_s"])
                        if cols else None)
            for key in sorted(per, key=lambda k: -per[k]["steps_per_s"]):
                v = per[key]
                out.append(
                    f"  {aspect} {gh}x{gw}: mesh {key:<5s} "
                    f"bh={v['block_h']:<3d} "
                    f"{v['steps_per_s']:9.2f} steps/s measured, "
                    f"calibrated {(v['calibrated_gflops'] or 0):8.1f} GF/s"
                )
            out.append(
                f"  {aspect}: model pick {pick}, best measured {best_meas}"
                + (f", best ring {best_ring}" if best_ring else "")
                + (f", best column mesh {best_col}" if best_col else "")
            )
            if per[pick]["wall_s"] > 1.10 * per[best_meas]["wall_s"]:
                raise RuntimeError(
                    f"mesh sweep 2i: model-picked mesh {pick} measured "
                    f"{per[pick]['wall_s'] * 1e3:.2f} ms — more than 10% "
                    f"worse than the best measured mesh {best_meas} at "
                    f"{per[best_meas]['wall_s'] * 1e3:.2f} ms "
                    f"({aspect} {gh}x{gw})"
                )
            mesh_bench[aspect] = {
                "grid": [gh, gw], "d": int(mesh_d),
                "block_h_values": list(mesh_bhs),
                "m": mesh_m, "steps": mesh_steps,
                "meshes": per,
                "model_pick": pick, "best_measured": best_meas,
                "best_ring": best_ring, "best_col": best_col,
            }
    else:
        reason = (f"needs >= 2 devices, have {jax.device_count()} "
                  "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        mesh_bench = {"skipped": reason}
        out.append(f"\n## DSE sweep 2i: 2-D mesh sweep skipped — {reason}")

    # Render the study's convergence/Pareto report next to the JSON —
    # the artifact the CI bench job uploads.
    study = Study.resume(study_name)
    study_report = study.report(
        out_dir=os.path.dirname(BENCH_PATH), basename="BENCH_study"
    )
    out.append(
        f"\n## DSE sweep 2f: study report — "
        + study.report_text().splitlines()[0]
    )
    out.append(f"[wrote {study_report['text']} / {study_report['html']}]")

    out.append("\n## DSE sweep 3: LM mesh planner (granite-34b, 256 chips)")
    g = get_arch("granite-34b")
    stats = ArchStats(
        name=g.name, params=g.num_params(), active_params=g.active_params(),
        n_layers=g.n_layers, d_model=g.d_model, global_batch=256,
        seq_len=4096,
    )
    plans = plan(stats, 256)
    out.append(render_plans(plans, top=8))
    mlups = f"{runs[0].measured_mlups:.2f}" if runs else "n/a"
    out.append(
        f"dse_sweep,{(time.time()-t0)*1e6:.0f},"
        f"fpga_best=({best.n};{best.m});tpu_best_m={tbest.m};"
        f"tpu_best_d={tbest.n};"
        f"measured_mlups={mlups}"
    )

    if bench is not None:
        bench["fpga"] = {
            "best": {"n": int(best.n), "m": int(best.m),
                     "sustained_gflops": float(best.sustained_gflops),
                     "perf_per_watt": float(best.perf_per_watt)},
            "paper_best": {"n": 1, "m": 4, "perf_per_watt": 2.416},
        }
        cal = calibrate_backend(interpret=interpret, reps=reps)
        for name, app_ex, sr in (("lbm", mex, mres),
                                 ("diffusion", dex, dres)):
            # The recorded best comes from the *model* lattice over the
            # full device axis — machine-independent, so the committed
            # PR-over-PR trajectory doesn't move with how many devices
            # the regenerating machine happened to have. Executed points
            # are measurements and are necessarily platform-bound.
            sw = app_ex.sweep_tpu(bh_values=(8, 16, 32, 64),
                                  m_values=(1, 2, 4, 8))
            b = sw.best("sustained_gflops")
            # The headline prediction is *calibrated* to the backend
            # this run measured on — a raw TPU-v5e roofline number next
            # to interpret-mode measurements is not comparable; the raw
            # model figure stays as model_gflops for the machine-free
            # trajectory.
            cb = cal.model(d=int(b.n)).evaluate(
                app_ex.workload, int(b.detail["block_rows"]), int(b.m),
                d=int(b.n), dx=int(b.detail.get("dx", 1)),
            )
            bench[name] = {
                "best": {"d": int(b.n), "m": int(b.m),
                         "block_h": int(b.detail["block_rows"]),
                         "calibrated_gflops": float(cb.sustained_gflops),
                         "model_gflops": float(b.sustained_gflops)},
                "executed": [e.as_dict() for e in sr.executed],
                # The one search-result schema (SEARCH_RESULT_FIELDS):
                # never a hand-picked subset that can drift from the CLI.
                "search": sr.as_dict(),
            }
        bench["autotune"] = autotune
        bench["overlap"] = overlap_bench
        bench["program"] = program_bench
        bench["study"] = {
            "name": study_name,
            "records": len(study.records),
            "report_html": os.path.basename(study_report["html"]),
            "report_text": os.path.basename(study_report["text"]),
        }
        bench["grid"] = [MEASURE_H, MEASURE_W]
        bench["mesh"] = mesh_bench
        bench["exec_d"] = [int(d) for d in exec_d]
        bench["interpret"] = bool(interpret)
        bench["measure"] = {
            "backend": cal.backend,
            "reps": int(reps),
            "platform_elem_gflops": float(cal.elem_gflops),
            "platform_mem_gbs": float(cal.mem_gbs),
            "cache": None if cache is None else cache.stats(),
            "cache_hits_on_repeat": int(pass2_hits),
        }
    return out


def write_bench(path: str = BENCH_PATH, topk: int = 3,
                interpret: bool = True, reps: int = 3) -> list[str]:
    """Run the sweeps and record ``BENCH_dse.json`` (the PR-over-PR
    trajectory file: best point, sustained GFLOPS, calibrated
    predicted-vs-measured error, and measurement-cache stats per app).

    Uses the default persistent measurement cache, so re-invoking the
    benchmark skips recompile+retime for every already-seen frontier
    point and calibration anchor. The generic platform probes
    (``platform_elem_gflops`` / ``platform_mem_gbs``) are deliberately
    re-measured each run — they record the platform this run actually
    had, not a cached one."""
    bench: dict = {}
    out = run(topk=topk, interpret=interpret, reps=reps, bench=bench,
              cache=MeasurementCache())
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(bench, fh, indent=2, sort_keys=True)
        fh.write("\n")
    out.append(f"[wrote {path}]")
    return out


if __name__ == "__main__":
    print("\n".join(write_bench()))
