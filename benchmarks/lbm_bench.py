"""LBM throughput measured on CPU (the only real hardware here), across
the (n, m) structures: reference, SPD-compiled PE, temporal cascades, and
the Pallas temporal-blocking kernel (interpret mode), plus physics checks.

MLUPS = million lattice-site updates per second. CPU numbers validate
*relative* behavior (fused m-steps amortize memory traffic) — absolute
roofline numbers for the TPU target come from the DSE model.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.apps import lbm
from repro.kernels.lbm_stream.ops import lbm_run_blocked


def _time(f, *args, reps=3):
    f(*args)  # warm/compile
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(h: int = 128, w: int = 256, steps: int = 8) -> list[str]:
    out = []
    f0, attr, _ = lbm.taylor_green_init(h, w)
    one_tau = 1.0 / 0.8
    sites = h * w * steps

    rows = []

    t = _time(lambda f: lbm.ref_run(f, attr, one_tau, steps), f0)
    rows.append(("jnp reference (m=1)", t))

    for m in (1, 2, 4):
        sim = lbm.LBMSimulation(lbm.LBMProblem(h, w, mode="wrap"), m=m)
        t = _time(lambda f, s=sim: s.run(f, attr, steps), f0)
        rows.append((f"SPD-compiled cascade m={m}", t))

    for m in (2, 8):
        t = _time(
            lambda f, m=m: lbm_run_blocked(
                f, attr, one_tau, steps=steps, m=m, block_h=h // 4
            ),
            f0,
        )
        rows.append((f"pallas temporal-block m={m} (interpret)", t))

    out.append("## LBM throughput (CPU), grid %dx%d, %d steps" % (h, w, steps))
    for name, t in rows:
        out.append(f"{name:42s} {t*1e3:9.2f} ms  {sites/t/1e6:8.1f} MLUPS")
        out.append(f"lbm/{name.replace(' ', '_')},{t*1e6:.0f},"
                   f"mlups={sites/t/1e6:.1f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
