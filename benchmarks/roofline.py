"""Roofline analysis over the dry-run artifacts (one row per arch x shape
x mesh), per the three-term model:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_chip / HBM_bw_per_chip
  collective = collective_bytes_per_chip / ICI_link_bw

The compiled SPMD module is the per-chip program, so cost_analysis() and
the HLO collective census are already per-chip; the assignment's
"(chips x ...)" denominators cancel against global numerators.

Hardware constants (TPU v5e, stated in EXPERIMENTS.md):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s per ICI link.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "experiments", "dryrun")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    kind: str
    t_compute: float
    t_memory: float
    t_collective: float
    bound: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    roofline_frac: float
    fix_hint: str

    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


def model_flops(art: dict) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (derived from
    the live config — artifacts may carry stale parameter counts)."""
    from repro.configs import get_arch

    cfg = get_arch(art["arch"])
    active = cfg.active_params()
    tokens = art["global_batch"] * (
        art["seq_len"] if art["kind"] in ("train", "prefill") else 1
    )
    if cfg.enc_dec and art["kind"] in ("train", "prefill"):
        # encoder sees S frames, decoder S/4 tokens, each through half the
        # stack (approximation documented in EXPERIMENTS.md)
        tokens = art["global_batch"] * (art["seq_len"] * 5 // 8)
    c = 6.0 if art["kind"] == "train" else 2.0
    return c * active * tokens


_HINTS = {
    ("compute", "train"): "compute-bound: raise MFU via fused attention "
                          "kernel + less remat recompute",
    ("compute", "prefill"): "compute-bound: fused flash-attention kernel "
                            "lifts the attention FLOP efficiency",
    ("compute", "decode"): "compute-bound (unusual for decode): shrink "
                           "redundant per-token recompute",
    ("memory", "train"): "memory-bound: increase arithmetic intensity "
                         "(larger per-chip batch, fuse optimizer update)",
    ("memory", "prefill"): "memory-bound: block-resident attention "
                           "(flash) cuts HBM round-trips",
    ("memory", "decode"): "memory-bound: expected for decode — weights/KV "
                          "stream once per token; quantize KV or batch more",
    ("collective", "train"): "collective-bound: overlap gradient "
                             "reduce-scatter with backward; compress "
                             "cross-pod traffic (int8 EF)",
    ("collective", "prefill"): "collective-bound: reshard to cut activation "
                               "all-gathers (seq-parallel attention)",
    ("collective", "decode"): "collective-bound: KV-shard alignment; keep "
                              "decode collectives to one all-reduce/layer",
}


def analyze(art: dict) -> RooflineRow:
    # trip-count-corrected per-chip totals (repro.launch.hlo_cost); the raw
    # cost_analysis() numbers undercount while-loop bodies and are kept in
    # the artifact only for reference
    hc = art["hlo_cost"]
    flops_dev = hc["flops"]
    bytes_dev = hc["hbm_proxy_bytes"]
    # deployment-dtype projection when present (CPU float-normalization
    # promotes bf16 collectives to f32; TPU keeps them bf16)
    coll_dev = hc.get("coll_bytes_dtype", hc["coll_bytes"])
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / ICI_BW
    bound = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(art)
    hlo_global = flops_dev * art["n_devices"]
    useful = mf / hlo_global if hlo_global else 0.0
    step = max(t_c, t_m, t_x)
    # achieved fraction of the compute roofline if the dominant term were
    # perfectly overlapped with the rest
    frac = (mf / art["n_devices"] / PEAK_FLOPS) / step if step else 0.0
    return RooflineRow(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        kind=art["kind"], t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bound=bound, model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=useful, roofline_frac=frac,
        fix_hint=_HINTS[(bound, art["kind"])],
    )


def load_artifacts(mesh: str = "pod16x16") -> list[dict]:
    arts = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def render(rows: list[RooflineRow]) -> str:
    head = (
        "| arch | shape | compute s | memory s | collective s | bound | "
        "useful (6ND/HLO) | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|"
    )
    body = [
        f"| {r.arch} | {r.shape} | {r.t_compute:.4f} | {r.t_memory:.4f} | "
        f"{r.t_collective:.4f} | {r.bound} | {r.useful_ratio:.3f} | "
        f"{r.roofline_frac:.3f} |"
        for r in rows
    ]
    return "\n".join([head] + body)


def main(csv: bool = True) -> list[RooflineRow]:
    arts = load_artifacts()
    rows = [analyze(a) for a in arts]
    rows.sort(key=lambda r: r.roofline_frac)
    print(render(rows))
    if csv:
        print("\nname,us_per_call,derived")
        for r in rows:
            print(f"roofline/{r.arch}/{r.shape},{r.step_time()*1e6:.1f},"
                  f"frac={r.roofline_frac:.3f};bound={r.bound}")
    return rows


if __name__ == "__main__":
    main()
