"""Benchmark driver — one section per paper table/figure + the roofline.

Prints human-readable sections and ``name,us_per_call,derived`` CSV lines.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import dse_sweep, lbm_bench, table3

    sections = []
    sections += table3.run()
    sections.append("")
    sections += dse_sweep.run()
    sections.append("")
    sections += lbm_bench.run()
    sections.append("")
    # roofline table (requires dry-run artifacts; degrade gracefully)
    try:
        from benchmarks import roofline

        arts = roofline.load_artifacts()
        if arts:
            sections.append("## Roofline (from dry-run artifacts)")
            rows = [roofline.analyze(a) for a in arts]
            rows.sort(key=lambda r: r.roofline_frac)
            sections.append(roofline.render(rows))
            for r in rows:
                sections.append(
                    f"roofline/{r.arch}/{r.shape},{r.step_time()*1e6:.1f},"
                    f"frac={r.roofline_frac:.3f};bound={r.bound}"
                )
        else:
            sections.append("## Roofline: no dry-run artifacts found "
                            "(run python -m repro.launch.dryrun --all)")
    except Exception as e:  # pragma: no cover
        sections.append(f"## Roofline: unavailable ({e})")

    print("\n".join(sections))


if __name__ == "__main__":
    main()
