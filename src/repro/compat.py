"""Cross-version jax shims for APIs that moved between releases.

Everything here degrades to the older spelling when the newer one is
absent, so the same source runs on jax 0.4.x and current jax.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:
    import functools

    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        # newer jax renamed check_rep -> check_vma; accept the new
        # spelling everywhere and translate for the old implementation
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)


def pvary(x, axes):
    """Mark ``x`` device-varying over ``axes`` inside shard_map.

    Uses the varying-axis type system where jax has one
    (``lax.pcast(..., to="varying")`` / ``lax.pvary``); on older jax the
    replication checker is simply disabled (check_vma=False -> check_rep)
    and the marking is a no-op.
    """
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, axes, to="varying")
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, axes)
    return x


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any jax version
    (older jax wraps the per-module properties dict in a one-element list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def set_mesh(mesh):
    """``with set_mesh(mesh): ...`` — ambient-mesh context on any jax.

    Newer jax has ``jax.set_mesh``; on older versions the ``Mesh`` object
    is itself the context manager that installs the ambient mesh.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


__all__ = ["cost_analysis", "pvary", "set_mesh", "shard_map"]
