"""AdamW with configurable state dtype (f32 default; bf16 for the 1T-param
kimi-k2 config per DESIGN.md §Arch-notes) + cosine LR schedule + global-norm
clipping. Pure-pytree, optax-free, eval_shape-safe."""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(cfg: AdamWConfig, params) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """-> (new_params, new_state, metrics). Update math runs in f32 even
    when states are stored bf16 (quantize on store)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, state["step"])
    sdt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(sdt), vf.astype(sdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
