"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
* topology-agnostic: arrays are saved unsharded (gathered to host), so a
  restore may use a different mesh / dp width — elastic rescaling is a
  no-op at the checkpoint layer and re-sharding happens at jit boundaries.
* atomic: writes go to ``step_XXXXXXXX.tmp/`` then ``os.replace`` to the
  final name; readers never observe partial checkpoints.
* validated: every array records a crc32; restore verifies and *skips* to
  the newest valid checkpoint when one is corrupt (torn write, dead host).
* async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a daemon thread, keeping the step path clear.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree: Any) -> list[str]:
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Synchronous atomic checkpoint write. Returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
    manifest = {
        "step": step,
        "extra": extra or {},
        "arrays": [],
    }
    arrays = {}
    for i, arr in enumerate(host_leaves):
        key = f"a{i}"
        # bf16 has no numpy dtype; view as uint16 with a tag
        if arr.dtype == jax.numpy.bfloat16:
            arrays[key] = arr.view(np.uint16)
            dtype_tag = "bfloat16"
        else:
            arrays[key] = arr
            dtype_tag = str(arr.dtype)
        manifest["arrays"].append(
            {
                "key": key,
                "shape": list(arr.shape),
                "dtype": dtype_tag,
                "crc32": zlib.crc32(np.ascontiguousarray(arrays[key]).tobytes()),
            }
        )
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background, one in flight."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree
        )

        def _work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def _validate_and_load(path: str) -> tuple[dict, list[np.ndarray]] | None:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            leaves = []
            for rec in manifest["arrays"]:
                arr = z[rec["key"]]
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != rec["crc32"]:
                    return None
                if rec["dtype"] == "bfloat16":
                    arr = arr.view(jax.numpy.bfloat16)
                if list(arr.shape) != rec["shape"]:
                    return None
                leaves.append(arr)
        return manifest, leaves
    except Exception:
        return None


def restore_latest(ckpt_dir: str, like: Any) -> tuple[int, Any, dict] | None:
    """Restore the newest *valid* checkpoint into the structure of ``like``
    (a pytree of arrays or ShapeDtypeStructs). Corrupt checkpoints are
    skipped. Returns (step, tree, extra) or None."""
    _, treedef = _flatten(like)
    want_shapes = [
        (tuple(l.shape), jax.numpy.dtype(l.dtype))
        for l in jax.tree_util.tree_leaves(like)
    ]
    for step in reversed(available_steps(ckpt_dir)):
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        got = _validate_and_load(path)
        if got is None:
            continue
        manifest, leaves = got
        if len(leaves) != len(want_shapes):
            continue
        ok = all(
            tuple(a.shape) == s and a.dtype == d
            for a, (s, d) in zip(leaves, want_shapes)
        )
        if not ok:
            continue
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return step, tree, manifest.get("extra", {})
    return None


def corrupt_for_test(ckpt_dir: str, step: int) -> None:
    """Deliberately flip bytes in a checkpoint (failure-injection tests).

    Spray 16-byte garbage every 256 bytes so at least one stored array
    payload is hit regardless of zip layout."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        for off in range(128, max(size - 32, 129), 256):
            f.seek(off)
            f.write(b"\xde\xad\xbe\xef" * 4)
