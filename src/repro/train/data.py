"""Data pipeline: deterministic synthetic token streams + a binary memmap
corpus format, both host-sharded, with background prefetch.

Determinism contract: batch content is a pure function of (seed, step,
host_id) — a restarted job resumes the exact stream (fault tolerance), and
an elastically rescaled job re-partitions it (num_hosts enters the hash).
"""

from __future__ import annotations

import os
import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    path: str | None = None  # memmap corpus; None -> synthetic


class SyntheticTokens:
    """Counter-based deterministic token stream (no state to checkpoint)."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide by num_hosts")
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.num_hosts

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        # Philox counter-based bits: reproducible random access by step.
        rng = np.random.Generator(
            np.random.Philox(key=c.seed, counter=[step, c.host_id, 0, 0])
        )
        toks = rng.integers(
            0, c.vocab, (self.per_host, c.seq_len + 1), dtype=np.int64
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapTokens:
    """Token windows from a flat uint32 binary corpus, strided by host."""

    def __init__(self, cfg: DataConfig):
        if cfg.path is None:
            raise ValueError("MemmapTokens requires cfg.path")
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.per_host = cfg.global_batch // cfg.num_hosts
        self.n_windows = (len(self.data) - 1) // cfg.seq_len
        if self.n_windows < self.per_host:
            raise ValueError("corpus too small for one batch")

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=c.seed ^ 0xDA7A, counter=[step, c.host_id, 0, 0])
        )
        idx = rng.integers(0, self.n_windows, self.per_host)
        toks = np.stack(
            [
                self.data[i * c.seq_len: i * c.seq_len + c.seq_len + 1]
                for i in idx
            ]
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_corpus(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.uint32).tofile(path)


def make_source(cfg: DataConfig):
    return MemmapTokens(cfg) if cfg.path else SyntheticTokens(cfg)


class Prefetcher:
    """Background-thread prefetch with bounded queue; keeps the input
    pipeline off the training step's critical path."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
