"""Production training loop: checkpoint/restart, failure injection,
straggler mitigation, deterministic data resume.

The loop is structured as supervisor + worker (both in-process here; on a
real fleet the supervisor is the job scheduler): ``run_with_restarts``
restarts the step loop from the newest valid checkpoint whenever a
(simulated or real) fault surfaces, which is the restart path a node
failure would take at scale.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from . import checkpoint as ckpt
from .data import DataConfig, Prefetcher, make_source


class FaultInjected(RuntimeError):
    pass


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    log_every: int = 10
    # fault tolerance knobs
    max_restarts: int = 10
    fail_at_steps: tuple = ()  # inject a fault right after these steps
    # straggler mitigation: steps slower than `straggler_factor` x the
    # running median are logged and counted; persistent stragglers would
    # trigger re-dispatch on a real fleet (here: recorded + surfaced).
    straggler_factor: float = 3.0


@dataclass
class LoopState:
    step: int = 0
    restarts: int = 0
    straggler_events: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


def run(
    cfg: LoopConfig,
    data_cfg: DataConfig,
    train_step: Callable,  # (params, opt_state, batch) -> (params, opt, metrics)
    params: Any,
    opt_state: Any,
    log: Callable[[str], None] = print,
) -> tuple[Any, Any, LoopState]:
    """One worker incarnation: resume from checkpoint, run to completion or
    fault."""
    state = LoopState()
    tree = {"params": params, "opt": opt_state}
    restored = ckpt.restore_latest(cfg.ckpt_dir, tree)
    if restored is not None:
        start_step, tree, extra = restored
        state.step = start_step
        log(f"[loop] restored step {start_step} from {cfg.ckpt_dir}")
    params, opt_state = tree["params"], tree["opt"]

    saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir)
    source = make_source(data_cfg)
    prefetch = Prefetcher(source, start_step=state.step)
    times: list[float] = []
    try:
        while state.step < cfg.total_steps:
            step_no, batch = prefetch.next()
            assert step_no == state.step, "data pipeline out of sync"
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            state.losses.append(loss)
            state.step_times.append(dt)
            if len(times) > 5:
                med = float(np.median(times[-50:]))
                if dt > cfg.straggler_factor * med:
                    state.straggler_events += 1
                    log(f"[loop] straggler step {state.step}: "
                        f"{dt:.3f}s vs median {med:.3f}s")
            state.step += 1
            if state.step % cfg.log_every == 0:
                log(f"[loop] step {state.step} loss {loss:.4f} "
                    f"({dt*1e3:.0f} ms)")
            if state.step % cfg.ckpt_every == 0:
                saver.save(state.step, {"params": params, "opt": opt_state})
            if state.step in cfg.fail_at_steps:
                raise FaultInjected(f"injected fault after step {state.step}")
        saver.save(state.step, {"params": params, "opt": opt_state})
        saver.wait()
    finally:
        prefetch.close()
        # Drain any in-flight async write before this incarnation exits: a
        # real process death takes its writer with it, but here the "crash"
        # is an exception and the daemon thread would survive to race the
        # restarted worker on the same step_XXXXXXXX.tmp directory.
        try:
            saver.wait()
        except Exception:
            pass  # torn-write recovery is restore_latest's job
    return params, opt_state, state


def run_with_restarts(
    cfg: LoopConfig,
    data_cfg: DataConfig,
    train_step: Callable,
    params: Any,
    opt_state: Any,
    log: Callable[[str], None] = print,
) -> tuple[Any, Any, LoopState]:
    """Supervisor: restart the worker from checkpoint on faults."""
    total = LoopState()
    fail_at = set(cfg.fail_at_steps)
    for attempt in range(cfg.max_restarts + 1):
        try:
            params, opt_state, st = run(
                cfg, data_cfg, train_step, params, opt_state, log
            )
            total.step = st.step
            total.losses.extend(st.losses)
            total.step_times.extend(st.step_times)
            total.straggler_events += st.straggler_events
            return params, opt_state, total
        except FaultInjected as e:
            log(f"[supervisor] fault: {e}; restarting "
                f"({attempt + 1}/{cfg.max_restarts})")
            total.restarts += 1
            # this fault fired; don't fire it again after restart
            done = {s for s in fail_at if s <= _latest_step(cfg.ckpt_dir)}
            fail_at -= {min(fail_at)} if fail_at else set()
            cfg = LoopConfig(**{**cfg.__dict__, "fail_at_steps": tuple(fail_at)})
    raise RuntimeError("exceeded max_restarts")


def _latest_step(ckpt_dir: str) -> int:
    steps = ckpt.available_steps(ckpt_dir)
    return steps[-1] if steps else 0
