"""Training launcher.

Two modes:
* default — run a real training job on the local devices (CPU-scale here;
  the same code path drives a TPU slice: sharding specs come from
  ``repro.parallel.sharding`` and the loop handles checkpoint/restart,
  faults, stragglers).
* ``--plan-only`` — print the mesh plan the DSE planner recommends for the
  arch at a target chip count (the paper's design-space exploration as a
  deployment step).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch granite-34b --plan-only --chips 256
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import SHAPES, get_arch
from repro.core.planner import ArchStats, plan, render_plans
from repro.models import registry
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, run_with_restarts
from repro.train.optimizer import AdamWConfig, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced() config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--plan-only", action="store_true")
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.plan_only:
        shape = SHAPES["train_4k"]
        stats = ArchStats(
            name=cfg.name, params=cfg.num_params(),
            active_params=cfg.active_params(), n_layers=cfg.n_layers,
            d_model=cfg.d_model, global_batch=shape.global_batch,
            seq_len=shape.seq_len,
        )
        print(f"[train] mesh plans for {cfg.name} @ {args.chips} chips:")
        print(render_plans(plan(stats, args.chips), top=10))
        return

    if args.smoke:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: {cfg.num_params()/1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps,
                          state_dtype=cfg.opt_state_dtype)
    opt_state = init_state(opt_cfg, params)
    step = jax.jit(bundle.make_train_step(opt_cfg, args.microbatches))

    import jax.numpy as jnp

    def train_step(p, o, batch):
        return step(p, o, {k: jnp.asarray(v) for k, v in batch.items()})

    loop_cfg = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, fail_at_steps=tuple(args.fail_at),
    )
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)
    _, _, st = run_with_restarts(loop_cfg, data_cfg, train_step, params,
                                 opt_state)
    print(f"[train] finished {st.step} steps "
          f"({st.restarts} restarts, {st.straggler_events} stragglers); "
          f"loss {st.losses[0]:.4f} -> {st.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
