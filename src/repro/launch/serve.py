"""Serving launcher: stand up the continuous-batching engine for an arch
(reduced config on CPU; the decode path is the one the decode_* dry-run
cells compile at production scale).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    print(f"[serve] {cfg.name} (reduced: {cfg.num_params()/1e6:.1f}M) "
          f"slots={args.max_batch} cache={args.max_seq}")
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    eng = ServeEngine(bundle, params, max_batch=args.max_batch,
                      max_seq=args.max_seq, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(4, 16)).tolist()
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.new_tokens,
                           temperature=args.temperature))
    done = eng.run_until_drained()
    dt = time.time() - t0
    n_tok = sum(len(c.tokens) for c in done)
    print(f"[serve] {len(done)} completions, {n_tok} tokens, "
          f"{n_tok/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
