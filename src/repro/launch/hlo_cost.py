"""Trip-count-aware cost analysis of compiled (SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a 10-iteration scan of a matmul reports 1x the matmul FLOPs). Every scan in
this codebase (layer stacks, microbatch accumulation, attention/SSD chunk
loops) would therefore be undercounted by its trip count.

This module re-derives costs from ``compiled.as_text()`` with loop
multiplication:

  * builds the computation call graph (while bodies, fusions, calls,
    conditionals),
  * infers static trip counts from each while condition's
    ``compare(iv, constant(N))``,
  * accumulates per-computation dot-FLOPs, collective bytes (result-shape
    bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), and an HBM-traffic proxy (top-level instruction
    output bytes; fusion internals excluded since only fusion results
    materialize),
  * folds them up from ENTRY with multiplicity.

The compiled module is per-device, so all totals are per-chip.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# "%name = f32[2,3]{1,0} op(...)" (also tuple types)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                        r"(?:%?([\w.\-]+)|\{([^}]*)\})")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """Total elements and bytes across all shapes in a type string."""
    elems = 0
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    coll_bytes: float = 0.0
    coll_elems: float = 0.0  # element count (for dtype-corrected bytes)
    coll_counts: dict = field(default_factory=dict)
    out_bytes: float = 0.0  # HBM-traffic proxy
    # call sites: (callee, multiplier_kind) where kind 'while' resolves trip
    calls: list = field(default_factory=list)  # (callee_name, trip or 1)
    is_fusion_internal: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    name_shape: dict[str, str] = {}  # instr name -> type string
    cur: Computation | None = None
    cond_const: dict[str, int] = {}  # cond computation -> constant bound
    whiles: list[tuple[str, str, str]] = []  # (parent, body, cond)
    entry: str | None = None
    fusion_comps: set[str] = set()

    lines = text.splitlines()
    for line in lines:
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line):
            name = hdr.group(1)
            cur = comps.setdefault(name, Computation(name))
            if line.startswith("ENTRY"):
                entry = name
            if name.startswith(("fused_", "wide.")) or ".fused" in name:
                fusion_comps.add(name)
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        iname, type_str, op, rest = m.groups()
        name_shape[iname] = type_str

        if op == "dot":
            out_dims = _first_shape_dims(type_str)
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            # contracting size from lhs shape + lhs_contracting_dims. Newer
            # XLA dumps print operands with inline types —
            # ``dot(f32[128,256]{1,0} %lhs, ...)`` — so read the lhs shape
            # straight off the operand list when present, falling back to
            # the defining instruction's recorded shape otherwise.
            cd_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            lhs_shape: list[int] = []
            tm = _SHAPE_RE.match(rest.lstrip())
            if tm and tm.group(1) in _DTYPE_BYTES:
                lhs_shape = [int(d) for d in tm.group(2).split(",") if d]
            else:
                lhs_m = re.match(r"\s*%?([\w.\-]+)", rest)
                if lhs_m:
                    lhs_shape = _first_shape_dims(
                        name_shape.get(lhs_m.group(1), "")
                    )
            k = 1
            if cd_m and lhs_shape:
                for ci in cd_m.group(1).split(","):
                    if ci and int(ci) < len(lhs_shape):
                        k *= lhs_shape[int(ci)]
            cur.dot_flops += 2.0 * out_elems * k
        elif op in _COLLECTIVES or any(
            op == f"{c}-start" for c in _COLLECTIVES
        ):
            base = op.replace("-start", "")
            e, b = _shape_elems_bytes(type_str)
            cur.coll_bytes += b
            cur.coll_elems += e
            cur.coll_counts[base] = cur.coll_counts.get(base, 0) + 1
        elif op == "while":
            body_m = re.search(r"body=%?([\w.\-]+)", line)
            cond_m = re.search(r"condition=%?([\w.\-]+)", line)
            if body_m and cond_m:
                whiles.append((cur.name, body_m.group(1), cond_m.group(1)))
        elif op == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", line)
            if cm:
                fusion_comps.add(cm.group(1))
                # fused dots/collectives still execute; only their
                # intermediate buffers vanish (out_bytes zeroed below)
                cur.calls.append((cm.group(1), 1))
        elif op in ("call", "custom-call"):
            cm = re.search(r"to_apply=%?([\w.\-]+)", line)
            if cm:
                cur.calls.append((cm.group(1), 1))
        elif op == "conditional":
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for b in bm.group(1).split(","):
                    cur.calls.append((b.strip().lstrip("%"), 1))
            else:
                for key in ("true_computation", "false_computation"):
                    km = re.search(rf"{key}=%?([\w.\-]+)", line)
                    if km:
                        cur.calls.append((km.group(1), 1))

        if op == "constant" and "s32[]" in type_str:
            cm = re.search(r"constant\((\d+)\)", line)
            if cm:
                cond_const[cur.name] = max(
                    cond_const.get(cur.name, 0), int(cm.group(1))
                )

        # HBM proxy: top-level (non-fusion-internal) instruction outputs;
        # skip pure metadata ops
        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast"):
            _, b = _shape_elems_bytes(type_str)
            cur.out_bytes += b

    # resolve while trips
    for parent, body, cond in whiles:
        trip = cond_const.get(cond, 1) or 1
        comps.setdefault(parent, Computation(parent)).calls.append((body, trip))
        comps.setdefault(parent, Computation(parent)).calls.append((cond, trip))

    for fname in fusion_comps:
        if fname in comps:
            comps[fname].is_fusion_internal = True
    comps["__entry__"] = comps.get(entry, Computation("__entry__"))
    return comps


@dataclass
class HloCost:
    flops: float
    coll_bytes: float
    coll_elems: float
    coll_counts: dict
    hbm_proxy_bytes: float
    n_whiles: int

    def coll_bytes_dtype(self, dtype_bytes: int) -> float:
        """Collective bytes at the model's native dtype width.

        The CPU backend's float-normalization pass rewrites every bf16 op
        (including collectives) to f32, so measured wire bytes are 2x what
        the same program moves on a TPU. This projects element counts back
        to the deployment dtype (EXPERIMENTS.md §Roofline methodology)."""
        return self.coll_elems * dtype_bytes


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = comps["__entry__"]
    memo: dict[str, tuple] = {}
    visiting: set[str] = set()

    def fold(name: str):
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return (0.0, 0.0, 0.0, {}, 0.0)
        visiting.add(name)
        c = comps[name]
        fl, cb, ce, ob = c.dot_flops, c.coll_bytes, c.coll_elems, c.out_bytes
        counts = dict(c.coll_counts)
        if c.is_fusion_internal:
            ob = 0.0  # fusion internals don't materialize
        for callee, mult in c.calls:
            cf, ccb, cce, ccnt, cob = fold(callee)
            fl += mult * cf
            cb += mult * ccb
            ce += mult * cce
            ob += mult * cob
            for k, v in ccnt.items():
                counts[k] = counts.get(k, 0) + mult * v
        visiting.discard(name)
        memo[name] = (fl, cb, ce, counts, ob)
        return memo[name]

    fl, cb, ce, counts, ob = fold(entry.name)
    n_whiles = sum(
        1 for c in comps.values() for call in c.calls if call[1] > 1
    )
    return HloCost(flops=fl, coll_bytes=cb, coll_elems=ce,
                   coll_counts=counts, hbm_proxy_bytes=ob, n_whiles=n_whiles)
