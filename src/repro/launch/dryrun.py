import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (architecture x input-shape
x mesh) cell on the production mesh, with no device allocation
(ShapeDtypeStruct stand-ins everywhere).

Per cell this records, into experiments/dryrun/<arch>__<shape>__<mesh>.json:
  * compiled.memory_analysis()   — per-device bytes (proves it fits)
  * compiled.cost_analysis()     — HLO FLOPs / bytes for the roofline
  * collective bytes + op counts — parsed from the compiled SPMD HLO
  * wall compile time, input sharding summary

Usage:
  python -m repro.launch.dryrun --arch granite-34b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--resume]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis, set_mesh
from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.models import registry
from repro.launch.mesh import dp_axes_for, make_production_mesh, mesh_axis_sizes
from repro.launch.hlo_cost import analyze_hlo
from repro.parallel.hints import with_hints
from repro.parallel.sharding import build_cache_specs, build_param_specs
from repro.train.optimizer import AdamWConfig, init_state

# per-arch tuned microbatch counts (EXPERIMENTS.md §Perf): kimi's FSDP
# weight gathers scale with the microbatch count, and its per-microbatch
# activations are small enough to halve it
TUNED_MICROBATCHES = {"kimi-k2-1t-a32b": 4}

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "experiments", "dryrun",
)

# bytes per element for HLO shape parsing
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the SPMD module.

    The compiled module is the per-device program, so these are
    bytes-per-chip."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        # HLO: "%x = TYPE[SHAPE] op-name(...)" or fusion lines; match ops
        for op in _COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                lhs = line.split(f" {op}", 1)[0]
                b = _shape_bytes(lhs)
                stats[op]["count"] += 1
                stats[op]["bytes"] += b
                break
    stats["total_bytes"] = sum(
        v["bytes"] for k, v in stats.items() if isinstance(v, dict)
    )
    stats["total_count"] = sum(
        v["count"] for k, v in stats.items() if isinstance(v, dict)
    )
    return stats


def _mem_dict(ma) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def build_cell(cfg, shape, mesh, *, num_microbatches: int = 8,
               fsdp: bool = True):
    """-> (fn, arg_shapes: tuple, in_shardings: tuple).

    Weight-sharding policy: ZeRO-1 by default (params TP-sharded over
    'model' only; optimizer states additionally sharded over the dp axes,
    costing one grad reduce-scatter + one param all-gather per step).
    Full FSDP (weights dp-sharded too, re-gathered per layer per
    microbatch) only when the per-model-shard weights exceed the HBM
    budget — i.e. kimi-k2's 1T params (129 GB per 16-way shard)."""
    bundle = registry.build(cfg)
    sizes = mesh_axis_sizes(mesh)
    dp = dp_axes_for(mesh, shape.global_batch)
    fsdp_axes = None
    if fsdp:
        fsdp_axes = ("pod", "data") if "pod" in sizes else ("data",)
    weights_per_shard = cfg.num_params() * 2 / sizes["model"]
    # > ~6 GB/chip forces FSDP — but only training carries optimizer
    # states; inference weights stay TP/EP-sharded (kimi: 8 GB/chip, fits)
    # so decode/prefill never pay per-layer weight gathers
    heavy = weights_per_shard > 6e9 and shape.kind == "train"
    # inference cells of over-budget MoE archs (kimi): 2-D expert sharding
    # (E over 'model', FFN dim over 'data') keeps weights resident
    expert_cols = (
        "data"
        if (cfg.moe and shape.kind != "train" and weights_per_shard > 6e9)
        else None
    )
    params_shape = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    pspecs = build_param_specs(
        params_shape,
        n_experts=cfg.moe.n_experts if cfg.moe else 0,
        model_axis_size=sizes["model"],
        axis_sizes=sizes,
        fsdp_axes=fsdp_axes if heavy else None,
        expert_cols_axis=expert_cols,
    )
    opt_pspecs = build_param_specs(
        params_shape,
        n_experts=cfg.moe.n_experts if cfg.moe else 0,
        model_axis_size=sizes["model"],
        axis_sizes=sizes,
        fsdp_axes=fsdp_axes,  # ZeRO: optimizer states always fully sharded
    )
    sh = lambda spec: NamedSharding(mesh, spec)
    batch_specs = registry.input_specs(cfg, shape)

    def batch_spec_for(k, v):
        if k == "pos":
            return P()
        if dp is not None and v.shape[0] % _np(dp, sizes) == 0:
            return P(dp)
        return P()

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        opt_shape = jax.eval_shape(lambda p: init_state(opt_cfg, p),
                                   params_shape)
        ospecs = {
            "m": opt_pspecs, "v": opt_pspecs, "step": P(),
        }
        mb = TUNED_MICROBATCHES.get(cfg.name, num_microbatches)
        if shape.global_batch % mb:
            mb = 1
        fn = bundle.make_train_step(opt_cfg, num_microbatches=mb,
                                    dp_axes=dp)
        args = (params_shape, opt_shape, batch_specs)
        in_sh = (
            jax.tree_util.tree_map(sh, pspecs),
            jax.tree_util.tree_map(sh, ospecs),
            {k: sh(batch_spec_for(k, v)) for k, v in batch_specs.items()},
        )
        return fn, args, in_sh

    if shape.kind == "prefill":
        fn_ = bundle.make_prefill_step()

        def fn(params, batch):
            return fn_(params, batch)

        args = (params_shape, batch_specs)
        in_sh = (
            jax.tree_util.tree_map(sh, pspecs),
            {k: sh(batch_spec_for(k, v)) for k, v in batch_specs.items()},
        )
        return fn, args, in_sh

    # decode
    b = shape.global_batch
    s_cache = shape.seq_len if cfg.family != "audio" else shape.seq_len // 4
    cache_shape = jax.eval_shape(lambda: bundle.cache_init(b, s_cache))
    cspecs = build_cache_specs(
        cache_shape, dp_axes=dp, n_kv_heads=cfg.n_kv_heads,
        model_axis_size=sizes["model"], axis_sizes=sizes,
    )
    dec = bundle.make_decode_step()
    specs = registry.input_specs(cfg, shape)

    def fn(params, token, cache, pos):
        return dec(params, token, cache, pos)

    args = (params_shape, specs["token"], cache_shape, specs["pos"])
    in_sh = (
        jax.tree_util.tree_map(sh, pspecs),
        sh(batch_spec_for("token", specs["token"])),
        jax.tree_util.tree_map(sh, cspecs),
        sh(P()),
    )
    return fn, args, in_sh


def _np(axes, sizes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= sizes[a]
        return n
    return sizes[axes]


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             num_microbatches: int = 8, fsdp: bool = True,
             save: bool = True, sp_enable: bool = False) -> dict:
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    fn, args, in_sh = build_cell(
        cfg, shape, mesh, num_microbatches=num_microbatches, fsdp=fsdp
    )
    sizes = mesh_axis_sizes(mesh)
    # sequence parallelism for full-sequence paths (train/prefill): the
    # residual stream shards its seq dim over the TP axis (DESIGN.md,
    # EXPERIMENTS.md §Perf granite iteration 1)
    # sp='model' (true sequence parallelism) measured WORSE for attention
    # archs (chunked-attn scan vs seq sharding, EXPERIMENTS.md §Perf it.1);
    # sp=None keeps the bf16 residual pin only. Opt back in via --sp.
    sp = (
        "model"
        if sp_enable
        and shape.kind in ("train", "prefill")
        and shape.seq_len % sizes["model"] == 0
        else None
    )
    dp = dp_axes_for(mesh, shape.global_batch)
    # explicit shard_map all-to-all MoE dispatch for heavy-MoE training
    # cells (kimi): EXPERIMENTS.md §Perf kimi it.5 — the dp->ep token
    # exchange at wire-minimum bytes. Inference kimi uses 2-D expert
    # sharding instead (different weight layout).
    cfg_ = ARCHS[arch_name]
    ep_ok = cfg_.moe and cfg_.moe.n_experts % sizes["model"] == 0
    heavy_ = cfg_.num_params() * 2 / sizes["model"] > 6e9
    use_a2a = bool(ep_ok and heavy_ and shape.kind == "train")
    fsdp_axes_ = ("pod", "data") if "pod" in sizes else ("data",)
    fn = with_hints(
        fn, ep="model", ep_size=sizes["model"], dp=dp,
        dp_size=_np(dp, sizes), sp=sp,
        a2a=mesh if use_a2a else None,
        fsdp=fsdp_axes_ if use_a2a else None,
    )
    with set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = cost_analysis(compiled)
        hlo = compiled.as_text()
    coll = collective_stats(hlo)
    # trip-count-corrected costs (XLA cost_analysis counts loop bodies once;
    # see repro.launch.hlo_cost)
    hc = analyze_hlo(hlo)
    art = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(mesh.devices.size),
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "num_microbatches": num_microbatches if shape.kind == "train" else 0,
        "fsdp": fsdp,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(ma),
        "cost": {k: float(v) for k, v in ca.items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "hlo_cost": {
            "flops": hc.flops,
            "coll_bytes": hc.coll_bytes,
            "coll_elems": hc.coll_elems,
            # deployment-dtype projection of the CPU-backend f32-promoted
            # collectives (see HloCost.coll_bytes_dtype)
            "coll_bytes_dtype": hc.coll_bytes_dtype(
                2 if cfg.dtype == "bfloat16" else 4
            ),
            "coll_counts": hc.coll_counts,
            "hbm_proxy_bytes": hc.hbm_proxy_bytes,
            "n_whiles": hc.n_whiles,
        },
        "model_params": cfg.num_params(),
        "active_params": cfg.active_params(),
    }
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(
            ARTIFACT_DIR, f"{arch_name}__{shape_name}__{mesh_name}.json"
        )
        with open(path, "w") as f:
            json.dump(art, f, indent=1)
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already exists")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="enable true sequence parallelism (see EXPERIMENTS.md)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for a, s in cells:
        path = os.path.join(ARTIFACT_DIR, f"{a}__{s}__{mesh_name}.json")
        if args.resume and os.path.exists(path):
            print(f"[dryrun] skip (exists): {a} x {s} x {mesh_name}")
            continue
        print(f"[dryrun] {a} x {s} x {mesh_name} ...", flush=True)
        try:
            art = run_cell(a, s, multi_pod=args.multi_pod,
                           num_microbatches=args.microbatches,
                           fsdp=not args.no_fsdp, sp_enable=args.sp)
            if "skipped" in art:
                print(f"[dryrun]   SKIP: {art['skipped']}")
                continue
            mem = art["memory"]
            print(
                f"[dryrun]   ok: compile {art['compile_s']:.1f}s  "
                f"flops/dev {art['hlo_cost']['flops']:.3e}  "
                f"args/dev {mem.get('argument_size_in_bytes', 0)/2**30:.2f} GiB  "
                f"temp/dev {mem.get('temp_size_in_bytes', 0)/2**30:.2f} GiB  "
                f"coll/dev {art['hlo_cost']['coll_bytes']/2**30:.3f} GiB"
            )
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"[dryrun]   FAIL: {e}")
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        raise SystemExit(1)
    print("[dryrun] all cells compiled")


if __name__ == "__main__":
    main()
