"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches JAX device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes_for(mesh, global_batch: int):
    """Data-parallel axes for a batch: ('pod','data') when both divide,
    'data' when only the single-pod width divides, else None (replicate —
    the long_500k batch=1 case)."""
    sizes = mesh_axis_sizes(mesh)
    if "pod" in sizes:
        full = sizes["pod"] * sizes["data"]
        if global_batch % full == 0:
            return ("pod", "data")
    if global_batch % sizes["data"] == 0:
        return ("data",)
    return None
