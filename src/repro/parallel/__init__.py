"""Distribution substrate: sharding rules, pipeline parallelism, gradient
compression."""
