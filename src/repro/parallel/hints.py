"""Ambient sharding hints for model-internal with_sharding_constraint.

Model code (e.g. the MoE dispatch) sometimes needs explicit activation
shardings — GSPMD's default choice for scatter/gather patterns is
involuntary replication. But model code must also run un-meshed (CPU smoke
tests). This tiny layer provides thread-local hints: the launcher traces
step functions inside ``sharding_hints(ep='model', dp=('data',))`` and
model code calls ``constrain(x, lambda ep, dp: P(ep, None, None))`` which
is a no-op when no hints are active.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Callable

import jax

_TLS = threading.local()


def _current() -> dict | None:
    return getattr(_TLS, "hints", None)


@contextmanager
def sharding_hints(**kw):
    prev = _current()
    _TLS.hints = kw
    try:
        yield
    finally:
        _TLS.hints = prev


def hints_active() -> bool:
    return _current() is not None


def hint(name: str, default=None):
    h = _current()
    return h.get(name, default) if h else default


def constrain(x, spec_fn: Callable[[dict], "jax.sharding.PartitionSpec"]):
    """Apply with_sharding_constraint(spec_fn(hints)) when hints are active."""
    h = _current()
    if not h:
        return x
    spec = spec_fn(h)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def with_hints(fn, **kw):
    """Wrap fn so the hints are active while it is traced."""

    def wrapped(*args, **kwargs):
        with sharding_hints(**kw):
            return fn(*args, **kwargs)

    return wrapped
