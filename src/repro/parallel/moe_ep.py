"""Explicit expert-parallel MoE dispatch: shard_map + lax.all_to_all.

GSPMD lowers the two-stage pjit dispatch's dp->ep reshard as
all-gather + slice (EXPERIMENTS.md §Perf kimi it.3) — each expert shard
receives ~ep_size x the bytes a real all-to-all would move. This module
implements the canonical pattern explicitly:

  per device: route local tokens -> per-destination-rank capacity buffers
  all_to_all over the expert ('model') axis      [token payload only]
  local expert FFN (weights all-gathered over the FSDP axes, as FSDP does)
  all_to_all back -> combine with gates

Wire bytes per device per layer: tokens_loc x top_k x d x dtype — the
information-theoretic minimum for token-choice routing.

Differentiable end-to-end (all_to_all transposes to all_to_all); used via
the 'a2a' sharding hint by ``repro.models.layers.moe_apply``.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def moe_ep_apply(xt, idx, gates, w_gate, w_up, w_down, *, mesh, dp_axes,
                 ep_axis: str, fsdp_axes, capacity_factor: float,
                 top_k: int, n_experts: int):
    """xt: (N, d) tokens; idx/gates: (N, k) routing; weights (E, d, f) etc.

    Returns (N, d) combined expert outputs.
    """
    ep = mesh.shape[ep_axis]
    e_loc = n_experts // ep
    n = xt.shape[0]
    # tokens shard over dp AND ep axes: without the ep split, the ep ranks
    # of one dp row would all route the same (replicated) tokens and the
    # all_to_all would move/compute ep x duplicated work
    tok_axes = tuple(dp_axes or ()) + (ep_axis,)
    dp_size = 1
    for a in tok_axes:
        dp_size *= mesh.shape[a]
    n_loc = n // dp_size
    cap = int(max(top_k, capacity_factor * n_loc * top_k / n_experts))
    dtype = xt.dtype

    w_specs = (
        P(ep_axis, fsdp_axes, None),  # w_gate (E, d, f)
        P(ep_axis, fsdp_axes, None),  # w_up
        P(ep_axis, fsdp_axes, None),  # w_down (E, f, d): FSDP on f
    )

    def body(xt_l, idx_l, gates_l, wg_l, wu_l, wd_l):
        # weights: undo the FSDP shard for this layer (the FSDP gather)
        if fsdp_axes:
            wg_l = jax.lax.all_gather(wg_l, fsdp_axes, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, fsdp_axes, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, fsdp_axes, axis=1, tiled=True)

        nk = idx_l.reshape(-1)  # (N_loc*k,) global expert ids
        # position within each expert's local capacity via one-hot cumsum
        onehot = jax.nn.one_hot(nk, n_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        keep = pos < cap
        src = jnp.repeat(xt_l, top_k, axis=0)  # (N_loc*k, d)
        # send buffer laid out (ep, E_loc, C, d): dim 0 is destination rank
        send = jnp.zeros((ep, e_loc, cap, xt_l.shape[-1]), dtype)
        dest = nk // e_loc
        el = nk % e_loc
        send = send.at[
            jnp.where(keep, dest, 0),
            jnp.where(keep, el, 0),
            jnp.where(keep, pos, cap - 1),
        ].add(jnp.where(keep[:, None], src, 0), mode="drop")

        # token payload crosses the wire exactly once each way
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (ep_src, E_loc, C, d) -> local experts serve all sources
        h = jnp.einsum("secd,edf->secf", recv, wg_l)
        u = jnp.einsum("secd,edf->secf", recv, wu_l)
        y = jnp.einsum("secf,efd->secd", jax.nn.silu(h) * u, wd_l)
        back = jax.lax.all_to_all(y, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # back: (ep_dest==expert rank, E_loc, C, d), same layout as `send`
        val = back[
            jnp.where(keep, dest, 0),
            jnp.where(keep, el, 0),
            jnp.where(keep, pos, cap - 1),
        ]
        val = jnp.where(keep[:, None], val, 0)
        out = (
            val.reshape(n_loc, top_k, -1)
            * gates_l[..., None].astype(dtype)
        ).sum(1)
        return out

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(tok_axes, None), P(tok_axes, None), P(tok_axes, None), *w_specs
        ),
        out_specs=P(tok_axes, None),
        check_vma=False,
    )(xt, idx, gates, w_gate, w_up, w_down)
