"""Pipeline parallelism: the paper's *temporal cascade* in LM form.

``S`` stages (layer groups) live on ``S`` mesh devices along a ``stage``
axis; ``M`` microbatches stream through. The schedule is the classic
GPipe-style fill/drain: utilization ``M / (M + S - 1)`` — exactly the
paper's prologue/epilogue loss with m*d replaced by (S-1) stage-steps
(DESIGN.md §4). Communication is a single ``lax.ppermute`` per tick, which
overlaps with the next tick's stage compute under XLA's async collectives.

Implementation: ``shard_map`` over the stage axis; each device scans over
T = M + S - 1 ticks, pushing activations to its right neighbor.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax

from repro.compat import pvary, shard_map
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_utilization(n_micro: int, n_stages: int) -> float:
    return n_micro / (n_micro + n_stages - 1)


def pipelined_forward(
    mesh: Mesh,
    stage_fn: Callable,  # (stage_params, x) -> y, same shape
    stage_axis: str = "stage",
):
    """Build a pipelined forward: (stacked_stage_params, microbatches) -> out.

    ``stacked_stage_params``: pytree with leading axis S (one slice per
    stage). ``microbatches``: (M, mb, ...) array. Returns (M, mb, ...) after
    all S stages.
    """
    n_stages = mesh.shape[stage_axis]

    def run(stage_params, micro):
        # shard_map leaves a local size-1 stage axis on the params; drop it
        stage_params = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        m = micro.shape[0]
        t_total = m + n_stages - 1
        stage = jax.lax.axis_index(stage_axis)

        # carries are device-varying (each stage holds different data):
        # mark them so under shard_map's varying-axis type system
        buf = pvary(jnp.zeros_like(micro), (stage_axis,))  # output slots
        state = pvary(jnp.zeros_like(micro[0]), (stage_axis,))  # in-flight

        def tick(carry, t):
            state, buf = carry
            # stage 0 ingests microbatch t (when available)
            feed = micro[jnp.clip(t, 0, m - 1)]
            x = jnp.where(stage == 0, feed, state)
            y = stage_fn(stage_params, x)
            # last stage retires microbatch t-(S-1) into the buffer
            out_idx = t - (n_stages - 1)
            do_store = jnp.logical_and(stage == n_stages - 1, out_idx >= 0)
            stored = jax.lax.dynamic_update_index_in_dim(
                buf, y, jnp.clip(out_idx, 0, m - 1), 0
            )
            buf = jnp.where(do_store, stored, buf)
            # shift to the right neighbor
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)],
            )
            return (nxt, buf), None

        (_, buf), _ = jax.lax.scan(tick, (state, buf), jnp.arange(t_total))
        # only the last stage holds real outputs; broadcast them
        buf = jax.lax.ppermute(
            buf, stage_axis,
            [((n_stages - 1 + i) % n_stages, i) for i in range(n_stages)],
        )
        return buf

    return jax.jit(
        shard_map(
            run,
            mesh=mesh,
            in_specs=(P(stage_axis), P()),
            out_specs=P(),
            # the final broadcast ppermute replicates buf across stages, but
            # the varying-axis checker cannot infer that statically
            check_vma=False,
        )
    )


def stack_stage_params(per_layer_params, n_stages: int):
    """Regroup (L, ...) scan-stacked layer params into (S, L/S, ...)."""
    def regroup(a):
        l = a.shape[0]
        if l % n_stages:
            raise ValueError(f"layers {l} must divide stages {n_stages}")
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])

    return jax.tree_util.tree_map(regroup, per_layer_params)
