"""Gradient compression for cross-pod all-reduce (DESIGN.md §6).

Two schemes, both with error feedback (the residual re-enters the next
step, so compression error accumulates to zero over time):

* int8 uniform quantization with per-tensor scale — 4x traffic cut on the
  slow pod-interconnect hop, negligible quality loss with EF.
* top-k magnitude sparsification — k fraction of entries + indices.

``compressed_psum`` is the in-graph primitive: quantize -> lax.psum ->
dequantize. The int32 sum of int8 payloads is exact, so EF sees the true
quantization error.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "int8_ef"  # 'int8_ef' | 'topk_ef' | 'none'
    topk_frac: float = 0.01


def init_residuals(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quant_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_int8(g, r):
    """-> (payload, deq, new_residual). deq is this worker's contribution
    as the receivers will see it."""
    x = g.astype(jnp.float32) + r
    q, scale = _quant_int8(x)
    deq = q.astype(jnp.float32) * scale
    return (q, scale), deq, x - deq


def compress_topk(g, r, frac: float):
    x = (g.astype(jnp.float32) + r).reshape(-1)
    k = max(1, int(frac * x.size))
    _, idx = jax.lax.top_k(jnp.abs(x), k)
    vals = x[idx]
    deq = jnp.zeros_like(x).at[idx].set(vals)
    return (vals, idx), deq.reshape(g.shape), (x - deq).reshape(g.shape)


def compressed_psum(grads: Any, residuals: Any, axis_name: str,
                    cfg: CompressionConfig = CompressionConfig()):
    """All-reduce (mean) with compression + error feedback, for use inside
    shard_map/pmap bodies. Returns (mean_grads, new_residuals)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        if cfg.scheme == "none":
            return jax.lax.psum(g.astype(jnp.float32), axis_name) / n, r
        if cfg.scheme == "int8_ef":
            (q, scale), _, new_r = compress_int8(g, r)
            # wire payload is (int8 q, f32 scale); the reduction sums each
            # worker's dequantized contribution q*scale
            total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
            return total / n, new_r
        if cfg.scheme == "topk_ef":
            _, deq, new_r = compress_topk(g, r, cfg.topk_frac)
            return jax.lax.psum(deq, axis_name) / n, new_r
        raise ValueError(cfg.scheme)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
        jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
    )


def payload_bytes(params: Any, cfg: CompressionConfig) -> int:
    """Analytic wire-bytes per step (feeds the roofline collective term)."""
    leaves = jax.tree_util.tree_leaves(params)
    n = sum(int(jnp.size(l)) for l in leaves)
    if cfg.scheme == "int8_ef":
        return n + 4 * len(leaves)
    if cfg.scheme == "topk_ef":
        k = int(cfg.topk_frac * n)
        return 8 * k  # f32 value + i32 index
    return 2 * n  # bf16 baseline
