"""Logical-axis sharding rules: parameter/activation PartitionSpecs.

One rule table serves every architecture. Rules match on the *leaf path*
(joined dict keys) and leaf rank; stacked per-layer leaves (leading L axis)
get a ``None`` prepended automatically. Tensor-parallel placements follow
Megatron conventions: column-parallel up-projections, row-parallel
down-projections, vocab-sharded embeddings, expert-sharded MoE.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (regex on path, spec for the *trailing* dims of the leaf)
# Order matters: first match wins.
_RULES: list[tuple[str, tuple]] = [
    (r"moe/router$", (None, None)),
    # MoE expert stacks (E, d, f) / (E, f, d): expert-parallel over 'model'
    # when E divides the axis, else fall back to TP within the expert.
    (r"moe/w_(gate|up)$", ("__expert__", None, "__expert_tp_col__")),
    (r"moe/w_down$", ("__expert__", "__expert_tp_row__", None)),
    # embed: d-sharded (token gather stays local; vocab-sharding forces the
    # partitioner into involuntary full rematerialization of the gather)
    (r"(embed)$", (None, "model")),
    (r"lm_head$", (None, "model")),
    # column-parallel in-projections
    (r"(wq|wv|wk|w_gate|w_up|w_in|in_proj|w_zifo|w_if)$", (None, "model")),
    # row-parallel out-projections
    (r"(wo|w_down|out_proj|w_out)$", ("model", None)),
    (r"(bq|bk|bv)$", ("model",)),
    (r"conv_w$", (None, "model")),
    (r"conv_b$", ("model",)),
    (r"r_zifo$", (None, None, None)),
    # everything 1-D (norm scales, A_log, D, dt_bias): replicated
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(axis, axis_sizes: dict) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(axis, 1)


def param_pspec(path, leaf, *, n_experts: int = 0, model_axis_size: int = 1,
                axis_sizes: dict | None = None, fsdp_axes=None,
                expert_cols_axis=None) -> P:
    """Resolve one leaf's PartitionSpec.

    ``fsdp_axes`` (e.g. ``('pod', 'data')``): ZeRO-3-style weight sharding —
    placed on the first still-unsharded dim of every >=2-D weight leaf.
    Every placement is divisibility-checked against ``axis_sizes`` and
    dropped (replicated) when the dim does not divide, so odd vocabularies
    (whisper's 51865) degrade gracefully instead of failing to lower.
    """
    axis_sizes = axis_sizes or {"model": model_axis_size}
    ps = _path_str(path)
    rank = len(leaf.shape)
    for pat, spec in _RULES:
        if not re.search(pat, ps):
            continue
        if spec is None:
            spec = ()
        spec = list(spec)
        ep_ok = n_experts and (n_experts % _axis_size("model", axis_sizes) == 0)
        for i, s in enumerate(spec):
            if s == "__expert__":
                spec[i] = "model" if ep_ok else None
            elif s in ("__expert_tp_col__", "__expert_tp_row__"):
                if ep_ok:
                    # inference 2-D expert sharding: FFN dim over a second
                    # axis keeps weights resident (no per-layer d-gathers);
                    # the f-contraction pays one small activation AR instead
                    spec[i] = expert_cols_axis
                else:
                    spec[i] = "model"
        extra = rank - len(spec)
        if extra < 0:
            return P()
        spec = [None] * extra + spec
        # divisibility check for the base (tensor-parallel) placement
        for i, s in enumerate(spec):
            if s is not None and leaf.shape[i] % _axis_size(s, axis_sizes):
                spec[i] = None
        # FSDP: shard the first free dim of substantial weight leaves.
        # The embedding table is excluded: its gather needs the vocab dim
        # whole, and FSDP on d would leave the lookup output oddly sharded.
        if fsdp_axes and rank >= 2 and ps and not re.search(
                r"(router|embed)$", ps):
            n_fsdp = _axis_size(tuple(fsdp_axes), axis_sizes)
            # skip the scan-stack axis (dim 0 of stacked layers): start at
            # the first dim belonging to the weight itself
            start = extra
            for i in range(start, rank):
                if spec[i] is None and leaf.shape[i] % n_fsdp == 0 \
                        and leaf.shape[i] >= 2 * n_fsdp:
                    spec[i] = tuple(fsdp_axes)
                    break
        return P(*spec)
    return P()


def build_param_specs(params_shape: Any, *, n_experts: int = 0,
                      model_axis_size: int = 1, axis_sizes: dict | None = None,
                      fsdp_axes=None, expert_cols_axis=None):
    """Map a params shape-pytree to a PartitionSpec pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(
            path, leaf, n_experts=n_experts, model_axis_size=model_axis_size,
            axis_sizes=axis_sizes, fsdp_axes=fsdp_axes,
            expert_cols_axis=expert_cols_axis,
        ),
        params_shape,
    )


def batch_pspec(dp_axes) -> P:
    return P(dp_axes, None)


def stream_grid_pspec(axis: str = "d", axis_x: str | None = None) -> P:
    """(P, H, W) stream-grid sharding: rows (y) split across ``axis``.

    The channel dim stays whole (every shard needs all P fields of its
    rows) and rows shard contiguously so each device owns one H/d-row
    band — the decomposition ``repro.core.distribute`` halo-exchanges
    (docs/pipeline.md §distribute). ``axis_x`` additionally splits the
    columns (x) for the 2-D device mesh (DESIGN.md §15): each device
    then owns one contiguous ``(H/dy, W/dx)`` tile.
    """
    return P(None, axis, axis_x)


def cache_pspec(path, leaf, *, dp_axes, n_kv_heads: int,
                model_axis_size: int, axis_sizes: dict | None = None) -> P:
    """KV/SSM cache shardings: batch over dp, heads over 'model' when they
    divide. batch==1 (long-context decode): the sequence dim takes the dp
    axes instead, so a 500k-token cache spreads across the fleet."""
    axis_sizes = axis_sizes or {"model": model_axis_size}
    ps = _path_str(path)
    rank = len(leaf.shape)

    def fits(dim_size, axis):
        return axis is not None and dim_size % _axis_size(axis, axis_sizes) == 0

    if re.search(r"(^|/)(k|v|xk|xv)$", ps) and rank >= 4:
        b, hkv, s, hd = leaf.shape[-4:]
        if fits(hkv, "model"):
            kv_model, kv_seq = "model", None
        else:
            # MQA/GQA heads don't divide the TP axis: seq-shard the cache
            # instead (flash-decoding layout, see layers._kv_decode_spec)
            kv_model = None
            kv_seq = "model" if fits(s, "model") else None
        if fits(b, dp_axes):
            spec = [dp_axes, kv_model, kv_seq, None]
        elif fits(s, dp_axes):
            spec = [None, kv_model, dp_axes, None]
        else:
            spec = [None, kv_model, kv_seq, None]
        return P(*([None] * (rank - 4) + spec))
    if re.search(r"ssm/h$", ps) and rank >= 4:
        b, h = leaf.shape[-4:-2]
        spec = [
            dp_axes if fits(b, dp_axes) else None,
            "model" if fits(h, "model") else None,
            None, None,
        ]
        return P(*([None] * (rank - 4) + spec))
    if re.search(r"ssm/conv$", ps) and rank >= 3:
        b, _, c = leaf.shape[-3:]
        spec = [
            dp_axes if fits(b, dp_axes) else None,
            None,
            "model" if fits(c, "model") else None,
        ]
        return P(*([None] * (rank - 3) + spec))
    # generic state leaves: batch-shard dim 0 when possible
    if rank >= 1 and fits(leaf.shape[0], dp_axes):
        return P(*([dp_axes] + [None] * (rank - 1)))
    return P()


def build_cache_specs(cache_shape: Any, *, dp_axes, n_kv_heads: int,
                      model_axis_size: int, axis_sizes: dict | None = None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_pspec(
            path, leaf, dp_axes=dp_axes, n_kv_heads=n_kv_heads,
            model_axis_size=model_axis_size, axis_sizes=axis_sizes,
        ),
        cache_shape,
    )
