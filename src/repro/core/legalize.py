"""Shared (block_h, m) legalization for temporal-blocking stream kernels.

A design point chosen by the analytic models (`repro.core.dse`) is
grid-agnostic: the sweep lattice may propose a block height that does not
divide the concrete grid, a fused-step count the halo cannot source, or a
stripe that overflows VMEM. Both kernel back ends — the hand-written
``repro.kernels.lbm_stream`` and the generic SPD codegen path
``repro.kernels.spd_stream`` — legalize through the functions here, so
model and measurement always agree on what "the closest legal plan" means
(docs/pipeline.md §legalize).

``VMEM_BYTES`` is the single definition of the on-chip vector-memory
budget: the DSE model's :class:`~repro.core.dse.TPUTarget` feasibility
check and the legalizer's stripe clamp both read it, so a point the model
calls feasible is one the legalizer will not shrink.
"""

from __future__ import annotations

#: TPU v5e on-chip vector memory (VMEM) capacity in bytes. Single source of
#: truth for the DSE model (``TPUTarget.vmem_bytes``) and the legalizer.
VMEM_BYTES = 128 * 1024 * 1024

#: The pipelined kernels double-buffer the next block's DMA, so a stripe
#: effectively occupies twice its size. Shared with ``TPUModel``.
VMEM_DOUBLE_BUFFER = 2


def stripe_vmem_bytes(block_h: int, m: int, width: int, words: int,
                      halo: int = 1,
                      double_buffer: bool = True) -> int:
    """VMEM bytes of one (block_h + 2·m·halo)-row f32 stripe of ``words``
    fields, matching the residency term of ``TPUModel.evaluate``."""
    rows = block_h + 2 * m * halo
    mult = VMEM_DOUBLE_BUFFER if double_buffer else 1
    return rows * max(width, 1) * max(words, 1) * 4 * mult


def blocking_plan(h: int, block_h: int, m: int, *, halo: int = 1,
                  width: int = 0, words: int = 0,
                  vmem_bytes: int = VMEM_BYTES) -> tuple[int, int]:
    """Legalize a model-chosen (block_h, m) for a grid of ``h`` rows.

    The temporal-blocking kernels require ``block_h | h`` and
    ``m * halo <= block_h`` (the y-halo is sourced from one neighbor
    stripe per side; ``halo`` is the per-step stencil reach inferred by
    ``repro.core.codegen``, 1 for the LBM kernel). The model's lattice is
    grid-agnostic, so its pick may violate either; this returns the
    closest legal plan: the largest divisor of ``h`` that is <= the
    requested block (or the smallest one >= m*halo when the request is
    too small), with ``m`` clamped into [1, h].

    When ``width``/``words`` are supplied the plan is additionally kept
    under the shared VMEM budget (:data:`VMEM_BYTES`): only legal
    divisors whose stripe fits are considered — the same residency
    arithmetic ``TPUModel`` uses for its feasibility mask — and a
    ``ValueError`` is raised when none does (better than an opaque
    on-device VMEM allocation failure).
    """
    if h < 1:
        raise ValueError(f"grid height must be positive, got {h}")
    halo = max(0, int(halo))
    m = max(1, min(int(m), h))
    floor = max(1, m * halo)
    divisors = [d for d in range(1, h + 1) if h % d == 0]
    legal = [d for d in divisors if d >= floor]
    while not legal and m > 1:  # m*halo exceeds the grid: shrink m
        m -= 1
        floor = max(1, m * halo)
        legal = [d for d in divisors if d >= floor]
    if not legal:  # even one fused step cannot source its halo
        raise ValueError(
            f"stencil halo {halo} cannot be sourced on a grid of h={h} "
            f"rows (needs a block of >= {halo} rows dividing h)"
        )
    if width and words:
        fits = [
            d for d in legal
            if stripe_vmem_bytes(d, m, width, words, halo) <= vmem_bytes
        ]
        if not fits:  # no legal block fits: fail loudly, not on-device
            smallest = min(legal)
            raise ValueError(
                f"no legal block for h={h} fits VMEM: smallest stripe "
                f"(block_h={smallest}, m={m}, halo={halo}) needs "
                f"{stripe_vmem_bytes(smallest, m, width, words, halo)} B "
                f"> budget {vmem_bytes} B"
            )
        legal = fits
    under = [d for d in legal if d <= block_h]
    return (max(under) if under else min(legal)), m


def resolve_run_plan(h: int, point, steps: int | None = None, *,
                     halo: int = 1, width: int = 0,
                     words: int = 0) -> tuple[int, int, int]:
    """Turn a DSE design point into a concrete (block_h, m, steps) plan.

    ``point`` is any object with ``m`` and ``detail['block_rows']`` (a
    :class:`repro.core.dse.DesignPoint` from a TPU sweep). The blocking is
    legalized with :func:`blocking_plan`; ``steps`` defaults to one fused
    launch (m steps) and is rounded down to a multiple of m.
    """
    block_h, m = blocking_plan(
        h, int(point.detail["block_rows"]), int(point.m),
        halo=halo, width=width, words=words,
    )
    nsteps = m if steps is None else max(m, (steps // m) * m)
    return block_h, m, nsteps


__all__ = [
    "VMEM_BYTES",
    "VMEM_DOUBLE_BUFFER",
    "blocking_plan",
    "resolve_run_plan",
    "stripe_vmem_bytes",
]
