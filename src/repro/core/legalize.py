"""Shared (block_h, m, d) legalization for temporal-blocking stream kernels.

A design point chosen by the analytic models (`repro.core.dse`) is
grid-agnostic: the sweep lattice may propose a block height that does not
divide the concrete grid, a fused-step count the halo cannot source, a
stripe that overflows VMEM, or a device count that does not split the
grid into equal shards. All kernel back ends — the hand-written
``repro.kernels.lbm_stream``, the generic SPD codegen path
``repro.kernels.spd_stream``, and the multi-device
``repro.core.distribute`` wrapper — legalize through the functions here,
so model and measurement always agree on what "the closest legal plan"
means (docs/pipeline.md §legalize).

``VMEM_BYTES`` is the single definition of the on-chip vector-memory
budget: the DSE model's :class:`~repro.core.dse.TPUTarget` feasibility
check and the legalizer's stripe clamp both read it, so a point the model
calls feasible is one the legalizer will not shrink.

The device axis ``d`` (spatial parallelism across chips,
docs/pipeline.md §distribute) legalizes *per shard*: the grid's ``h``
rows must split into ``d`` equal shards (a hard error otherwise — there
is no "closest" shard count), and the (block_h, m) plan is then
legalized against the shard height ``h / d``, with the same VMEM stripe
accounting a single device uses (every shard keeps its own
``block_h + 2·m·halo``-row stripes resident).

``dx`` factors the device count into a 2-D mesh ``(dy, dx)`` with
``dy = d / dx`` (DESIGN.md §15): rows shard over ``dy`` as before and
columns shard over ``dx``, so the shard geometry is
``(h / dy, width / dx)``. Legalization then runs against the shard
height ``h / dy`` and prices stripes at the per-shard width plus the
``2·m·halo_x`` guard columns each fused launch keeps resident — wide
grids legalize larger ``block_h``/``m`` under ``dx > 1`` because the
per-stripe width term shrinks by ``dx``. A width the column axis does
not divide is a hard error (:func:`shard_width`), exactly mirroring the
row axis.

``double_buffer`` is a first-class plan dimension (docs/pipeline.md
§stream): with it on, the streaming kernels ping/pong two stripe
buffers so copy overlaps compute, and every stripe is accounted at
``VMEM_DOUBLE_BUFFER`` times its size; with it off, one buffer streams
sequentially and the whole budget holds a single stripe — the
*streaming fallback* :func:`blocking_plan` drops to when no
double-buffered stripe fits.

The batch axis ``b`` (docs/pipeline.md §serve, DESIGN.md §13) stacks
``b`` independent simulations into one launch along a leading array
dimension: every stripe then holds ``b`` members' rows at once, so all
stripe accounting scales linearly — ``b × stripe_vmem_bytes(..., b=1)``
— single-sourced here so the serving engine's batched plans and the
model's feasibility mask (``TPUModel.evaluate``) price the identical
geometry.

``fusion`` is the program-graph plan dimension (docs/pipeline.md
§program, DESIGN.md §14): a multi-stage stream program partitions its
stage chain into *fusion clusters* — ``"3"`` fuses three stages into
one stripe body, ``"1+2"`` cuts after the first stage, ``"1+1+1"``
pipelines every stage as its own launch. A fused cluster's composed
halo is the **sum** of its member stages' per-step stencil extents, and
its stripe residency is the **sum** of the member stages' stripes at
that composed halo (:func:`cluster_vmem_bytes`), so
:func:`program_blocking_plan` legalizes the whole partition against the
same ``VMEM_BYTES`` budget a single core uses. The empty string is the
legacy single-core plan.

Plan identity is single-sourced here as :data:`PLAN_FIELDS` /
:class:`RunPlan` (mirroring ``EXECUTED_POINT_FIELDS``): the search
runner, the study journal, and the measurement cache all derive their
keys from ``RunPlan.key()`` / ``RunPlan.from_dict``, so adding a plan
dimension (as ``fusion`` was) is a one-line change here rather than a
drift across call sites.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

#: TPU v5e on-chip vector memory (VMEM) capacity in bytes. Single source of
#: truth for the DSE model (``TPUTarget.vmem_bytes``) and the legalizer.
VMEM_BYTES = 128 * 1024 * 1024

#: Ping/pong streaming keeps two stripes resident (one computing, one in
#: DMA flight), so a double-buffered stripe occupies twice its size.
#: Single source of truth: ``TPUModel`` and the legalizer both call
#: :func:`stripe_vmem_bytes` rather than re-implementing this multiplier.
VMEM_DOUBLE_BUFFER = 2

#: The one definition of plan identity, in dataclass-field order
#: (mirrors ``EXECUTED_POINT_FIELDS`` in ``repro.core.search``). The
#: study journal, measurement-cache keys, and strategy dedupe tables all
#: derive their tuples from :class:`RunPlan` over these fields, so a new
#: plan dimension is added *here* and nowhere else.
PLAN_FIELDS = (
    "block_h", "m", "steps", "d", "reps", "double_buffer", "b", "fusion",
    "dx",
)


@dataclass(frozen=True)
class RunPlan:
    """One concrete, legalized measurement plan — the unit of identity
    for the in-run dedupe table, the measurement cache, and the study
    journal (docs/pipeline.md §legalize, §study).

    ``fusion`` is the program-graph partition spec (docs/pipeline.md
    §program) — ``""`` for single-core plans, ``"2+1"``-style cluster
    sizes for stream programs — carried as plan identity so a fused and
    a pipelined execution of the same lattice point are distinct
    measurements.

    ``dx`` is the column axis of the 2-D device mesh (DESIGN.md §15):
    ``d`` stays the *total* device count (the compatible ``dy·dx``
    spelling, so journals and caches written by the 1-D ring replay
    unchanged) and ``dx`` factors it, ``dy = d / dx``. ``dx = 1`` is
    the legacy row-ring plan.
    """

    block_h: int
    m: int
    steps: int
    d: int
    reps: int
    double_buffer: bool = True
    b: int = 1
    fusion: str = ""
    dx: int = 1

    def key(self) -> tuple:
        """Hashable identity tuple, ordered exactly as PLAN_FIELDS."""
        return (self.block_h, self.m, self.steps, self.d, self.reps,
                bool(self.double_buffer), self.b, self.fusion, self.dx)

    def as_dict(self) -> dict:
        return {
            "block_h": self.block_h, "m": self.m, "steps": self.steps,
            "d": self.d, "reps": self.reps,
            "double_buffer": bool(self.double_buffer), "b": self.b,
            "fusion": self.fusion, "dx": self.dx,
        }

    @classmethod
    def from_dict(cls, rec: dict) -> "RunPlan":
        """Rebuild a plan from a journal/report record, tolerating
        records written before newer plan dimensions existed (absent
        ``double_buffer``/``b``/``fusion``/``dx`` take their
        defaults — a ``d``-only 1-D-ring record is the ``dx = 1``
        mesh, DESIGN.md §15)."""
        return cls(
            block_h=int(rec["block_h"]), m=int(rec["m"]),
            steps=int(rec["steps"]), d=int(rec["d"]),
            reps=int(rec.get("reps", 1)),
            double_buffer=bool(rec.get("double_buffer", True)),
            b=int(rec.get("b", 1)),
            fusion=str(rec.get("fusion", "") or ""),
            dx=int(rec.get("dx", 1)),
        )


assert tuple(f.name for f in fields(RunPlan)) == PLAN_FIELDS


def parse_fusion(spec: str, nstages: int) -> tuple[int, ...]:
    """Parse a fusion partition spec into a tuple of cluster sizes.

    ``"3"`` → ``(3,)`` (fully fused), ``"1+2"`` → ``(1, 2)``,
    ``"1+1+1"`` → fully pipelined; ``""`` means fully fused (the
    default for a program, and the only spelling for ``nstages == 1``).
    Sizes must be positive and sum to ``nstages`` — a spec for the
    wrong program shape is a hard error, not a closest-legal fallback.
    """
    if nstages < 1:
        raise ValueError(f"program needs >= 1 stage, got {nstages}")
    if not spec:
        return (nstages,)
    try:
        sizes = tuple(int(part) for part in str(spec).split("+"))
    except ValueError:
        raise ValueError(f"malformed fusion spec {spec!r}") from None
    if any(s < 1 for s in sizes):
        raise ValueError(f"fusion spec {spec!r} has a non-positive cluster")
    if sum(sizes) != nstages:
        raise ValueError(
            f"fusion spec {spec!r} partitions {sum(sizes)} stages, "
            f"program has {nstages}"
        )
    return sizes


def stripe_vmem_bytes(block_h, m, width: int, words: int,
                      halo: int = 1, double_buffer: bool = True,
                      b: int = 1, halo_x: int = 0):
    """VMEM bytes of one (block_h + 2·m·halo)-row f32 stripe of ``words``
    fields, matching the residency term of ``TPUModel.evaluate``.

    ``double_buffer=True`` prices the ping/pong pair
    (:data:`VMEM_DOUBLE_BUFFER` stripes resident); ``False`` prices the
    single-buffer streaming fallback. ``b`` is the batch axis
    (docs/pipeline.md §serve): ``b`` stacked simulations keep ``b``
    copies of every stripe resident, a plain linear multiplier — the one
    place the batched geometry is priced, so model and legalizer cannot
    drift. ``block_h``/``m`` may be numpy arrays (the model's batched
    lattice evaluation broadcasts through).

    ``halo_x`` prices the guard columns of a column-sharded stripe
    (DESIGN.md §15): under ``dx > 1`` every fused launch keeps
    ``2·m·halo_x`` neighbor columns resident alongside the per-shard
    ``width``, mirroring the ``2·m·halo`` guard rows. Callers pass 0
    when the column axis is unsharded, keeping legacy accounting
    byte-identical.
    """
    rows = block_h + 2 * m * halo
    mult = VMEM_DOUBLE_BUFFER if double_buffer else 1
    if getattr(b, "shape", None) in (None, ()):  # scalar: clamp to >= 1
        b = max(int(b), 1)
    # else: array batch-axis values broadcast straight through (the
    # model's batched lattice evaluation pre-clamps them)
    if getattr(width, "shape", None) in (None, ()):  # scalar: clamp
        width = max(int(width), 1)
    cols = width + 2 * m * halo_x
    return rows * cols * max(words, 1) * 4 * mult * b


def shard_width(w: int, dx: int) -> int:
    """Columns per shard when ``w`` grid columns split across ``dx``
    devices (the column axis of the 2-D mesh, DESIGN.md §15).

    Exactly mirrors :func:`shard_height`: a width the column axis does
    not divide is a hard error — there is no "closest" mesh shape to
    fall back to.
    """
    dx = int(dx)
    if dx < 1:
        raise ValueError(f"column device axis must be >= 1, got dx={dx}")
    if w % dx:
        raise ValueError(
            f"grid width w={w} does not split into dx={dx} equal shards "
            f"(column-sharded stream kernels need w % dx == 0)"
        )
    return w // dx


def shard_height(h: int, d: int) -> int:
    """Rows per shard when ``h`` grid rows split across ``d`` devices.

    The sharded stream kernels decompose the grid along y into ``d``
    equal contiguous shards (docs/pipeline.md §distribute); a height the
    device axis does not divide is a hard error — unlike (block_h, m)
    there is no "closest legal" shard count to fall back to.
    """
    d = int(d)
    if d < 1:
        raise ValueError(f"device axis must be >= 1, got d={d}")
    if h % d:
        raise ValueError(
            f"grid height h={h} does not split into d={d} equal shards "
            f"(sharded stream kernels need h % d == 0)"
        )
    return h // d


def mesh_shape(d: int, dx: int) -> tuple[int, int]:
    """Factor a total device count into the ``(dy, dx)`` mesh
    (DESIGN.md §15).

    ``d`` stays the total device count everywhere (plan identity,
    journals, caches); ``dx`` must divide it — a non-factorizing pair is
    a hard error, like an unshardable grid.
    """
    d, dx = int(d), int(dx)
    if d < 1:
        raise ValueError(f"device axis must be >= 1, got d={d}")
    if dx < 1:
        raise ValueError(f"column device axis must be >= 1, got dx={dx}")
    if d % dx:
        raise ValueError(
            f"mesh dx={dx} does not divide the device count d={d} "
            f"(a (dy, dx) mesh needs d == dy * dx)"
        )
    return d // dx, dx


def legal_block_values(h: int, m: int, *, halo: int = 1,
                       width: int = 0, words: int = 0,
                       vmem_bytes: int = VMEM_BYTES,
                       d: int = 1,
                       double_buffer: bool = True,
                       b: int = 1, dx: int = 1,
                       halo_x: int = 0) -> tuple[int, ...]:
    """Every legal ``block_h`` for ``m`` fused steps on an ``h``-row grid.

    The ascending chain of shard-height divisors that can source the
    ``m·halo`` stencil halo and (when the stripe geometry is supplied)
    fit the shared VMEM budget — i.e. exactly the values
    :func:`blocking_plan` chooses among for the same ``double_buffer``
    setting. Search strategies (``repro.core.search``, docs/pipeline.md
    §search) step block_h through this chain directly, which is what
    makes the block height a first-class searched dimension rather than
    a legalization byproduct; an empty tuple means no block is legal for
    this ``m`` (the neighborhood move is simply not available).

    ``dx`` factors ``d`` into the 2-D mesh (DESIGN.md §15): the divisor
    chain runs over the shard height ``h / dy`` and stripes are priced
    at the per-shard width ``width / dx`` plus the ``2·m·halo_x`` guard
    columns.
    """
    if h < 1:
        raise ValueError(f"grid height must be positive, got {h}")
    dy, dx = mesh_shape(d, dx)
    local_h = shard_height(h, dy)
    local_w = shard_width(width, dx) if width else width
    guard_x = max(0, int(halo_x)) if dx > 1 else 0
    halo = max(0, int(halo))
    m = max(1, min(int(m), local_h))
    floor = max(1, m * halo)
    legal = [
        v for v in range(1, local_h + 1)
        if local_h % v == 0 and v >= floor
    ]
    if width and words:
        legal = [
            v for v in legal
            if stripe_vmem_bytes(v, m, local_w, words, halo,
                                 double_buffer, b=b,
                                 halo_x=guard_x) <= vmem_bytes
        ]
    return tuple(legal)


def blocking_plan(h: int, block_h: int, m: int, *, halo: int = 1,
                  width: int = 0, words: int = 0,
                  vmem_bytes: int = VMEM_BYTES, d: int = 1,
                  double_buffer: bool = True,
                  b: int = 1, dx: int = 1,
                  halo_x: int = 0) -> tuple[int, int, bool]:
    """Legalize a model-chosen (block_h, m) for a grid of ``h`` rows.

    The temporal-blocking kernels require ``block_h | h`` and
    ``m * halo <= block_h`` (the y-halo is sourced from one neighbor
    stripe per side; ``halo`` is the per-step stencil reach inferred by
    ``repro.core.codegen``, 1 for the LBM kernel). The model's lattice is
    grid-agnostic, so its pick may violate either; this returns the
    closest legal plan ``(block_h, m, double_buffer)``: the largest
    divisor of ``h`` that is <= the requested block (or the smallest one
    >= m*halo when the request is too small), with ``m`` clamped into
    [1, h].

    With ``d > 1`` the plan is legalized *per shard*: ``h`` must split
    into ``d`` equal shards (:func:`shard_height` raises otherwise) and
    the divisor search runs over the shard height ``h / d`` — each shard
    of the distributed kernel (docs/pipeline.md §distribute) tiles its
    own rows independently, with the same per-stripe VMEM residency as a
    single device.

    When ``width``/``words`` are supplied the plan is additionally kept
    under the shared VMEM budget (:data:`VMEM_BYTES`): only legal
    divisors whose stripe fits are considered — the same residency
    arithmetic ``TPUModel`` uses for its feasibility mask. A
    double-buffered request whose smallest ping/pong stripe pair
    overflows the budget falls back to ``double_buffer=False`` (the
    single-buffer streaming path, docs/pipeline.md §stream), whose
    stripe budget is the whole VMEM; only when even that cannot fit is a
    ``ValueError`` raised (better than an opaque on-device VMEM
    allocation failure).

    ``b > 1`` legalizes a batched launch (docs/pipeline.md §serve):
    the same divisor chain, with every stripe priced at ``b`` members'
    residency — a batch that would overflow VMEM shrinks the block (or
    drops to single-buffer) exactly as a wider grid would.

    ``dx > 1`` legalizes against the 2-D mesh shard geometry
    ``(h / dy, width / dx)`` (DESIGN.md §15): the divisor chain runs
    over the ``dy``-shard height and every stripe is priced at the
    per-shard width plus its ``2·m·halo_x`` guard columns — the reason
    wide grids legalize larger blocks under column sharding.
    """
    if h < 1:
        raise ValueError(f"grid height must be positive, got {h}")
    dy, dx = mesh_shape(d, dx)
    local_h = shard_height(h, dy)
    width = shard_width(width, dx) if width else width
    halo_x = max(0, int(halo_x)) if dx > 1 else 0
    halo = max(0, int(halo))
    m = max(1, min(int(m), local_h))
    floor = max(1, m * halo)
    divisors = [v for v in range(1, local_h + 1) if local_h % v == 0]
    legal = [v for v in divisors if v >= floor]
    while not legal and m > 1:  # m*halo exceeds the shard: shrink m
        m -= 1
        floor = max(1, m * halo)
        legal = [v for v in divisors if v >= floor]
    if not legal:  # even one fused step cannot source its halo
        raise ValueError(
            f"stencil halo {halo} cannot be sourced on a shard of "
            f"h={local_h} rows (needs a block of >= {halo} rows dividing "
            f"it{f'; grid h={h} over d={d} shards' if d > 1 else ''})"
        )
    double_buffer = bool(double_buffer)
    b = max(1, int(b))
    if width and words:
        fits = [
            v for v in legal
            if stripe_vmem_bytes(v, m, width, words, halo,
                                 double_buffer, b=b,
                                 halo_x=halo_x) <= vmem_bytes
        ]
        if not fits and double_buffer:
            # Streaming fallback: a single-buffered stripe has the whole
            # budget to itself, so stripes up to VMEM_DOUBLE_BUFFER times
            # larger still stream (sequentially) through VMEM.
            double_buffer = False
            fits = [
                v for v in legal
                if stripe_vmem_bytes(v, m, width, words, halo,
                                     double_buffer, b=b,
                                     halo_x=halo_x) <= vmem_bytes
            ]
        if not fits:  # no legal block fits: fail loudly, not on-device
            smallest = min(legal)
            raise ValueError(
                f"no legal block for shard h={local_h} fits VMEM even via "
                f"the single-buffer streaming fallback "
                f"(double_buffer=False): smallest stripe "
                f"(block_h={smallest}, m={m}, halo={halo}, b={b}) needs "
                f"{stripe_vmem_bytes(smallest, m, width, words, halo, False, b=b, halo_x=halo_x)}"
                f" B > budget {vmem_bytes} B"
            )
        legal = fits
    under = [v for v in legal if v <= block_h]
    return (max(under) if under else min(legal)), m, double_buffer


def constraint_violation(h: int, block_h: int, m: int, *, halo: int = 1,
                         width: int = 0, words: int = 0,
                         vmem_bytes: int = VMEM_BYTES, d: int = 1,
                         double_buffer: bool = True,
                         b: int = 1, dx: int = 1,
                         halo_x: int = 0) -> float:
    """Continuous distance-to-feasibility of a (block_h, m, d) request.

    Exactly ``0.0`` iff :func:`blocking_plan` would produce a legal plan
    for the same arguments (including via the single-buffer streaming
    fallback); positive otherwise, and **monotone in the VMEM
    overshoot** — the deeper the smallest legal stripe overflows the
    budget, the larger the distance. Surrogate search strategies
    (docs/pipeline.md §study) use this as a penalty signal instead of
    hard-rejecting infeasible candidates: a continuous violation gives
    the sampler a gradient toward the feasible region, where a boolean
    would leave it blind (the ``constraint_violation``-as-gradient trick
    of Optuna-style DSE harnesses).

    The three failure modes, by increasing distance-from-legal:

    * **VMEM overflow** — every legal divisor's stripe exceeds the
      budget even single-buffered: violation is the fractional overshoot
      of the *smallest* legal single-buffered stripe,
      ``(bytes - vmem_bytes) / vmem_bytes``;
    * **unsourceable halo** — the per-step stencil reach exceeds the
      shard height: ``1 +`` the fractional excess (strictly above every
      VMEM violation of the same order);
    * **unshardable grid** — ``h % dy != 0`` (or, for a 2-D mesh,
      ``width % dx != 0`` / ``d % dx != 0``, DESIGN.md §15) has no
      closest legal plan at all: ``1 +`` the fractional remainder.
    """
    if h < 1:
        raise ValueError(f"grid height must be positive, got {h}")
    d, dx = int(d), int(dx)
    if d < 1:
        raise ValueError(f"device axis must be >= 1, got d={d}")
    if dx < 1:
        raise ValueError(f"column device axis must be >= 1, got dx={dx}")
    if d % dx:
        return 1.0 + (d % dx) / dx
    dy = d // dx
    if h % dy:
        return 1.0 + (h % dy) / dy
    if width and width % dx:
        return 1.0 + (width % dx) / dx
    local_h = h // dy
    width = width // dx if width else width
    halo_x = max(0, int(halo_x)) if dx > 1 else 0
    halo = max(0, int(halo))
    m = max(1, min(int(m), local_h))
    if halo > local_h:
        # even one fused step cannot source its halo on this shard
        return 1.0 + (halo - local_h) / local_h
    if not (width and words):
        return 0.0
    # Mirror blocking_plan's m-shrink loop, then price the smallest
    # legal stripe against the budget. blocking_plan falls back to
    # double_buffer=False before erroring, so a request is only
    # infeasible when even the single-buffered stripe overflows.
    divisors = [v for v in range(1, local_h + 1) if local_h % v == 0]
    floor = max(1, m * halo)
    legal = [v for v in divisors if v >= floor]
    while not legal and m > 1:
        m -= 1
        floor = max(1, m * halo)
        legal = [v for v in divisors if v >= floor]
    b = max(1, int(b))
    need = min(
        stripe_vmem_bytes(v, m, width, words, halo, double_buffer, b=b,
                          halo_x=halo_x)
        for v in legal
    )
    if need <= vmem_bytes:
        return 0.0
    if double_buffer:
        need = min(
            stripe_vmem_bytes(v, m, width, words, halo, False, b=b,
                              halo_x=halo_x)
            for v in legal
        )
        if need <= vmem_bytes:
            return 0.0
    return (need - vmem_bytes) / vmem_bytes


def cluster_vmem_bytes(block_h, m, width: int, stage_words,
                       stage_halos, double_buffer: bool = True,
                       b: int = 1):
    """VMEM bytes of one fusion cluster's stripe set (docs/pipeline.md
    §program, DESIGN.md §14).

    A fused cluster evaluates its member stages inside one stripe body,
    so every member stage's field set stays stripe-resident at once: the
    residency is the **sum** of the member stages' stripes, each priced
    at the cluster's *composed* halo — the sum of the members' per-step
    stencil extents, since stage k's reads reach through every upstream
    member's stencil. ``stage_words``/``stage_halos`` are the member
    stages' field counts and per-step halos, in chain order.
    """
    halo_c = sum(int(x) for x in stage_halos)
    return sum(
        stripe_vmem_bytes(block_h, m, width, int(w), halo_c,
                          double_buffer, b=b)
        for w in stage_words
    )


def program_blocking_plan(h: int, block_h: int, m: int, *,
                          stages, fusion: str = "", width: int = 0,
                          vmem_bytes: int = VMEM_BYTES, d: int = 1,
                          double_buffer: bool = True,
                          b: int = 1, dx: int = 1) -> tuple[int, int, bool]:
    """Legalize a (block_h, m) plan for a stream *program* under a
    fusion partition (docs/pipeline.md §program, DESIGN.md §14).

    ``stages`` is the program's stage chain as ``(words, halo)`` pairs;
    ``fusion`` partitions it into clusters (:func:`parse_fusion`). Every
    cluster must satisfy the single-core constraints at its *composed*
    halo — block divides the shard, the cluster's fused steps can source
    their halo, and the cluster's stripe set
    (:func:`cluster_vmem_bytes`) fits the shared budget; the returned
    plan is the closest one legal for **all** clusters at once.

    Temporal blocking only applies within a single launch, so a
    single-cluster (fully fused) partition blocks ``m`` steps per HBM
    round trip while a multi-cluster (pipelined) partition launches each
    cluster at one program step at a time — the per-cluster fused-step
    count is ``m`` iff the partition has one cluster, else 1. A
    partition with no legal block raises a ``ValueError`` naming the
    offending cluster (better than an opaque on-device VMEM failure).

    ``dx > 1`` legalizes against the 2-D mesh shard geometry
    (DESIGN.md §15): the divisor chain runs over the ``dy``-shard height
    and every cluster's stripe set is priced at the per-shard width
    ``width / dx``.
    """
    stages = [(int(w), int(hh)) for (w, hh) in stages]
    sizes = parse_fusion(fusion, len(stages))
    clusters, lo = [], 0
    for s in sizes:
        clusters.append(stages[lo:lo + s])
        lo += s
    dy, dx = mesh_shape(d, dx)
    local_h = shard_height(h, dy)
    width = shard_width(width, dx) if width else width
    fused = len(clusters) == 1
    m = max(1, min(int(m), local_h))
    b = max(1, int(b))
    spec = fusion or str(len(stages))
    divisors = [v for v in range(1, local_h + 1) if local_h % v == 0]
    geom = [
        (sum(w for w, _ in c), sum(hh for _, hh in c)) for c in clusters
    ]

    def _legal(m_c, db, vmem):
        """Blocks legal for every cluster; (legal, offending ci)."""
        legal = divisors
        for ci, (words_sum, halo_c) in enumerate(geom):
            ok = [v for v in legal if v >= max(1, m_c * halo_c)]
            if vmem and width and words_sum:
                ok = [
                    v for v in ok
                    if cluster_vmem_bytes(v, m_c, width,
                                          [w for w, _ in clusters[ci]],
                                          [hh for _, hh in clusters[ci]],
                                          db, b=b) <= vmem_bytes
                ]
            if not ok:
                return [], ci
            legal = ok
        return legal, None

    # Mirror blocking_plan: shrink the fused-step count only when a
    # cluster's composed halo cannot be sourced on the shard at all
    # (pipelined clusters launch one program step at a time, m_c = 1).
    m_c = m if fused else 1
    while True:
        legal, ci = _legal(m_c, double_buffer, vmem=False)
        if legal:
            break
        if m_c > 1:
            m_c -= 1
            continue
        halo_c = geom[ci][1]
        raise ValueError(
            f"fusion cluster {ci} of spec {spec!r}: composed stencil "
            f"halo {halo_c} cannot be sourced on a shard of h={local_h} "
            f"rows (needs a block of >= {halo_c} rows dividing it"
            f"{f'; grid h={h} over d={d} shards' if d > 1 else ''})"
        )
    db = bool(double_buffer)
    fits, ci = _legal(m_c, db, vmem=True)
    if not fits and db:
        # Streaming fallback: single-buffered stripes have the whole
        # budget to themselves (docs/pipeline.md §stream).
        db = False
        fits, ci = _legal(m_c, db, vmem=True)
    if not fits:
        words_sum, halo_c = geom[ci]
        smallest = min(legal)
        raise ValueError(
            f"fusion cluster {ci} of spec {spec!r} fits no legal block "
            f"on shard h={local_h} even via the single-buffer streaming "
            f"fallback (double_buffer=False): smallest stripe set "
            f"(block_h={smallest}, m={m_c}, composed halo={halo_c}, "
            f"words={words_sum}, b={b}) needs "
            f"{cluster_vmem_bytes(smallest, m_c, width, [w for w, _ in clusters[ci]], [hh for _, hh in clusters[ci]], False, b=b)}"
            f" B > budget {vmem_bytes} B"
        )
    if fused:
        m = m_c
    under = [v for v in fits if v <= block_h]
    return (max(under) if under else min(fits)), m, db


def resolve_run_plan(
    h: int, point, steps: int | None = None, *, halo: int = 1,
    width: int = 0, words: int = 0, d: int = 1,
    vmem_bytes: int = VMEM_BYTES, b: int | None = None,
    stages=None, fusion: str | None = None,
    dx: int | None = None, halo_x: int = 0,
) -> tuple[int, int, int, bool]:
    """Turn a DSE design point into a concrete
    (block_h, m, steps, double_buffer) plan.

    ``point`` is any object with ``m`` and ``detail['block_rows']`` (a
    :class:`repro.core.dse.DesignPoint` from a TPU sweep); a
    ``detail['double_buffer']`` entry requests the buffer protocol
    (default ping/pong). The blocking is legalized with
    :func:`blocking_plan` — per shard when ``d > 1``, with the
    double-buffered→single-buffered streaming fallback applied; ``steps``
    defaults to one fused launch (m steps) and is rounded down to a
    multiple of m.

    ``b`` is the batch axis (docs/pipeline.md §serve): ``None`` reads
    the point's ``detail['b']`` (1 when absent, matching pre-batch
    points), an explicit value overrides. The batch scales the VMEM
    accounting; it is not returned — it is a launch-shape property the
    caller already holds, not something legalization changes.

    ``stages``/``fusion`` switch to the program-graph legalization
    (docs/pipeline.md §program): ``stages`` is the program's
    ``(words, halo)`` chain and ``fusion`` the partition spec (``None``
    reads the point's ``detail['fusion']``), legalized through
    :func:`program_blocking_plan` instead of the single-core
    :func:`blocking_plan`. The return shape is unchanged — fusion, like
    ``b``, is identity the caller already holds.

    ``dx`` is the mesh column axis (DESIGN.md §15): ``None`` reads the
    point's ``detail['dx']`` (1 when absent, matching pre-mesh points),
    an explicit value overrides; ``halo_x`` is the per-step x stencil
    reach the guard columns must cover.
    """
    detail = getattr(point, "detail", None) or {}
    requested_db = bool(detail.get("double_buffer", True))
    if b is None:
        b = int(detail.get("b", 1))
    if fusion is None:
        fusion = str(detail.get("fusion", "") or "")
    if dx is None:
        dx = int(detail.get("dx", 1))
    if stages is not None:
        block_h, m, double_buffer = program_blocking_plan(
            h, int(point.detail["block_rows"]), int(point.m),
            stages=stages, fusion=fusion, width=width,
            vmem_bytes=vmem_bytes, d=d, double_buffer=requested_db, b=b,
            dx=dx,
        )
    else:
        block_h, m, double_buffer = blocking_plan(
            h, int(point.detail["block_rows"]), int(point.m),
            halo=halo, width=width, words=words, d=d,
            vmem_bytes=vmem_bytes, double_buffer=requested_db, b=b,
            dx=dx, halo_x=halo_x,
        )
    nsteps = m if steps is None else max(m, (steps // m) * m)
    return block_h, m, nsteps, double_buffer


__all__ = [
    "PLAN_FIELDS",
    "RunPlan",
    "VMEM_BYTES",
    "VMEM_DOUBLE_BUFFER",
    "blocking_plan",
    "cluster_vmem_bytes",
    "constraint_violation",
    "legal_block_values",
    "mesh_shape",
    "parse_fusion",
    "program_blocking_plan",
    "resolve_run_plan",
    "shard_height",
    "shard_width",
    "stripe_vmem_bytes",
]
