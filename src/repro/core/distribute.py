"""Multi-device spatial parallelism for stream kernels: the device axis.

The paper's spatial parallelism duplicates pipelines until one chip's
resources (or its memory link) give out. This module is the
production-scale continuation (DESIGN.md §8, docs/pipeline.md
§distribute): duplicate across *chips*. A codegen'd
:class:`~repro.core.codegen.StreamKernel`'s ``(P, H, W)`` grid is
decomposed along y into ``d`` equal shards on a one-axis ring
:class:`~jax.sharding.Mesh`; every device runs the same temporal-blocking
Pallas launch on its own shard under ``shard_map``, and before each fused
m-step launch the ``m·halo`` boundary rows are exchanged with both ring
neighbors via ``lax.ppermute`` (the mesh ring is what makes the global
periodic boundary come out right: shard 0's up-neighbor is shard d-1).

Halo-exchange protocol, per fused launch (DESIGN.md §8):

1. each shard sends its bottom ``m·halo`` rows to the next shard on the
   ring and its top ``m·halo`` rows to the previous one (two
   ``ppermute`` collectives — on TPU these ride the ICI links the DSE
   model's ``t_collective`` term prices);
2. the received rows are padded out to one full ``block_h`` guard block
   per side, giving the extended shard
   ``[pad | up-halo | local | down-halo | pad]``;
3. :func:`repro.kernels.spd_stream.sharded.spd_multistep_halo` advances
   the shard m steps with the exact single-device stripe assembly, the
   guard blocks standing in for the neighbor blocks.

Because step 3 reuses the single-device kernel body and step 1 delivers
exactly the rows the periodic index maps would have read, the sharded
run is **bit-identical** to the single-device kernel for any legal
``d`` — the correctness contract asserted in ``tests/test_distribute.py``
for ``d ∈ {1, 2, 4}`` on both shipped apps.

**Overlapped exchange** (docs/pipeline.md §overlap): only the shard's
two *edge* blocks read exchanged rows — every interior block's stripe
is fully local. When a shard has at least three blocks, the fused
launch is decomposed into an interior launch that needs nothing from
the ``ppermute`` collectives plus two one-block edge launches that do,
so XLA is free to run the halo exchange on the ICI links while the
interior blocks compute. Each block's stripe is assembled from exactly
the same rows either way, which keeps the decomposition bitwise
identical to the monolithic launch (and the sharded run bit-identical
to single-device); shards shorter than three blocks fall back to the
monolithic exchange-then-compute path.

Plans come from the shared legalizer (docs/pipeline.md §legalize) with
per-shard accounting: ``blocking_plan(..., d=d)`` requires ``d | H`` and
tiles the *shard* height. Off-TPU, ``d`` host devices are available under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` with the kernels
in interpret mode — how CI runs the distribution suite.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.sharding import stream_grid_pspec

from .legalize import resolve_run_plan, shard_height

#: Name of the device axis on the ring mesh.
DEVICE_AXIS = "d"

__all__ = [
    "DEVICE_AXIS",
    "ShardedStreamKernel",
    "device_axis_values",
    "ring_mesh",
]


def device_axis_values(max_d: int) -> tuple[int, ...]:
    """Powers of two up to ``max_d`` — the default sweep of the d axis."""
    if max_d < 1:
        raise ValueError(f"max_d must be >= 1, got {max_d}")
    vals = []
    v = 1
    while v <= max_d:
        vals.append(v)
        v *= 2
    return tuple(vals)


def ring_mesh(d: int, devices: Sequence | None = None) -> Mesh:
    """A one-axis mesh of ``d`` devices named :data:`DEVICE_AXIS`.

    The axis order is a ring for ``lax.ppermute``: neighbor exchange
    between shard i and shards (i±1) mod d realizes the grid's periodic
    y boundary across chips. Raises when the platform has fewer than
    ``d`` devices (off-TPU, force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    if d < 1:
        raise ValueError(f"device axis must be >= 1, got d={d}")
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) < d:
        raise ValueError(
            f"need {d} devices for a d={d} ring, have {len(devs)} "
            f"(off-TPU: XLA_FLAGS=--xla_force_host_platform_device_count={d})"
        )
    return Mesh(np.array(devs[:d]), (DEVICE_AXIS,))


class ShardedStreamKernel:
    """A codegen'd stream kernel decomposed across ``d`` devices along y.

    Obtained via :meth:`repro.core.codegen.StreamKernel.sharded`. The
    public surface mirrors the single-device kernel —
    :meth:`run_blocked` / :meth:`run_for_point` — so the explorer times
    single- and multi-device frontier points through one code path
    (docs/pipeline.md §execute); ``d == 1`` simply delegates to the
    wrapped kernel (no mesh, no exchange).
    """

    def __init__(self, kernel, d: int, devices: Sequence | None = None,
                 overlap: bool = True):
        self.kernel = kernel
        self.d = int(d)
        self.halo = kernel.halo
        self.overlap = bool(overlap)
        self.mesh = ring_mesh(self.d, devices) if self.d > 1 else None
        self._jitted: dict = {}

    # ---- the shard-mapped launch loop --------------------------------------

    def _fn(self, steps: int, m: int, block_h: int, double_buffer: bool,
            overlap: bool, interpret: bool):
        """Build (and cache) the jitted shard_map'd run for one plan."""
        key = (steps, m, block_h, double_buffer, overlap, interpret)
        cached = self._jitted.get(key)
        if cached is not None:
            return cached
        from repro.kernels.spd_stream.streaming import (
            spd_multistep_halo_streamed,
            spd_multistep_streamed,
        )

        d, halo = self.d, self.halo
        step_fn = self.kernel._step_fn
        mh = m * halo
        perm_dn = [(i, (i + 1) % d) for i in range(d)]  # bottom rows -> next
        perm_up = [(i, (i - 1) % d) for i in range(d)]  # top rows -> previous

        def local_run(local, scal):
            p, lh, w = local.shape
            nblk = lh // block_h

            def shard_launch(ext, scal):
                return spd_multistep_halo_streamed(
                    step_fn, ext, scal, m=m, block_h=block_h, halo=halo,
                    double_buffer=double_buffer, interpret=interpret,
                )

            def body(_, cur):
                if mh == 0:
                    # Elementwise core: shards never read each other.
                    return spd_multistep_streamed(
                        step_fn, cur, scal, m=m, block_h=block_h, halo=0,
                        double_buffer=double_buffer, interpret=interpret,
                    )
                # Ring halo exchange: receive the up-neighbor's bottom
                # rows and the down-neighbor's top rows (periodic in y
                # because the ring closes).
                up = jax.lax.ppermute(
                    cur[:, lh - mh:, :], DEVICE_AXIS, perm_dn
                )
                dn = jax.lax.ppermute(cur[:, :mh, :], DEVICE_AXIS, perm_up)
                pad = jnp.zeros((p, block_h - mh, w), cur.dtype)
                if overlap and nblk >= 3:
                    # Overlapped exchange (docs/pipeline.md §overlap):
                    # the interior blocks 1..nblk-2 read only local rows
                    # — the shard itself is their guard-extended array —
                    # so their launch carries no data dependence on the
                    # ppermute results and runs while the exchange is in
                    # flight. Only the two one-block edge launches
                    # consume the received rows. Every block's stripe is
                    # assembled from the same rows as the monolithic
                    # launch below, keeping the decomposition (and the
                    # sharded run) bitwise identical.
                    interior = shard_launch(cur, scal)
                    ext_top = jnp.concatenate(
                        [pad, up, cur[:, :2 * block_h, :]], axis=1
                    )
                    ext_bot = jnp.concatenate(
                        [cur[:, lh - 2 * block_h:, :], dn, pad], axis=1
                    )
                    top = shard_launch(ext_top, scal)
                    bot = shard_launch(ext_bot, scal)
                    return jnp.concatenate([top, interior, bot], axis=1)
                ext = jnp.concatenate([pad, up, cur, dn, pad], axis=1)
                return shard_launch(ext, scal)

            return jax.lax.fori_loop(0, steps // m, body, local)

        spec = stream_grid_pspec(DEVICE_AXIS)
        fn = jax.jit(shard_map(
            local_run, mesh=self.mesh, in_specs=(spec, P(None)),
            out_specs=spec, check_vma=False,
        ))
        self._jitted[key] = fn
        return fn

    # ---- launches (mirroring StreamKernel) ---------------------------------

    def run_blocked(self, state, regs: Sequence = (), *, steps: int,
                    m: int, block_h: int, double_buffer: bool = True,
                    overlap: bool | None = None, interpret: bool = True):
        """Advance ``steps`` time steps, halo-exchanging every m steps.

        ``double_buffer`` selects the per-shard streamed launch's buffer
        protocol (docs/pipeline.md §stream); ``overlap`` toggles the
        exchange/compute overlap decomposition (docs/pipeline.md
        §overlap, default: the kernel's construction-time setting).
        """
        if self.d == 1:
            return self.kernel.run_blocked(
                state, regs, steps=steps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        if overlap is None:
            overlap = self.overlap
        p, h, w = state.shape
        local_h = shard_height(h, self.d)
        if local_h % block_h:
            raise ValueError(
                f"shard height {local_h} (h={h} over d={self.d}) must be "
                f"divisible by block_h={block_h}"
            )
        if m * self.halo > block_h:
            raise ValueError(
                f"m*halo={m * self.halo} must be <= block_h={block_h} "
                "(halo source)"
            )
        if steps % m:
            raise ValueError(f"steps={steps} must be a multiple of m={m}")
        fn = self._fn(steps, m, block_h, bool(double_buffer), bool(overlap),
                      interpret)
        return fn(state, self.kernel._scal(regs))

    def run_for_point(self, state, regs: Sequence = (), *, point,
                      steps: int | None = None, interpret: bool = True):
        """Advance the grid using a DSE design point's (block_h, m).

        The point is legalized *per shard* with the shared
        :func:`repro.core.legalize.resolve_run_plan` (``d`` = this
        kernel's shard count). Returns
        ``(result, (block_h, m, double_buffer))``.
        """
        p, h, w = state.shape
        block_h, m, nsteps, double_buffer = resolve_run_plan(
            h, point, steps, halo=self.halo, width=w, words=p, d=self.d,
        )
        out = self.run_blocked(
            state, regs, steps=nsteps, m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )
        return out, (block_h, m, double_buffer)
