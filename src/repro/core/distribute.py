"""Multi-device spatial parallelism for stream kernels: the device mesh.

The paper's spatial parallelism duplicates pipelines until one chip's
resources (or its memory link) give out. This module is the
production-scale continuation (DESIGN.md §8, §15, docs/pipeline.md
§distribute): duplicate across *chips*. A codegen'd
:class:`~repro.core.codegen.StreamKernel`'s ``(P, H, W)`` grid is
decomposed across a 2-D device mesh ``(dy, dx)``: rows split into ``dy``
equal shards on the row axis (the original one-axis ring) and columns
into ``dx`` equal shards on the column axis, ``d = dy·dx`` devices in
total. Every device runs the same temporal-blocking Pallas launch on its
own ``(H/dy, W/dx)`` shard under ``shard_map``, and before each fused
m-step launch the boundary data is exchanged with the mesh neighbors via
``lax.ppermute`` (both axes are rings, which is what makes the global
periodic boundary come out right: shard 0's up-neighbor is shard dy-1,
and column shard 0's left-neighbor is column shard dx-1).

Halo-exchange protocol, per fused launch (DESIGN.md §8 for the row axis,
§15 for the column axis):

1. each shard sends its bottom ``m·halo`` rows to the next row shard and
   its top ``m·halo`` rows to the previous one, and — when ``dx > 1`` —
   its rightmost ``m·halo_x`` columns to the next column shard and its
   leftmost to the previous one (four ``ppermute`` collectives issued
   together, all depending only on the current shard — on TPU these ride
   the ICI links the DSE model's ``t_collective`` term prices, row and
   column volumes separately);
2. a small second hop column-permutes the edges of the received row
   guards to fetch the four ``(m·halo, m·halo_x)`` corner blocks from
   the diagonal neighbors, then the shard is extended to
   ``[left-guard | local | right-guard]`` in x and the row guards padded
   out to one full ``block_h`` guard block per side, giving
   ``[pad | up-halo | local | down-halo | pad]`` over the extended
   width;
3. :func:`repro.kernels.spd_stream.sharded.spd_multistep_halo` (via its
   streamed twin) advances the shard m steps with the exact
   single-device stripe assembly — under ``dx > 1`` the stripe body is
   the kernel's *guarded* variant
   (:meth:`~repro.core.codegen.StreamKernel._step_fn_guarded`), whose x
   stencil reads are non-periodic zero-fill shifts so the guard columns
   supply the neighbor values; the ``m·halo_x`` guard columns go stale
   one stencil reach per application (the same trapezoid as the guard
   rows) and are cropped from the launch output.

Because step 3 reuses the single-device kernel arithmetic and steps 1–2
deliver exactly the rows and columns the periodic index maps / periodic
in-register x shifts would have read, the sharded run is **bit-identical**
to the single-device kernel for any legal mesh — the correctness
contract asserted in ``tests/test_distribute.py`` (1-D ring) and
``tests/test_mesh.py`` (the 2-D mesh matrix).

**Overlapped exchange** (docs/pipeline.md §overlap, DESIGN.md §12, §15):
only the shard's two *edge* row blocks read exchanged rows — every
interior block's stripe is fully local in y. When a shard has at least
three blocks, the fused launch is decomposed into an interior launch
plus two one-block edge launches; the interior launch depends on the
column exchange (every row block spans the full shard width) but not on
the row exchange or the corner hop, so XLA is free to run the row
exchange and corner fetch on the ICI links while the interior blocks
compute — the generalization of the 1-D overlap, where the interior
depended on no collective at all. Each block's stripe is assembled from
exactly the same values either way, which keeps the decomposition
bitwise identical to the monolithic launch (and the sharded run
bit-identical to single-device); shards shorter than three blocks fall
back to the monolithic exchange-then-compute path.

Plans come from the shared legalizer (docs/pipeline.md §legalize) with
per-shard accounting: ``blocking_plan(..., d=d, dx=dx)`` requires
``dy | H`` and ``dx | W`` and tiles the *shard* geometry — the
per-stripe width term drops to ``W/dx`` (plus the guard columns), which
is what lets wide grids legalize larger ``block_h``/``m`` under column
sharding. Off-TPU, the mesh devices are available under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` with the kernels
in interpret mode — how CI runs the distribution and mesh suites.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.sharding import stream_grid_pspec

from .legalize import mesh_shape, resolve_run_plan, shard_height, shard_width

#: Name of the row device axis (the original ring axis).
DEVICE_AXIS = "d"

#: Name of the column device axis of the 2-D mesh (DESIGN.md §15).
DEVICE_AXIS_X = "dx"

__all__ = [
    "DEVICE_AXIS",
    "DEVICE_AXIS_X",
    "ShardedStreamKernel",
    "device_axis_values",
    "device_mesh",
    "mesh_axis_values",
    "ring_mesh",
]


def device_axis_values(max_d: int) -> tuple[int, ...]:
    """Powers of two up to ``max_d`` — the default sweep of the d axis."""
    if max_d < 1:
        raise ValueError(f"max_d must be >= 1, got {max_d}")
    vals = []
    v = 1
    while v <= max_d:
        vals.append(v)
        v *= 2
    return tuple(vals)


def mesh_axis_values(max_d: int) -> tuple[tuple[int, int], ...]:
    """Every power-of-two mesh shape ``(dy, dx)`` with ``dy·dx <= max_d``.

    The mesh-shape enumeration of the device count's factorizations
    (DESIGN.md §15): the searched lattice of spatial decompositions, the
    2-D generalization of :func:`device_axis_values`. ``(d, 1)`` shapes
    are the legacy 1-D rings.
    """
    return tuple(
        (dy, dx)
        for dy in device_axis_values(max_d)
        for dx in device_axis_values(max_d)
        if dy * dx <= max_d
    )


def ring_mesh(d: int, devices: Sequence | None = None) -> Mesh:
    """A one-axis mesh of ``d`` devices named :data:`DEVICE_AXIS`.

    The axis order is a ring for ``lax.ppermute``: neighbor exchange
    between shard i and shards (i±1) mod d realizes the grid's periodic
    y boundary across chips. Raises when the platform has fewer than
    ``d`` devices (off-TPU, force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    if d < 1:
        raise ValueError(f"device axis must be >= 1, got d={d}")
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) < d:
        raise ValueError(
            f"need {d} devices for a d={d} ring, have {len(devs)} "
            f"(off-TPU: XLA_FLAGS=--xla_force_host_platform_device_count={d})"
        )
    return Mesh(np.array(devs[:d]), (DEVICE_AXIS,))


def device_mesh(dy: int, dx: int,
                devices: Sequence | None = None) -> Mesh:
    """A two-axis ``(dy, dx)`` device mesh (DESIGN.md §15).

    Rows shard over :data:`DEVICE_AXIS`, columns over
    :data:`DEVICE_AXIS_X`; both axes are rings for ``lax.ppermute``, so
    the grid's periodic boundary closes across chips in y *and* x.
    Raises when the platform has fewer than ``dy·dx`` devices.
    """
    if dy < 1 or dx < 1:
        raise ValueError(f"mesh axes must be >= 1, got (dy={dy}, dx={dx})")
    d = dy * dx
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) < d:
        raise ValueError(
            f"need {d} devices for a ({dy}, {dx}) mesh, have {len(devs)} "
            f"(off-TPU: XLA_FLAGS=--xla_force_host_platform_device_count={d})"
        )
    return Mesh(
        np.array(devs[:d]).reshape(dy, dx), (DEVICE_AXIS, DEVICE_AXIS_X)
    )


class ShardedStreamKernel:
    """A codegen'd stream kernel decomposed across a ``(dy, dx)`` mesh.

    Obtained via :meth:`repro.core.codegen.StreamKernel.sharded`. The
    public surface mirrors the single-device kernel —
    :meth:`run_blocked` / :meth:`run_for_point` — so the explorer times
    single- and multi-device frontier points through one code path
    (docs/pipeline.md §execute); ``d == 1`` simply delegates to the
    wrapped kernel (no mesh, no exchange). ``d`` is the *total* device
    count and ``dx`` its column factor (``dy = d / dx``, DESIGN.md §15);
    ``dx == 1`` keeps the original 1-D ring path byte-for-byte.
    """

    def __init__(self, kernel, d: int, devices: Sequence | None = None,
                 overlap: bool = True, dx: int = 1):
        self.kernel = kernel
        self.d = int(d)
        self.dy, self.dx = mesh_shape(self.d, dx)
        self.halo = kernel.halo
        self.halo_x = int(getattr(kernel, "halo_x", kernel.halo))
        self.overlap = bool(overlap)
        if self.d == 1:
            self.mesh = None
        elif self.dx == 1:
            self.mesh = ring_mesh(self.d, devices)
        else:
            self.mesh = device_mesh(self.dy, self.dx, devices)
        self._jitted: dict = {}

    # ---- the shard-mapped launch loop --------------------------------------

    def _fn(self, steps: int, m: int, block_h: int, double_buffer: bool,
            overlap: bool, interpret: bool):
        """Build (and cache) the jitted shard_map'd run for one plan."""
        key = (steps, m, block_h, double_buffer, overlap, interpret)
        cached = self._jitted.get(key)
        if cached is not None:
            return cached
        local_run = (
            self._local_run_ring if self.dx == 1 else self._local_run_mesh
        )(steps, m, block_h, double_buffer, overlap, interpret)
        spec = stream_grid_pspec(
            DEVICE_AXIS, axis_x=DEVICE_AXIS_X if self.dx > 1 else None
        )
        fn = jax.jit(shard_map(
            local_run, mesh=self.mesh, in_specs=(spec, P(None)),
            out_specs=spec, check_vma=False,
        ))
        self._jitted[key] = fn
        return fn

    def _local_run_ring(self, steps, m, block_h, double_buffer, overlap,
                        interpret):
        """The 1-D row-ring per-shard loop (DESIGN.md §8) — unchanged
        from the pre-mesh module, so ``dx == 1`` plans lower exactly as
        before."""
        from repro.kernels.spd_stream.streaming import (
            spd_multistep_halo_streamed,
            spd_multistep_streamed,
        )

        d, halo = self.d, self.halo
        step_fn = self.kernel._step_fn
        mh = m * halo
        perm_dn = [(i, (i + 1) % d) for i in range(d)]  # bottom rows -> next
        perm_up = [(i, (i - 1) % d) for i in range(d)]  # top rows -> previous

        def local_run(local, scal):
            p, lh, w = local.shape
            nblk = lh // block_h

            def shard_launch(ext, scal):
                return spd_multistep_halo_streamed(
                    step_fn, ext, scal, m=m, block_h=block_h, halo=halo,
                    double_buffer=double_buffer, interpret=interpret,
                )

            def body(_, cur):
                if mh == 0:
                    # Elementwise core: shards never read each other.
                    return spd_multistep_streamed(
                        step_fn, cur, scal, m=m, block_h=block_h, halo=0,
                        double_buffer=double_buffer, interpret=interpret,
                    )
                # Ring halo exchange: receive the up-neighbor's bottom
                # rows and the down-neighbor's top rows (periodic in y
                # because the ring closes).
                up = jax.lax.ppermute(
                    cur[:, lh - mh:, :], DEVICE_AXIS, perm_dn
                )
                dn = jax.lax.ppermute(cur[:, :mh, :], DEVICE_AXIS, perm_up)
                pad = jnp.zeros((p, block_h - mh, w), cur.dtype)
                if overlap and nblk >= 3:
                    # Overlapped exchange (docs/pipeline.md §overlap):
                    # the interior blocks 1..nblk-2 read only local rows
                    # — the shard itself is their guard-extended array —
                    # so their launch carries no data dependence on the
                    # ppermute results and runs while the exchange is in
                    # flight. Only the two one-block edge launches
                    # consume the received rows. Every block's stripe is
                    # assembled from the same rows as the monolithic
                    # launch below, keeping the decomposition (and the
                    # sharded run) bitwise identical.
                    interior = shard_launch(cur, scal)
                    ext_top = jnp.concatenate(
                        [pad, up, cur[:, :2 * block_h, :]], axis=1
                    )
                    ext_bot = jnp.concatenate(
                        [cur[:, lh - 2 * block_h:, :], dn, pad], axis=1
                    )
                    top = shard_launch(ext_top, scal)
                    bot = shard_launch(ext_bot, scal)
                    return jnp.concatenate([top, interior, bot], axis=1)
                ext = jnp.concatenate([pad, up, cur, dn, pad], axis=1)
                return shard_launch(ext, scal)

            return jax.lax.fori_loop(0, steps // m, body, local)

        return local_run

    def _local_run_mesh(self, steps, m, block_h, double_buffer, overlap,
                        interpret):
        """The 2-D mesh per-shard loop (DESIGN.md §15): column-halo
        exchange + guard columns around the row-ring protocol, with the
        stripe body switched to the kernel's guarded (zero-fill x)
        variant so the guard columns stand in for the periodic x
        wrap."""
        from repro.kernels.spd_stream.streaming import (
            spd_multistep_halo_streamed,
            spd_multistep_streamed,
        )

        dy, halo, halo_x = self.dy, self.halo, self.halo_x
        dx = self.dx
        step_fn = self.kernel._step_fn_guarded
        mh = m * halo
        mhx = m * halo_x
        # Row-ring permutes run over DEVICE_AXIS (per mesh column);
        # column-ring permutes over DEVICE_AXIS_X (per mesh row). A
        # size-1 row axis degenerates to the identity permute, which
        # delivers the shard its *own* boundary rows — exactly the
        # periodic wrap.
        perm_dn = [(i, (i + 1) % dy) for i in range(dy)]
        perm_up = [(i, (i - 1) % dy) for i in range(dy)]
        perm_r = [(j, (j + 1) % dx) for j in range(dx)]  # right cols -> next
        perm_l = [(j, (j - 1) % dx) for j in range(dx)]  # left cols -> prev

        def local_run(local, scal):
            p, lh, w = local.shape
            nblk = lh // block_h

            def shard_launch(ext, scal):
                return spd_multistep_halo_streamed(
                    step_fn, ext, scal, m=m, block_h=block_h, halo=halo,
                    double_buffer=double_buffer, interpret=interpret,
                )

            def exchange_x(cur):
                """[left-guard | local | right-guard] via the dx ring."""
                left = jax.lax.ppermute(
                    cur[:, :, w - mhx:], DEVICE_AXIS_X, perm_r
                )
                right = jax.lax.ppermute(
                    cur[:, :, :mhx], DEVICE_AXIS_X, perm_l
                )
                return jnp.concatenate([left, cur, right], axis=2)

            def body(_, cur):
                if mh == 0 and mhx == 0:
                    # Elementwise core: shards never read each other.
                    return spd_multistep_streamed(
                        step_fn, cur, scal, m=m, block_h=block_h, halo=0,
                        double_buffer=double_buffer, interpret=interpret,
                    )
                if mh == 0:
                    # x-only stencil: column exchange, launch over the
                    # extended width, crop the stale guard columns.
                    out = spd_multistep_streamed(
                        step_fn, exchange_x(cur), scal, m=m,
                        block_h=block_h, halo=0,
                        double_buffer=double_buffer, interpret=interpret,
                    )
                    return out[:, :, mhx:mhx + w]
                # All first-hop collectives depend only on `cur` and are
                # issued together: the row exchange (guard rows at local
                # width) and, when the core reads in x, the column
                # exchange.
                up0 = jax.lax.ppermute(
                    cur[:, lh - mh:, :], DEVICE_AXIS, perm_dn
                )
                dn0 = jax.lax.ppermute(cur[:, :mh, :], DEVICE_AXIS, perm_up)
                if mhx:
                    curx = exchange_x(cur)
                    # Corner second hop (DESIGN.md §15): column-permute
                    # the received row guards' edges, which fetches the
                    # diagonal neighbors' (mh, mhx) corner blocks — the
                    # same values a width-extended row exchange would
                    # have shipped, but only (mh × mhx) elements per
                    # link.
                    ul = jax.lax.ppermute(
                        up0[:, :, w - mhx:], DEVICE_AXIS_X, perm_r
                    )
                    ur = jax.lax.ppermute(
                        up0[:, :, :mhx], DEVICE_AXIS_X, perm_l
                    )
                    dl = jax.lax.ppermute(
                        dn0[:, :, w - mhx:], DEVICE_AXIS_X, perm_r
                    )
                    dr = jax.lax.ppermute(
                        dn0[:, :, :mhx], DEVICE_AXIS_X, perm_l
                    )
                    upx = jnp.concatenate([ul, up0, ur], axis=2)
                    dnx = jnp.concatenate([dl, dn0, dr], axis=2)
                else:
                    curx, upx, dnx = cur, up0, dn0
                wx = w + 2 * mhx
                pad = jnp.zeros((p, block_h - mh, wx), cur.dtype)
                if overlap and nblk >= 3:
                    # Overlap generalization (DESIGN.md §15): the
                    # interior blocks span the full (extended) shard
                    # width, so they depend on the column exchange but
                    # NOT on the row exchange or the corner hop — the
                    # interior launch runs while those are in flight.
                    # Every block's stripe assembles the same values as
                    # the monolithic launch below: bitwise identical.
                    interior = shard_launch(curx, scal)
                    ext_top = jnp.concatenate(
                        [pad, upx, curx[:, :2 * block_h, :]], axis=1
                    )
                    ext_bot = jnp.concatenate(
                        [curx[:, lh - 2 * block_h:, :], dnx, pad], axis=1
                    )
                    top = shard_launch(ext_top, scal)
                    bot = shard_launch(ext_bot, scal)
                    out = jnp.concatenate([top, interior, bot], axis=1)
                else:
                    ext = jnp.concatenate(
                        [pad, upx, curx, dnx, pad], axis=1
                    )
                    out = shard_launch(ext, scal)
                return out[:, :, mhx:mhx + w] if mhx else out

            return jax.lax.fori_loop(0, steps // m, body, local)

        return local_run

    # ---- launches (mirroring StreamKernel) ---------------------------------

    def run_blocked(self, state, regs: Sequence = (), *, steps: int,
                    m: int, block_h: int, double_buffer: bool = True,
                    overlap: bool | None = None, interpret: bool = True):
        """Advance ``steps`` time steps, halo-exchanging every m steps.

        ``double_buffer`` selects the per-shard streamed launch's buffer
        protocol (docs/pipeline.md §stream); ``overlap`` toggles the
        exchange/compute overlap decomposition (docs/pipeline.md
        §overlap, default: the kernel's construction-time setting).
        """
        if self.d == 1:
            return self.kernel.run_blocked(
                state, regs, steps=steps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        if overlap is None:
            overlap = self.overlap
        p, h, w = state.shape
        local_h = shard_height(h, self.dy)
        local_w = shard_width(w, self.dx)
        if local_h % block_h:
            raise ValueError(
                f"shard height {local_h} (h={h} over d={self.dy}) must be "
                f"divisible by block_h={block_h}"
            )
        if m * self.halo > block_h:
            raise ValueError(
                f"m*halo={m * self.halo} must be <= block_h={block_h} "
                "(halo source)"
            )
        if self.dx > 1 and m * self.halo_x > local_w:
            raise ValueError(
                f"m*halo_x={m * self.halo_x} must be <= the shard width "
                f"{local_w} (w={w} over dx={self.dx}; the column guard is "
                "sourced from one neighbor shard per side)"
            )
        if steps % m:
            raise ValueError(f"steps={steps} must be a multiple of m={m}")
        fn = self._fn(steps, m, block_h, bool(double_buffer), bool(overlap),
                      interpret)
        return fn(state, self.kernel._scal(regs))

    def run_for_point(self, state, regs: Sequence = (), *, point,
                      steps: int | None = None, interpret: bool = True):
        """Advance the grid using a DSE design point's (block_h, m).

        The point is legalized *per shard* with the shared
        :func:`repro.core.legalize.resolve_run_plan` (``d``/``dx`` =
        this kernel's mesh shape, DESIGN.md §15). Returns
        ``(result, (block_h, m, double_buffer))``.
        """
        p, h, w = state.shape
        block_h, m, nsteps, double_buffer = resolve_run_plan(
            h, point, steps, halo=self.halo, width=w, words=p, d=self.d,
            dx=self.dx, halo_x=self.halo_x,
        )
        out = self.run_blocked(
            state, regs, steps=nsteps, m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )
        return out, (block_h, m, double_buffer)
