"""Honest model↔measurement loop: timing, calibration, measurement cache.

The paper's workflow only means something when the analytic (n, m) model
is compared against *measured* performance of the platform actually
running (docs/pipeline.md §measure, DESIGN.md §9). Off-TPU the Pallas
kernels execute under the interpreter at host speed, so diffing them
against the TPU-v5e roofline produced ``rel_error ≈ 0.9999`` on every
point — numerically meaningless. This module makes the loop honest,
in three pieces:

1. **Timing harness** — :func:`time_run`: warm-up calls are separated
   from measured reps (compile/trace time never pollutes the sample),
   *every* rep is synchronized with ``jax.block_until_ready`` (JAX
   dispatch is async; blocking only the last rep under-counts wall
   time), the reported wall time is the median of the reps (robust to
   scheduler noise), and the timer's own overhead — measured from
   back-to-back ``perf_counter`` pairs — is subtracted.

2. **Backend calibration** — micro-benchmarks measure the live
   platform's effective elementwise f32 throughput
   (:func:`measure_elementwise_gflops`, a generated FMA-chain SPD core
   run through the real §codegen kernel path) and memory bandwidth
   (:func:`measure_memory_bandwidth_gbs`), producing a
   :class:`BackendCalibration` whose :meth:`~BackendCalibration.target`
   is a :class:`~repro.core.dse.TPUTarget` with *measured* constants.
   :func:`calibrate_execution` anchors the compute constant through the
   same ``run_factory`` the explorer times (the honest form: interpreter
   throughput on CPU, chip throughput on TPU), over a small probe set
   spanning the lattice's fused-step range (:data:`PROBE_PLANS`), so
   predicted-vs-measured becomes a real model-fidelity signal — the
   model must still predict how performance moves across the
   (block_h, m, d) lattice from those anchors.

3. **Measurement cache** — :class:`MeasurementCache`: a persistent
   on-disk store keyed by (core fingerprint, grid shape, run plan,
   backend, interpret, reps, warmup), so repeated sweeps and benchmark
   runs skip recompile+retime. :func:`core_fingerprint` derives a
   stable content hash from the SPD core's DFG structure; a changed
   core, plan, or backend is a changed key, never a stale hit.

``Explorer.execute_frontier`` threads all three (docs/pipeline.md
§execute): it times every frontier point through :func:`measured_run`
and reports rel_error against the calibrated prediction.
"""

from __future__ import annotations

import hashlib
import json
import os
import statistics
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .dse import StreamWorkload, TPUModel, TPUTarget
from .legalize import blocking_plan

__all__ = [
    "BackendCalibration",
    "MeasurementCache",
    "PROBE_PLANS",
    "Timing",
    "calibrate_backend",
    "calibrate_execution",
    "code_salt",
    "core_fingerprint",
    "default_cache_path",
    "measure_elementwise_gflops",
    "measure_memory_bandwidth_gbs",
    "measured_run",
    "resolve_cache",
    "time_run",
    "timer_overhead",
]


# --------------------------------------------------------------------------
# Timing harness
# --------------------------------------------------------------------------


def timer_overhead(samples: int = 64) -> float:
    """Median cost of one timed-region bracket (two ``perf_counter`` calls).

    Subtracted from every measured rep so sub-millisecond kernels are not
    inflated by the clock itself.
    """
    deltas = []
    for _ in range(max(8, samples)):
        t0 = time.perf_counter()
        t1 = time.perf_counter()
        deltas.append(t1 - t0)
    return statistics.median(deltas)


@dataclass(frozen=True)
class Timing:
    """One timed measurement: median-of-reps wall time plus the raw sample."""

    wall_s: float  # median per-rep wall time, timer overhead subtracted
    times_s: tuple  # every measured rep (post-subtraction), in order
    reps: int
    warmup: int
    overhead_s: float  # per-bracket timer overhead that was subtracted

    @property
    def total_s(self) -> float:
        return float(sum(self.times_s))


def time_run(
    fn: Callable[[], object],
    *,
    reps: int = 3,
    warmup: int = 1,
    block: Callable | None = None,
) -> Timing:
    """Time ``fn`` honestly: warm up, block every rep, take the median.

    * ``warmup`` un-timed calls run (and are blocked) first, so
      compilation/tracing never lands in the measured sample;
    * each of the ``reps`` measured calls is individually synchronized
      with ``block`` (default ``jax.block_until_ready``) *inside* its
      timed region — JAX dispatch is asynchronous, and blocking only the
      final dispatch lets reps overlap and under-counts wall time;
    * the reported ``wall_s`` is the median rep, with the timer's own
      bracket overhead (:func:`timer_overhead`) subtracted and the
      result floored at 1 ns so downstream rates stay finite.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0, got {warmup}")
    if block is None:
        block = jax.block_until_ready
    for _ in range(warmup):
        block(fn())
    overhead = timer_overhead()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        block(fn())
        t1 = time.perf_counter()
        times.append(max(t1 - t0 - overhead, 1e-9))
    return Timing(
        wall_s=max(statistics.median(times), 1e-9),
        times_s=tuple(times),
        reps=reps,
        warmup=warmup,
        overhead_s=overhead,
    )


# --------------------------------------------------------------------------
# Core fingerprints (cache keys that survive process restarts)
# --------------------------------------------------------------------------


def _core_struct(core) -> dict:
    """A canonical, JSON-stable description of a DFG ``Core``."""
    return {
        "name": core.name,
        "main_in": [list(i.ports) for i in core.main_in],
        "main_out": [list(i.ports) for i in core.main_out],
        "brch_in": [list(i.ports) for i in core.brch_in],
        "brch_out": [list(i.ports) for i in core.brch_out],
        "regs": list(core.regs),
        "params": {k: float(v) for k, v in sorted(core.params.items())},
        "drcts": [[list(d), list(s)] for d, s in core.drcts],
        "nodes": [
            [
                n.name,
                n.kind,
                list(n.inputs),
                list(n.outputs),
                repr(n.expr),
                n.module,
                n.delay,
                list(n.params),
            ]
            for n in core.nodes
        ],
    }


def backend_descriptor() -> str:
    """Cache-key identity of the live platform: backend *and* device kind.

    ``jax.default_backend()`` alone says only "cpu"/"tpu" — two TPU
    generations (or two different machines sharing a cache directory)
    would alias onto one key and serve each other's timings.
    """
    kind = "?"
    try:
        devs = jax.devices()
        if devs:
            kind = getattr(devs[0], "device_kind", "?") or "?"
    except RuntimeError:  # no backend initialized: keep the bare name
        pass
    return f"{jax.default_backend()}/{kind}"


def core_fingerprint(obj) -> str:
    """Stable content hash of an SPD core (any pipeline stage of it).

    Accepts a ``StreamKernel``, ``CompiledCore``, DFG ``Core``, or a
    plain string tag (for hand-written back ends with no SPD source,
    e.g. ``lbm_stream``). Two structurally identical cores fingerprint
    identically across processes; any change to the graph changes the
    key, so the measurement cache can never serve a stale core's time.
    """
    if isinstance(obj, str):
        return "tag:" + obj
    compiled = getattr(obj, "compiled", obj)  # StreamKernel -> CompiledCore
    core = getattr(compiled, "core", compiled)  # CompiledCore -> Core
    blob = json.dumps(_core_struct(core), sort_keys=True).encode()
    return "spd:" + hashlib.sha256(blob).hexdigest()[:16]


# --------------------------------------------------------------------------
# Persistent measurement cache
# --------------------------------------------------------------------------


def default_cache_path() -> str:
    """``$REPRO_MEASURE_CACHE`` or ``~/.cache/repro/measure-cache.json``."""
    env = os.environ.get("REPRO_MEASURE_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "measure-cache.json"
    )


#: Source files whose implementation determines a measurement's wall
#: time even when the SPD core's DFG (the fingerprint) is unchanged:
#: the kernel launchers and the stripe/shard lowerings.
_SALT_MODULES = (
    # the harness itself: what a rep includes and what a record stores
    "repro.core.measure",
    # graph evaluation: the per-element work the kernels execute
    "repro.core.compiler",
    "repro.core.dfg",
    "repro.core.library",
    # stripe lowering + launches
    "repro.core.codegen",
    "repro.core.program",
    "repro.core.distribute",
    "repro.kernels.spd_stream.spd_stream",
    "repro.kernels.spd_stream.sharded",
    "repro.kernels.spd_stream.streaming",
    "repro.kernels.spd_stream.ops",
    "repro.kernels.lbm_stream.lbm_stream",
    "repro.kernels.lbm_stream.ops",
)

_CODE_SALT: list[str] = []  # computed once per process


def code_salt() -> str:
    """Hash of the jax version + kernel-implementation sources.

    Folded into every cache key: a kernel optimization or a jax upgrade
    changes measured wall times without changing any core's DFG, so it
    must invalidate the cache — otherwise the trajectory file would
    silently record the *old* platform's timings as fresh measurements.
    """
    if not _CODE_SALT:
        h = hashlib.sha256()
        h.update(jax.__version__.encode())
        import importlib.util

        for mod in _SALT_MODULES:
            try:
                spec = importlib.util.find_spec(mod)
                if spec and spec.origin:
                    with open(spec.origin, "rb") as fh:
                        h.update(fh.read())
            except (ImportError, OSError):  # absent module: salt w/o it
                h.update(mod.encode())
        _CODE_SALT.append(h.hexdigest()[:12])
    return _CODE_SALT[0]


class MeasurementCache:
    """On-disk store of timed measurements, keyed by what determines them.

    A key is the SHA-256 of (core fingerprint, grid shape, run plan
    ``(block_h, m, steps, d, double_buffer)``, backend, interpret, reps,
    warmup) plus
    the :func:`code_salt` — the jax version and the kernel
    implementation sources — so neither a changed core *nor* a changed
    kernel/runtime can ever serve a stale timing (see :meth:`make_key`).
    Values are the :class:`Timing` facts plus the human-readable key
    fields, so the cache file doubles as a measurement log. Writes are
    atomic (temp file + ``os.replace``) and re-merge the on-disk state
    first, so concurrent benchmark runs do not clobber each other's
    entries. ``hits``/``misses`` count this process's lookups (reported
    by ``benchmarks/dse_sweep.py``).
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = os.fspath(path) if path is not None else default_cache_path()
        self.hits = 0
        self.misses = 0
        self._data: dict[str, dict] = self._load()

    # ---- keys --------------------------------------------------------------

    @staticmethod
    def make_key(
        fingerprint: str,
        grid_shape: Sequence[int],
        plan: Sequence[int],
        backend: str,
        interpret: bool,
        reps: int,
        warmup: int,
    ) -> str:
        """Deterministic key over everything a measurement depends on."""
        fields = {
            "fingerprint": fingerprint,
            "grid_shape": [int(v) for v in grid_shape],
            # (block_h, m, steps, d[, db, b[, fusion]]) — the trailing
            # fusion spec is a string (docs/pipeline.md §program)
            "plan": [v if isinstance(v, str) else int(v) for v in plan],
            "backend": backend,
            "interpret": bool(interpret),
            "reps": int(reps),
            "warmup": int(warmup),
            "code": code_salt(),  # kernel sources + jax version
        }
        blob = json.dumps(fields, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:24]

    # ---- lookups -----------------------------------------------------------

    def get(self, key: str) -> dict | None:
        rec = self._data.get(key)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def peek(self, key: str) -> dict | None:
        """Like :meth:`get` but without touching the hit/miss counters.

        Surrogate search strategies (docs/pipeline.md §study) scan every
        candidate's key to warm-start from prior measurements; those
        scans are bookkeeping, not lookups, and must not distort the
        stats the benchmarks report.
        """
        return self._data.get(key)

    def put(self, key: str, record: dict) -> None:
        self._data[key] = dict(record)
        self._flush()

    def stats(self) -> dict:
        return {
            "path": self.path,
            "entries": len(self._data),
            "hits": self.hits,
            "misses": self.misses,
        }

    def __len__(self) -> int:
        return len(self._data)

    # ---- persistence -------------------------------------------------------

    def _load(self) -> dict[str, dict]:
        try:
            with open(self.path, encoding="utf-8") as fh:
                data = json.load(fh)
            return data if isinstance(data, dict) else {}
        except (OSError, ValueError):
            return {}

    def _flush(self) -> None:
        # Full re-load + rewrite per put() is deliberate: a measurement
        # costs seconds, a rewrite of this file costs well under a
        # millisecond at realistic cache sizes, and flushing eagerly
        # means a crashed or interrupted sweep keeps everything it paid
        # for while concurrent runs merge instead of clobbering.
        directory = os.path.dirname(self.path) or "."
        os.makedirs(directory, exist_ok=True)
        # Serialize the load→merge→replace against concurrent writers: two
        # processes flushing between each other's load and replace would
        # otherwise drop whichever record landed in the window. Study
        # resume (docs/pipeline.md §study) leans on this contract, so it
        # is a lock, not a race we tolerate. Best-effort: platforms or
        # filesystems without flock fall back to the unlocked merge.
        lock_fh = None
        try:
            import fcntl

            lock_fh = open(f"{self.path}.lock", "w")
            fcntl.flock(lock_fh, fcntl.LOCK_EX)
        except (ImportError, OSError):
            lock_fh = None
        try:
            merged = self._load()  # re-merge concurrent writers, newest wins
            merged.update(self._data)
            self._data = merged
            tmp = f"{self.path}.tmp.{os.getpid()}"
            try:
                with open(tmp, "w", encoding="utf-8") as fh:
                    json.dump(merged, fh, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except OSError:
                # A read-only cache location must never fail the measurement.
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        finally:
            if lock_fh is not None:
                try:
                    import fcntl

                    fcntl.flock(lock_fh, fcntl.LOCK_UN)
                except (ImportError, OSError):
                    pass
                lock_fh.close()


def resolve_cache(policy) -> MeasurementCache | None:
    """Normalize an ``execute_frontier`` cache policy argument.

    ``None``/``False`` → no caching; ``True`` → the default on-disk
    cache (:func:`default_cache_path`); a path → a cache at that path;
    a :class:`MeasurementCache` → itself (lets callers read hit/miss
    stats afterwards).
    """
    if policy is None or policy is False:
        return None
    if policy is True:
        return MeasurementCache()
    if isinstance(policy, MeasurementCache):
        return policy
    return MeasurementCache(policy)


def measured_run(
    run: Callable[[], object],
    *,
    key: str | None = None,
    cache: MeasurementCache | None = None,
    reps: int = 3,
    warmup: int = 1,
) -> tuple[float, bool]:
    """Time ``run`` through the cache: ``(wall_s, came_from_cache)``.

    With a cache and a key, a prior measurement under the identical key
    is returned without recompiling or retiming; otherwise the run is
    timed with :func:`time_run` and the result stored.
    """
    if cache is not None and key is not None:
        rec = cache.get(key)
        if rec is not None:
            return float(rec["wall_s"]), True
    timing = time_run(run, reps=reps, warmup=warmup)
    if cache is not None and key is not None:
        cache.put(
            key,
            {
                "wall_s": timing.wall_s,
                "times_s": list(timing.times_s),
                "reps": timing.reps,
                "warmup": timing.warmup,
                "overhead_s": timing.overhead_s,
            },
        )
    return timing.wall_s, False


# --------------------------------------------------------------------------
# Backend calibration
# --------------------------------------------------------------------------


#: Per-process memo of bandwidth probes, keyed by (backend, mbytes,
#: reps, warmup): platform bandwidth does not drift within one process,
#: and re-probing on every calibrated execute_frontier call would pay a
#: fresh jit + timed passes each time.
_MEM_PROBE_MEMO: dict[tuple, float] = {}


def measure_memory_bandwidth_gbs(
    mbytes: int = 32, *, reps: int = 3, warmup: int = 1, memo: bool = True
) -> float:
    """Effective f32 streaming bandwidth (GB/s) of the live backend.

    Times a jitted elementwise pass over an ``mbytes`` f32 buffer — one
    read + one write per element, the same traffic shape as a stream
    kernel's HBM round-trip — and reports moved bytes / median wall.
    Memoized per process (pass ``memo=False`` to force a fresh probe);
    deliberately *not* persisted to the on-disk measurement cache, so
    every session re-measures the platform it actually has.
    """
    key = (jax.default_backend(), mbytes, reps, warmup)
    if memo and key in _MEM_PROBE_MEMO:
        return _MEM_PROBE_MEMO[key]
    n = max(1, (mbytes * 2**20) // 4)
    x = jnp.full((n,), 1.5, jnp.float32)
    f = jax.jit(lambda a: a + 1.0)
    timing = time_run(lambda: f(x), reps=reps, warmup=warmup)
    bw = 2 * n * 4 / timing.wall_s / 1e9
    if memo:
        _MEM_PROBE_MEMO[key] = bw
    return bw


def _fma_chain_spd(chain: int) -> str:
    """SPD source of a ``chain``-deep FMA pipeline (2·chain flops/elem)."""
    lines = [
        "Name CalibChain;",
        "Main_In {mi::u};",
        "Main_Out {mo::v};",
        "Append_Reg {rg::a};",
    ]
    prev = "u"
    for i in range(chain):
        out = "v" if i == chain - 1 else f"t{i}"
        lines.append(f"EQU N{i}, {out} = {prev}*a + 0.125;")
        prev = out
    return "\n".join(lines)


def measure_elementwise_gflops(
    interpret: bool = True,
    *,
    chain: int = 32,
    shape: tuple[int, int] = (128, 128),
    m: int = 1,
    block_h: int = 32,
    reps: int = 3,
    warmup: int = 1,
) -> float:
    """Effective elementwise f32 throughput (GFLOP/s) of the live backend.

    Compiles a generated ``chain``-deep FMA SPD core through the real
    §codegen path and times its temporal-blocking Pallas launch in the
    requested mode — so the number reflects the execution path the
    explorer actually measures (the Pallas interpreter on CPU, the
    compiled kernel on TPU), not a synthetic numpy loop.
    """
    from .compiler import Registry
    from .spd import parse_spd

    h, w = shape
    kern = Registry().compile(parse_spd(_fma_chain_spd(chain))).stream_kernel()
    state = jnp.full((1, h, w), 0.5, jnp.float32)
    bh, mm, _ = blocking_plan(h, block_h, m, halo=kern.halo, width=w, words=1)
    timing = time_run(
        lambda: kern.run_blocked(
            state, (0.997,), steps=mm, m=mm, block_h=bh, interpret=interpret
        ),
        reps=reps,
        warmup=warmup,
    )
    flops = h * w * mm * 2 * chain  # halo = 0: no recompute term
    return flops / timing.wall_s / 1e9


@dataclass(frozen=True)
class BackendCalibration:
    """Measured constants of the platform actually running.

    ``elem_gflops`` / ``mem_gbs`` are the single-device effective
    elementwise f32 throughput and memory bandwidth; ``by_d`` optionally
    carries measured *aggregate* throughput per device-axis value (on a
    host with forced devices, d "chips" share one CPU, so aggregate
    throughput is measured, not assumed d-linear). :meth:`target` folds
    the measurements into a :class:`~repro.core.dse.TPUTarget`, which
    :meth:`repro.core.dse.TPUModel.calibrated` wraps into a model — the
    calibrated side of the predicted-vs-measured diff
    (docs/pipeline.md §measure).
    """

    backend: str
    interpret: bool
    elem_gflops: float
    mem_gbs: float
    by_d: tuple = ()  # ((d, aggregate_gflops), ...)
    detail: Mapping = field(default_factory=dict)

    def gflops(self, d: int = 1) -> float:
        """Measured aggregate throughput across ``d`` devices.

        Falls back to the single-device figure when ``d`` was not probed
        — deliberately conservative: unprobed scaling is not assumed.
        """
        return float(dict(self.by_d).get(int(d), self.elem_gflops))

    def target(self, d: int = 1, base: TPUTarget | None = None) -> TPUTarget:
        """A :class:`TPUTarget` carrying this calibration's constants.

        Per-chip compute is aggregate/d so the model's ``× d`` scaling
        reproduces the *measured* aggregate for that device count.
        Bandwidth divides by ``d`` only when the "devices" share one
        host memory system (CPU backend / interpret mode — forced host
        devices split one machine's bandwidth); on real accelerators
        the probe measured a single chip's HBM and every chip has its
        own, so the per-chip constant stands.
        """
        base = base or TPUTarget()
        d = max(1, int(d))
        mode = ":interpret" if self.interpret else ""
        shared_memory = self.interpret or self.backend == "cpu"
        return replace(
            base,
            name=f"{base.name}+measured[{self.backend}{mode}]",
            vpu_f32_tflops=self.gflops(d) / d / 1e3,
            hbm_gbs=self.mem_gbs / d if shared_memory else self.mem_gbs,
        )

    def model(self, d: int = 1, base: TPUTarget | None = None) -> TPUModel:
        """Shorthand for ``TPUModel.calibrated(self, d=d, base=base)``."""
        return TPUModel.calibrated(self, d=d, base=base)


def calibrate_backend(
    interpret: bool = True,
    *,
    chain: int = 32,
    shape: tuple[int, int] = (128, 128),
    mem_mbytes: int = 32,
    reps: int = 3,
    warmup: int = 1,
) -> BackendCalibration:
    """Generic platform calibration from the two micro-benchmarks.

    The compute constant comes from the FMA-chain probe kernel
    (:func:`measure_elementwise_gflops`), the bandwidth constant from
    :func:`measure_memory_bandwidth_gbs` — no application core needed.
    For per-kernel anchoring inside the explorer's measurement loop use
    :func:`calibrate_execution`.
    """
    gflops = measure_elementwise_gflops(
        interpret, chain=chain, shape=shape, reps=reps, warmup=warmup
    )
    mem = measure_memory_bandwidth_gbs(mem_mbytes, reps=reps, warmup=warmup)
    return BackendCalibration(
        backend=jax.default_backend(),
        interpret=interpret,
        elem_gflops=gflops,
        mem_gbs=mem,
        by_d=((1, gflops),),
        detail={"chain": chain, "shape": list(shape), "mem_mbytes": mem_mbytes},
    )


#: Default calibration probe set, as (block_h, m) pairs. Two anchors
#: spanning the lattice's fused-step range: interpret-mode cost has a
#: per-launch/per-application overhead component the roofline does not
#: model, so a single anchor at one m systematically mis-prices points
#: at another. Each probe legalizes like any frontier point; the
#: anchors' geometric mean becomes the platform's effective throughput.
PROBE_PLANS: tuple = ((16, 4), (64, 8))


def calibrate_execution(
    run_factory: Callable,
    *,
    workload: StreamWorkload,
    grid_shape: tuple[int, int],
    halo: int | None = None,
    width: int = 0,
    words: int = 0,
    d_values: Sequence[int] = (1,),
    probe_plans: Sequence[tuple[int, int]] = PROBE_PLANS,
    interpret: bool = True,
    reps: int = 3,
    warmup: int = 1,
    cache: MeasurementCache | None = None,
    fingerprint: str | None = None,
    mem_gbs: float | None = None,
) -> BackendCalibration:
    """Anchor the compute constant through the *actual* execution path.

    Runs a small probe set — ``probe_plans`` as (block_h, m) requests,
    each legalized exactly like a frontier point (duplicates after
    legalization collapse) — through the same ``run_factory`` the
    explorer times, per requested device-axis value, and backs the
    platform's effective elementwise throughput out of the wall times
    (counting halo-recomputed sites: that is work the backend really
    performed; the anchor is the geometric mean over the probe set).
    The model then has to predict every frontier point from these
    anchors, which is what makes the reported rel_error a model-fidelity
    signal rather than a host-vs-TPU speed ratio
    (docs/pipeline.md §measure).

    Probe measurements go through the same :class:`MeasurementCache`
    key space as frontier runs, so repeated sweeps skip re-calibration
    and a probe plan that legalizes onto a frontier point's plan reuses
    its timing outright.
    """
    h, w = grid_shape
    halo = workload.halo if halo is None else halo
    backend = backend_descriptor()
    by_d = []
    for d in d_values:
        d = int(d)
        plans = []
        for req_bh, req_m in probe_plans:
            try:
                bh, m, db = blocking_plan(
                    h, req_bh, req_m, halo=halo, width=width, words=words,
                    d=d,
                )
            except ValueError:
                continue  # this anchor has no legal plan here (e.g. a
                #           VMEM-tight grid); the others still calibrate
            if (bh, m, db) not in plans:
                plans.append((bh, m, db))
        rates = []
        for bh, m, db in plans:
            nsteps = m
            try:
                run = run_factory(nsteps, m, bh, d, db)
            except TypeError:  # legacy 4-arg factories predate the knob
                run = run_factory(nsteps, m, bh, d)
            if run is None:
                continue
            # Same key space as frontier runs: (fingerprint, grid,
            # plan, ...) fully determine a measurement, so a probe plan
            # that coincides with a frontier point shares its timing
            # (no duplicate compile+retime on a cold run).
            key = None
            if cache is not None and fingerprint is not None:
                key = MeasurementCache.make_key(
                    fingerprint, (h, w), (bh, m, nsteps, d, int(db)),
                    backend, interpret, reps, warmup,
                )
            wall, _ = measured_run(
                run, key=key, cache=cache, reps=reps, warmup=warmup
            )
            useful = bh / (bh + 2 * m * halo) if halo else 1.0
            computed_flops = h * w * nsteps * workload.flops_per_elem / useful
            rates.append(computed_flops / wall / 1e9)
        if rates:
            by_d.append((d, float(statistics.geometric_mean(rates))))
    if not by_d:
        raise ValueError(
            "calibrate_execution: run_factory produced no runnable probe "
            f"for any d in {tuple(d_values)}"
        )
    if mem_gbs is None:
        mem_gbs = measure_memory_bandwidth_gbs(reps=reps, warmup=warmup)
    anchor = dict(by_d)
    return BackendCalibration(
        backend=backend,
        interpret=interpret,
        elem_gflops=anchor.get(1, by_d[0][1]),
        mem_gbs=float(mem_gbs),
        by_d=tuple(by_d),
        detail={
            "probe_plans": [list(p) for p in probe_plans],
            "grid_shape": [h, w],
            "flops_per_elem": workload.flops_per_elem,
        },
    )
