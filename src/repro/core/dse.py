"""Design-space exploration over (n, m) = (spatial, temporal) parallelism.

Two targets are modeled:

* :class:`FPGAModel` — the paper's platform (Stratix V 5SGXEA7 + DDR3),
  calibrated against Table III. Reproduces peak ``P(n,m) = n*m*NFlops*F``
  (Eq. 10), the bandwidth-limited utilization ``u(n) = min(1, BWeff/(n*BWpipe))``,
  the resource constraints (DSP/ALM/BRAM), and a power model fit to the six
  measured configurations, from which perf/W and the paper's winning
  configuration (n, m) = (1, 4) fall out.

* :class:`TPUModel` — the adapted platform (TPU v5e). Temporal parallelism
  becomes *temporal blocking* (m fused time-steps per HBM round-trip with an
  m-deep VMEM halo, see ``repro.kernels.lbm_stream``); spatial parallelism
  becomes parallel grid blocks / chips. The model predicts the roofline
  fraction per (block, m) point under VMEM-capacity and halo-overhead
  constraints.

All numbers flow from a :class:`StreamWorkload`, which is produced directly
from a compiled SPD core's :class:`~repro.core.compiler.HardwareReport`.

Both models expose two evaluation surfaces:

* ``evaluate(w, ...)`` — one scalar design point, returning a rich
  :class:`DesignPoint` (limits, detail dict).
* ``evaluate_batch(w, ...)`` — the same arithmetic over *arrays* of
  coordinates, returning a dict of NumPy arrays with no per-point Python
  loops. ``repro.core.explorer`` sweeps whole (n, m, block) lattices
  through this path and extracts Pareto frontiers from the result
  (DESIGN.md §5); the scalar and batched paths are asserted equal
  point-for-point in ``tests/test_explorer.py``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from .legalize import (
    VMEM_BYTES,
    cluster_vmem_bytes,
    parse_fusion,
    stripe_vmem_bytes,
)

# --------------------------------------------------------------------------
# Workload description
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StreamWorkload:
    """One iterative stream computation, per pipeline (n=1, m=1)."""

    name: str
    flops_per_elem: int  # N_Flops (paper: 131)
    words_in: int  # main-stream words read per element (paper: 10)
    words_out: int  # main-stream words written per element (paper: 10)
    depth: int  # pipeline depth d of one PE (paper: 855 for x1)
    buffer_bits: int  # stencil buffer bits of one PE
    elems: int  # stream length T (paper grid: 720*300)
    grid_w: int = 0  # row width (2-D workloads; drives lane-shared buffers)
    # Per-step stencil reach in rows (repro.core.codegen inference; 1 for
    # LBM, 0 for elementwise cores). The TPU model's stripe residency and
    # halo-recompute terms use it, so the model and the kernel legalizer
    # (repro.core.legalize) account the same stripe geometry.
    halo: int = 1
    # Per-step stencil reach in *columns* (x). ``-1`` — the default —
    # means "same as ``halo``", which is exact for every shipped core
    # (the diffusion 5-point and LBM D2Q9 stencils are symmetric), so
    # existing workload constructions stay valid. The 2-D mesh terms
    # (DESIGN.md §15) read it through :attr:`stencil_halo_x`.
    halo_x: int = -1
    # Stream-program stage chain (docs/pipeline.md §program, DESIGN.md
    # §14): per-stage ``(flops_per_elem, words, halo)`` triples in chain
    # order, produced by ``StreamProgram.workload``. Empty for a
    # single-core workload. When present, ``TPUModel.evaluate(...,
    # fusion=)`` prices fusion partitions cluster by cluster — the
    # totals above stay the fully-fused aggregates.
    stages: tuple = ()

    @classmethod
    def from_report(cls, report, elems: int, grid_w: int = 0) -> "StreamWorkload":
        return cls(
            name=report.name,
            flops_per_elem=report.flops,
            words_in=report.stream_in_words,
            words_out=report.stream_out_words,
            depth=report.depth,
            buffer_bits=report.buffer_bits,
            elems=elems,
            grid_w=grid_w,
            halo=getattr(report, "halo", 1),
            halo_x=int(getattr(report, "halo_x", -1)),
        )

    @property
    def stencil_halo_x(self) -> int:
        """Effective column stencil reach (``halo_x``, falling back to
        the row reach ``halo`` when unset — DESIGN.md §15)."""
        return self.halo_x if self.halo_x >= 0 else self.halo

    def fusion_clusters(self, fusion: str = "") -> list[dict]:
        """Partition ``stages`` into fusion clusters (docs/pipeline.md
        §program): each cluster dict carries its aggregate ``flops``,
        member ``words``/``halos`` lists and the *composed* halo (the
        sum of member halos — the legalizer's rule). Raises if the
        workload carries no stage chain."""
        if not self.stages:
            raise ValueError(
                f"workload {self.name!r} has no program stages; "
                "fusion pricing needs StreamProgram.workload(...)"
            )
        sizes = parse_fusion(fusion, len(self.stages))
        out, lo = [], 0
        for s in sizes:
            members = self.stages[lo:lo + s]
            lo += s
            out.append({
                "flops": sum(int(f) for f, _, _ in members),
                "words": [int(w) for _, w, _ in members],
                "halos": [int(h) for _, _, h in members],
                "halo": sum(int(h) for _, _, h in members),
            })
        return out


@dataclass
class DesignPoint:
    n: int
    m: int
    feasible: bool
    limits: list[str] = field(default_factory=list)
    peak_gflops: float = 0.0
    utilization: float = 0.0
    sustained_gflops: float = 0.0
    power_w: float = 0.0
    perf_per_watt: float = 0.0
    detail: dict = field(default_factory=dict)

    def key(self) -> tuple[int, int]:
        return (self.n, self.m)


# --------------------------------------------------------------------------
# FPGA target (paper platform), Table III-calibrated
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class FPGATarget:
    name: str = "stratix-v-5sgxea7"
    alms: int = 234_720
    regs: int = 938_880
    bram_bits: int = 52_428_800
    dsps: int = 256
    freq_ghz: float = 0.18
    # DDR3-800 x 512bit: 12.8 GB/s nominal per direction; the measured
    # effective per-direction bandwidth backed out of Table III's
    # utilizations (0.557*2*7.2 = 8.02, 0.279*4*7.2 = 8.03) is ~8.02 GB/s.
    bw_nominal_gbs: float = 12.8
    bw_eff_gbs: float = 8.02
    # SoC peripherals (PCIe, DDR3 controllers, DMA) from Table III.
    soc_alms: int = 54_997
    soc_regs: int = 87_163
    soc_bram_bits: int = 3_110_753
    soc_dsps: int = 0
    # Per-operator synthesis cost model (ALMs / DSPs), loosely calibrated to
    # the paper's per-pipeline footprint (~31.8 kALM, 48 DSP for 131 ops).
    alm_per_add: float = 380.0
    alm_per_mul: float = 75.0
    alm_per_div: float = 3_000.0
    alm_per_ctrl: float = 2_000.0  # per-PE stream control overhead
    dsp_per_mul: float = 0.8


# Table III (measured) — kept as data both for calibration and for the
# reproduction benchmark to diff against.
TABLE3_MEASURED = {
    # (n, m): (ALMs, Regs, BRAM bits, DSPs, utilization, GFlop/s, W, GFlop/sW)
    (1, 1): (34_310, 62_145, 573_370, 48, 0.999, 23.5, 28.1, 0.837),
    (1, 2): (63_687, 122_426, 1_243_564, 96, 0.999, 47.1, 30.6, 1.542),
    (1, 4): (129_738, 244_196, 2_987_730, 192, 0.999, 94.2, 39.0, 2.416),
    (2, 1): (64_119, 122_630, 642_410, 96, 0.557, 26.3, 32.3, 0.812),
    (2, 2): (136_742, 244_195, 1_316_604, 192, 0.558, 52.6, 37.4, 1.405),
    (4, 1): (128_431, 243_626, 859_604, 192, 0.279, 26.3, 33.2, 0.792),
}


class FPGAModel:
    """Analytic performance/power/resource model of the paper's platform."""

    def __init__(self, target: FPGATarget = FPGATarget()):
        self.target = target
        self._fit_power()

    # ---- power: W ~ c0 + c1*(n*m) + c2*sustained + c3*bw_used. Terms map to
    # static+idle board power, per-pipeline logic area, switching activity,
    # and DDR activity; least-squares over the six measured configurations
    # (R^2 ~ 0.988, max 2.3% error).
    def _fit_power(self) -> None:
        rows, y = [], []
        for (n, m), rec in TABLE3_MEASURED.items():
            rows.append([1.0, n * m, rec[5], self._bw_used(n)])
            y.append(rec[6])
        a, *_ = np.linalg.lstsq(np.asarray(rows), np.asarray(y), rcond=None)
        self.power_coef = a  # [c0, c1, c2, c3]
        pred = np.asarray(rows) @ a
        ss_res = float(np.sum((pred - np.asarray(y)) ** 2))
        ss_tot = float(np.sum((np.asarray(y) - np.mean(y)) ** 2))
        self.power_r2 = 1.0 - ss_res / ss_tot

    def _bw_used(self, n: int, words: int = 10) -> float:
        t = self.target
        demand = n * words * 4 * t.freq_ghz
        return min(demand, t.bw_eff_gbs)

    def power_w(self, n: int, m: int, sustained_gflops: float,
                words: int = 10) -> float:
        c0, c1, c2, c3 = self.power_coef
        w = float(
            c0 + c1 * n * m + c2 * sustained_gflops + c3 * self._bw_used(n, words)
        )
        # the linear fit extrapolates below the board's idle draw for tiny
        # workloads; clamp to a 20 W idle floor (paper board idles ~25 W)
        return max(w, 20.0)

    # ---- resources ---------------------------------------------------------
    def pipeline_alms(self, w: StreamWorkload, census: dict | None = None) -> float:
        t = self.target
        if census is None:
            # fall back to the paper's LBM mix if a census is not supplied
            census = {"add": 70, "mul": 60, "div": 1}
        return (
            t.alm_per_add * census.get("add", 0)
            + t.alm_per_mul * census.get("mul", 0)
            + t.alm_per_div * (census.get("div", 0) + census.get("sqrt", 0))
            + t.alm_per_ctrl
        )

    def pipeline_dsps(self, census: dict | None = None) -> int:
        if census is None:
            census = {"mul": 60}
        return int(round(self.target.dsp_per_mul * census.get("mul", 0)))

    def buffer_bits(self, w: StreamWorkload, n: int, m: int) -> int:
        """m PEs each with an n-lane *shared* buffer (paper §II-B).

        The shared buffer holds the same rows regardless of n (lanes tap the
        same lines), plus per-lane ingress/egress registers; cascading
        multiplies the whole thing by m.
        """
        per_pe = w.buffer_bits + (n - 1) * 32 * 64  # lane regs
        return m * per_pe

    # ---- performance (Eq. 10 + utilization) --------------------------------
    def evaluate(
        self,
        w: StreamWorkload,
        n: int,
        m: int,
        census: dict | None = None,
        overlapped_passes: bool = True,
    ) -> DesignPoint:
        t = self.target
        pt = DesignPoint(n=n, m=m, feasible=True)
        peak = n * m * w.flops_per_elem * t.freq_ghz  # GFlop/s (Eq. 10)

        # Bandwidth-limited utilization: an n-wide stream demands n x
        # words * 4 B * F per direction; read/write are symmetric here.
        bw_per_lane = max(w.words_in, w.words_out) * 4 * t.freq_ghz  # GB/s
        u_bw = min(1.0, t.bw_eff_gbs / (n * bw_per_lane))
        # Pipeline fill/drain: T elements through an (m*d)-deep pipeline.
        depth = m * w.depth
        u_pipe = 1.0 if overlapped_passes else w.elems / (w.elems + depth)
        u = u_bw * u_pipe
        sustained = peak * u

        # Resource feasibility.
        alms = t.soc_alms + n * m * self.pipeline_alms(w, census)
        dsps = t.soc_dsps + n * m * self.pipeline_dsps(census)
        bram = t.soc_bram_bits + self.buffer_bits(w, n, m)
        if alms > t.alms:
            pt.feasible = False
            pt.limits.append(f"ALM {alms:.0f}>{t.alms}")
        if dsps > t.dsps:
            pt.feasible = False
            pt.limits.append(f"DSP {dsps}>{t.dsps}")
        if bram > t.bram_bits:
            pt.feasible = False
            pt.limits.append(f"BRAM {bram}>{t.bram_bits}")
        if u_bw < 1.0:
            pt.limits.append("bandwidth-bound")

        power = self.power_w(n, m, sustained, words=max(w.words_in, w.words_out))
        pt.peak_gflops = peak
        pt.utilization = u
        pt.sustained_gflops = sustained
        pt.power_w = power
        pt.perf_per_watt = sustained / power if power > 0 else 0.0
        pt.detail = {
            "alms": alms,
            "dsps": dsps,
            "bram_bits": bram,
            "u_bw": u_bw,
            "u_pipe": u_pipe,
            "bw_required_gbs": n * bw_per_lane,
            "depth": depth,
        }
        return pt

    def evaluate_batch(
        self,
        w: StreamWorkload,
        n,
        m,
        census: dict | None = None,
        overlapped_passes: bool = True,
    ) -> dict[str, np.ndarray]:
        """Vectorized :meth:`evaluate` over coordinate arrays ``n``, ``m``.

        ``n`` and ``m`` are broadcast against each other; every returned
        array has the broadcast shape. The arithmetic is bit-identical to
        the scalar path (same float64 expressions, same clamps), so
        ``evaluate_batch(w, [n], [m])`` agrees with ``evaluate(w, n, m)``
        point-for-point.
        """
        t = self.target
        n = np.asarray(n, dtype=np.int64)
        m = np.asarray(m, dtype=np.int64)
        n, m = np.broadcast_arrays(n, m)
        nm = n * m

        peak = nm * float(w.flops_per_elem) * t.freq_ghz  # Eq. (10)
        words = max(w.words_in, w.words_out)
        bw_per_lane = words * 4 * t.freq_ghz
        u_bw = np.minimum(1.0, t.bw_eff_gbs / (n * bw_per_lane))
        depth = m * w.depth
        if overlapped_passes:
            u_pipe = np.ones(n.shape)
        else:
            u_pipe = w.elems / (w.elems + depth)
        u = u_bw * u_pipe
        sustained = peak * u

        alms = t.soc_alms + nm * self.pipeline_alms(w, census)
        dsps = t.soc_dsps + nm * self.pipeline_dsps(census)
        bram = t.soc_bram_bits + m * (w.buffer_bits + (n - 1) * 32 * 64)
        feasible = (alms <= t.alms) & (dsps <= t.dsps) & (bram <= t.bram_bits)

        c0, c1, c2, c3 = self.power_coef
        bw_used = np.minimum(n * words * 4 * t.freq_ghz, t.bw_eff_gbs)
        power = np.maximum(c0 + c1 * nm + c2 * sustained + c3 * bw_used, 20.0)
        ppw = np.where(power > 0, sustained / power, 0.0)
        resource_frac = np.maximum(
            np.maximum(alms / t.alms, dsps / t.dsps), bram / t.bram_bits
        )
        return {
            "n": n,
            "m": m,
            "feasible": feasible,
            "peak_gflops": peak,
            "utilization": u,
            "sustained_gflops": sustained,
            "power_w": power,
            "perf_per_watt": ppw,
            "alms": alms,
            "dsps": dsps,
            "bram_bits": bram,
            "u_bw": u_bw,
            "u_pipe": u_pipe,
            "bw_required_gbs": n * bw_per_lane,
            "depth": depth,
            "resource_frac": resource_frac,
        }

    def explore(
        self,
        w: StreamWorkload,
        n_values: Sequence[int] = (1, 2, 4),
        m_values: Sequence[int] = (1, 2, 4),
        census: dict | None = None,
    ) -> list[DesignPoint]:
        pts = [
            self.evaluate(w, n, m, census)
            for n in n_values
            for m in m_values
        ]
        return sorted(
            pts, key=lambda p: (p.feasible, p.perf_per_watt), reverse=True
        )


# --------------------------------------------------------------------------
# TPU target (the hardware adaptation)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TPUTarget:
    name: str = "tpu-v5e"
    peak_bf16_tflops: float = 197.0
    # LBM runs in f32 on the VPU (elementwise math, no MXU contraction).
    # Assumed VPU f32 throughput; configurable, stated in EXPERIMENTS.md.
    vpu_f32_tflops: float = 4.9
    hbm_gbs: float = 819.0
    # Shared with the kernel legalizer (repro.core.legalize): the model's
    # VMEM feasibility mask and blocking_plan's stripe clamp read the same
    # budget, so a model-feasible point is never shrunk at run time.
    vmem_bytes: int = VMEM_BYTES
    ici_gbs_per_link: float = 50.0
    hbm_bytes_per_chip: int = 16 * 2**30
    # Simple per-chip power model for the perf/W frontier axis: idle floor
    # plus activity proportional to the achieved fraction of the VPU roof.
    # (v5e board powers are not published per-op; these assumed constants
    # are stated in DESIGN.md §5 and only rank points, they are not claims.)
    chip_idle_w: float = 75.0
    chip_peak_w: float = 170.0
    # Fixed dispatch latency per *extra* kernel launch in an m-step
    # block (DESIGN.md §14): a fused cluster is one launch per block, a
    # pipelined k-cluster program is m·k — the roofline alone cannot
    # separate them when memory is cheap. 0.0 keeps every single-launch
    # prediction bit-identical; benchmarks/dse_sweep.py §2h calibrates
    # it from a tiny-grid probe through the real execution path.
    launch_overhead_s: float = 0.0


class TPUModel:
    """Roofline model of temporal blocking (the cascaded-PE analogue).

    A block of ``bh`` rows x full width is made VMEM-resident; ``m`` fused
    time-steps are applied before writing back, so HBM traffic per element is
    constant in m while compute scales with m — exactly the paper's temporal
    parallelism argument, with VMEM playing the BRAM role and the halo
    (2m rows, recomputed) playing the prologue/epilogue role.
    """

    def __init__(self, target: TPUTarget = TPUTarget()):
        self.target = target

    @classmethod
    def calibrated(
        cls, calibration, d: int = 1, base: TPUTarget | None = None
    ) -> "TPUModel":
        """A model whose target carries *measured* platform constants.

        ``calibration`` is a :class:`repro.core.measure.BackendCalibration`
        (anything with a ``target(d, base)`` method): the returned model
        predicts against the effective throughput/bandwidth of the
        platform actually running — the Pallas interpreter on CPU, the
        chip on TPU — so predicted-vs-measured is a model-fidelity
        signal, not a host-vs-TPU speed ratio
        (docs/pipeline.md §measure, DESIGN.md §9).
        """
        return cls(calibration.target(d=d, base=base))

    def evaluate(
        self,
        w: StreamWorkload,
        bh: int,
        m: int,
        d: int = 1,
        double_buffer: bool = True,
        b: int = 1,
        fusion: str = "",
        dx: int = 1,
    ) -> DesignPoint:
        """One (block_h, m, d, b, fusion, dx) design point. ``d`` is the
        device axis — the *total* number of chips; ``dx`` factors it
        into a ``(dy, dx) = (d // dx, dx)`` mesh (DESIGN.md §15): rows
        shard across ``dy`` as before, columns across ``dx``. ``dx == 1``
        reproduces the 1-D ring numbers bit-for-bit. Under ``dx > 1``
        the per-shard width ``grid_w / dx`` drives the VMEM stripe (plus
        ``2·m·halo_x`` guard columns), the useful fraction gains the
        column trapezoid factor ``w_s / (w_s + 2·m·halo_x)``, and the
        collective term prices the two exchanges separately — the column
        exchange volume scales with shard *height*, the row exchange
        with shard *width*, which is what lets the model pick
        aspect-matched meshes. ``b`` is the batch axis —
        the number of independent simulations stacked into one launch
        (docs/pipeline.md §serve): compute, HBM traffic and VMEM
        residency all scale linearly with ``b``, and the VMEM term is
        priced by the legalizer's own ``stripe_vmem_bytes(..., b=b)``
        so modeled and executed geometry agree.

        ``fusion`` prices a stream-program partition (docs/pipeline.md
        §program, DESIGN.md §14; needs ``w.stages``). Fused (one
        cluster): one HBM pass per m-step block, stripes summed at the
        composed halo — more VMEM, less traffic. Pipelined (k > 1
        clusters): every *cut* edge costs a full-grid HBM write + read
        per program step — ``m·k`` passes per m-step block — while each
        cluster's temporal block collapses to one step (halo recompute
        shrinks) and VMEM holds only the largest cluster's stripes.
        """
        t = self.target
        d = int(d)
        dx = max(1, int(dx))
        b = max(1, int(b))
        pt = DesignPoint(n=d, m=m, feasible=True)
        grid_w = w.grid_w or int(math.sqrt(w.elems))
        bytes_per_elem = 4 * (w.words_in + w.words_out)
        clusters = w.fusion_clusters(fusion) if w.stages else None
        fusion = (
            "+".join(str(s) for s in parse_fusion(fusion, len(w.stages)))
            if w.stages else ""
        )

        # Mesh factorization (DESIGN.md §15): d chips arrange as a
        # (dy, dx) mesh. dx must divide the device count and the width —
        # the sharded kernel hard-errors on both, so the model marks
        # non-factorizing points infeasible instead of pricing them.
        hx = w.stencil_halo_x
        dy = max(d // dx, 1)
        shard_w = max(grid_w // dx, 1)
        if d % dx:
            pt.feasible = False
            pt.limits.append(f"mesh {d}%dx={dx}!=0")
        if dx > 1 and (not w.grid_w or grid_w % dx):
            pt.feasible = False
            pt.limits.append(f"colshard {grid_w}%{dx}!=0")

        # The dy axis decomposes the grid along y into dy equal shards
        # (halo-exchanged over ICI). A height dy does not divide has no
        # executable geometry — the sharded kernel rejects it — so the
        # model marks it infeasible instead of pricing an impossible run.
        if w.grid_w and dy > 1 and (w.elems // w.grid_w) % dy:
            pt.feasible = False
            pt.limits.append(f"shard {w.elems // w.grid_w}%{dy}!=0")

        # A block taller than the shard cannot be clamped into the
        # launch geometry (``resolve_run_plan`` clamps *within* the
        # shard height) — a dy-heavy mesh on a short grid caps the
        # legal block_h, which is exactly why wide grids prefer column
        # sharding (DESIGN.md §15). Non-tiling-but-smaller blocks stay
        # feasible: the runner clamps them to a legal divisor.
        if w.grid_w and dy > 1:
            shard_h = (w.elems // w.grid_w) // dy
            if shard_h and bh > shard_h:
                pt.feasible = False
                pt.limits.append(f"block {bh}>shard_h={shard_h}")

        # The batched leading dim runs through the single-device stream
        # kernels only; a batched *and* sharded launch has no executable
        # geometry (repro.core.distribute handles (P, H, W) state).
        if b > 1 and d > 1:
            pt.feasible = False
            pt.limits.append(f"batched b={b} + sharded d={d} unsupported")

        # VMEM residency: priced by the legalizer's own stripe formula
        # (repro.core.legalize) — one source of truth, so a feasible
        # point is never silently shrunk at run time and model/legalizer
        # budgets cannot drift apart. Programs price each cluster's
        # stripe *set* at its composed halo and keep the max (clusters
        # launch one at a time).
        guard = hx if dx > 1 else 0  # guard columns only when column-sharded
        if clusters is None:
            vmem = stripe_vmem_bytes(
                bh, m, shard_w, w.words_in, halo=w.halo,
                double_buffer=double_buffer, b=b, halo_x=guard,
            )
        else:
            m_c = m if len(clusters) == 1 else 1
            vmem = max(
                cluster_vmem_bytes(
                    bh, m_c, shard_w, c["words"], c["halos"],
                    double_buffer, b=b,
                )
                for c in clusters
            )
        if vmem > t.vmem_bytes:
            pt.feasible = False
            pt.limits.append(f"VMEM {vmem}>{t.vmem_bytes}")

        # Halo overhead: the 2·m·halo halo rows are recomputed per block;
        # under dx > 1 the 2·m·halo_x guard columns add the analogous
        # column trapezoid (DESIGN.md §15). The batch axis multiplies
        # sites (b independent grids advance per launch), leaving the
        # useful fraction unchanged.
        if clusters is None:
            colf = shard_w / (shard_w + 2 * m * hx) if dx > 1 else 1.0
            useful = bh / (bh + 2 * m * w.halo) * colf
            flops = b * w.elems * w.flops_per_elem * m / useful
            hbm_passes = 1
            launches = 1
            exch_halo = m * w.halo  # halo rows exchanged per m-step block
            exch_halo_x = m * hx  # guard columns exchanged per block
        else:
            m_c = m if len(clusters) == 1 else 1
            # Per-cluster recompute at the cluster's composed halo; the
            # cluster fuses m_c steps (m when fused, 1 per launch when
            # pipelined — a program step is one pass through the chain).
            launches = m // m_c  # cluster launches per m-step block
            flops = sum(
                b * w.elems * c["flops"] * launches * m_c
                / (bh / (bh + 2 * m_c * c["halo"]))
                / ((shard_w / (shard_w + 2 * m_c * c["halo"]))
                   if dx > 1 else 1.0)
                for c in clusters
            )
            useful = (b * w.elems * w.flops_per_elem * m) / flops
            # Every cut edge costs a full-grid HBM write + read per
            # program step: k clusters = m·k grid passes per m-step
            # block vs the fused path's single pass.
            hbm_passes = 1 if len(clusters) == 1 else m * len(clusters)
            exch_halo = sum(
                launches * m_c * c["halo"] for c in clusters
            )
            exch_halo_x = exch_halo  # stage halos are symmetric in x/y
            launches = launches * len(clusters)  # total per m-step block
        t_compute = flops / (d * t.vpu_f32_tflops * 1e12)
        t_memory = (
            hbm_passes * b * w.elems * bytes_per_elem
            / (d * t.hbm_gbs * 1e9)
        )
        # Cross-chip halo exchange: the row exchange moves 2·m·halo rows
        # per neighbor pair at the per-shard *width*, the column exchange
        # 2·m·halo_x columns at the per-shard *height* (per cluster
        # launch for pipelined programs) — two separately priced volumes,
        # so tall and wide grids prefer different mesh shapes.
        grid_h = w.elems // grid_w
        halo_bytes = 0.0
        if dy > 1:
            halo_bytes += 2 * 2 * exch_halo * shard_w * w.words_in * 4
        if dx > 1:
            halo_bytes += 2 * 2 * exch_halo_x * (grid_h // dy) * w.words_in * 4
        t_coll = halo_bytes / (t.ici_gbs_per_link * 1e9)

        # Dispatch latency for the launches beyond the first: 0 for
        # every single-launch block (legacy predictions unchanged),
        # (m·k - 1)·overhead for a pipelined k-cluster program — the
        # term that separates fused from pipelined once calibration has
        # made HBM cheap (DESIGN.md §14).
        t_launch = (launches - 1) * t.launch_overhead_s
        step_time = max(t_compute, t_memory, t_coll) + t_launch
        useful_flops = b * w.elems * w.flops_per_elem * m
        sustained = useful_flops / step_time / 1e9 if step_time > 0 else 0.0
        peak = d * t.vpu_f32_tflops * 1e3  # GFlop/s
        # One spelling for the binding resource, shared verbatim with
        # evaluate_batch's data["bound"] (asserted in tests/test_explorer).
        bound = (
            "compute-bound"
            if t_compute >= max(t_memory, t_coll)
            else ("memory-bound" if t_memory >= t_coll else "collective-bound")
        )
        pt.limits.append(bound)
        pt.peak_gflops = peak
        pt.sustained_gflops = sustained
        pt.utilization = sustained / peak if peak else 0.0
        pt.power_w = d * (
            t.chip_idle_w + (t.chip_peak_w - t.chip_idle_w) * pt.utilization
        )
        pt.perf_per_watt = sustained / pt.power_w if pt.power_w > 0 else 0.0
        pt.detail = {
            "vmem_bytes": vmem,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "halo_useful_fraction": useful,
            "arithmetic_intensity": m * w.flops_per_elem / bytes_per_elem,
            "block_rows": bh,
            "vmem_frac": vmem / t.vmem_bytes,
            "d": d,
            "dx": dx,
            "dy": dy,
            "double_buffer": bool(double_buffer),
            "b": b,
            "fusion": fusion,
            "hbm_passes": hbm_passes,
            "launches": launches,
            "t_launch_s": t_launch,
        }
        return pt

    def evaluate_batch(
        self,
        w: StreamWorkload,
        bh,
        m,
        d=1,
        double_buffer: bool = True,
        b=1,
        fusion: str = "",
        dx=1,
    ) -> dict[str, np.ndarray]:
        """Vectorized :meth:`evaluate` over ``bh``/``m``/``d``/``b``/``dx``
        arrays.

        Coordinates broadcast against each other; returns a dict of arrays
        in the broadcast shape, numerically identical to the scalar path.
        ``d`` is the device axis (the *total* chip count); the returned
        dict carries it under both ``"n"`` and ``"d"``. ``dx`` is the
        column axis of the ``(dy, dx)`` mesh (DESIGN.md §15), returned
        under ``"dx"`` with the derived ``"dy"`` alongside. ``b`` is the
        batch axis (docs/pipeline.md §serve), returned under ``"b"``.
        ``fusion`` is one partition spec for the whole lattice slab (the
        sweep loops over specs and concatenates, docs/pipeline.md
        §program); it is returned under ``"fusion"`` as an object column.
        """
        t = self.target
        bh = np.asarray(bh, dtype=np.int64)
        m = np.asarray(m, dtype=np.int64)
        chips = np.asarray(d, dtype=np.int64)
        batch = np.maximum(np.asarray(b, dtype=np.int64), 1)
        dxa = np.maximum(np.asarray(dx, dtype=np.int64), 1)
        bh, m, chips, batch, dxa = np.broadcast_arrays(
            bh, m, chips, batch, dxa
        )
        grid_w = w.grid_w or int(math.sqrt(w.elems))
        bytes_per_elem = 4 * (w.words_in + w.words_out)
        clusters = w.fusion_clusters(fusion) if w.stages else None
        fusion = (
            "+".join(str(s) for s in parse_fusion(fusion, len(w.stages)))
            if w.stages else ""
        )

        # Mesh factorization (DESIGN.md §15) — same derivations as the
        # scalar path, elementwise.
        hx = w.stencil_halo_x
        dya = np.maximum(chips // dxa, 1)
        shard_w = np.maximum(grid_w // dxa, 1)

        guard = np.where(dxa > 1, hx, 0)
        if clusters is None:
            vmem = stripe_vmem_bytes(
                bh, m, shard_w, w.words_in, halo=w.halo,
                double_buffer=double_buffer, b=batch, halo_x=guard,
            )
        else:
            m_c = np.where(len(clusters) == 1, m, 1)
            vmem = np.maximum.reduce([
                cluster_vmem_bytes(
                    bh, m_c, shard_w, c["words"], c["halos"],
                    double_buffer, b=batch,
                )
                for c in clusters
            ])
        feasible = vmem <= t.vmem_bytes
        # the mesh must factor the device count (scalar path's hard limit)
        feasible = feasible & (chips % dxa == 0)
        if w.grid_w:
            # y-sharding needs dy equal shards, x-sharding dx equal
            # shards (same checks as the scalar path and the
            # repro.core.distribute kernel's hard errors).
            grid_h = w.elems // w.grid_w
            feasible = feasible & ((dya == 1) | (grid_h % dya == 0))
            feasible = feasible & ((dxa == 1) | (grid_w % dxa == 0))
            # blocks taller than the shard cannot be clamped into the
            # launch geometry (scalar path's limit)
            shard_h = np.maximum(grid_h // dya, 1)
            feasible = feasible & ((dya == 1) | (bh <= shard_h))
        else:
            # no known width: column sharding has no executable geometry
            feasible = feasible & (dxa == 1)
        # batched + sharded has no executable geometry (scalar path's limit)
        feasible = feasible & ((batch == 1) | (chips == 1))

        if clusters is None:
            colf = np.where(
                dxa > 1, shard_w / (shard_w + 2 * m * hx), 1.0
            )
            useful = bh / (bh + 2 * m * w.halo) * colf
            flops = batch * w.elems * w.flops_per_elem * m / useful
            hbm_passes = np.ones_like(m, dtype=np.float64)
            launches = np.ones_like(m, dtype=np.float64)
            exch_halo = (m * w.halo).astype(np.float64)
            exch_halo_x = (m * hx).astype(np.float64)
        else:
            m_c = np.where(len(clusters) == 1, m, 1)
            launches = m // m_c
            flops = sum(
                batch * w.elems * c["flops"] * launches * m_c
                / (bh / (bh + 2 * m_c * c["halo"]))
                / np.where(
                    dxa > 1,
                    shard_w / (shard_w + 2 * m_c * c["halo"]),
                    1.0,
                )
                for c in clusters
            )
            useful = (batch * w.elems * w.flops_per_elem * m) / flops
            hbm_passes = np.where(
                len(clusters) == 1, 1.0, (m * len(clusters)).astype(np.float64)
            )
            exch_halo = sum(
                (launches * m_c * c["halo"]).astype(np.float64)
                for c in clusters
            )
            exch_halo_x = exch_halo  # stage halos are symmetric in x/y
            launches = (launches * len(clusters)).astype(np.float64)
        t_compute = flops / (chips * t.vpu_f32_tflops * 1e12)
        t_memory = (
            hbm_passes * batch * w.elems * bytes_per_elem
            / (chips * t.hbm_gbs * 1e9)
        )
        # Two exchange volumes (DESIGN.md §15): rows at shard width over
        # dy, guard columns at shard height over dx.
        shard_h = (w.elems // grid_w) // dya
        halo_bytes = np.where(
            dya > 1, 2.0 * 2 * exch_halo * shard_w * w.words_in * 4, 0.0
        ) + np.where(
            dxa > 1, 2.0 * 2 * exch_halo_x * shard_h * w.words_in * 4, 0.0
        )
        t_coll = halo_bytes / (t.ici_gbs_per_link * 1e9)

        # Same launch-dispatch term as the scalar path (0 when
        # launches == 1, so legacy slabs are numerically unchanged).
        step_time = (
            np.maximum(np.maximum(t_compute, t_memory), t_coll)
            + (launches - 1) * t.launch_overhead_s
        )
        useful_flops = batch * w.elems * w.flops_per_elem * m
        sustained = np.where(step_time > 0, useful_flops / step_time / 1e9, 0.0)
        peak = chips * t.vpu_f32_tflops * 1e3
        util = np.where(peak > 0, sustained / peak, 0.0)
        power = chips * (t.chip_idle_w + (t.chip_peak_w - t.chip_idle_w) * util)
        ppw = np.where(power > 0, sustained / power, 0.0)
        bound = np.where(
            t_compute >= np.maximum(t_memory, t_coll),
            "compute-bound",
            np.where(t_memory >= t_coll, "memory-bound", "collective-bound"),
        )
        return {
            "n": chips,
            "d": chips,
            "dx": dxa,
            "dy": dya,
            "m": m,
            "b": batch,
            "block_rows": bh,
            "feasible": feasible,
            "peak_gflops": peak,
            "utilization": util,
            "sustained_gflops": sustained,
            "power_w": power,
            "perf_per_watt": ppw,
            "vmem_bytes": vmem,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "halo_useful_fraction": useful,
            "arithmetic_intensity": m * w.flops_per_elem / bytes_per_elem,
            "bound": bound,
            "resource_frac": vmem / t.vmem_bytes,
            "fusion": np.full(bh.shape, fusion, dtype=object),
            "launches": launches,
        }

    def explore(
        self,
        w: StreamWorkload,
        bh_values: Iterable[int] = (8, 16, 32, 64, 128, 256),
        m_values: Iterable[int] = (1, 2, 4, 8, 16, 32),
        d: int = 1,
    ) -> list[DesignPoint]:
        pts = [
            self.evaluate(w, bh, m, d)
            for bh in bh_values
            for m in m_values
        ]
        return sorted(
            pts,
            key=lambda p: (p.feasible, p.sustained_gflops),
            reverse=True,
        )


def render_table(points: Sequence[DesignPoint]) -> str:
    """Markdown Table-III-style rendering of design points.

    TPU points (which carry ``block_rows`` in their detail) get an extra
    ``bh`` column so same-(n, m) blockings stay distinguishable.
    """
    with_bh = any("block_rows" in p.detail for p in points)
    bh_head, bh_rule = ("| bh ", "|----") if with_bh else ("", "")
    head = (
        f"| n | m {bh_head}| feasible | peak GF/s | util | sustained GF/s "
        "| W | GF/sW | limits |\n"
        f"|---|---{bh_rule}|----------|-----------|------|----------------"
        "|---|-------|--------|"
    )
    rows = []
    for p in points:
        bh_cell = f"| {p.detail.get('block_rows', '-')} " if with_bh else ""
        rows.append(
            f"| {p.n} | {p.m} {bh_cell}| {'y' if p.feasible else 'N'} | "
            f"{p.peak_gflops:8.1f} | {p.utilization:.3f} | "
            f"{p.sustained_gflops:10.1f} | {p.power_w:5.1f} | "
            f"{p.perf_per_watt:.3f} | {';'.join(p.limits)} |"
        )
    return "\n".join([head] + rows)
