"""Pluggable, budgeted design-space search (docs/pipeline.md §search).

The subsystem the explorer facade (``Explorer.search``) drives: a
:class:`~repro.core.search.strategies.SearchStrategy` decides which
(n, m, d, block_h) candidates to spend measurements on, and the
:class:`~repro.core.search.runner.SearchRunner` is the single
legalize→run→time→calibrate engine every strategy shares — one plan
dedupe table, one calibration anchor set, one measurement cache, one
hard budget. :class:`SearchResult` is what a search returns: the
executed points plus the accounting (strategy name, budget spent,
per-plan measurement counts) that ``repro-explore --json`` and
``BENCH_dse.json`` record. Durable, resumable searches layer on top:
:class:`~repro.core.search.study.Study` journals every trial and
:class:`~repro.core.search.surrogate.TPESearch` learns where to measure
next from them (docs/pipeline.md §study).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .runner import (
    EXECUTED_POINT_FIELDS,
    PLAN_FIELDS,
    BudgetExhausted,
    ExecutedPoint,
    RunPlan,
    SearchRunner,
    kernel_run_factory,
)
from .strategies import (
    STRATEGIES,
    ExhaustiveSearch,
    LocalRefine,
    SearchStepper,
    SearchStrategy,
    SuccessiveHalving,
    get_strategy,
)
from .study import Study, default_study_dir
from .surrogate import TPESearch

__all__ = [
    "BudgetExhausted",
    "EXECUTED_POINT_FIELDS",
    "ExecutedPoint",
    "ExhaustiveSearch",
    "LocalRefine",
    "PLAN_FIELDS",
    "RunPlan",
    "SEARCH_RESULT_FIELDS",
    "STRATEGIES",
    "SearchResult",
    "SearchRunner",
    "SearchStepper",
    "SearchStrategy",
    "Study",
    "SuccessiveHalving",
    "TPESearch",
    "default_study_dir",
    "get_strategy",
    "kernel_run_factory",
]


#: The one search-result record schema: ``SearchResult.as_dict`` (the
#: CLI ``--json`` report and every ``BENCH_dse.json`` search section)
#: carries exactly these keys — asserted in ``tests/test_study.py``.
SEARCH_RESULT_FIELDS = (
    "strategy",
    "budget",
    "budget_spent",
    "measurements",
    "skipped_devices",
    "skipped_illegal",
    "study",
    "replayed",
    "best",
    "executed",
)


@dataclass
class SearchResult:
    """One search invocation: executed points + budget accounting.

    ``executed`` is in measurement order (what the strategy did);
    ``best`` ranks by *measured* GFLOPS — the search's answer.
    ``budget_spent`` counts live timings only: cache and in-run dedupe
    hits are free, so a repeated search reports 0 spent.
    ``measurements`` is the per-candidate ledger — one record per
    concrete plan timed live, with its count (successive halving times
    a surviving plan once per rung, at increasing reps).
    """

    strategy: str
    executed: list[ExecutedPoint] = field(default_factory=list)
    budget: int | None = None
    budget_spent: int = 0
    measurements: list[dict] = field(default_factory=list)
    skipped_devices: int = 0
    skipped_illegal: int = 0
    study: str | None = None  # durable study this search journaled into
    replayed: int = 0  # completed trials replayed from it (0 budget each)

    @property
    def best(self) -> ExecutedPoint | None:
        """The measured-best *finalist* (None when nothing ran).

        Only measurements at the highest rep count present compete:
        under a rung schedule (successive halving) those are the
        full-rep finals, so neither a plan's own lucky 1-rep screening
        number nor an eliminated candidate's inflated screening wall
        can outrank an honest final. For single-rep-level strategies
        (exhaustive, refine) this is simply the measured argmax.
        """
        if not self.executed:
            return None
        max_reps = max(e.reps for e in self.executed)
        finalists = [e for e in self.executed if e.reps == max_reps]
        return max(finalists, key=lambda e: e.measured_gflops)

    def __len__(self) -> int:
        return len(self.executed)

    def as_dict(self) -> dict:
        """JSON-ready record — the one serialization
        (:data:`SEARCH_RESULT_FIELDS`) shared by the CLI ``--json``
        report and every BENCH_dse.json search section."""
        best = self.best
        return {
            "strategy": self.strategy,
            "budget": None if self.budget is None else int(self.budget),
            "budget_spent": int(self.budget_spent),
            "measurements": list(self.measurements),
            "skipped_devices": int(self.skipped_devices),
            "skipped_illegal": int(self.skipped_illegal),
            "study": self.study,
            "replayed": int(self.replayed),
            "best": None if best is None else best.as_dict(),
            "executed": [e.as_dict() for e in self.executed],
        }
