"""Pluggable, budgeted design-space search (docs/pipeline.md §search).

The subsystem the explorer facade (``Explorer.search``) drives: a
:class:`~repro.core.search.strategies.SearchStrategy` decides which
(n, m, d, block_h) candidates to spend measurements on, and the
:class:`~repro.core.search.runner.SearchRunner` is the single
legalize→run→time→calibrate engine every strategy shares — one plan
dedupe table, one calibration anchor set, one measurement cache, one
hard budget. :class:`SearchResult` is what a search returns: the
executed points plus the accounting (strategy name, budget spent,
per-plan measurement counts) that ``repro-explore --json`` and
``BENCH_dse.json`` record.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .runner import (
    BudgetExhausted,
    ExecutedPoint,
    RunPlan,
    SearchRunner,
    kernel_run_factory,
)
from .strategies import (
    STRATEGIES,
    ExhaustiveSearch,
    LocalRefine,
    SearchStrategy,
    SuccessiveHalving,
    get_strategy,
)

__all__ = [
    "BudgetExhausted",
    "ExecutedPoint",
    "ExhaustiveSearch",
    "LocalRefine",
    "RunPlan",
    "STRATEGIES",
    "SearchResult",
    "SearchRunner",
    "SearchStrategy",
    "SuccessiveHalving",
    "get_strategy",
    "kernel_run_factory",
]


@dataclass
class SearchResult:
    """One search invocation: executed points + budget accounting.

    ``executed`` is in measurement order (what the strategy did);
    ``best`` ranks by *measured* GFLOPS — the search's answer.
    ``budget_spent`` counts live timings only: cache and in-run dedupe
    hits are free, so a repeated search reports 0 spent.
    ``measurements`` is the per-candidate ledger — one record per
    concrete plan timed live, with its count (successive halving times
    a surviving plan once per rung, at increasing reps).
    """

    strategy: str
    executed: list[ExecutedPoint] = field(default_factory=list)
    budget: int | None = None
    budget_spent: int = 0
    measurements: list[dict] = field(default_factory=list)
    skipped_devices: int = 0
    skipped_illegal: int = 0

    @property
    def best(self) -> ExecutedPoint | None:
        """The measured-best *finalist* (None when nothing ran).

        Only measurements at the highest rep count present compete:
        under a rung schedule (successive halving) those are the
        full-rep finals, so neither a plan's own lucky 1-rep screening
        number nor an eliminated candidate's inflated screening wall
        can outrank an honest final. For single-rep-level strategies
        (exhaustive, refine) this is simply the measured argmax.
        """
        if not self.executed:
            return None
        max_reps = max(e.reps for e in self.executed)
        finalists = [e for e in self.executed if e.reps == max_reps]
        return max(finalists, key=lambda e: e.measured_gflops)

    def __len__(self) -> int:
        return len(self.executed)

    def as_dict(self) -> dict:
        """JSON-ready record (the CLI ``--json`` / BENCH schema)."""
        best = self.best
        return {
            "strategy": self.strategy,
            "budget": None if self.budget is None else int(self.budget),
            "budget_spent": int(self.budget_spent),
            "measurements": list(self.measurements),
            "skipped_devices": int(self.skipped_devices),
            "skipped_illegal": int(self.skipped_illegal),
            "best": None if best is None else best.as_dict(),
            "executed": [e.as_dict() for e in self.executed],
        }
