"""Search strategies over the (n, m, d, block_h) design lattice.

The paper's workflow is a *search* problem — find the best mix of
temporal and spatial parallelism under resource and bandwidth
constraints — and this module is where the searching happens
(docs/pipeline.md §search, DESIGN.md §10). A strategy is anything
satisfying :class:`SearchStrategy`: given a model :class:`Sweep` (the
batched lattice evaluation, docs/pipeline.md §execute) and a
:class:`~repro.core.search.runner.SearchRunner` (the one legalize→run→
time engine), it decides *which points to spend measurements on* and
returns the executed points, newest last. Three ship:

* :class:`ExhaustiveSearch` — the repo's original behavior, now one
  strategy among peers: walk the model's Pareto frontier best-first
  (or the whole feasible lattice with ``frontier_only=False``) and
  measure until ``k`` points have executed or the budget is gone.
* :class:`LocalRefine` — model-seeded hill-climb: measure the top
  frontier seeds, then step through the (block_h, m, d) neighborhood of
  the best measured point — block_h moves along the *legal divisor
  chain* (:func:`repro.core.legalize.legal_block_values`), which is
  what promotes it from a legalization byproduct to a first-class
  searched dimension — and keep moving while measurements improve.
* :class:`SuccessiveHalving` — budgeted racing: screen a wide,
  model-ranked, plan-deduped candidate pool with cheap low-rep
  timings, promote the measured-best ``1/eta`` fraction to the next
  rung with ``eta×`` the reps, and finish the survivors at full reps —
  so most of the budget lands on the candidates measurement (not the
  model) says are best.

Every strategy runs through the same runner, so they share the plan
dedupe table, the calibration anchors, the measurement cache, and the
hard budget (:exc:`~repro.core.search.runner.BudgetExhausted` ends a
search mid-flight; whatever was measured is returned).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from ..legalize import legal_block_values
from .runner import BudgetExhausted, ExecutedPoint, SearchRunner

__all__ = [
    "ExhaustiveSearch",
    "LocalRefine",
    "STRATEGIES",
    "SearchStepper",
    "SearchStrategy",
    "SuccessiveHalving",
    "get_strategy",
]


@runtime_checkable
class SearchStrategy(Protocol):
    """What the Explorer facade needs from a strategy.

    ``name`` identifies the strategy in reports (CLI ``--strategy``
    values, ``BENCH_dse.json``); ``search`` spends the runner's budget
    and returns the executed points in measurement order.
    """

    name: str

    def search(
        self, sweep, runner: SearchRunner
    ) -> list[ExecutedPoint]: ...


def _ranked_candidates(sweep, runner: SearchRunner) -> list:
    """All feasible lattice points, model-best first, deduped by plan.

    Lattice points that legalize to the same concrete run plan are one
    candidate (the model-best spelling wins); points this platform
    cannot run (device-starved, no legal plan) are dropped up front so
    no strategy wastes budget discovering that.
    """
    feas = np.flatnonzero(sweep.feasible)
    order = np.argsort(
        -np.asarray(sweep.data["sustained_gflops"], float)[feas]
    )
    seen: set = set()
    out = []
    for i in feas[order]:
        pt = sweep.point(int(i))
        plan = runner.plan_for(pt)
        if plan is None:
            continue
        dedup = (plan.block_h, plan.m, plan.steps, plan.d,
                 plan.double_buffer, plan.b, plan.fusion, plan.dx)
        if dedup in seen:
            continue
        seen.add(dedup)
        out.append(pt)
    return out


@dataclass
class ExhaustiveSearch:
    """Measure the model's ranking top-down — the original explorer loop.

    With ``frontier_only=True`` (the default, and the
    ``execute_frontier`` facade) the walk is over the Pareto frontier —
    a handful of points — stopping after ``k`` executed points when
    ``k`` is set. ``frontier_only=False`` measures every feasible,
    runnable, plan-deduped lattice point (budget permitting) — the
    expensive ground-truth reference the cheaper strategies are judged
    against in ``tests/test_search.py``; ask for it explicitly.
    """

    name = "exhaustive"
    k: int | None = None
    frontier_only: bool = True

    def search(self, sweep, runner: SearchRunner) -> list[ExecutedPoint]:
        if self.frontier_only:
            candidates = sweep.frontier()
        else:
            candidates = _ranked_candidates(sweep, runner)
        out: list[ExecutedPoint] = []
        for pt in candidates:
            if self.k is not None and len(out) >= self.k:
                break
            try:
                e = runner.measure(pt)
            except BudgetExhausted:
                break
            if e is not None:
                out.append(e)
        return out


@dataclass
class LocalRefine:
    """Model-seeded hill-climb over the (block_h, m, d) neighborhood.

    The model proposes, measurement disposes: the top ``seeds``
    frontier points are measured, then the best measured point's
    one-coordinate moves — block_h to the adjacent legal divisors
    (first-class, not just whatever legalization returned), m and d
    halved/doubled, the mesh column axis dx halved/doubled at fixed d
    (DESIGN.md §15), double_buffer flipped (ping/pong vs single-buffer
    streaming, docs/pipeline.md §stream) — are measured, moving
    whenever a neighbor beats the incumbent, until a round yields no
    improvement, ``max_rounds`` is hit, or the budget runs out.
    """

    name = "refine"
    seeds: int = 2
    max_rounds: int = 8

    def search(self, sweep, runner: SearchRunner) -> list[ExecutedPoint]:
        out: list[ExecutedPoint] = []
        seen: set = set()  # plans already in `out` (moves often collapse)
        best: ExecutedPoint | None = None

        def visit(pt) -> ExecutedPoint | None:
            e = runner.measure(pt)
            if e is None:
                return None
            plan = (e.block_h, e.m, e.steps, e.d, e.double_buffer, e.b,
                    e.fusion, e.dx)
            if plan not in seen:
                seen.add(plan)
                out.append(e)
            return e

        try:
            for pt in sweep.frontier()[: max(1, self.seeds)]:
                e = visit(pt)
                if e is not None and (
                    best is None or e.measured_gflops > best.measured_gflops
                ):
                    best = e
            if best is None:
                return out
            for _ in range(self.max_rounds):
                improved = False
                for nb, nm, nd, ndb, ndx in self._neighborhood(best, runner):
                    # Moves stay within the incumbent's fusion partition
                    # (docs/pipeline.md §program) — the fusion axis is
                    # explored by the sweep lattice, not the hill-climb.
                    pt = runner.point(nb, nm, nd, double_buffer=ndb,
                                      fusion=best.fusion or None,
                                      dx=ndx)
                    if pt is None or not pt.feasible:
                        continue
                    e = visit(pt)
                    if e is not None and (
                        e.measured_gflops > best.measured_gflops
                    ):
                        best = e
                        improved = True
                if not improved:
                    break
        except BudgetExhausted:
            pass
        return out

    @staticmethod
    def _neighborhood(best: ExecutedPoint, runner: SearchRunner):
        """One-coordinate moves from the incumbent's *legalized* plan."""
        bh, m, d, db = best.block_h, best.m, best.d, best.double_buffer
        dx = max(1, int(getattr(best, "dx", 1) or 1))
        moves: list[tuple[int, int, int, bool, int]] = []
        # block_h: the adjacent legal divisors for this (m, d, db, dx) —
        # the chain blocking_plan chooses among, searched directly.
        chain = legal_block_values(
            runner.h, m, halo=runner.halo, width=runner.width,
            words=runner.words, d=d, double_buffer=db,
            dx=dx, halo_x=runner.halo_x if dx > 1 else 0,
        )
        below = [v for v in chain if v < bh]
        above = [v for v in chain if v > bh]
        if below:
            moves.append((below[-1], m, d, db, dx))
        if above:
            moves.append((above[0], m, d, db, dx))
        # m: halve / double the fused-step count.
        if m > 1:
            moves.append((bh, max(1, m // 2), d, db, dx))
        moves.append((bh, m * 2, d, db, dx))
        # d: halve / double the device axis within the platform.
        if d > 1 and (d // 2) % dx == 0:
            moves.append((bh, m, d // 2, db, dx))
        if 2 * d <= runner.max_devices and runner.h % (2 * d) == 0:
            moves.append((bh, m, 2 * d, db, dx))
        # dx: reshape the mesh at fixed total device count (DESIGN.md
        # §15) — trade row shards for column shards, the move that
        # matches the mesh to the grid aspect.
        if dx > 1:
            moves.append((bh, m, d, db, dx // 2))
        if d % (2 * dx) == 0 and runner.w % (2 * dx) == 0:
            moves.append((bh, m, d, db, 2 * dx))
        # double_buffer: flip the streamed launch's buffer protocol
        # (ping/pong overlap vs the single-buffer streaming fallback).
        moves.append((bh, m, d, not db, dx))
        return moves


@dataclass
class SuccessiveHalving:
    """Screen wide and cheap, finish narrow and honest.

    Rung 0 measures up to ``n0`` model-ranked candidates at
    ``screen_reps`` (1 by default: one synchronized, warm timing each);
    each next rung keeps the measured-best ``ceil(n/eta)`` and
    multiplies the reps by ``eta``, capped at the runner's full ``reps``
    — the survivors' final numbers are full-rep, same as any other
    strategy's. Under a hard budget ``n0`` is sized so the whole
    schedule fits: n0·(1 + 1/eta + 1/eta² + …) ≤ budget.
    """

    name = "halving"
    eta: int = 3
    screen_reps: int = 1
    n0: int | None = None

    def search(self, sweep, runner: SearchRunner) -> list[ExecutedPoint]:
        if self.eta < 2:
            raise ValueError(f"eta must be >= 2, got {self.eta}")
        candidates = _ranked_candidates(sweep, runner)
        if not candidates:
            return []
        n0 = self.n0
        if n0 is None:
            if runner.budget is not None:
                # geometric schedule total ≈ n0·eta/(eta−1) ≤ remaining
                n0 = max(1, int(runner.remaining() * (self.eta - 1)
                                // self.eta))
            else:
                n0 = len(candidates)
        rung = candidates[: max(1, n0)]
        reps = min(max(1, self.screen_reps), runner.reps)
        out: list[ExecutedPoint] = []
        try:
            while rung:
                scored: list[ExecutedPoint] = []
                for pt in rung:
                    e = runner.measure(pt, reps=reps)
                    if e is None:
                        continue
                    scored.append(e)
                    out.append(e)
                scored.sort(key=lambda e: -e.measured_gflops)
                if not scored or (len(scored) == 1 and reps >= runner.reps):
                    break
                if reps >= runner.reps:
                    # full-rep rung already ran: the survivors are final
                    break
                keep = max(1, math.ceil(len(scored) / self.eta))
                rung = [e.point for e in scored[:keep]]
                reps = min(runner.reps, reps * self.eta)
        except BudgetExhausted:
            pass
        return out


class SearchStepper:
    """Drive any search strategy one live measurement at a time.

    The non-blocking ``suggest/observe`` seam the serving engine's tick
    loop needs (docs/pipeline.md §serve, DESIGN.md §13): a long-running
    service cannot hand the device to ``strategy.search`` for a whole
    budget's worth of timings, but every shipped strategy is
    *deterministic given the runner's dedupe table* — so each
    :meth:`step` simply re-runs the strategy under a budget of
    ``spent + 1``. Everything earlier steps measured replays for free
    from the table, the strategy fast-forwards to its next unmeasured
    candidate, times exactly that one, and is cut off. One step ≈ one
    kernel timing; ticks interleave in between.

    The stepper never exceeds the runner's own hard budget (``cap``):
    once spent reaches it, :attr:`exhausted` is set and stepping ends —
    the caller falls back to the best measured point so far, or to the
    model-predicted plan when nothing was measured
    (docs/pipeline.md §serve). A step that measures nothing new means
    the strategy has converged (:attr:`done`); the final ``executed``
    list is then exactly what one blocking ``search()`` call would have
    returned.
    """

    def __init__(self, strategy, sweep, runner: SearchRunner):
        self.strategy = get_strategy(strategy)
        self.sweep = sweep
        self.runner = runner
        self.cap = runner.budget  # the search's true hard budget
        self.executed: list[ExecutedPoint] = []
        self.done = False
        self.exhausted = False

    def step(self) -> ExecutedPoint | None:
        """Advance by at most one live timing.

        Returns the newly measured point, or ``None`` when the search
        is over (converged or budget-exhausted — check the flags).
        """
        if self.done:
            return None
        spent0 = self.runner.budget_spent
        if self.cap is not None and spent0 >= self.cap:
            self.done = self.exhausted = True
            return None
        self.runner.budget = spent0 + 1
        try:
            self.executed = self.strategy.search(self.sweep, self.runner)
        except BudgetExhausted:  # strategies catch this; belt and braces
            pass
        finally:
            self.runner.budget = self.cap
        if self.runner.budget_spent == spent0:
            # The strategy finished without wanting another timing.
            self.done = True
            return None
        # Parallel trial execution, minimal form (docs/pipeline.md
        # §search): the budget cut-off recorded the candidate the
        # strategy wanted next; warm its compile on idle devices while
        # the caller ticks. measure() joins the warm-up before its timed
        # reps, so measured wall-clock stays per-trial-isolated.
        self.runner.prefetch()
        fresh = [e for e in self.executed if not e.cached]
        return fresh[-1] if fresh else None

    def best(self) -> ExecutedPoint | None:
        """Measured-best executed point so far (None before any timing)."""
        return max(
            self.executed, key=lambda e: e.measured_gflops, default=None,
        )


from .surrogate import TPESearch  # noqa: E402 — registry import, not a cycle

#: CLI / facade registry: ``--strategy`` spellings → constructors.
STRATEGIES = {
    "exhaustive": ExhaustiveSearch,
    "refine": LocalRefine,
    "halving": SuccessiveHalving,
    "tpe": TPESearch,
}


def get_strategy(spec) -> SearchStrategy:
    """Normalize a strategy spec: a name, a class, or an instance."""
    if isinstance(spec, str):
        try:
            return STRATEGIES[spec]()
        except KeyError:
            raise ValueError(
                f"unknown search strategy {spec!r} "
                f"(want one of {sorted(STRATEGIES)})"
            ) from None
    if isinstance(spec, type):
        spec = spec()
    if not isinstance(spec, SearchStrategy):
        raise TypeError(
            f"{spec!r} does not implement SearchStrategy "
            "(needs .name and .search(sweep, runner))"
        )
    return spec
