"""The one model→measurement engine behind every search strategy.

:class:`SearchRunner` is the plan-evaluation loop that used to live
inside ``Explorer.execute_frontier``, factored out so that *any*
:class:`~repro.core.search.strategies.SearchStrategy` — exhaustive
frontier walk, local refinement, successive halving — executes through
the identical legalize→run→time path (docs/pipeline.md §search,
§execute, §measure). One call to :meth:`SearchRunner.measure` takes a
model :class:`~repro.core.dse.DesignPoint` and

1. **legalizes** it through the shared
   :func:`repro.core.legalize.resolve_run_plan` (per shard when the
   point's device axis ``d > 1``, always with the concrete stripe
   geometry so the VMEM clamp applies on every back end);
2. **dedupes** the concrete plan: distinct lattice points that legalize
   to the same ``(block_h, m, steps, d)`` are timed **once per search**
   — the second request is served from the in-run plan table even with
   the persistent cache off;
3. **times** it with the honest harness
   (:func:`repro.core.measure.time_run` semantics: warm-up separated,
   every rep synchronized, median wall) through the shared
   :class:`~repro.core.measure.MeasurementCache` key space, charging the
   **measurement budget** only for live timings — cache and dedupe hits
   are free, which is what lets strategies compose across invocations;
4. **predicts** the executed geometry under the backend calibration
   (one probe per device-axis value, memoized per runner) so
   ``rel_error`` stays a model-fidelity signal.

When a live timing would exceed the budget, :exc:`BudgetExhausted` is
raised *before* the kernel runs — the budget is a hard cap on
measurements performed, not a soft target — and strategies catch it to
finalize with what they have. Calibration probes are platform overhead
shared by all candidates (bounded by the probe-set size per device-axis
value) and are not charged against the candidate budget; searches that
must be exactly budget-bounded run with ``calibrate=False``.

The timing primitive is injectable (``timer``): tests drive whole
strategies with a deterministic fake timer that maps a
:class:`RunPlan` to a synthetic wall time, so budget accounting and
strategy decisions are asserted without host-timing noise.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

from ..dse import DesignPoint, StreamWorkload
from ..legalize import PLAN_FIELDS, RunPlan, resolve_run_plan

__all__ = [
    "BudgetExhausted",
    "EXECUTED_POINT_FIELDS",
    "ExecutedPoint",
    "PLAN_FIELDS",
    "RunPlan",
    "SearchRunner",
    "kernel_run_factory",
]


class BudgetExhausted(RuntimeError):
    """A live measurement was requested beyond the hard budget."""


def _point_b(point) -> int:
    """The batch-axis width a design point was modeled at (1 if none).

    Carried in ``DesignPoint.detail`` (set by ``TPUModel.evaluate``) so
    pre-batch points — older studies, FPGA points — legalize as b=1.
    """
    detail = getattr(point, "detail", None) or {}
    try:
        return max(1, int(detail.get("b", 1)))
    except (TypeError, ValueError):
        return 1


def _point_fusion(point) -> str:
    """The fusion partition a design point was modeled at ("" if none).

    Carried in ``DesignPoint.detail`` (set by ``TPUModel.evaluate`` when
    the workload has program stages — docs/pipeline.md §program) so
    single-core points keep the legacy empty spec.
    """
    detail = getattr(point, "detail", None) or {}
    return str(detail.get("fusion", "") or "")


def _point_dx(point) -> int:
    """The mesh column axis a design point was modeled at (1 if none).

    Carried in ``DesignPoint.detail`` (set by ``TPUModel.evaluate``,
    DESIGN.md §15) so pre-mesh points — older studies, FPGA points —
    legalize as the 1-D row ring.
    """
    detail = getattr(point, "detail", None) or {}
    try:
        return max(1, int(detail.get("dx", 1)))
    except (TypeError, ValueError):
        return 1


# RunPlan itself is single-sourced in ``repro.core.legalize`` (one
# PLAN_FIELDS tuple shared by the legalizer, the runner, the study
# journal and the measurement-cache key space — docs/pipeline.md
# §search); it is re-exported here because the search package is where
# most call sites import it from.


#: The one executed-point record schema. Single source of truth for
#: every serialized form of a measurement: ``ExecutedPoint.as_dict``
#: (the CLI ``--json`` report and ``BENCH_dse.json``) and the ``point``
#: field of a study trial record (docs/pipeline.md §study) all carry
#: exactly these keys — asserted in ``tests/test_study.py``, so
#: downstream tooling cannot silently drift apart.
EXECUTED_POINT_FIELDS = (
    "block_h",
    "m",
    "d",
    "dx",
    "double_buffer",
    "b",
    "fusion",
    "steps",
    "wall_s",
    "measured_mlups",
    "measured_gflops",
    "predicted_gflops",
    "calibrated_gflops",
    "rel_error",
    "rel_error_model",
    "cached",
    "reps",
    "interpret",
)


@dataclass
class ExecutedPoint:
    """One design point run through the real Pallas kernel."""

    point: DesignPoint
    block_h: int  # block actually used (clamped to divide the shard height)
    m: int
    d: int  # device axis: shards the grid ran across (1 = single device)
    steps: int
    wall_s: float  # median-of-reps wall time (repro.core.measure.time_run)
    measured_mlups: float
    measured_gflops: float
    predicted_gflops: float  # uncalibrated model (TPU-v5e roofline constants)
    rel_error: float  # (prediction - measured) / prediction, calibrated
    #                   prediction when calibration ran, raw model otherwise
    interpret: bool
    # Prediction under measured platform constants (docs/pipeline.md
    # §measure); None when the runner measured with calibrate=False.
    calibrated_gflops: float | None = None
    rel_error_model: float = 0.0  # always vs the uncalibrated model
    cached: bool = False  # wall time came from the measurement cache (or
    #                       this search already timed the same plan)
    reps: int = 1
    double_buffer: bool = True  # streamed buffer protocol actually run
    b: int = 1  # batch axis: independent simulations stacked in the launch
    fusion: str = ""  # program fusion partition actually run ("" = single core)
    dx: int = 1  # mesh column axis: the d devices ran as a (d//dx, dx)
    #              mesh (DESIGN.md §15); 1 = the 1-D row ring

    def as_dict(self) -> dict:
        """JSON-ready record — the one serialization shared by the CLI's
        ``--json`` report, ``benchmarks/dse_sweep.py``'s
        ``BENCH_dse.json``, and study trial records (one schema —
        :data:`EXECUTED_POINT_FIELDS` — extended in one place)."""
        return {
            "block_h": int(self.block_h),
            "m": int(self.m),
            "d": int(self.d),
            "dx": int(self.dx),
            "double_buffer": bool(self.double_buffer),
            "b": int(self.b),
            "fusion": str(self.fusion),
            "steps": int(self.steps),
            "wall_s": float(self.wall_s),
            "measured_mlups": float(self.measured_mlups),
            "measured_gflops": float(self.measured_gflops),
            "predicted_gflops": float(self.predicted_gflops),
            "calibrated_gflops": (
                None if self.calibrated_gflops is None
                else float(self.calibrated_gflops)
            ),
            "rel_error": float(self.rel_error),
            "rel_error_model": float(self.rel_error_model),
            "cached": bool(self.cached),
            "reps": int(self.reps),
            "interpret": bool(self.interpret),
        }


def kernel_run_factory(kern, state, regs: Sequence, interpret: bool):
    """The default back end: a codegen'd StreamKernel, sharded for d>1.

    Returns the ``run_factory(nsteps, m, block_h, d, double_buffer, b,
    dx)`` the runner calls; ``d > 1`` plans go through
    ``kern.sharded(d, dx=dx)`` (cached per ``(d, dx)`` on the kernel,
    docs/pipeline.md §distribute) — ``dx > 1`` runs the ``(d//dx, dx)``
    device mesh (DESIGN.md §15) — and ``double_buffer`` selects the
    streamed launch's buffer protocol (docs/pipeline.md §stream).
    ``b > 1`` plans tile ``state`` into a ``(b, P, H, W)`` batch
    (docs/pipeline.md §serve); batched sharded geometry does not exist,
    so ``b > 1`` with ``d > 1`` declines.
    """
    import jax.numpy as jnp

    def run_factory(nsteps: int, m: int, block_h: int, d: int,
                    double_buffer: bool = True, b: int = 1, dx: int = 1):
        if b > 1:
            if d > 1:
                return None  # no batched sharded launch (see TPUModel)
            batched = jnp.stack([state] * b)
            return lambda: kern.run_blocked(
                batched, regs, steps=nsteps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        if d == 1:
            return lambda: kern.run_blocked(
                state, regs, steps=nsteps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        runner = kern.sharded(d, dx=dx)  # cached per (d, dx) on the kernel
        return lambda: runner.run_blocked(
            state, regs, steps=nsteps, m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )

    return run_factory


class SearchRunner:
    """Legalize → run → time → calibrate, with dedupe and a hard budget.

    Built once per search invocation (``Explorer.search`` /
    ``Explorer.execute_frontier``); strategies call :meth:`measure` per
    candidate and :meth:`point` to materialize neighborhood coordinates
    through the scalar model. All constructor arguments describe the
    fixed context of one search: the workload/grid being measured, the
    back end (``run_factory``), the measurement policy (reps/warmup/
    interpret/calibrate/cache), and the budget.
    """

    def __init__(
        self,
        *,
        workload: StreamWorkload,
        grid_shape: tuple[int, int],
        run_factory: Callable,
        model=None,
        scalar_kwargs: dict | None = None,
        fingerprint: str | None = None,
        halo: int | None = None,
        width: int | None = None,
        words: int | None = None,
        stages: tuple | None = None,
        steps: int | None = None,
        interpret: bool = True,
        reps: int = 3,
        warmup: int = 1,
        calibrate: bool = True,
        cache=None,
        budget: int | None = None,
        timer: Callable | None = None,
        max_devices: int | None = None,
    ):
        from .. import measure

        self.workload = workload
        self.h, self.w = int(grid_shape[0]), int(grid_shape[1])
        self.run_factory = run_factory
        self.model = model
        self.scalar_kwargs = dict(scalar_kwargs or {})
        self.fingerprint = fingerprint
        self.halo = workload.halo if halo is None else int(halo)
        # Column stencil reach for mesh (dx > 1) plans (DESIGN.md §15):
        # sizes the guard columns the legalizer prices per shard.
        self.halo_x = int(getattr(workload, "stencil_halo_x", self.halo))
        self.width = self.w if width is None else int(width)
        self.words = workload.words_in if words is None else int(words)
        # Per-stage (words, halo) geometry of a multi-core program: when
        # set, plans legalize through the fused-cluster accounting
        # (legalize.program_blocking_plan) at each point's fusion spec.
        self.stages = None if stages is None else tuple(stages)
        self.steps = steps
        self.interpret = bool(interpret)
        self.reps = int(reps)
        self.warmup = int(warmup)
        self.calibrate = bool(calibrate)
        self.cache = measure.resolve_cache(cache)
        if self.cache is not None and fingerprint is None:
            import warnings

            warnings.warn(
                "SearchRunner: measurement cache disabled — this back end "
                "has no core fingerprint; pass cache_tag= to identify the "
                "kernel",
                RuntimeWarning,
                stacklevel=3,
            )
            self.cache = None
        self.budget = None if budget is None else int(budget)
        if self.budget is not None and self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")
        self.timer = timer
        if max_devices is None:
            import jax

            max_devices = jax.device_count()
        self.max_devices = int(max_devices)
        self.backend = measure.backend_descriptor()
        # ---- durable study attachment (docs/pipeline.md §study) -----------
        # Explorer.search wires these after replaying a resumed study's
        # completed trials into `_walls`: every measured point is then
        # journaled to the study as a trial, and replayed plans are free.
        self.study = None
        self.study_meta: dict = {}
        self.replayed = 0  # trials replayed into the dedupe table on resume
        # ---- per-search state ---------------------------------------------
        self.budget_spent = 0  # live timings charged against the budget
        self.skipped_devices = 0  # candidates needing more devices than we have
        self.skipped_illegal = 0  # candidates with no legal run plan
        self._walls: dict[tuple, float] = {}  # plan.key() -> wall_s (dedupe)
        self._counts: dict[tuple, int] = {}  # plan.key() -> live timings
        self._cal_models: dict[int, object] = {}
        self._cal_mem: list[float] = []  # bandwidth probe, shared across d
        # ---- next-candidate prefetch (docs/pipeline.md §search) ------------
        # When a budget cut-off interrupts a strategy, the point it was
        # about to measure is recorded here; SearchStepper.step hands it
        # to prefetch() so its compile/warm-up runs on idle devices while
        # the caller ticks — timed reps never overlap the warm-up
        # (measure() joins any in-flight prefetch before timing).
        self.last_blocked = None  # the candidate BudgetExhausted cut off
        self.prefetched = 0  # warm-ups dispatched (observability)
        self._prefetch = None  # (plan.key(), Thread) of an in-flight warm-up

    # ---- model-side helpers ------------------------------------------------

    def point(self, block_h: int, m: int, d: int = 1,
              double_buffer: bool | None = None,
              fusion: str | None = None,
              dx: int | None = None) -> DesignPoint | None:
        """Materialize a lattice coordinate through the scalar model.

        Strategies use this to price neighborhood moves (LocalRefine's
        (block_h, m, d, double_buffer, dx) steps) before spending budget
        on them. ``double_buffer=None`` inherits the sweep's setting (the
        runner's ``scalar_kwargs``); ``dx=None`` keeps the model's 1-D
        ring default (DESIGN.md §15). ``None`` when the runner was built
        without a model (custom back ends that only replay frontier
        points).
        """
        if self.model is None:
            return None
        kwargs = dict(self.scalar_kwargs)
        if double_buffer is not None:
            kwargs["double_buffer"] = bool(double_buffer)
        if fusion is not None:
            kwargs["fusion"] = str(fusion)
        if dx is not None:
            kwargs["dx"] = int(dx)
        return self.model.evaluate(
            self.workload, int(block_h), int(m), d=int(d), **kwargs,
        )

    def plan_for(self, point, *, reps: int | None = None) -> RunPlan | None:
        """The concrete legalized plan a point would execute as.

        ``None`` when the point cannot run here (device-starved or no
        legal plan) — used by strategies to dedupe candidate pools
        before spending any budget.
        """
        d = max(1, int(point.n))
        if d > self.max_devices:
            return None
        b = _point_b(point)
        fusion = _point_fusion(point)
        dx = _point_dx(point)
        try:
            block_h, m, nsteps, double_buffer = resolve_run_plan(
                self.h, point, self.steps, halo=self.halo,
                width=self.width, words=self.words, d=d, b=b,
                stages=self.stages, fusion=fusion,
                dx=dx, halo_x=self.halo_x,
            )
        except ValueError:
            return None
        return RunPlan(block_h, m, nsteps, d,
                       self.reps if reps is None else int(reps),
                       double_buffer, b, fusion, dx)

    # ---- cache / study key space -------------------------------------------

    def study_fingerprint(self) -> str | None:
        """The fingerprint namespace this runner's walls live in.

        An injected timer produces synthetic walls: they live in their
        own key namespace so an honest run can never be served a
        fabricated timing — from the cache *or* from a replayed study
        trial (docs/pipeline.md §study) — and vice versa.
        """
        if self.fingerprint is None:
            return None
        if self.timer is None:
            return self.fingerprint
        return f"injected-timer:{self.fingerprint}"

    def cache_key(self, plan: RunPlan) -> str | None:
        """The MeasurementCache key this plan's timing is stored under.

        The same content key identifies the plan in study trial records,
        which is what lets :meth:`Study.replay_into` and the TPE
        warm-start recognize already-measured plans across processes.
        ``None`` when the back end has no core fingerprint.
        """
        from .. import measure

        fp = self.study_fingerprint()
        if fp is None:
            return None
        plan_key = (plan.block_h, plan.m, plan.steps, plan.d,
                    int(plan.double_buffer), plan.b)
        if plan.fusion:  # "" keeps pre-program cache keys byte-identical
            plan_key = plan_key + (plan.fusion,)
        if plan.dx > 1:  # 1 keeps pre-mesh cache keys byte-identical
            # always carry the fusion slot before dx so key tuples stay
            # unambiguous by length (6 legacy / 7 fusion / 8 fusion+dx)
            if not plan.fusion:
                plan_key = plan_key + (plan.fusion,)
            plan_key = plan_key + (plan.dx,)
        return measure.MeasurementCache.make_key(
            fp, (self.h, self.w), plan_key,
            self.backend, self.interpret, plan.reps, self.warmup,
        )

    def peek_wall(self, plan: RunPlan) -> float | None:
        """A known wall time for this plan, or None — never measures.

        Checks the in-run dedupe table (which a resumed study replays
        into) and then the persistent cache, without charging budget or
        perturbing cache hit/miss statistics. Surrogate strategies use
        this to warm-start from prior knowledge before sampling.
        """
        wall = self._walls.get(plan.key())
        if wall is not None:
            return wall
        if self.cache is not None:
            key = self.cache_key(plan)
            if key is not None:
                rec = self.cache.peek(key)
                if rec is not None:
                    return float(rec["wall_s"])
        return None

    # ---- accounting --------------------------------------------------------

    def remaining(self) -> float:
        """Live measurements left under the budget (inf when unbudgeted)."""
        if self.budget is None:
            return float("inf")
        return max(0, self.budget - self.budget_spent)

    def measurements(self) -> list[dict]:
        """Per-candidate measurement counts: one record per concrete
        plan this search timed live (the ``--json`` / BENCH schema)."""
        return [
            {**RunPlan(*key).as_dict(), "count": count}
            for key, count in sorted(self._counts.items())
        ]

    # ---- the engine --------------------------------------------------------

    def measure(
        self, point, *, reps: int | None = None
    ) -> ExecutedPoint | None:
        """Legalize, execute and time one design point.

        Returns ``None`` when the point cannot run on this platform
        (more shards than devices, no legal plan, or a back end that
        declines it); raises :exc:`BudgetExhausted` when a live timing
        would exceed the budget. Identical plans — across lattice
        points, strategies, or (via the persistent cache) processes —
        are timed once.
        """
        from .. import measure

        d = max(1, int(point.n))
        if d > self.max_devices:
            self.skipped_devices += 1
            return None
        b = _point_b(point)
        fusion = _point_fusion(point)
        dx = _point_dx(point)
        reps = self.reps if reps is None else int(reps)
        try:
            block_h, m, nsteps, double_buffer = resolve_run_plan(
                self.h, point, self.steps, halo=self.halo,
                width=self.width, words=self.words, d=d, b=b,
                stages=self.stages, fusion=fusion,
                dx=dx, halo_x=self.halo_x,
            )
        except ValueError:
            self.skipped_illegal += 1
            return None
        plan = RunPlan(block_h, m, nsteps, d, reps, double_buffer, b,
                       fusion, dx)

        cached = True
        wall = self._walls.get(plan.key())  # in-run dedupe, cache-independent
        if wall is None:
            run = self._factory_run(plan)
            if run is None:
                return None  # this back end cannot execute the point
            key = None
            if self.cache is not None:
                key = self.cache_key(plan)
                if key is not None:
                    rec = self.cache.get(key)
                    if rec is not None:
                        wall = float(rec["wall_s"])
            if wall is None:
                if self.budget is not None and self.budget_spent >= self.budget:
                    # Remember the candidate this cut-off interrupted:
                    # SearchStepper hands it to prefetch() so its
                    # compile/warm-up overlaps the caller's ticks.
                    self.last_blocked = point
                    raise BudgetExhausted(
                        f"measurement budget of {self.budget} exhausted "
                        f"before timing plan {plan.as_dict()}"
                    )
                # Timed reps never overlap a background warm-up: wait
                # out any in-flight prefetch before the clock starts.
                self._join_prefetch()
                wall, record = self._time(plan, run)
                self.budget_spent += 1
                self._counts[plan.key()] = self._counts.get(plan.key(), 0) + 1
                cached = False
                if self.cache is not None and key is not None:
                    self.cache.put(key, record)
            self._walls[plan.key()] = wall

        sites = self.h * self.w * nsteps * b  # every batch member counts
        flops_per_elem = self.workload.flops_per_elem
        mlups = sites / wall / 1e6
        measured = sites * flops_per_elem / wall / 1e9
        predicted = point.sustained_gflops
        calibrated = None
        if self.calibrate:
            # Predict the geometry actually run (legalized plan, not the
            # raw lattice pick) under the measured platform constants.
            calibrated = self._calibrated_model(d, (block_h, m)).evaluate(
                self.workload, block_h, m, d=d, double_buffer=double_buffer,
                b=b, fusion=fusion, dx=dx,
            ).sustained_gflops
        headline = calibrated if calibrated is not None else predicted
        executed = ExecutedPoint(
            point=point,
            block_h=block_h,
            m=m,
            d=d,
            steps=nsteps,
            wall_s=wall,
            measured_mlups=mlups,
            measured_gflops=measured,
            predicted_gflops=predicted,
            rel_error=(headline - measured) / headline if headline else 0.0,
            interpret=self.interpret,
            calibrated_gflops=calibrated,
            rel_error_model=(
                (predicted - measured) / predicted if predicted else 0.0
            ),
            cached=cached,
            reps=reps,
            double_buffer=double_buffer,
            b=b,
            fusion=fusion,
            dx=dx,
        )
        if self.study is not None:
            self.study.record_trial(self, executed, **self.study_meta)
        return executed

    def log_violation(self, coords: tuple, violation: float) -> None:
        """Journal an infeasible candidate to the attached study.

        Surrogate strategies call this when they observe a candidate
        with a positive :func:`~repro.core.legalize.constraint_violation`
        distance; the study keeps it so a resumed search re-learns the
        infeasible region without re-deriving it. A no-op without a
        study.
        """
        if self.study is not None:
            self.study.record_violation(
                self, tuple(coords), float(violation), **self.study_meta
            )

    # ---- next-candidate prefetch (docs/pipeline.md §search) ---------------

    def prefetch(self, point=None) -> bool:
        """Dispatch a candidate's compile/warm-up on idle devices.

        The minimal parallel-trial-execution seam: when the trial under
        measurement uses fewer than the platform's devices
        (``plan.d < max_devices``), the *next* candidate's un-timed
        warm-up call runs on a background thread so its compile overlaps
        the caller's ticks instead of the next timed step.
        ``point=None`` consumes :attr:`last_blocked` — the candidate the
        last :exc:`BudgetExhausted` cut off, which is exactly what the
        strategy will ask for next (:class:`SearchStepper` relies on
        this). Measured wall-clock stays per-trial-isolated:
        :meth:`measure` joins any in-flight warm-up before its timed
        reps start, so timings never overlap. Returns ``True`` when a
        warm-up was dispatched.
        """
        if point is None:
            point, self.last_blocked = self.last_blocked, None
        if point is None:
            return False
        plan = self.plan_for(point)
        if plan is None or self._walls.get(plan.key()) is not None:
            return False
        if plan.d >= self.max_devices:
            return False  # the mesh uses every device: nothing is idle
        if self._prefetch is not None:
            if self._prefetch[1].is_alive():
                return False  # one in-flight warm-up at a time
            self._prefetch = None
        run = self._factory_run(plan)
        if run is None:
            return False
        import threading

        def warm():
            try:
                run()
            except Exception:
                pass  # a failing warm-up must never kill the search

        thread = threading.Thread(target=warm, daemon=True)
        thread.start()
        self._prefetch = (plan.key(), thread)
        self.prefetched += 1
        return True

    def _join_prefetch(self) -> None:
        """Wait out any in-flight warm-up (timed reps never overlap it)."""
        if self._prefetch is not None:
            self._prefetch[1].join()
            self._prefetch = None

    # ---- internals ---------------------------------------------------------

    def _factory_run(self, plan: RunPlan):
        """Build the nullary launch callable for a concrete plan.

        One dispatch chain shared by :meth:`measure` and
        :meth:`prefetch`: newer factory kwargs (``fusion``/``b``/``dx``)
        are only passed when the plan needs them, so legacy and custom
        back ends keep working unmodified; a back end that cannot
        express the plan returns (or is treated as) ``None``.
        """
        nsteps, m, block_h = plan.steps, plan.m, plan.block_h
        d, double_buffer, b = plan.d, plan.double_buffer, plan.b
        fusion, dx = plan.fusion, plan.dx
        if dx != 1:
            # Mesh plans need a dx-aware factory (DESIGN.md §15); back
            # ends that predate the axis cannot execute them.
            kwargs = {"b": b, "dx": dx}
            if fusion:
                kwargs["fusion"] = fusion
            try:
                return self.run_factory(nsteps, m, block_h, d,
                                        double_buffer, **kwargs)
            except TypeError:
                return None
        if fusion:
            # Program plans need a fusion-aware factory; single-core
            # back ends never see the kwarg for the "" spec.
            return self.run_factory(nsteps, m, block_h, d,
                                    double_buffer, b=b, fusion=fusion)
        if b != 1:
            # Batched plans need a batch-aware factory; older ones
            # (and custom back ends) never see the kwarg for b=1.
            return self.run_factory(nsteps, m, block_h, d,
                                    double_buffer, b=b)
        try:
            return self.run_factory(nsteps, m, block_h, d, double_buffer)
        except TypeError:  # legacy 4-arg factories predate the knob
            return self.run_factory(nsteps, m, block_h, d)

    def _time(self, plan: RunPlan, run: Callable) -> tuple[float, dict]:
        """One live timing: the injected timer or the honest harness."""
        from .. import measure

        if self.timer is not None:
            wall = float(self.timer(plan, run, plan.reps, self.warmup))
            return wall, {
                "wall_s": wall, "reps": plan.reps, "warmup": self.warmup,
            }
        timing = measure.time_run(run, reps=plan.reps, warmup=self.warmup)
        return timing.wall_s, {
            "wall_s": timing.wall_s,
            "times_s": list(timing.times_s),
            "reps": timing.reps,
            "warmup": timing.warmup,
            "overhead_s": timing.overhead_s,
        }

    def _calibrated_model(self, d: int, fallback_plan: tuple[int, int]):
        """Calibrated TPUModel for device count d (one probe per d).

        When none of the default probe anchors has a legal plan on this
        grid (e.g. a VMEM-tight width), the point's own legalized
        ``(block_h, m)`` — which just legalized, so it always works —
        becomes the anchor.
        """
        from .. import measure

        model = self._cal_models.get(d)
        if model is None:
            kw = dict(
                workload=self.workload,
                grid_shape=(self.h, self.w),
                halo=self.halo,
                width=self.width,
                words=self.words,
                d_values=(d,),
                interpret=self.interpret,
                reps=self.reps,
                warmup=self.warmup,
                cache=self.cache,
                fingerprint=self.fingerprint,
                mem_gbs=self._cal_mem[0] if self._cal_mem else None,
            )
            try:
                cal = measure.calibrate_execution(self.run_factory, **kw)
            except ValueError:
                kw["probe_plans"] = (fallback_plan,)
                cal = measure.calibrate_execution(self.run_factory, **kw)
            if not self._cal_mem:
                self._cal_mem.append(cal.mem_gbs)
            model = self._cal_models[d] = cal.model(d=d)
        return model
