"""Surrogate-model search: Tree-structured Parzen Estimator (TPE).

The hand-rolled strategies in ``strategies.py`` spend budget in fixed
patterns (walk the frontier, hill-climb, race rungs). :class:`TPESearch`
instead *learns where to measure next* from the measurements themselves
— the Optuna-style sampler the DSE harness in SNIPPETS.md builds its
studies on, specialized to the (n, m, d, block_h) lattice
(docs/pipeline.md §study, DESIGN.md §11):

* observed trials are split into **good** (top ``gamma`` quantile by
  measured GFLOP/s) and **bad** (the rest); two Parzen windows
  ``l(x)`` / ``g(x)`` — Gaussian kernels over the log2 coordinates —
  density-model each side, and the next candidate is the unmeasured one
  maximizing ``l(x)/g(x)``: likely-good, unlike-bad;
* **legalizer infeasibility is a continuous penalty**, not a hard
  reject: a candidate with no legal run plan is observed at its
  :func:`~repro.core.legalize.constraint_violation` distance and always
  classified *bad* — the sampler learns a gradient away from the
  infeasible region without spending a single measurement on it (the
  ``constraint_violation``-as-gradient idiom);
* the sampler **warm-starts from prior knowledge**: plans the attached
  :class:`~repro.core.search.study.Study` replayed into the runner's
  dedupe table and plans already in the persistent
  :class:`~repro.core.measure.MeasurementCache` for the same core
  fingerprint are observed first, for free — a resumed study continues
  where it stopped with zero re-measurement;
* every random draw comes from one ``numpy`` generator seeded with
  ``seed``, and every ranking uses stable order (model-best first), so
  a seeded search is **reproducible trial-for-trial** — the property
  the deterministic harness in ``tests/test_study.py`` asserts.

``max_trials`` bounds *observations* (measured + warm-started +
violations), while the runner's ``budget`` bounds live measurements;
a resumed study whose replayed trials already cover ``max_trials``
therefore spends zero budget.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from ..legalize import constraint_violation
from .runner import BudgetExhausted, ExecutedPoint, SearchRunner

__all__ = ["TPESearch"]


@dataclass(eq=False)  # identity equality: ndarray fields don't compare
class _Candidate:
    """One deduplicated lattice candidate the sampler can pick."""

    point: object  # the DesignPoint this candidate measures
    coords: tuple  # (block_h, m, d) — legalized when a plan exists
    x: np.ndarray  # log2 feature vector the Parzen windows model
    plan: object  # legalized RunPlan; None = infeasible (violation > 0)
    violation: float  # constraint_violation distance (0.0 = legal)
    model_gflops: float


@dataclass
class TPESearch:
    """Tree-structured Parzen Estimator over the (n, m, d, block_h) lattice.

    Parameters mirror the classic TPE knobs: ``n_startup`` observations
    are taken before density modeling starts (model-best first, then a
    seeded random permutation — exploration the model cannot bias);
    ``gamma`` is the good-quantile; ``bandwidth`` the Gaussian kernel
    width in log2 lattice units; ``prior_weight`` a uniform pseudo-count
    that keeps fresh densities from collapsing onto the first
    observations.
    """

    name: str = field(default="tpe", init=False)
    seed: int = 0
    n_startup: int = 4
    gamma: float = 0.25
    bandwidth: float = 0.75
    prior_weight: float = 1.0
    max_trials: int | None = None

    # ---- candidate pool ----------------------------------------------------

    def _candidates(self, sweep, runner: SearchRunner) -> list[_Candidate]:
        """The full lattice, model-best first, deduped, violations kept.

        Unlike ``_ranked_candidates`` this does *not* drop candidates
        without a legal plan: they become zero-cost violation
        observations that teach the sampler the feasible region's shape.
        Device-starved coordinates are dropped (no amount of sampling
        makes more chips appear).
        """
        gflops = np.asarray(sweep.data["sustained_gflops"], float)
        order = np.argsort(-gflops, kind="stable")
        seen_coords: set = set()
        seen_plans: set = set()
        out: list[_Candidate] = []
        for i in order:
            i = int(i)
            bh = int(sweep.data["block_rows"][i])
            m = int(sweep.data["m"][i])
            d = max(1, int(sweep.data["n"][i]))
            b = (int(sweep.data["b"][i]) if "b" in sweep.data else 1)
            fus = (str(sweep.data["fusion"][i])
                   if "fusion" in sweep.data else "")
            dxv = (max(1, int(sweep.data["dx"][i]))
                   if "dx" in sweep.data else 1)
            # Candidate coords stay numeric (the study journals them as
            # ints); the fusion spec joins the dedupe key separately.
            # The mesh axis joins only when column-sharded (DESIGN.md
            # §15), keeping pre-mesh coords — and old study violation
            # records — byte-identical.
            coords = (bh, m, d, b) if dxv == 1 else (bh, m, d, b, dxv)
            if coords + (fus,) in seen_coords:
                continue
            seen_coords.add(coords + (fus,))
            if d > runner.max_devices:
                runner.skipped_devices += 1
                continue
            pt = sweep.point(i)
            req_db = bool(
                (getattr(pt, "detail", None) or {}).get("double_buffer", True)
            )
            plan = runner.plan_for(pt)
            if plan is None:
                viol = constraint_violation(
                    runner.h, bh, m, halo=runner.halo, width=runner.width,
                    words=runner.words, d=d, double_buffer=req_db, b=b,
                    dx=dxv, halo_x=runner.halo_x,
                )
                out.append(_Candidate(
                    point=pt, coords=coords,
                    x=self._features(bh, m, d, req_db, b, fus, dxv),
                    plan=None, violation=max(viol, 1e-9),
                    model_gflops=float(gflops[i]),
                ))
                continue
            pkey = (plan.block_h, plan.m, plan.steps, plan.d,
                    plan.double_buffer, plan.b, plan.fusion, plan.dx)
            if pkey in seen_plans:
                continue  # same concrete plan: model-best spelling wins
            seen_plans.add(pkey)
            out.append(_Candidate(
                point=pt,
                coords=(
                    (plan.block_h, plan.m, plan.d, plan.b)
                    if plan.dx == 1
                    else (plan.block_h, plan.m, plan.d, plan.b, plan.dx)
                ),
                x=self._features(plan.block_h, plan.m, plan.d,
                                 plan.double_buffer, plan.b, plan.fusion,
                                 plan.dx),
                plan=plan, violation=0.0,
                model_gflops=float(gflops[i]),
            ))
        return out

    @staticmethod
    def _features(bh: int, m: int, d: int,
                  double_buffer: bool = True, b: int = 1,
                  fusion: str = "", dx: int = 1) -> np.ndarray:
        """Log2 lattice coordinates plus the binary buffer-protocol axis:
        the natural metric of a power-of-two sweep (one halving/doubling
        = one unit in every dimension; a double_buffer flip likewise,
        docs/pipeline.md §stream). The batch axis b joins in log2 too
        (docs/pipeline.md §serve), and a program's fusion partition
        (docs/pipeline.md §program) contributes its cluster count in
        log2 — finer partitions are farther from fully fused, and
        single-core plans ("" = one cluster) sit at the legacy origin.
        The mesh column axis dx (DESIGN.md §15) joins in log2 as well;
        ring plans (dx = 1) contribute 0, so pre-mesh sweeps keep their
        pairwise distances — and their seeded sampling order — exactly."""
        nclusters = fusion.count("+") + 1 if fusion else 1
        return np.array(
            [math.log2(max(1, bh)), math.log2(max(1, m)),
             math.log2(max(1, d)), float(bool(double_buffer)),
             math.log2(max(1, b)), math.log2(max(1, nclusters)),
             math.log2(max(1, dx))], float,
        )

    # ---- density model -----------------------------------------------------

    def _density(self, x: np.ndarray, obs: list[np.ndarray]) -> float:
        """Parzen window with a uniform prior pseudo-count."""
        k = 0.0
        for xo in obs:
            diff = x - xo
            k += math.exp(-float(diff @ diff) / (2.0 * self.bandwidth ** 2))
        return (self.prior_weight * 1.0 + k) / (self.prior_weight + len(obs))

    def _pick(self, pool: list[_Candidate],
              good: list[np.ndarray], bad: list[np.ndarray]) -> _Candidate:
        """argmax l(x)/g(x); ties resolve to the model-best candidate
        (the pool is model-ranked, and argmax keeps the first max)."""
        scores = np.array([
            self._density(c.x, good) / max(self._density(c.x, bad), 1e-12)
            for c in pool
        ])
        return pool[int(np.argmax(scores))]

    # ---- the strategy ------------------------------------------------------

    def search(self, sweep, runner: SearchRunner) -> list[ExecutedPoint]:
        rng = np.random.default_rng(self.seed)
        pool = self._candidates(sweep, runner)
        out: list[ExecutedPoint] = []
        good_obs: list[tuple[float, np.ndarray]] = []  # (gflops, x) feasible
        bad_x: list[np.ndarray] = []  # violation observations (always bad)
        trials = 0

        def room() -> bool:
            return self.max_trials is None or trials < self.max_trials

        def observe(c: _Candidate) -> ExecutedPoint | None:
            nonlocal trials
            trials += 1
            if c.plan is None:
                bad_x.append(c.x)
                runner.log_violation(c.coords, c.violation)
                return None
            e = runner.measure(c.point)
            if e is None:
                return None
            good_obs.append((e.measured_gflops, c.x))
            out.append(e)
            return e

        # Phase 0 — warm start: anything the study replayed or the
        # persistent cache already holds is observed for free, and
        # counts toward max_trials (that is what makes a fully-replayed
        # resume spend zero budget).
        remaining: list[_Candidate] = []
        for c in pool:
            if (c.plan is not None and room()
                    and runner.peek_wall(c.plan) is not None):
                observe(c)
            else:
                remaining.append(c)

        # Phase 1 — startup: the model's best first, then a seeded
        # permutation of the rest, until n_startup total observations.
        if remaining and room() and trials < self.n_startup:
            startup = [remaining[0]]
            rest = remaining[1:]
            if rest:
                startup.extend(
                    rest[int(j)] for j in rng.permutation(len(rest))
                )
            taken: list[_Candidate] = []
            try:
                for c in startup:
                    if not room() or trials >= self.n_startup:
                        break
                    observe(c)
                    taken.append(c)
            except BudgetExhausted:
                return out
            remaining = [c for c in remaining if c not in taken]

        # Phase 2 — TPE: split observations good/bad, model densities,
        # measure the argmax of l/g, repeat.
        try:
            while remaining and room():
                if good_obs:
                    ranked = sorted(good_obs, key=lambda t: -t[0])
                    n_good = max(1, math.ceil(self.gamma * len(ranked)))
                    good = [x for _, x in ranked[:n_good]]
                    bad = [x for _, x in ranked[n_good:]] + bad_x
                else:
                    good, bad = [], bad_x
                c = self._pick(remaining, good, bad)
                remaining.remove(c)
                observe(c)
        except BudgetExhausted:
            pass
        return out
