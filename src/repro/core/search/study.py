"""Durable, resumable search studies (docs/pipeline.md §study).

A :class:`Study` is a named JSON-lines journal of everything a search
learned: every measured trial (the full
:data:`~repro.core.search.runner.EXECUTED_POINT_FIELDS` record plus its
measurement context) and every infeasible candidate (its lattice
coordinates and continuous
:func:`~repro.core.legalize.constraint_violation` distance). Trials are
keyed by the same content fingerprints as
:class:`~repro.core.measure.MeasurementCache` — the core-IR fingerprint,
grid shape, backend descriptor, interpret flag and measurement policy —
so a study written by one process is meaningful to any other process
measuring the same kernel, and synthetic walls from an injected test
timer (namespaced ``injected-timer:``) can never replay into an honest
run.

The write path is a single ``os.write`` on an ``O_APPEND`` descriptor
per record: POSIX appends of one small buffer are atomic, so two
processes appending trials to the same study concurrently interleave
whole records and lose nothing (the concurrency regression test in
``tests/test_study.py`` exercises exactly this). Loading tolerates a
torn trailing line — a crash mid-append costs at most the record being
written, never the journal.

``Study.resume(name, dir)`` re-opens a journal by name;
:meth:`Study.replay_into` then seeds a
:class:`~repro.core.search.runner.SearchRunner`'s plan-dedupe table with
every context-matching measured wall, so an interrupted search continues
with **zero** re-measurement — a replayed plan is served from the dedupe
table before the budget check, exactly like an in-run duplicate.
:meth:`Study.report` renders the journal as a convergence/Pareto report
(text and a self-contained HTML page) for the BENCH artifacts.
"""

from __future__ import annotations

import json
import os

__all__ = [
    "Study",
    "TRIAL_CONTEXT_FIELDS",
    "default_study_dir",
]

#: The measurement-context keys every trial record carries (in addition
#: to ``point`` / ``coords``). Together they name the same identity as a
#: MeasurementCache key: a trial replays into a runner only when all of
#: them match the runner's own context.
TRIAL_CONTEXT_FIELDS = (
    "fingerprint",
    "grid",
    "backend",
    "interpret",
    "warmup",
)


def default_study_dir() -> str:
    """Where named studies live: ``$REPRO_STUDY_DIR`` or
    ``~/.cache/repro/studies`` (parallel to the measurement cache)."""
    env = os.environ.get("REPRO_STUDY_DIR")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "studies"
    )


class Study:
    """A named durable journal of search trials.

    Parameters
    ----------
    name:
        The study's identity. Resuming a search means re-opening a
        study with the same name in the same directory.
    dir:
        Directory holding ``<name>.jsonl``; :func:`default_study_dir`
        when omitted.
    """

    VERSION = 1

    def __init__(self, name: str, dir: str | None = None):
        if not name or os.sep in name or name.startswith("."):
            raise ValueError(f"invalid study name: {name!r}")
        self.name = name
        self.dir = default_study_dir() if dir is None else str(dir)
        self.path = os.path.join(self.dir, f"{name}.jsonl")
        self.records: list[dict] = []
        self._seen: set[tuple] = set()  # identity of every loaded/written rec
        self._load()

    # ---- construction ------------------------------------------------------

    @classmethod
    def resume(cls, name: str, dir: str | None = None) -> "Study":
        """Re-open a study by name (creating it if it does not exist yet).

        Identical to the constructor — the separate name documents
        intent at call sites: ``Study.resume("nightly-lbm")`` says the
        prior trials are expected and will be replayed.
        """
        return cls(name, dir)

    # ---- persistence -------------------------------------------------------

    def _load(self) -> None:
        self.records = []
        self._seen = set()
        try:
            with open(self.path, encoding="utf-8") as fh:
                raw = fh.read()
        except FileNotFoundError:
            return
        for line in raw.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue  # torn trailing line from a crashed writer
            if not isinstance(rec, dict):
                continue
            self.records.append(rec)
            ident = self._identity(rec)
            if ident is not None:
                self._seen.add(ident)

    def reload(self) -> None:
        """Re-read the journal (picks up records from other processes)."""
        self._load()

    def _append(self, rec: dict) -> None:
        """Durably append one record: a single atomic O_APPEND write."""
        os.makedirs(self.dir, exist_ok=True)
        data = (json.dumps(rec, sort_keys=True) + "\n").encode("utf-8")
        fd = os.open(self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
        try:
            os.write(fd, data)
        finally:
            os.close(fd)
        self.records.append(rec)
        ident = self._identity(rec)
        if ident is not None:
            self._seen.add(ident)

    @staticmethod
    def _identity(rec: dict) -> tuple | None:
        """What makes two records duplicates of one another.

        Measured trials: the measurement context plus the concrete
        legalized plan. Violations: the context plus the raw lattice
        coordinates. ``None`` for unrecognized records (never deduped).
        """
        ctx = tuple(
            json.dumps(rec.get(f), sort_keys=True)
            for f in TRIAL_CONTEXT_FIELDS
        )
        point = rec.get("point")
        if isinstance(point, dict):
            return ctx + (
                "trial",
                point.get("block_h"), point.get("m"),
                point.get("steps"), point.get("d"), point.get("reps"),
                # Older journals predate the double_buffer plan dimension
                # (docs/pipeline.md §stream); they recorded the
                # then-default ping/pong protocol. Likewise b=1 for
                # journals older than the batch axis
                # (docs/pipeline.md §serve).
                bool(point.get("double_buffer", True)),
                int(point.get("b", 1)),
                # "" for journals older than the fusion plan dimension
                # (docs/pipeline.md §program).
                str(point.get("fusion", "") or ""),
                # 1 (the row ring) for journals older than the mesh
                # column axis (DESIGN.md §15): a d-only record resumes
                # into the (dy, dx) identity with zero re-measurement.
                int(point.get("dx", 1) or 1),
            )
        coords = rec.get("coords")
        if coords is not None:
            return ctx + ("violation", tuple(coords))
        return None

    # ---- recording ---------------------------------------------------------

    def _context(self, runner) -> dict:
        return {
            "fingerprint": runner.study_fingerprint(),
            "grid": [runner.h, runner.w],
            "backend": runner.backend,
            "interpret": bool(runner.interpret),
            "warmup": int(runner.warmup),
        }

    def record_trial(self, runner, executed, **meta) -> bool:
        """Journal one measured point; False when it is already recorded.

        ``executed`` is an :class:`~repro.core.search.runner
        .ExecutedPoint`; its ``as_dict()`` — the one executed-point
        schema — becomes the record's ``point`` field verbatim, and the
        record also carries the runner's MeasurementCache key for the
        plan so cache and study agree on the plan's content identity.
        """
        from .runner import RunPlan

        point = executed.as_dict()
        plan = RunPlan.from_dict(point)
        rec = {
            "v": self.VERSION,
            "study": self.name,
            "trial": len(self.records),
            "key": runner.cache_key(plan),
            **self._context(runner),
            "point": point,
            "violation": 0.0,
            **{k: v for k, v in meta.items() if v is not None},
        }
        if self._identity(rec) in self._seen:
            return False
        self._append(rec)
        return True

    def record_violation(self, runner, coords: tuple,
                         violation: float, **meta) -> bool:
        """Journal an infeasible candidate's (block_h, m, d) coordinates
        and its continuous constraint-violation distance."""
        rec = {
            "v": self.VERSION,
            "study": self.name,
            "trial": len(self.records),
            "key": None,
            **self._context(runner),
            "point": None,
            "coords": [int(c) for c in coords],
            "violation": float(violation),
            **{k: v for k, v in meta.items() if v is not None},
        }
        if self._identity(rec) in self._seen:
            return False
        self._append(rec)
        return True

    # ---- queries -----------------------------------------------------------

    def _matches(self, rec: dict, ctx: dict) -> bool:
        return all(rec.get(f) == ctx[f] for f in TRIAL_CONTEXT_FIELDS)

    def trials_for(self, runner) -> list[dict]:
        """Every measured trial recorded under this runner's context."""
        ctx = self._context(runner)
        return [
            r for r in self.records
            if isinstance(r.get("point"), dict) and self._matches(r, ctx)
        ]

    def violations_for(self, runner) -> list[dict]:
        """Every infeasible-candidate record under this runner's context."""
        ctx = self._context(runner)
        return [
            r for r in self.records
            if r.get("point") is None and r.get("coords") is not None
            and self._matches(r, ctx)
        ]

    def replay_into(self, runner) -> int:
        """Seed the runner's plan-dedupe table from completed trials.

        Every measured trial whose context (fingerprint, grid, backend,
        interpret, warmup) matches the runner becomes an entry in its
        in-run wall table — the table :meth:`SearchRunner.measure`
        consults *before* the budget check, so a replayed plan costs
        zero budget and zero kernel runs. Returns the number of plans
        replayed; the runner's ``replayed`` counter is bumped so the
        search result can report it.
        """
        from .runner import RunPlan

        n = 0
        for rec in self.trials_for(runner):
            plan = RunPlan.from_dict(rec["point"])
            if plan.key() not in runner._walls:
                runner._walls[plan.key()] = float(rec["point"]["wall_s"])
                n += 1
        runner.replayed += n
        return n

    # ---- reporting ---------------------------------------------------------

    def _measured(self) -> list[dict]:
        return [r for r in self.records if isinstance(r.get("point"), dict)]

    def convergence(self) -> list[tuple[int, float]]:
        """(trial index, best measured GFLOP/s so far) per measured trial."""
        out, best = [], float("-inf")
        for i, rec in enumerate(self._measured()):
            g = float(rec["point"]["measured_gflops"])
            best = max(best, g)
            out.append((i, best))
        return out

    def pareto(self) -> list[dict]:
        """Non-dominated trials over (measured GFLOP/s ↑, devices ↓).

        The paper's trade-off: more spatial parallelism (d) buys
        throughput at the cost of devices; the Pareto set is every trial
        no other trial beats on both axes.
        """
        meas = self._measured()
        front = []
        for rec in meas:
            p = rec["point"]
            dominated = any(
                float(o["point"]["measured_gflops"])
                >= float(p["measured_gflops"])
                and int(o["point"]["d"]) <= int(p["d"])
                and (
                    float(o["point"]["measured_gflops"])
                    > float(p["measured_gflops"])
                    or int(o["point"]["d"]) < int(p["d"])
                )
                for o in meas
            )
            if not dominated:
                front.append(rec)
        front.sort(key=lambda r: (int(r["point"]["d"]),
                                  -float(r["point"]["measured_gflops"])))
        # one representative per device count
        seen_d, uniq = set(), []
        for rec in front:
            d = int(rec["point"]["d"])
            if d not in seen_d:
                seen_d.add(d)
                uniq.append(rec)
        return uniq

    def report_text(self) -> str:
        """Human-readable convergence + Pareto summary of the journal."""
        meas = self._measured()
        nviol = len(self.records) - len(meas)
        lines = [
            f"study {self.name!r}: {len(self.records)} records "
            f"({len(meas)} measured trials, {nviol} infeasible candidates)",
        ]
        if not meas:
            lines.append("  (no measured trials yet)")
            return "\n".join(lines)
        conv = self.convergence()
        best_rec = max(
            meas, key=lambda r: float(r["point"]["measured_gflops"])
        )
        bp = best_rec["point"]
        lines.append(
            f"  best: {bp['measured_gflops']:.3f} GFLOP/s at "
            f"block_h={bp['block_h']} m={bp['m']} d={bp['d']} "
            f"(trial {meas.index(best_rec)})"
        )
        lines.append("  convergence (trial -> best-so-far GFLOP/s):")
        step = max(1, len(conv) // 8)
        shown = conv[::step]
        if shown[-1] != conv[-1]:
            shown.append(conv[-1])
        for i, best in shown:
            lines.append(f"    {i:4d}  {best:.3f}")
        lines.append("  pareto (devices -> best GFLOP/s):")
        for rec in self.pareto():
            p = rec["point"]
            lines.append(
                f"    d={p['d']:2d}  {p['measured_gflops']:.3f} GFLOP/s  "
                f"(block_h={p['block_h']}, m={p['m']})"
            )
        return "\n".join(lines)

    def report_html(self) -> str:
        """Self-contained HTML report: convergence SVG + Pareto table.

        No external assets or scripts — one file that renders anywhere,
        suitable for CI artifact upload next to ``BENCH_dse.json``.
        """
        conv = self.convergence()
        pareto = self.pareto()
        meas = self._measured()
        svg = self._convergence_svg(conv)
        rows = "\n".join(
            "<tr><td>{d}</td><td>{g:.3f}</td><td>{bh}</td><td>{m}</td>"
            "<td>{s}</td></tr>".format(
                d=r["point"]["d"], g=float(r["point"]["measured_gflops"]),
                bh=r["point"]["block_h"], m=r["point"]["m"],
                s=r.get("strategy", "?"),
            )
            for r in pareto
        )
        return f"""<!DOCTYPE html>
<html lang="en"><head><meta charset="utf-8">
<title>study {self.name}</title>
<style>
 body {{ font: 14px/1.5 system-ui, sans-serif; margin: 2em; }}
 table {{ border-collapse: collapse; }}
 td, th {{ border: 1px solid #999; padding: 0.3em 0.8em; }}
 svg {{ border: 1px solid #ccc; }}
</style></head><body>
<h1>Study <code>{self.name}</code></h1>
<p>{len(self.records)} records — {len(meas)} measured trials,
{len(self.records) - len(meas)} infeasible candidates.</p>
<h2>Convergence (best measured GFLOP/s by trial)</h2>
{svg}
<h2>Pareto front: throughput vs device count</h2>
<table><tr><th>d</th><th>GFLOP/s</th><th>block_h</th><th>m</th>
<th>strategy</th></tr>
{rows}
</table>
<pre>{self.report_text()}</pre>
</body></html>
"""

    @staticmethod
    def _convergence_svg(conv: list[tuple[int, float]],
                         w: int = 560, h: int = 240) -> str:
        if not conv:
            return "<p>(no measured trials)</p>"
        xs = [i for i, _ in conv]
        ys = [g for _, g in conv]
        x0, x1 = min(xs), max(max(xs), min(xs) + 1)
        y0, y1 = 0.0, max(max(ys), 1e-12)
        pad = 30
        def px(x):  # noqa: E306 — tiny local mappers
            return pad + (x - x0) / (x1 - x0) * (w - 2 * pad)
        def py(y):
            return h - pad - (y - y0) / (y1 - y0) * (h - 2 * pad)
        pts = " ".join(f"{px(i):.1f},{py(g):.1f}" for i, g in conv)
        return (
            f'<svg width="{w}" height="{h}" viewBox="0 0 {w} {h}" '
            f'xmlns="http://www.w3.org/2000/svg">'
            f'<line x1="{pad}" y1="{h - pad}" x2="{w - pad}" '
            f'y2="{h - pad}" stroke="#333"/>'
            f'<line x1="{pad}" y1="{pad}" x2="{pad}" y2="{h - pad}" '
            f'stroke="#333"/>'
            f'<polyline points="{pts}" fill="none" stroke="#06c" '
            f'stroke-width="2"/>'
            f'<text x="{w - pad}" y="{h - 8}" text-anchor="end" '
            f'font-size="11">trial</text>'
            f'<text x="6" y="{pad}" font-size="11">{y1:.2f} GF/s</text>'
            "</svg>"
        )

    def report(self, out_dir: str | None = None,
               basename: str | None = None) -> dict:
        """Write the text and HTML reports; returns their paths + text."""
        out_dir = self.dir if out_dir is None else str(out_dir)
        base = basename or f"{self.name}.report"
        os.makedirs(out_dir, exist_ok=True)
        text = self.report_text()
        html = self.report_html()
        txt_path = os.path.join(out_dir, f"{base}.txt")
        html_path = os.path.join(out_dir, f"{base}.html")
        with open(txt_path, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
        with open(html_path, "w", encoding="utf-8") as fh:
            fh.write(html)
        return {"text": txt_path, "html": html_path, "summary": text}
