"""Streaming program graphs: multi-core fusion/pipelining as one layer.

The paper's DSL is hierarchical — full applications are chains of
stream cores, and the DSE picks the parallelism mix for the whole
structure. This module is that layer (docs/pipeline.md §program,
DESIGN.md §14): a :class:`StreamProgram` takes a DAG of compiled SPD
cores (producer→consumer edges with per-edge stencil extents) and
lowers each *fusion cluster* of a partition to one ``pallas_call``:

* **fused** — a cluster's member stages are chained inside a single
  stripe body, by synthesizing an SPD wrapper core that calls the
  member cores in sequence (the same sub-core chaining idiom as
  ``apps.lbm.pe_spd``) with edge extents realized as ``Stencil2D``
  nodes; the wrapper compiles through the ordinary
  :class:`~repro.core.codegen.StreamKernel` path, so stencil-offset
  inference composes the member halos automatically and the launch is
  the standard ``m``-blocked temporal-blocking kernel.
* **pipelined** — clusters on either side of a *cut* edge run as
  chained launches: one jitted ``fori_loop`` advances the program a
  step at a time, each step running every cluster's kernel back to
  back, so intermediate fields stay on device between launches (no
  host round-trip — asserted under ``jax.transfer_guard`` in
  ``tests/test_program.py``).

The fusion partition (``"3"`` fully fused, ``"1+2"``, ``"1+1+1"`` fully
pipelined — :func:`repro.core.legalize.parse_fusion`) is a first-class
plan dimension: legalized by
:func:`~repro.core.legalize.program_blocking_plan` (cluster stripes are
the *sum* of member-stage stripes at the *composed* halo), priced by
``TPUModel.evaluate(..., fusion=)`` (one HBM pass when fused, one per
cluster per step when pipelined), and searched through the
``repro.core.search`` strategies next to ``(n, m, d, block_h,
double_buffer, b)``.

Supported graphs: linear chains (every stage has exactly one producer
and one consumer edge). A general DAG is validated down to this shape —
diamond/fan-out programs raise :class:`ProgramError`; the partition
algebra below is defined on chains and the acceptance apps (uLBM's
collide+stream → boundary → moments, advection → react/diffuse) are
chains.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from .codegen import CodegenError, StreamKernel, stencil_summary
from .compiler import CompiledCore, Registry
from .dfg import SPDError
from .legalize import parse_fusion, resolve_run_plan
from .spd import parse_spd


class ProgramError(SPDError):
    """The core DAG cannot be lowered as a stream program (with why)."""


def fusion_partitions(nstages: int) -> tuple[str, ...]:
    """All fusion partition specs of an ``nstages``-stage chain.

    The 2^(n-1) ordered compositions of ``nstages``, as canonical
    ``"+"``-joined specs — ``fusion_partitions(3)`` is ``('3', '2+1',
    '1+2', '1+1+1')`` (fully fused first, fully pipelined last). This
    is the fusion axis the sweep lattice enumerates (docs/pipeline.md
    §program).
    """

    def _comps(n):
        if n == 0:
            yield ()
            return
        for first in range(n, 0, -1):
            for rest in _comps(n - first):
                yield (first,) + rest

    return tuple(
        "+".join(str(s) for s in comp) for comp in _comps(int(nstages))
    )


@dataclass(frozen=True)
class ProgramStage:
    """One stage of a stream program: a compiled core plus the
    ``(dy, dx)`` stencil extent of its incoming producer edge (``(0, 0)``
    for the source stage — there is no edge feeding it)."""

    compiled: CompiledCore
    extent: tuple[int, int] = (0, 0)

    @property
    def name(self) -> str:
        return self.compiled.core.name


class StreamProgram:
    """A producer→consumer DAG of SPD cores, lowerable per fusion
    partition (docs/pipeline.md §program, DESIGN.md §14).

    ``stages`` are compiled cores (or registry names) sharing one
    registry; ``edges`` are ``(producer, consumer)`` or ``(producer,
    consumer, (dy, dx))`` tuples over stage indices or names, validated
    to form the linear chain ``0 → 1 → … → n-1`` (``None`` means the
    chain with zero extents). Every stage must be stream-lowerable on
    its own (``|main_in| == |main_out|``, no branch streams) and all
    stages must agree on the main port count ``P`` — cluster launches
    chain ``(P, H, W)`` states stage to stage exactly as fused steps
    chain them within one core.

    ``Append_Reg`` scalars concatenate in stage order into one flat
    program register tuple; cluster kernels slice their members' span.
    """

    def __init__(self, registry: Registry, stages: Sequence,
                 edges: Sequence | None = None, *, width: int = 0,
                 name: str = "program"):
        self.registry = registry
        self.name = str(name)
        self.width = int(width)
        resolved = []
        for s in stages:
            if isinstance(s, str):
                s = registry.lookup(s)
            if not isinstance(s, CompiledCore):
                raise ProgramError(
                    f"program stage {s!r} is not a compiled SPD core"
                )
            resolved.append(s)
        if not resolved:
            raise ProgramError("a stream program needs >= 1 stage")
        names = [c.core.name for c in resolved]
        if len(set(names)) != len(names):
            raise ProgramError(f"duplicate stage cores: {names}")
        extents = self._chain_extents(names, edges)
        self.stages: tuple[ProgramStage, ...] = tuple(
            ProgramStage(c, e) for c, e in zip(resolved, extents)
        )
        ports = None
        for st in self.stages:
            core = st.compiled.core
            if core.brch_input_ports() or core.brch_output_ports():
                raise ProgramError(
                    f"stage {core.name}: branch streams are not "
                    "lowerable in a stream program"
                )
            if len(core.main_input_ports()) != len(core.main_output_ports()):
                raise ProgramError(
                    f"stage {core.name}: |main_in| != |main_out| "
                    f"({len(core.main_input_ports())} != "
                    f"{len(core.main_output_ports())}); program edges "
                    "chain outputs into the consumer's inputs"
                )
            if ports is None:
                ports = len(core.main_input_ports())
            elif len(core.main_input_ports()) != ports:
                raise ProgramError(
                    f"stage {core.name} has {len(core.main_input_ports())} "
                    f"main ports, chain carries {ports}; all stages of a "
                    "program share one (P, H, W) stream shape"
                )
            if st.extent != (0, 0) and not self.width:
                raise ProgramError(
                    f"edge into stage {core.name} has extent {st.extent}; "
                    "non-zero edge extents need the program's grid "
                    "width (StreamProgram(..., width=W)) to synthesize "
                    "their Stencil2D nodes"
                )
        self.P = ports
        self._cluster_kernels: dict[tuple[int, int], StreamKernel] = {}
        self._program_kernels: dict[str, "ProgramKernel"] = {}

    @staticmethod
    def _chain_extents(names, edges):
        """Validate the edge set as the linear chain; per-stage extents."""
        n = len(names)
        if edges is None:
            return [(0, 0)] * n
        index = {nm: i for i, nm in enumerate(names)}
        extents = [(0, 0)] * n
        seen = set()
        for e in edges:
            if len(e) == 2:
                prod, cons = e
                ext = (0, 0)
            else:
                prod, cons, ext = e
            prod = index[prod] if isinstance(prod, str) else int(prod)
            cons = index[cons] if isinstance(cons, str) else int(cons)
            if cons != prod + 1 or not (0 <= prod < n - 1):
                raise ProgramError(
                    f"edge {prod}->{cons} is not a chain edge; stream "
                    "programs support linear chains (stage i feeds "
                    "stage i+1) — diamond/fan-out DAGs are not lowerable"
                )
            if (prod, cons) in seen:
                raise ProgramError(f"duplicate edge {prod}->{cons}")
            seen.add((prod, cons))
            dy, dx = ext
            extents[cons] = (int(dy), int(dx))
        if len(seen) != n - 1:
            missing = [
                (i, i + 1) for i in range(n - 1) if (i, i + 1) not in seen
            ]
            raise ProgramError(
                f"program edges leave the chain disconnected: missing "
                f"{missing}"
            )
        return extents

    # ---- per-stage geometry (the legalizer/model contract) ----------------

    @property
    def nstages(self) -> int:
        return len(self.stages)

    def stage_halo(self, k: int) -> int:
        """Per-step stencil reach of stage ``k`` *through* its incoming
        edge: the stage's own inferred halo composed with the producer
        edge's extent (satellite memoization keys on this pair — see
        :func:`repro.core.codegen.stencil_summary`)."""
        st = self.stages[k]
        return stencil_summary(
            st.compiled, incoming=(st.extent,) * self.P
        ).halo()

    def stage_geometry(self) -> tuple[tuple[int, int], ...]:
        """``(words, halo)`` per stage, in chain order — the ``stages``
        argument of :func:`repro.core.legalize.program_blocking_plan`:
        every stage stripes the full ``P``-channel state, and a fused
        cluster's composed halo is the sum of its members' entries."""
        return tuple(
            (self.P, self.stage_halo(k)) for k in range(self.nstages)
        )

    # ---- cluster synthesis -------------------------------------------------

    def _cluster_spd(self, lo: int, hi: int) -> str:
        """SPD text of the wrapper core fusing stages [lo, hi).

        The member cores are chained as sub-core calls (the ``pe_spd``
        idiom); each stage's incoming-edge extent — including the *cut*
        edge feeding the cluster when ``lo > 0`` — becomes a per-port
        ``Stencil2D`` node ahead of the stage call, so every program
        edge is applied exactly once across any partition.
        """
        xin = [f"x{j}" for j in range(self.P)]
        yout = [f"y{j}" for j in range(self.P)]
        lines = [
            f"Name {self.name}_f{lo}_{hi};",
            f"Main_In {{mi::{','.join(xin)}}};",
            f"Main_Out {{mo::{','.join(yout)}}};",
        ]
        regs = [
            f"s{k}_{r}"
            for k in range(lo, hi)
            for r in self.stages[k].compiled.core.regs
        ]
        if regs:
            lines.append(f"Append_Reg {{rg::{','.join(regs)}}};")
        cur = xin
        for k in range(lo, hi):
            dy, dx = self.stages[k].extent if k > 0 else (0, 0)
            if (dy, dx) != (0, 0):
                nxt = [f"e{k}_{j}" for j in range(self.P)]
                for j in range(self.P):
                    lines.append(
                        f"HDL E{k}_{j}, 0, ({nxt[j]}) = "
                        f"Stencil2D({cur[j]}), dy={dy}, dx={dx}, "
                        f"W={self.width}, mode=wrap;"
                    )
                cur = nxt
            outs = yout if k == hi - 1 else [
                f"t{k}_{j}" for j in range(self.P)
            ]
            args = cur + [
                f"s{k}_{r}" for r in self.stages[k].compiled.core.regs
            ]
            lines.append(
                f"HDL S{k}, 0, ({','.join(outs)}) = "
                f"{self.stages[k].name}({','.join(args)});"
            )
            cur = outs
        return "\n".join(lines) + "\n"

    def cluster_kernel(self, lo: int, hi: int) -> StreamKernel:
        """The :class:`StreamKernel` of the fused span [lo, hi), cached
        per span so partitions sharing a cluster share one kernel (and
        one jit cache)."""
        if not (0 <= lo < hi <= self.nstages):
            raise ProgramError(f"bad cluster span [{lo}, {hi})")
        key = (lo, hi)
        if key not in self._cluster_kernels:
            compiled = self.registry.compile(
                parse_spd(self._cluster_spd(lo, hi))
            )
            self._cluster_kernels[key] = StreamKernel(compiled)
        return self._cluster_kernels[key]

    def monolithic_kernel(self) -> StreamKernel:
        """The fully fused single-core kernel — the program's reference
        semantics (one stripe body chaining every stage)."""
        return self.cluster_kernel(0, self.nstages)

    def kernel(self, fusion: str = "") -> "ProgramKernel":
        """The program lowered under a fusion partition, cached per
        canonical spec (``""`` means fully fused)."""
        sizes = parse_fusion(fusion, self.nstages)
        spec = "+".join(str(s) for s in sizes)
        if spec not in self._program_kernels:
            self._program_kernels[spec] = ProgramKernel(self, spec)
        return self._program_kernels[spec]

    # ---- registers ---------------------------------------------------------

    def reg_names(self) -> tuple[str, ...]:
        """Flat program register names, stage order (``s{k}_{reg}``)."""
        return tuple(
            f"s{k}_{r}"
            for k, st in enumerate(self.stages)
            for r in st.compiled.core.regs
        )

    def reg_slice(self, lo: int, hi: int) -> slice:
        """Span of the flat register tuple owned by stages [lo, hi)."""
        counts = [len(st.compiled.core.regs) for st in self.stages]
        return slice(sum(counts[:lo]), sum(counts[:hi]))

    # ---- DSE hand-off ------------------------------------------------------

    def workload(self, elems: int, grid_w: int = 0):
        """Bind the program to a stream length: a
        :class:`~repro.core.dse.StreamWorkload` whose ``stages`` carry
        the per-stage (flops, words, halo) triples the fusion-aware
        model prices cluster by cluster (docs/pipeline.md §program)."""
        from .dse import StreamWorkload

        reports = [st.compiled.hardware_report for st in self.stages]
        stage_geom = tuple(
            (r.flops, self.P, self.stage_halo(k))
            for k, r in enumerate(reports)
        )
        return StreamWorkload(
            name=self.name,
            flops_per_elem=sum(r.flops for r in reports),
            words_in=self.P,
            words_out=self.P,
            depth=sum(r.depth for r in reports),
            buffer_bits=sum(r.buffer_bits for r in reports),
            elems=int(elems),
            grid_w=int(grid_w),
            halo=sum(h for _, _, h in stage_geom),
            stages=stage_geom,
        )

    def explorer(self, elems: int, grid_w: int = 0, **kw):
        """A DSE :class:`~repro.core.explorer.Explorer` over this
        program — ``sweep_tpu(fusion_values=...)`` then adds the
        partition to the lattice and ``search`` executes points through
        :func:`program_run_factory`."""
        from .explorer import Explorer

        kw.setdefault("core", self)
        return Explorer(self.workload(elems, grid_w), **kw)


class ProgramKernel:
    """A :class:`StreamProgram` lowered under one fusion partition.

    A single-cluster partition runs as the ordinary ``m``-blocked
    temporal-blocking launch of the fused wrapper kernel; a
    multi-cluster partition runs *pipelined* — one jitted ``fori_loop``
    whose body chains every cluster's stripe launch at one program step
    each, keeping intermediate fields on device (docs/pipeline.md
    §program). :meth:`run_unfused` is the naive baseline (a separate
    host dispatch per cluster per step, intermediates synced to host)
    that ``benchmarks/dse_sweep.py`` section 2h clocks the other two
    against.
    """

    def __init__(self, program: StreamProgram, fusion: str = ""):
        self.program = program
        sizes = parse_fusion(fusion, program.nstages)
        self.fusion = "+".join(str(s) for s in sizes)
        spans, lo = [], 0
        for s in sizes:
            spans.append((lo, lo + s))
            lo += s
        self.spans = tuple(spans)
        self.clusters = tuple(
            program.cluster_kernel(a, b) for a, b in spans
        )
        #: max per-cluster composed halo (info; legalization reads the
        #: per-stage geometry, the launches read each cluster kernel's
        #: own inferred halo).
        self.halo = max(k.halo for k in self.clusters)
        self._pipelined = jax.jit(
            self._pipelined_impl,
            static_argnames=("steps", "block_h", "double_buffer",
                            "interpret"),
        )

    @property
    def pipelined(self) -> bool:
        return len(self.clusters) > 1

    def _scals(self, regs: Sequence) -> tuple:
        names = self.program.reg_names()
        if len(regs) != len(names):
            raise CodegenError(
                f"program {self.program.name}: expected {len(names)} "
                f"register values {names}, got {len(regs)}"
            )
        return tuple(
            kern._scal(tuple(regs)[self.program.reg_slice(a, b)])
            for kern, (a, b) in zip(self.clusters, self.spans)
        )

    def _pipelined_impl(self, state, scals, *, steps, block_h,
                        double_buffer, interpret):
        """``steps`` program steps as one jitted chain: every cluster
        launches once per step at ``m=1`` (temporal blocking does not
        cross a cut edge), and because the whole loop is a single jit
        the inter-cluster fields never leave the device."""

        def body(_, s):
            for kern, scal in zip(self.clusters, scals):
                s = kern._streamed(
                    s, scal, m=1, block_h=block_h,
                    double_buffer=double_buffer, interpret=interpret,
                )
            return s

        return jax.lax.fori_loop(0, steps, body, state)

    def run_blocked(self, state, regs: Sequence = (), *, steps: int,
                    m: int, block_h: int, double_buffer: bool = True,
                    interpret: bool = True, d: int = 1, dx: int = 1):
        """Advance ``steps`` program steps under this partition.

        Fused (one cluster): the standard ``m``-blocked launch chain.
        Pipelined: the jitted per-step cluster chain (``m`` bounds the
        host-visible dispatch granularity but does not change the
        arithmetic — a program step is always one pass through every
        cluster). ``d > 1`` shards every cluster launch across the
        device mesh ``(d // dx, dx)`` — the row ring when ``dx == 1``
        (docs/pipeline.md §distribute, DESIGN.md §15).
        """
        scals = self._scals(regs)  # validates the register count
        if d > 1:
            return self._run_sharded(
                state, regs, steps=steps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret, d=d,
                dx=dx,
            )
        if not self.pipelined:
            (a, b), kern = self.spans[0], self.clusters[0]
            return kern.run_blocked(
                state, tuple(regs)[self.program.reg_slice(a, b)],
                steps=steps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        return self._pipelined(
            state, scals, steps=int(steps), block_h=int(block_h),
            double_buffer=bool(double_buffer), interpret=bool(interpret),
        )

    def _run_sharded(self, state, regs, *, steps, m, block_h,
                     double_buffer, interpret, d, dx=1):
        if not self.pipelined:
            (a, b), kern = self.spans[0], self.clusters[0]
            return kern.sharded(d, dx=dx).run_blocked(
                state, tuple(regs)[self.program.reg_slice(a, b)],
                steps=steps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret,
            )
        # Pipelined + sharded: each cluster advances one program step
        # per sharded launch. The shard_map outputs stay device-resident
        # between launches; only the dispatch returns to the host.
        for _ in range(int(steps)):
            for kern, (a, b) in zip(self.clusters, self.spans):
                state = kern.sharded(d, dx=dx).run_blocked(
                    state, tuple(regs)[self.program.reg_slice(a, b)],
                    steps=1, m=1, block_h=block_h,
                    double_buffer=double_buffer, interpret=interpret,
                )
        return state

    def run_unfused(self, state, regs: Sequence = (), *, steps: int,
                    block_h: int, double_buffer: bool = True,
                    interpret: bool = True):
        """The no-pipelining baseline: one host dispatch per cluster per
        step, with every intermediate field synced through the host —
        what a program executed as unrelated single-core runs costs
        (the wall-clock ``benchmarks/dse_sweep.py`` records as
        ``unfused``)."""
        import numpy as np

        scals = self._scals(regs)
        for _ in range(int(steps)):
            for kern, scal in zip(self.clusters, scals):
                out = kern._streamed(
                    state, scal, m=1, block_h=block_h,
                    double_buffer=double_buffer, interpret=interpret,
                )
                state = jnp.asarray(np.asarray(out))  # host round-trip
        return state

    def run_for_point(self, state, regs: Sequence = (), *, point,
                      steps: int | None = None, interpret: bool = True):
        """Advance the grid using a DSE design point, legalized for the
        whole partition via
        :func:`repro.core.legalize.program_blocking_plan` (every
        cluster's composed-halo stripe set must fit).
        Returns ``(result, (block_h, m, double_buffer))``.
        """
        *_, h, w = state.shape
        block_h, m, nsteps, double_buffer = resolve_run_plan(
            h, point, steps, width=w,
            stages=self.program.stage_geometry(), fusion=self.fusion,
        )
        out = self.run_blocked(
            state, regs, steps=nsteps, m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )
        return out, (block_h, m, double_buffer)

    def reference(self, state, regs: Sequence = (), *, m: int = 1):
        """``m`` program steps through the compiler's reference path of
        the fully fused wrapper (``CompiledCore.apply`` on whole grids)
        — the semantics every partition must reproduce bit for bit."""
        return self.program.monolithic_kernel().reference(
            state, regs, m=m
        )

    def pack(self, arrays: Sequence) -> jnp.ndarray:
        """Stack per-port (H, W) grids into the (P, H, W) program state."""
        return self.program.monolithic_kernel().pack(arrays)


def program_run_factory(program: StreamProgram, state, regs,
                        interpret: bool = True):
    """Adapt a program + initial state into the search runner's
    ``run_factory(nsteps, m, block_h, d, double_buffer, b, fusion,
    dx)`` protocol (docs/pipeline.md §search): the fusion partition
    selects the cached :class:`ProgramKernel`, everything else
    parameterizes its launch — ``dx`` picks the device-mesh column
    count (DESIGN.md §15). Batched program launches (``b > 1``) are
    declared unsupported (``None`` — the point is skipped), matching
    the model's infeasible cell.
    """

    def run_factory(nsteps, m, block_h, d, double_buffer=True, b=1,
                    fusion="", dx=1):
        if b > 1:
            return None
        pk = program.kernel(fusion)

        def run():
            return pk.run_blocked(
                state, regs, steps=nsteps, m=m, block_h=block_h,
                double_buffer=double_buffer, interpret=interpret, d=d,
                dx=dx,
            )

        return run

    return run_factory


__all__ = [
    "ProgramError",
    "ProgramKernel",
    "ProgramStage",
    "StreamProgram",
    "fusion_partitions",
    "program_run_factory",
]
