"""Design-space explorer: batched lattice sweeps -> Pareto frontier -> run.

This is the executable form of the paper's §III workflow (DESIGN.md §5,
docs/pipeline.md §execute). Where :mod:`repro.core.dse` models one (n, m)
point at a time, the explorer

1. enumerates the full coordinate lattice for a compiled SPD core —
   (n, m) for the FPGA target, (block_h, m, d) for the TPU target,
   where d is the device axis (chips the grid shards across,
   docs/pipeline.md §distribute) — and evaluates every point in one
   batched NumPy call
   (:meth:`FPGAModel.evaluate_batch` / :meth:`TPUModel.evaluate_batch`);
2. extracts the Pareto frontier over (throughput, perf/W, resource use)
   with a vectorized dominance check (:func:`pareto_mask`);
3. for the TPU target, *searches* the lattice with measurement in the
   loop: :meth:`Explorer.search` hands the sweep to a pluggable
   :class:`~repro.core.search.SearchStrategy`
   (docs/pipeline.md §search) driving the one legalize→run→time engine,
   :class:`~repro.core.search.SearchRunner` — any codegen'd SPD core
   runs through it, single-device or sharded across ``d`` devices with
   halo exchange (``repro.core.distribute``) — under an optional hard
   measurement budget. :meth:`Explorer.execute_frontier` is the
   original top-k frontier walk, now a thin facade over
   ``search(strategy=ExhaustiveSearch(k, frontier_only=True))``. All
   plans legalize through the shared :mod:`repro.core.legalize`;
   timing, backend calibration (the prediction is held against the
   platform actually running, so ``rel_error`` is a model-fidelity
   signal) and the persistent measurement cache come from
   :mod:`repro.core.measure` (docs/pipeline.md §measure).

The paper's "find the best among them" result — (n, m) = (1, 4) on the
Stratix V — falls out of ``Explorer.sweep_fpga(...).best()`` and is
asserted in ``tests/test_explorer.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .dse import (
    DesignPoint,
    FPGAModel,
    StreamWorkload,
    TPUModel,
    render_table,
)
from .search import (
    ExecutedPoint,
    ExhaustiveSearch,
    SearchResult,
    SearchRunner,
    get_strategy,
    kernel_run_factory,
)

__all__ = [
    "ExecutedPoint",
    "Explorer",
    "SearchResult",
    "Sweep",
    "pareto_mask",
    "render_executed",
]


# --------------------------------------------------------------------------
# Pareto frontier extraction
# --------------------------------------------------------------------------


def pareto_mask(objectives, maximize: Sequence[bool] | None = None) -> np.ndarray:
    """Boolean mask of non-dominated rows of an (P, K) objective matrix.

    A row i is dominated when some row j is >= on every column and > on at
    least one (after flipping minimized columns). Fully vectorized: one
    (P, P, K) broadcast, no per-point Python loop — fine for the few
    thousand points a lattice sweep produces.

    Rows with any non-finite objective are excluded up front and never
    returned: NaN compares False against everything, which would have
    made such rows "never dominated" and polluted the frontier.
    """
    X = np.asarray(objectives, dtype=float)
    if X.ndim == 1:
        X = X[:, None]
    if maximize is not None:
        sign = np.where(np.asarray(maximize, dtype=bool), 1.0, -1.0)
        X = X * sign
    mask = np.zeros(X.shape[0], dtype=bool)
    idx = np.flatnonzero(np.isfinite(X).all(axis=1))
    if idx.size == 0:
        return mask
    F = X[idx]
    ge = (F[None, :, :] >= F[:, None, :]).all(axis=-1)  # ge[i, j]: j >= i
    gt = (F[None, :, :] > F[:, None, :]).any(axis=-1)  # gt[i, j]: j > i somewhere
    dominated = (ge & gt).any(axis=1)
    mask[idx] = ~dominated
    return mask


# --------------------------------------------------------------------------
# Sweep result
# --------------------------------------------------------------------------

#: frontier objectives: maximize throughput and perf/W, minimize resources.
DEFAULT_OBJECTIVES = ("sustained_gflops", "perf_per_watt", "resource_frac")
DEFAULT_MAXIMIZE = (True, True, False)


@dataclass
class Sweep:
    """One batched lattice evaluation: coordinate + metric arrays.

    ``data`` holds one NumPy array per metric, all flattened to the same
    length; ``point(i)`` re-materializes index i as a full scalar
    :class:`DesignPoint` (via the scalar model path, so limits/detail are
    exactly what ``evaluate`` would have produced).
    """

    target: str  # 'fpga' | 'tpu'
    workload: StreamWorkload
    model: object
    data: dict[str, np.ndarray]
    census: dict | None = None
    coord_names: tuple = field(default=())
    scalar_kwargs: dict = field(default_factory=dict)  # extra evaluate() args

    def __post_init__(self):
        if not self.coord_names:
            self.coord_names = (
                ("n", "m") if self.target == "fpga" else ("block_rows", "m", "n")
            )

    def __len__(self) -> int:
        return int(self.data["sustained_gflops"].size)

    @property
    def feasible(self) -> np.ndarray:
        return self.data["feasible"]

    def metrics(self, names: Sequence[str]) -> np.ndarray:
        """Column-stack the named metric arrays into a (P, K) matrix."""
        return np.column_stack([np.asarray(self.data[n], float) for n in names])

    # ---- frontier ----------------------------------------------------------

    def pareto_mask(
        self,
        objectives: Sequence[str] = DEFAULT_OBJECTIVES,
        maximize: Sequence[bool] = DEFAULT_MAXIMIZE,
        feasible_only: bool = True,
    ) -> np.ndarray:
        """Non-dominated mask over the sweep (infeasible points excluded)."""
        mask = np.zeros(len(self), dtype=bool)
        pool = self.feasible if feasible_only else np.ones(len(self), bool)
        idx = np.flatnonzero(pool)
        if idx.size == 0:
            return mask
        X = self.metrics(objectives)[idx]
        mask[idx] = pareto_mask(X, maximize)
        return mask

    def frontier(
        self,
        objectives: Sequence[str] = DEFAULT_OBJECTIVES,
        maximize: Sequence[bool] = DEFAULT_MAXIMIZE,
        sort_by: str = "sustained_gflops",
    ) -> list[DesignPoint]:
        """Pareto-optimal points, materialized and sorted best-first."""
        idx = np.flatnonzero(self.pareto_mask(objectives, maximize))
        order = np.argsort(-np.asarray(self.data[sort_by], float)[idx])
        return [self.point(int(i)) for i in idx[order]]

    def best(self, key: str = "perf_per_watt") -> DesignPoint:
        """The single best feasible point by ``key`` (paper: argmax GF/sW)."""
        idx = np.flatnonzero(self.feasible)
        if idx.size == 0:
            raise ValueError(f"sweep of {len(self)} points has no feasible point")
        vals = np.asarray(self.data[key], float)[idx]
        return self.point(int(idx[int(np.argmax(vals))]))

    def top(self, k: int, key: str = "sustained_gflops") -> list[DesignPoint]:
        """Top-k feasible points by ``key`` (no dominance filtering)."""
        idx = np.flatnonzero(self.feasible)
        vals = np.asarray(self.data[key], float)[idx]
        order = np.argsort(-vals)[:k]
        return [self.point(int(i)) for i in idx[order]]

    # ---- materialization ---------------------------------------------------

    def point(self, i: int) -> DesignPoint:
        """Re-evaluate lattice index ``i`` through the scalar model path."""
        if self.target == "fpga":
            return self.model.evaluate(
                self.workload,
                int(self.data["n"][i]),
                int(self.data["m"][i]),
                self.census,
                **self.scalar_kwargs,
            )
        kwargs = dict(self.scalar_kwargs)
        if "b" in self.data:  # batch-axis sweeps (docs/pipeline.md §serve)
            kwargs["b"] = int(self.data["b"][i])
        if "fusion" in self.data:  # program sweeps (docs/pipeline.md §program)
            kwargs["fusion"] = str(self.data["fusion"][i])
        if "dx" in self.data:  # mesh-shape sweeps (DESIGN.md §15)
            kwargs["dx"] = int(self.data["dx"][i])
        return self.model.evaluate(
            self.workload,
            int(self.data["block_rows"][i]),
            int(self.data["m"][i]),
            d=int(self.data["n"][i]),
            **kwargs,
        )

    def table(self, k: int | None = None, frontier_only: bool = False) -> str:
        if frontier_only:
            pts = self.frontier()[:k] if k else self.frontier()
        else:
            order = np.argsort(-np.asarray(self.data["sustained_gflops"], float))
            pts = [self.point(int(i)) for i in (order[:k] if k else order)]
        return render_table(pts)


# --------------------------------------------------------------------------
# Explorer
# --------------------------------------------------------------------------


def _as_workload(source, elems: int | None, grid_w: int) -> StreamWorkload:
    if isinstance(source, StreamWorkload):
        return source
    report = getattr(source, "hardware_report", source)
    if elems is None:
        raise ValueError("elems is required when exploring from a core/report")
    return StreamWorkload.from_report(report, elems=elems, grid_w=grid_w)


class Explorer:
    """Sweeps a compiled SPD core's design space under both target models.

    ``source`` may be a :class:`StreamWorkload`, a
    :class:`~repro.core.compiler.HardwareReport`, or anything with a
    ``hardware_report`` attribute (``CompiledCore``, ``LBMSimulation``);
    for the latter two, ``elems`` (stream length) must be given. When the
    source is (or ``core`` names) a compiled core, TPU lattice points
    can be executed through its codegen'd Pallas kernel with
    :meth:`search` / :meth:`execute_frontier`
    (docs/pipeline.md §execute, §search).
    """

    def __init__(
        self,
        source,
        elems: int | None = None,
        grid_w: int = 0,
        fpga: FPGAModel | None = None,
        tpu: TPUModel | None = None,
        census: dict | None = None,
        core=None,
    ):
        from .compiler import CompiledCore

        self.workload = _as_workload(source, elems, grid_w)
        self.fpga = fpga or FPGAModel()
        self.tpu = tpu or TPUModel()
        report = getattr(source, "hardware_report", source)
        self.census = census or getattr(report, "census", None)
        self.core = core if core is not None else (
            source if isinstance(source, CompiledCore) else None
        )

    # ---- lattice sweeps ----------------------------------------------------

    def sweep_fpga(
        self,
        n_values: Sequence[int] = (1, 2, 4, 8),
        m_values: Sequence[int] = (1, 2, 4, 8),
        overlapped_passes: bool = True,
    ) -> Sweep:
        """Evaluate the full (n, m) lattice in one batched call."""
        n, m = np.meshgrid(
            np.asarray(n_values, np.int64), np.asarray(m_values, np.int64),
            indexing="ij",
        )
        data = self.fpga.evaluate_batch(
            self.workload, n.ravel(), m.ravel(), self.census,
            overlapped_passes=overlapped_passes,
        )
        return Sweep(
            "fpga", self.workload, self.fpga, data, self.census,
            scalar_kwargs={"overlapped_passes": overlapped_passes},
        )

    def sweep_tpu(
        self,
        bh_values: Sequence[int] = (8, 16, 32, 64, 128, 256),
        m_values: Sequence[int] = (1, 2, 4, 8, 16, 32),
        d_values: Sequence[int] = (1, 2, 4),
        double_buffer: bool = True,
        b_values: Sequence[int] = (1,),
        fusion_values: Sequence[str] = ("",),
        dx_values: Sequence[int] = (1,),
    ) -> Sweep:
        """Evaluate the (block_h, m, d[, b][, fusion][, dx]) lattice batched.

        ``d`` is the device axis — the *total* chip count the grid is
        sharded across (docs/pipeline.md §distribute). ``dx_values``
        adds the mesh-shape axis (DESIGN.md §15): each point's ``d``
        factors as a ``(dy, dx) = (d // dx, dx)`` mesh, with
        non-factorizing combinations marked infeasible by the model —
        so passing the full ``device_axis_values(...)`` list for both
        ``d_values`` and ``dx_values`` enumerates exactly the legal
        factorizations. The ``(1,)`` default keeps classic row-ring
        sweeps unchanged. ``double_buffer``
        threads through to both the batched evaluation and the scalar
        ``Sweep.point`` re-materialization. ``b_values`` adds the batch
        axis — independent simulations stacked into one launch
        (docs/pipeline.md §serve); the default keeps the classic 3-D
        lattice. ``fusion_values`` adds the program fusion-partition
        axis (docs/pipeline.md §program): one sub-lattice per spec,
        concatenated, with the spec carried per point in
        ``data["fusion"]`` — only meaningful when the workload has
        program ``stages``; the ``("",)`` default keeps single-core
        sweeps unchanged.
        """
        bh, m, d, b, dxg = np.meshgrid(
            np.asarray(bh_values, np.int64),
            np.asarray(m_values, np.int64),
            np.asarray(d_values, np.int64),
            np.asarray(b_values, np.int64),
            np.asarray(dx_values, np.int64),
            indexing="ij",
        )
        chunks = [
            self.tpu.evaluate_batch(
                self.workload, bh.ravel(), m.ravel(), d=d.ravel(),
                double_buffer=double_buffer, b=b.ravel(),
                fusion=str(spec), dx=dxg.ravel(),
            )
            for spec in fusion_values
        ]
        if len(chunks) == 1:
            data = chunks[0]
        else:
            data = {
                k: np.concatenate([c[k] for c in chunks])
                for k in chunks[0]
            }
        return Sweep(
            "tpu", self.workload, self.tpu, data,
            scalar_kwargs={"double_buffer": double_buffer},
        )

    def sweep(self, target: str, **kw) -> Sweep:
        if target == "fpga":
            return self.sweep_fpga(**kw)
        if target == "tpu":
            return self.sweep_tpu(**kw)
        raise ValueError(f"unknown target {target!r} (want 'fpga' or 'tpu')")

    # ---- model -> measurement (the pluggable search subsystem) -------------

    def search(
        self,
        sweep: "Sweep",
        state=None,
        regs: Sequence = (),
        *,
        strategy="exhaustive",
        budget: int | None = None,
        core=None,
        steps: int | None = None,
        interpret: bool = True,
        reps: int = 3,
        warmup: int = 1,
        calibrate: bool = True,
        cache=None,
        cache_tag: str | None = None,
        run_factory=None,
        grid_shape: tuple[int, int] | None = None,
        max_devices: int | None = None,
        timer=None,
        study=None,
        study_dir: str | None = None,
    ) -> SearchResult:
        """Search the TPU lattice with measurement in the loop.

        The facade over :mod:`repro.core.search`
        (docs/pipeline.md §search): ``strategy`` — a name
        (``"exhaustive"`` / ``"refine"`` / ``"halving"``), class, or
        :class:`~repro.core.search.SearchStrategy` instance — decides
        which (n, m, d, block_h) candidates to spend measurements on
        (the default, ``"exhaustive"``, measures the model's Pareto
        frontier — a handful of points — not the whole lattice; the
        full-lattice reference is
        ``ExhaustiveSearch(frontier_only=False)``, asked for
        explicitly); every measurement goes through one
        :class:`~repro.core.search.SearchRunner`
        (docs/pipeline.md §execute): legalized by the shared
        :func:`repro.core.legalize.resolve_run_plan` (per shard when the
        point's device axis ``d > 1``, and always with the concrete
        stripe geometry, so the VMEM clamp applies identically on the
        codegen and ``run_factory`` paths), executed, and timed with the
        honest harness :func:`repro.core.measure.time_run` — ``warmup``
        un-timed compile calls, ``reps`` measured calls each
        individually ``block_until_ready``'d, median wall time.
        Distinct lattice points that legalize to the same concrete plan
        are timed once per search.

        ``budget`` is a **hard cap on live measurements** for this
        invocation: once spent, the strategy is cut off mid-flight and
        the result carries whatever was measured. Cache hits and in-run
        dedupe hits are free — strategies compose across invocations
        through the shared :class:`~repro.core.measure.MeasurementCache`
        (``cache=True``/path/instance), whose keys include the core's
        DFG fingerprint; custom ``run_factory`` back ends have no core
        to hash, so they must pass ``cache_tag`` to identify the kernel
        (else caching is skipped for them; on the codegen path the
        fingerprint always wins and ``cache_tag`` is ignored).

        With ``calibrate=True`` (the default) the platform is probed
        through the same execution path
        (:func:`repro.core.measure.calibrate_execution`, one anchor per
        device-axis value encountered; probes are shared overhead, not
        charged against ``budget``) and each point's ``rel_error`` is
        reported against the *calibrated* prediction — the throughput of
        the backend actually running (Pallas interpreter on CPU, chip on
        TPU) — so the number is a model-fidelity signal. The raw
        uncalibrated diff survives as ``rel_error_model``.

        Default back end: ``core`` (or the compiled core this explorer
        was built from) lowers to a
        :class:`~repro.core.codegen.StreamKernel`; ``state`` is the
        stacked ``(P, H, W)`` grid and ``regs`` the core's
        ``Append_Reg`` values. Points with ``d > 1`` run through
        :class:`repro.core.distribute.ShardedStreamKernel` on a
        ``d``-ring mesh (docs/pipeline.md §distribute); points needing
        more devices than the platform has (``max_devices``, default
        ``jax.device_count()``) are skipped. Custom back ends plug in
        via ``run_factory(nsteps, m, block_h, d, double_buffer) ->
        nullary-callable | None`` plus the concrete
        ``grid_shape=(h, w)``; returning ``None`` skips the point. ``timer`` injects the timing
        primitive (tests drive whole strategies with a deterministic
        fake).

        ``study`` attaches a durable :class:`~repro.core.search.Study`
        journal (docs/pipeline.md §study): a name (resumed/created under
        ``study_dir`` via :meth:`Study.resume`) or an instance. Before
        the strategy runs, the study's completed trials for this exact
        measurement context (core fingerprint, grid, backend, interpret,
        warmup) are replayed into the runner's plan-dedupe table — an
        interrupted search resumed by name re-measures **zero** of them
        — and every new measurement is journaled back, so the study only
        grows. Back ends with no fingerprint (``run_factory`` without
        ``cache_tag``) cannot be identified across processes; the study
        is dropped with a warning for them.
        """
        from . import measure

        if sweep.target != "tpu":
            raise ValueError(
                "search needs a TPU sweep (the FPGA target is a model "
                "only; there is no Stratix V attached)"
            )
        halo = sweep.workload.halo
        fingerprint = cache_tag
        stages = None
        if run_factory is None:
            from .codegen import StreamKernel
            from .program import StreamProgram, program_run_factory

            core = core if core is not None else self.core
            if core is None:
                raise ValueError(
                    "Explorer.search needs a compiled core: build the "
                    "explorer from a CompiledCore or pass core=..."
                )
            if isinstance(core, StreamProgram):
                # Program back end (docs/pipeline.md §program): plans
                # legalize through the fused-cluster accounting and each
                # point's fusion spec picks the ProgramKernel partition.
                # The fingerprint is the fused monolithic wrapper's —
                # it hashes every member core's DFG.
                words, h, w = state.shape
                width = w
                stages = core.stage_geometry()
                fingerprint = measure.core_fingerprint(
                    core.monolithic_kernel()
                )
                run_factory = program_run_factory(
                    core, state, regs, interpret
                )
            else:
                kern = (
                    core if isinstance(core, StreamKernel)
                    else core.stream_kernel()
                )
                words, h, w = state.shape
                halo, width = kern.halo, w
                # The DFG fingerprint always wins on this path — a
                # cache_tag must never alias two structurally different
                # cores onto one cache key (stale hits); tags are for
                # run_factory back ends that have no SPD core to hash.
                fingerprint = measure.core_fingerprint(kern)
                run_factory = kernel_run_factory(kern, state, regs,
                                                 interpret)
        else:
            if grid_shape is None:
                raise ValueError("run_factory needs grid_shape=(h, w)")
            h, w = grid_shape
            # Thread the concrete stripe geometry so this path gets the
            # same VMEM legalization the codegen path does: the width is
            # the grid's, the resident words come from the workload.
            width, words = w, sweep.workload.words_in

        strat = get_strategy(strategy)
        runner = SearchRunner(
            workload=sweep.workload,
            grid_shape=(h, w),
            run_factory=run_factory,
            model=sweep.model,
            scalar_kwargs=sweep.scalar_kwargs,
            fingerprint=fingerprint,
            halo=halo,
            width=width,
            words=words,
            stages=stages,
            steps=steps,
            interpret=interpret,
            reps=reps,
            warmup=warmup,
            calibrate=calibrate,
            cache=cache,
            budget=budget,
            timer=timer,
            max_devices=max_devices,
        )
        replayed = 0
        if study is not None:
            from .search.study import Study

            if isinstance(study, str):
                study = Study.resume(study, study_dir)
            if runner.study_fingerprint() is None:
                import warnings

                warnings.warn(
                    "Explorer.search: study disabled — this back end has "
                    "no core fingerprint, so its trials cannot be "
                    "identified across processes; pass cache_tag= to "
                    "identify the kernel",
                    RuntimeWarning,
                    stacklevel=2,
                )
                study = None
            else:
                replayed = study.replay_into(runner)
                runner.study = study
                runner.study_meta = {
                    "strategy": strat.name,
                    "seed": getattr(strat, "seed", None),
                }
        executed = strat.search(sweep, runner)
        return SearchResult(
            strategy=strat.name,
            executed=executed,
            budget=runner.budget,
            budget_spent=runner.budget_spent,
            measurements=runner.measurements(),
            skipped_devices=runner.skipped_devices,
            skipped_illegal=runner.skipped_illegal,
            study=None if study is None else study.name,
            replayed=replayed,
        )

    def execute_frontier(
        self,
        sweep: "Sweep",
        state=None,
        regs: Sequence = (),
        core=None,
        k: int = 3,
        steps: int | None = None,
        interpret: bool = True,
        reps: int = 3,
        *,
        warmup: int = 1,
        calibrate: bool = True,
        cache=None,
        cache_tag: str | None = None,
        run_factory=None,
        grid_shape: tuple[int, int] | None = None,
        max_devices: int | None = None,
    ) -> list["ExecutedPoint"]:
        """Run the top-k *runnable* TPU frontier points and time them.

        The original explorer behavior, kept as a thin facade over
        :meth:`search` with
        ``strategy=ExhaustiveSearch(k=k, frontier_only=True)``
        (docs/pipeline.md §execute, §search): walk the Pareto frontier
        best-first until ``k`` points have actually executed, skipping
        points the platform has too few devices for. All measurement
        semantics — legalization, honest timing, calibration, the
        persistent cache, plan dedupe — are the runner's; see
        :meth:`search` for them.
        """
        result = self.search(
            sweep, state, regs,
            strategy=ExhaustiveSearch(k=k, frontier_only=True),
            core=core, steps=steps, interpret=interpret, reps=reps,
            warmup=warmup, calibrate=calibrate, cache=cache,
            cache_tag=cache_tag, run_factory=run_factory,
            grid_shape=grid_shape, max_devices=max_devices,
        )
        skipped = result.skipped_devices + result.skipped_illegal
        if skipped and len(result.executed) < k:
            import warnings

            reasons = []
            if result.skipped_devices:
                reasons.append(
                    f"{result.skipped_devices} needing more devices than "
                    "the platform has (sweep with d_values capped at "
                    "jax.device_count(); off-TPU: XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N)"
                )
            if result.skipped_illegal:
                reasons.append(
                    f"{result.skipped_illegal} with no legal run plan on "
                    "this grid (VMEM/halo constraints — see "
                    "repro.core.legalize)"
                )
            warnings.warn(
                f"execute_frontier skipped {skipped} frontier point(s) — "
                + "; ".join(reasons)
                + f" — and executed only {len(result.executed)} of the "
                f"requested {k}.",
                RuntimeWarning,
                stacklevel=2,
            )
        return result.executed


def render_executed(points: Sequence[ExecutedPoint]) -> str:
    """Markdown table of predicted-vs-measured frontier executions.

    ``calib GF/s`` is the prediction under measured platform constants
    (``-`` when calibration was off); ``rel err`` diffs against it when
    present (docs/pipeline.md §measure). ``src`` is ``cache`` when the
    wall time came from the measurement cache (or this search already
    timed the same plan). ``fuse`` is the program fusion partition the
    point ran as (docs/pipeline.md §program) — ``-`` for single-core
    plans. ``mesh`` is the point's device mesh ``dy x dx``
    (DESIGN.md §15) — ``1x1`` for single-device plans.
    """
    head = (
        "| block_h | m | d | mesh | db | fuse | steps | model GF/s "
        "| calib GF/s "
        "| measured GF/s | MLUPS | rel err | src | mode |\n"
        "|---------|---|---|------|----|------|-------|------------"
        "|------------"
        "|---------------|-------|---------|-----|------|"
    )
    rows = [
        f"| {e.block_h} | {e.m} | {e.d} | "
        f"{e.d // max(getattr(e, 'dx', 1) or 1, 1)}"
        f"x{getattr(e, 'dx', 1)} | "
        f"{'pp' if e.double_buffer else '1b'} | "
        f"{e.fusion or '-'} | {e.steps} | "
        f"{e.predicted_gflops:10.1f} | "
        + (f"{e.calibrated_gflops:10.4g}" if e.calibrated_gflops is not None
           else f"{'-':>10}")
        + f" | {e.measured_gflops:13.4g} | {e.measured_mlups:6.2f} | "
        f"{e.rel_error:+.3f} | {'cache' if e.cached else 'live'} | "
        f"{'interpret' if e.interpret else 'tpu'} |"
        for e in points
    ]
    return "\n".join([head] + rows)
