"""The SPD HDL-node library, implemented over JAX streams.

The paper ships these library modules (§II-D): Synchronous multiplexer,
Comparator, Eliminator, Delay, Stream forward, Stream backward, and 2D stencil
buffer. Here each becomes a :class:`LibraryModule`: a JAX dataflow
implementation plus a pipeline-delay/resource oracle for the hardware model.

Stream convention: a stream variable is a JAX array whose *leading* axes are
the stream coordinates. 1-D modules (Delay/Forward/Backward) shift along axis
0 of a flat stream; ``Stencil2D`` treats the stream as a row-major 2-D field
``(H, W[, ...lanes])`` — the 2-D analogue of the paper's Eq. (4) offsets
``x_{t±1}, x_{t±W}``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

import jax.numpy as jnp

from .dfg import Node, SPDError


class SPDModuleError(SPDError):
    pass


def _shift0(x, k: int, fill=0.0):
    """out[t] = x[t-k] (k>0: delay; k<0: forward), zero fill."""
    if k == 0:
        return x
    pad = jnp.full((abs(k),) + x.shape[1:], fill, dtype=x.dtype)
    if k > 0:
        return jnp.concatenate([pad, x[:-k]], axis=0)
    return jnp.concatenate([x[-k:], pad], axis=0)


def _shift2d(x, dy: int, dx: int, mode: str):
    """out[y, x] = in[y-dy, x-dx]; mode in {'wrap', 'zero'}."""
    if mode == "wrap":
        out = x
        if dy:
            out = jnp.roll(out, dy, axis=0)
        if dx:
            out = jnp.roll(out, dx, axis=1)
        return out
    if mode != "zero":
        raise SPDModuleError(f"Stencil2D: unknown boundary mode {mode!r}")
    out = x
    if dy:
        pad = jnp.zeros((abs(dy),) + x.shape[1:], x.dtype)
        out = (
            jnp.concatenate([pad, out[:-dy]], axis=0)
            if dy > 0
            else jnp.concatenate([out[-dy:], pad], axis=0)
        )
    if dx:
        pad = jnp.zeros((out.shape[0], abs(dx)) + out.shape[2:], x.dtype)
        out = (
            jnp.concatenate([pad, out[:, :-dx]], axis=1)
            if dx > 0
            else jnp.concatenate([out[:, -dx:], pad], axis=1)
        )
    return out


@dataclass
class LibraryModule:
    """A leaf HDL module: JAX impl + hardware-model oracles."""

    name: str
    n_in: int
    n_out: int
    param_names: tuple[str, ...]
    impl: Callable[[Sequence, Mapping], list]
    delay_fn: Callable[[Mapping], int]
    census_fn: Callable[[Mapping], dict] = lambda p: {}
    # Estimated on-chip buffer bits consumed (BRAM analogue), for the DSE.
    buffer_bits_fn: Callable[[Mapping], int] = lambda p: 0

    def resolve_params(self, node: Node, core_params: Mapping[str, float]) -> dict:
        """Bind an HDL node's positional/named params against this module."""
        out: dict = {}
        pos = 0
        for raw in node.params:
            if "=" in raw:
                k, v = raw.split("=", 1)
                out[k.strip()] = _coerce(v.strip(), core_params)
            else:
                if pos >= len(self.param_names):
                    raise SPDModuleError(
                        f"{self.name}: too many params on node {node.name}"
                    )
                out[self.param_names[pos]] = _coerce(raw.strip(), core_params)
                pos += 1
        return out

    def apply(self, inputs: Sequence, params: Mapping) -> list:
        if self.n_in >= 0 and len(inputs) != self.n_in:
            raise SPDModuleError(
                f"{self.name}: expected {self.n_in} inputs, got {len(inputs)}"
            )
        outs = self.impl(inputs, params)
        if self.n_out >= 0 and len(outs) != self.n_out:
            raise SPDModuleError(
                f"{self.name}: produced {len(outs)} outputs, expected {self.n_out}"
            )
        return outs


def _coerce(v: str, core_params: Mapping[str, float]):
    if v in core_params:
        return core_params[v]
    try:
        f = float(v)
        return int(f) if f == int(f) else f
    except ValueError:
        return v  # string param (e.g. boundary mode, comparator op)


# --------------------------------------------------------------------------
# Module implementations
# --------------------------------------------------------------------------


def _delay_impl(ins, p):
    return [_shift0(ins[0], int(p.get("k", 1)))]


def _forward_impl(ins, p):
    return [_shift0(ins[0], -int(p.get("k", 1)))]


def _mux_impl(ins, p):
    sel, a, b = ins
    return [jnp.where(sel != 0, a, b)]


_CMP_OPS = {
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
}


def _cmp_impl(ins, p):
    op = p.get("op", "eq")
    if op not in _CMP_OPS:
        raise SPDModuleError(f"Comparator: unknown op {op!r}")
    a, b = ins
    return [_CMP_OPS[op](a, b).astype(jnp.float32)]


def _eliminator_impl(ins, p):
    # Hardware semantics: drop elements with enable==0 (stream compaction).
    # Fixed-shape dataflow semantics: mask to zero; host-side compaction is
    # provided by repro.core.transforms.compact_stream.
    en, x = ins
    return [jnp.where(en != 0, x, jnp.zeros_like(x))]


def _stencil2d_impl(ins, p):
    dy, dx = int(p.get("dy", 0)), int(p.get("dx", 0))
    return [_shift2d(ins[0], dy, dx, str(p.get("mode", "zero")))]


def _stencil2d_delay(p) -> int:
    # The buffer must see max(dy,0) future rows + max(dx,0) future columns
    # before the aligned element can leave; +2 for ingress/egress registers.
    w = int(p.get("W", 0))
    dy, dx = int(p.get("dy", 0)), int(p.get("dx", 0))
    return max(-dy, 0) * max(w, 1) + max(-dx, 0) + 2


def _stencil2d_bits(p) -> int:
    w = int(p.get("W", 0))
    dy = abs(int(p.get("dy", 0)))
    return 32 * (dy * max(w, 1) + abs(int(p.get("dx", 0))) + 2)


def default_registry_modules() -> list[LibraryModule]:
    return [
        LibraryModule(
            "Delay", 1, 1, ("k",), _delay_impl,
            delay_fn=lambda p: int(p.get("k", 1)),
            buffer_bits_fn=lambda p: 32 * int(p.get("k", 1)),
        ),
        LibraryModule(
            "StreamForward", 1, 1, ("k",), _forward_impl,
            # Forward reference: everything else is delayed by k to meet it.
            delay_fn=lambda p: int(p.get("k", 1)),
            buffer_bits_fn=lambda p: 32 * int(p.get("k", 1)),
        ),
        LibraryModule(
            "StreamBackward", 1, 1, ("k",), _delay_impl,
            delay_fn=lambda p: int(p.get("k", 1)),
            buffer_bits_fn=lambda p: 32 * int(p.get("k", 1)),
        ),
        LibraryModule(
            "SyncMux", 3, 1, (), _mux_impl, delay_fn=lambda p: 2
        ),
        LibraryModule(
            "Comparator", 2, 1, ("op",), _cmp_impl, delay_fn=lambda p: 2
        ),
        LibraryModule(
            "Eliminator", 2, 1, (), _eliminator_impl, delay_fn=lambda p: 2
        ),
        LibraryModule(
            "Stencil2D", 1, 1, ("dy", "dx", "W", "mode"), _stencil2d_impl,
            delay_fn=_stencil2d_delay,
            buffer_bits_fn=_stencil2d_bits,
        ),
    ]
