"""Data-flow graph IR for SPD cores.

This module holds the hardware-facing side of the SPD compiler: the expression
AST for ``EQU`` formulae, the node/core IR produced by the parser, ASAP
pipeline scheduling with delay balancing (the paper's Fig. 3b step), pipeline
depth computation, and the floating-point-operator census that feeds the
design-space-exploration cost model (``N_Flops`` in the paper's Eq. 10).

The *semantic* compilation of a core to a JAX function lives in
``repro.core.compiler``; here we only reason about structure and timing
(stage two of the pipeline, docs/pipeline.md §dfg).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

# --------------------------------------------------------------------------
# Expression AST for EQU formulae
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    pass


@dataclass(frozen=True)
class Num(Expr):
    value: float


@dataclass(frozen=True)
class Var(Expr):
    name: str


@dataclass(frozen=True)
class Bin(Expr):
    op: str  # '+', '-', '*', '/'
    lhs: Expr
    rhs: Expr


@dataclass(frozen=True)
class Neg(Expr):
    arg: Expr


@dataclass(frozen=True)
class Call(Expr):
    fn: str  # 'sqrt' (extensible: 'abs', 'min', 'max', 'rsqrt', 'exp')
    args: tuple[Expr, ...]


SUPPORTED_CALLS = ("sqrt", "abs", "min", "max", "rsqrt", "exp")


def expr_vars(e: Expr) -> list[str]:
    """Free variables of an expression, in first-appearance order."""
    out: list[str] = []

    def walk(x: Expr) -> None:
        if isinstance(x, Var):
            if x.name not in out:
                out.append(x.name)
        elif isinstance(x, Bin):
            walk(x.lhs)
            walk(x.rhs)
        elif isinstance(x, Neg):
            walk(x.arg)
        elif isinstance(x, Call):
            for a in x.args:
                walk(a)

    walk(e)
    return out


def expr_op_census(e: Expr) -> dict[str, int]:
    """Count FP operators in a formula (the paper's Table IV census)."""
    census: dict[str, int] = {}

    def bump(k: str) -> None:
        census[k] = census.get(k, 0) + 1

    def walk(x: Expr) -> None:
        if isinstance(x, Bin):
            # '+' and '-' both map onto an FP adder.
            bump("add" if x.op in "+-" else ("mul" if x.op == "*" else "div"))
            walk(x.lhs)
            walk(x.rhs)
        elif isinstance(x, Neg):
            walk(x.arg)  # negation is a sign flip, not a pipelined FP op
        elif isinstance(x, Call):
            bump(x.fn)
            for a in x.args:
                walk(a)

    walk(e)
    return census


# Pipelined-operator latency model (cycles). Calibrated loosely against the
# Stratix V single-precision cores the paper used; fully overridable so other
# device models can be swapped in for the DSE.
DEFAULT_OP_LATENCY: dict[str, int] = {
    "add": 7,
    "mul": 5,
    "div": 28,
    "sqrt": 28,
    "rsqrt": 28,
    "abs": 1,
    "min": 2,
    "max": 2,
    "exp": 17,
}


def expr_depth(e: Expr, latency: Mapping[str, int] | None = None) -> int:
    """Critical-path latency (cycles) through a formula's operator tree."""
    lat = dict(DEFAULT_OP_LATENCY)
    if latency:
        lat.update(latency)

    def walk(x: Expr) -> int:
        if isinstance(x, (Num, Var)):
            return 0
        if isinstance(x, Bin):
            op = "add" if x.op in "+-" else ("mul" if x.op == "*" else "div")
            return lat[op] + max(walk(x.lhs), walk(x.rhs))
        if isinstance(x, Neg):
            return walk(x.arg)
        if isinstance(x, Call):
            inner = max((walk(a) for a in x.args), default=0)
            return lat[x.fn] + inner
        raise TypeError(f"unknown expr {x!r}")

    return walk(e)


# --------------------------------------------------------------------------
# Node / Core IR
# --------------------------------------------------------------------------


@dataclass
class Node:
    """One DFG node: an EQU formula or an HDL module call."""

    name: str
    kind: str  # 'equ' | 'hdl'
    inputs: tuple[str, ...]  # variable names consumed (positional for hdl)
    outputs: tuple[str, ...]  # variable names produced
    expr: Expr | None = None  # equ only
    module: str | None = None  # hdl only: module name
    delay: int | None = None  # hdl only: declared pipeline delay
    params: tuple[str, ...] = ()  # hdl only: raw parameter list


@dataclass
class Interface:
    name: str
    ports: tuple[str, ...]


@dataclass
class Core:
    """A parsed SPD core: interfaces + nodes + direct connections."""

    name: str
    main_in: list[Interface] = field(default_factory=list)
    main_out: list[Interface] = field(default_factory=list)
    brch_in: list[Interface] = field(default_factory=list)
    brch_out: list[Interface] = field(default_factory=list)
    regs: list[str] = field(default_factory=list)  # Append_Reg constant inputs
    params: dict[str, float] = field(default_factory=dict)
    nodes: list[Node] = field(default_factory=list)
    # DRCT lines: (dest ports) = (src ports), applied pairwise.
    drcts: list[tuple[tuple[str, ...], tuple[str, ...]]] = field(default_factory=list)

    # ---- interface helpers -------------------------------------------------
    def input_ports(self) -> list[str]:
        out = [p for itf in self.main_in for p in itf.ports]
        out += [p for itf in self.brch_in for p in itf.ports]
        out += list(self.regs)
        return out

    def main_input_ports(self) -> list[str]:
        return [p for itf in self.main_in for p in itf.ports]

    def main_output_ports(self) -> list[str]:
        return [p for itf in self.main_out for p in itf.ports]

    def brch_input_ports(self) -> list[str]:
        return [p for itf in self.brch_in for p in itf.ports]

    def brch_output_ports(self) -> list[str]:
        return [p for itf in self.brch_out for p in itf.ports]

    def output_ports(self) -> list[str]:
        return self.main_output_ports() + self.brch_output_ports()

    # ---- graph helpers -----------------------------------------------------
    def producers(self) -> dict[str, Node]:
        """variable name -> producing node (SSA check)."""
        prod: dict[str, Node] = {}
        for n in self.nodes:
            for v in n.outputs:
                if v in prod:
                    raise SPDGraphError(
                        f"core {self.name}: variable '{v}' assigned by both "
                        f"'{prod[v].name}' and '{n.name}' (must be SSA)"
                    )
                prod[v] = n
        return prod

    def alias_map(self) -> dict[str, str]:
        """DRCT wiring: destination variable -> source variable (resolved)."""
        alias: dict[str, str] = {}
        for dests, srcs in self.drcts:
            if len(dests) != len(srcs):
                raise SPDGraphError(
                    f"core {self.name}: DRCT arity mismatch {dests} = {srcs}"
                )
            for d, s in zip(dests, srcs):
                if d in alias:
                    raise SPDGraphError(f"core {self.name}: '{d}' DRCT-driven twice")
                alias[d] = s
        # Resolve chains (a<-b, b<-c => a<-c); reject cycles.
        resolved: dict[str, str] = {}
        for d in alias:
            seen = {d}
            s = alias[d]
            while s in alias:
                if s in seen:
                    raise SPDGraphError(f"core {self.name}: DRCT cycle at '{s}'")
                seen.add(s)
                s = alias[s]
            resolved[d] = s
        return resolved

    def toposort(self) -> list[Node]:
        """Topological order of nodes; raises on combinational cycles."""
        prod = self.producers()
        alias = self.alias_map()
        avail = set(self.input_ports())
        avail.update(self.params)  # params act as constants
        order: list[Node] = []
        pending = list(self.nodes)
        while pending:
            progressed = False
            for n in list(pending):
                deps = [alias.get(v, v) for v in n.inputs]
                if all(d in avail or d not in prod or prod[d] in order for d in deps):
                    # a dep is satisfied if it is a core input, a parameter, or
                    # produced by an already-ordered node
                    ok = True
                    for d in deps:
                        if d in avail:
                            continue
                        if d in prod:
                            if prod[d] not in order:
                                ok = False
                                break
                        else:
                            raise SPDGraphError(
                                f"core {self.name}: node '{n.name}' reads "
                                f"undriven variable '{d}'"
                            )
                    if not ok:
                        continue
                    order.append(n)
                    pending.remove(n)
                    avail.update(n.outputs)
                    progressed = True
            if not progressed:
                names = [n.name for n in pending]
                raise SPDGraphError(
                    f"core {self.name}: combinational cycle among {names}"
                )
        return order


class SPDError(Exception):
    """Base class for SPD front-end errors."""


class SPDGraphError(SPDError):
    pass


# --------------------------------------------------------------------------
# Pipeline scheduling: ASAP leveling + delay balancing
# --------------------------------------------------------------------------


@dataclass
class Schedule:
    """Result of pipeline scheduling a core.

    ``ready``      variable -> cycle its value emerges from the datapath
    ``node_start`` node name -> cycle its (aligned) inputs enter
    ``node_delay`` node name -> pipeline latency through the node
    ``balance_regs`` total inserted delay registers (32-bit words x cycles)
    ``depth``      pipeline depth d of the core (max over outputs, all outputs
                   padded to this depth as hardware would)
    """

    ready: dict[str, int]
    node_start: dict[str, int]
    node_delay: dict[str, int]
    balance_regs: int
    depth: int


# Delay/resource oracles for HDL modules whose cost depends on params (library
# modules register themselves here via repro.core.library).
DelayFn = Callable[[Sequence[str], Mapping[str, float]], int]


def schedule(
    core: Core,
    hdl_delay: Callable[[Node], int],
    op_latency: Mapping[str, int] | None = None,
) -> Schedule:
    """ASAP-schedule ``core`` and balance path delays.

    ``hdl_delay`` resolves the pipeline latency of an HDL node (declared
    delay, library oracle, or recursive sub-core depth).
    """
    alias = core.alias_map()
    ready: dict[str, int] = {p: 0 for p in core.input_ports()}
    ready.update({p: 0 for p in core.params})
    node_start: dict[str, int] = {}
    node_delay: dict[str, int] = {}
    balance = 0

    for n in core.toposort():
        deps = [alias.get(v, v) for v in n.inputs]
        times = [ready[d] for d in deps]
        start = max(times, default=0)
        # Delay balancing: every earlier-arriving input gets a FIFO of
        # (start - t) stages so all operands meet in the same cycle.
        balance += sum(start - t for t in times)
        d = expr_depth(n.expr, op_latency) if n.kind == "equ" else hdl_delay(n)
        node_start[n.name] = start
        node_delay[n.name] = d
        for v in n.outputs:
            ready[v] = start + d

    outs = []
    for p in core.output_ports():
        src = alias.get(p, p)
        if src not in ready:
            raise SPDGraphError(f"core {core.name}: output '{p}' undriven")
        ready[p] = ready[src]
        outs.append(ready[p])
    depth = max(outs, default=0)
    # Hardware pads all outputs to the common depth.
    balance += sum(depth - t for t in outs)
    return Schedule(ready, node_start, node_delay, balance, depth)


def op_census(
    core: Core,
    hdl_census: Callable[[Node], Mapping[str, int]],
) -> dict[str, int]:
    """Total FP-operator counts for a core (recursing into HDL nodes)."""
    total: dict[str, int] = {}
    for n in core.nodes:
        part = expr_op_census(n.expr) if n.kind == "equ" else hdl_census(n)
        for k, v in part.items():
            total[k] = total.get(k, 0) + v
    return total


def flop_count(census: Mapping[str, int]) -> int:
    """FP operators per streamed element (sqrt/div each count once)."""
    return sum(census.values())
