"""Parser for the SPD (stream processing description) DSL.

Accepts the paper's syntax (Figs. 4, 5, 6, 8, 10, 11 and Tables I/II):

    Name      <core name>;
    Main_In   {<if>::p1,p2,...};        Main_Out {<if>::p1,...};
    Brch_In   {<if>::p1,...};           Brch_Out {<if>::p1,...};
    Append_Reg{<if>::r1,r2,...};        # constant (register) inputs
    Param     <name> = <constant>;
    EQU       <node>, <out> = <formula>;
    HDL       <node>, <delay>, (outs)[(bouts)] = Module(ins)[(bins)] [, params];
    DRCT      (dest ports) = (src ports);

Strings after '#' are comments; statements may span lines and end with ';'.
Formulae support + - * / unary-minus, parentheses, numeric literals, named
parameters, and calls (sqrt, abs, min, max, rsqrt, exp).

This is the first stage of the compilation pipeline
(docs/pipeline.md §parse); the complete grammar, statement by statement,
is docs/spd_reference.md (whose snippets are parsed by this module in
``tests/test_docs.py``).
"""

from __future__ import annotations

import re

from .dfg import (
    Bin,
    Call,
    Core,
    Expr,
    Interface,
    Neg,
    Node,
    Num,
    SPDError,
    SUPPORTED_CALLS,
    Var,
)


class SPDParseError(SPDError):
    pass


# --------------------------------------------------------------------------
# Formula (Pratt) parser
# --------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?|\d+(?:[eE][+-]?\d+)?)"
    r"|(?P<ident>[A-Za-z_][A-Za-z_0-9:]*)"
    r"|(?P<op>[-+*/(),]))"
)


def _tokenize_formula(s: str) -> list[tuple[str, str]]:
    toks: list[tuple[str, str]] = []
    pos = 0
    while pos < len(s):
        m = _TOKEN_RE.match(s, pos)
        if not m:
            if s[pos:].strip() == "":
                break
            raise SPDParseError(f"bad token at {s[pos:]!r} in formula {s!r}")
        pos = m.end()
        for kind in ("num", "ident", "op"):
            v = m.group(kind)
            if v is not None:
                toks.append((kind, v))
                break
    toks.append(("end", ""))
    return toks


class _FormulaParser:
    def __init__(self, text: str):
        self.toks = _tokenize_formula(text)
        self.i = 0
        self.text = text

    def peek(self) -> tuple[str, str]:
        return self.toks[self.i]

    def next(self) -> tuple[str, str]:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, val: str) -> None:
        k, v = self.next()
        if v != val:
            raise SPDParseError(f"expected {val!r}, got {v!r} in {self.text!r}")

    def parse(self) -> Expr:
        e = self.expr()
        if self.peek()[0] != "end":
            raise SPDParseError(f"trailing tokens in formula {self.text!r}")
        return e

    def expr(self) -> Expr:  # additive
        e = self.term()
        while self.peek()[1] in ("+", "-"):
            op = self.next()[1]
            e = Bin(op, e, self.term())
        return e

    def term(self) -> Expr:  # multiplicative
        e = self.unary()
        while self.peek()[1] in ("*", "/"):
            op = self.next()[1]
            e = Bin(op, e, self.unary())
        return e

    def unary(self) -> Expr:
        if self.peek()[1] == "-":
            self.next()
            return Neg(self.unary())
        if self.peek()[1] == "+":
            self.next()
            return self.unary()
        return self.atom()

    def atom(self) -> Expr:
        kind, v = self.next()
        if kind == "num":
            return Num(float(v))
        if v == "(":
            e = self.expr()
            self.expect(")")
            return e
        if kind == "ident":
            if self.peek()[1] == "(":
                if v not in SUPPORTED_CALLS:
                    raise SPDParseError(f"unknown function {v!r} in {self.text!r}")
                self.next()
                args = [self.expr()]
                while self.peek()[1] == ",":
                    self.next()
                    args.append(self.expr())
                self.expect(")")
                return Call(v, tuple(args))
            return Var(_strip_qual(v))
        raise SPDParseError(f"unexpected token {v!r} in formula {self.text!r}")


def parse_formula(text: str) -> Expr:
    return _FormulaParser(text).parse()


# --------------------------------------------------------------------------
# Statement-level parsing
# --------------------------------------------------------------------------


def _strip_comments(text: str) -> str:
    return "\n".join(line.split("#", 1)[0] for line in text.splitlines())


def _strip_qual(name: str) -> str:
    """``Mi::sop`` -> ``sop`` (interface qualifier is advisory in this IR)."""
    return name.split("::")[-1].strip()


def _parse_iface(body: str, default: str) -> Interface:
    body = body.strip()
    if not (body.startswith("{") and body.endswith("}")):
        raise SPDParseError(f"interface body must be braced: {body!r}")
    inner = body[1:-1]
    ifname = default
    items = [x.strip() for x in inner.split(",") if x.strip()]
    if items and "::" in items[0]:
        ifname, first = items[0].split("::", 1)
        ifname = ifname.strip()
        items[0] = first.strip()
    ports = tuple(_strip_qual(x) for x in items)
    if len(set(ports)) != len(ports):
        raise SPDParseError(f"duplicate ports in interface {ifname}: {ports}")
    return Interface(ifname, ports)


def _parse_port_list(text: str) -> tuple[str, ...]:
    text = text.strip()
    if not (text.startswith("(") and text.endswith(")")):
        raise SPDParseError(f"expected parenthesized port list: {text!r}")
    return tuple(
        _strip_qual(x) for x in text[1:-1].split(",") if x.strip() != ""
    )


_CALL_RE = re.compile(
    r"^\s*(?P<outs>\([^()]*\))\s*(?P<bouts>\([^()]*\))?\s*=\s*"
    r"(?P<mod>[A-Za-z_][A-Za-z_0-9]*)\s*(?P<ins>\([^()]*\))\s*"
    r"(?P<bins>\([^()]*\))?\s*$"
)


def _parse_module_call(text: str) -> tuple[tuple[str, ...], str, tuple[str, ...]]:
    """``(o1,o2)(bo1) = Mod(i1,i2)(bi1)`` -> (outputs, module, inputs).

    Branch ports are concatenated after the main ports on each side, which
    matches how the compiler binds positional HDL arguments.
    """
    m = _CALL_RE.match(text)
    if not m:
        raise SPDParseError(f"bad module call: {text!r}")
    outs = _parse_port_list(m.group("outs"))
    if m.group("bouts"):
        outs += _parse_port_list(m.group("bouts"))
    ins = _parse_port_list(m.group("ins"))
    if m.group("bins"):
        ins += _parse_port_list(m.group("bins"))
    return outs, m.group("mod"), ins


def _split_top_commas(text: str, maxsplit: int = -1) -> list[str]:
    """Split on commas not nested in parentheses."""
    parts: list[str] = []
    depth = 0
    cur: list[str] = []
    n = 0
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0 and (maxsplit < 0 or n < maxsplit):
            parts.append("".join(cur))
            cur = []
            n += 1
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def parse_spd(text: str, *, name_hint: str = "core") -> Core:
    """Parse one SPD source into a :class:`Core`."""
    body = _strip_comments(text)
    stmts = [s.strip() for s in body.replace("\n", " ").split(";")]
    core = Core(name=name_hint)
    seen_name = False
    n_if = 0

    for stmt in stmts:
        if not stmt:
            continue
        m = re.match(r"^(\w+)\s*(.*)$", stmt, re.S)
        if not m:
            raise SPDParseError(f"bad statement: {stmt!r}")
        func, rest = m.group(1), m.group(2).strip()
        lf = func.lower()

        if lf == "name":
            core.name = rest.strip()
            seen_name = True
        elif lf in ("main_in", "main_out", "brch_in", "brch_out", "append_reg"):
            n_if += 1
            itf = _parse_iface(rest, default=f"if{n_if}")
            if lf == "main_in":
                core.main_in.append(itf)
            elif lf == "main_out":
                core.main_out.append(itf)
            elif lf == "brch_in":
                core.brch_in.append(itf)
            elif lf == "brch_out":
                core.brch_out.append(itf)
            else:  # Append_Reg: constant scalar inputs
                core.regs.extend(itf.ports)
        elif lf == "param":
            pm = re.match(r"^([A-Za-z_]\w*)\s*=\s*(.+)$", rest)
            if not pm:
                raise SPDParseError(f"bad Param: {stmt!r}")
            core.params[pm.group(1)] = float(pm.group(2))
        elif lf == "equ":
            parts = _split_top_commas(rest, maxsplit=1)
            if len(parts) != 2:
                raise SPDParseError(f"bad EQU: {stmt!r}")
            node_name = parts[0].strip()
            em = re.match(r"^([A-Za-z_][\w:]*)\s*=\s*(.+)$", parts[1].strip(), re.S)
            if not em:
                raise SPDParseError(f"bad EQU assignment: {stmt!r}")
            out = _strip_qual(em.group(1))
            expr = parse_formula(em.group(2))
            # Parameters are constants, not dataflow inputs.
            from .dfg import expr_vars

            ins = tuple(v for v in expr_vars(expr) if v not in core.params)
            core.nodes.append(
                Node(node_name, "equ", ins, (out,), expr=expr)
            )
        elif lf == "hdl":
            parts = _split_top_commas(rest)
            if len(parts) < 3:
                raise SPDParseError(f"bad HDL: {stmt!r}")
            node_name = parts[0].strip()
            delay = int(float(parts[1].strip()))
            call = parts[2].strip()
            params = tuple(p.strip() for p in parts[3:] if p.strip())
            outs, mod, ins = _parse_module_call(call)
            core.nodes.append(
                Node(
                    node_name,
                    "hdl",
                    ins,
                    outs,
                    module=mod,
                    delay=delay,
                    params=params,
                )
            )
        elif lf == "drct":
            dm = re.match(r"^(\([^()]*\))\s*=\s*(\([^()]*\))$", rest)
            if not dm:
                raise SPDParseError(f"bad DRCT: {stmt!r}")
            dests = _parse_port_list(dm.group(1))
            srcs = _parse_port_list(dm.group(2))
            core.drcts.append((dests, srcs))
        else:
            raise SPDParseError(f"unknown SPD function {func!r} in {stmt!r}")

    if not seen_name:
        raise SPDParseError("SPD source missing Name statement")
    return core


def parse_spd_file(path: str) -> Core:
    with open(path) as f:
        return parse_spd(f.read(), name_hint=path)
