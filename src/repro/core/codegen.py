"""SPD core → Pallas TPU stream-kernel codegen.

``repro.core.compiler`` lowers an SPD core to a per-point JAX dataflow
function; this module lowers the same :class:`CompiledCore` one level
further, into an *executable temporal-blocking Pallas kernel* with the
structure of the hand-written ``repro.kernels.lbm_stream`` — the missing
bottom of the paper's flow, where the generated datapath actually runs
(docs/pipeline.md §codegen, DESIGN.md §7). Three pieces:

1. **Stencil-offset inference** (:func:`stencil_summary`) — an abstract
   interpretation of the core's DFG that tracks, for every main output
   port, the set of (dy, dx) grid offsets of the main inputs it reads.
   ``Stencil2D`` nodes add their offset; EQU/elementwise nodes union
   their operands; sub-core calls compose offsets additively along the
   dataflow path. The per-step y-halo is ``max |dy|`` over all reads
   (docs/pipeline.md §codegen).
2. **Stripe lowering** (:meth:`StreamKernel._step_fn`) — re-evaluates the
   DFG over ``(rows, W)`` row stripes instead of whole grids: y stencil
   reads become non-periodic in-stripe shifts (the halo rows supply the
   neighbor values; ``halo`` edge rows go stale per application — the
   temporal-blocking trapezoid), x stencil reads become periodic
   in-register shifts (the full row width is VMEM-resident). Under a
   column-sharded 2-D device mesh the x reads switch to the same
   non-periodic zero-fill treatment as y (:meth:`StreamKernel
   ._step_fn_guarded`): the stripe then carries ``m·halo_x`` guard
   columns per side whose values came off-device, and columns consuming
   the zero fill are exactly the guard columns the launch crops
   (DESIGN.md §15).
3. **Launch + legalization** — the stripe function is handed to
   :func:`repro.kernels.spd_stream.spd_multistep` for the
   ``(block_h + 2·m·halo)``-row Pallas launch; explorer-chosen
   (block_h, m) plans are legalized by the shared
   :mod:`repro.core.legalize` (docs/pipeline.md §legalize) with this
   kernel's inferred halo.

Correctness contract (asserted in ``tests/test_codegen.py``): in
interpret mode the kernel bit-matches m repeated applications of the
compiler's reference JAX function (:meth:`StreamKernel.reference`), for
any legal (m, block_h) decomposition.

Supported cores: no branch streams, ``|main_in| == |main_out|`` (outputs
feed inputs across fused steps, the same chaining contract as
``temporal_cascade``), stream state expressed as ``Stencil2D`` nodes with
``mode=wrap`` (periodic grids; 1-D ``Delay``/``StreamForward``/
``StreamBackward`` state has no 2-D stripe equivalent and is rejected).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from .compiler import CompiledCore, eval_expr
from .dfg import SPDError
from .legalize import resolve_run_plan
from .library import LibraryModule

#: 1-D stream-state modules with no 2-D stripe lowering.
_STREAM_1D = ("Delay", "StreamForward", "StreamBackward")


class CodegenError(SPDError):
    """The core cannot be lowered to a stream kernel (with the reason)."""


# --------------------------------------------------------------------------
# Stencil-offset inference
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StencilSummary:
    """What a core's outputs read from the streamed grid.

    ``port_reads`` maps each output port to the set of
    ``(input_port, dy, dx)`` triples it (transitively) consumes:
    "this output reads that input at grid offset (y−dy, x−dx)".
    ``offsets`` is the union of all (dy, dx); ``halo_y``/``halo_x`` are
    the per-step stencil reach (``max |dy|`` / ``max |dx|``);
    ``modes`` collects the boundary modes of every Stencil2D crossed.
    """

    port_reads: Mapping[str, frozenset]
    offsets: frozenset
    halo_y: int
    halo_x: int
    modes: frozenset

    def halo(self) -> int:
        """Rows of halo one application of the core consumes per side."""
        return self.halo_y


def _normalize_incoming(incoming, n: int) -> tuple:
    """Canonical per-input ``(dy, dx)`` extents tuple for memo keys.

    ``None`` (the single-core case: inputs arrive straight off the grid)
    normalizes to all-zero extents — the same key as an explicit
    all-zero request, so both spellings share one memo entry.
    """
    if incoming is None:
        return ((0, 0),) * n
    ext = tuple((int(dy), int(dx)) for dy, dx in incoming)
    if len(ext) != n:
        raise CodegenError(
            f"incoming extents cover {len(ext)} inputs, core has {n}"
        )
    return ext


def _core_reads(compiled: CompiledCore, incoming=None) -> dict[str, set]:
    """Per-output ``(input_index, dy, dx)`` read sets of one core.

    Abstract interpretation over the toposorted DFG: every variable
    carries the set of (core-input index, dy, dx) it transitively reads.
    Indices are positions in ``core.input_ports()`` (main + brch + regs);
    register/param inputs are scalars and carry the empty set.

    ``incoming`` is the per-main-input ``(dy, dx)`` extent the producer
    edge applies before this core sees the stream (docs/pipeline.md
    §program): input ``i`` seeds at ``(i, dy_i, dx_i)`` instead of
    ``(i, 0, 0)``, so a program stage's summary composes its upstream
    edge reach.

    Memoized per (compiled core, incoming extents): sub-cores are shared
    across call sites (and cascades repeat the same PE m times), so
    without the cache the walk would re-derive every callee's read set
    at every call site — and fusion clusters reuse one sub-core at
    *different* incoming extents, so the memo must key on the pair, not
    the core alone, or the second use would read the first use's stale
    offsets.
    """
    core = compiled.core
    key = _normalize_incoming(
        incoming,
        len(core.main_input_ports()) + len(core.brch_input_ports()),
    )
    memo = getattr(compiled, "_stencil_reads_memo", None)
    if memo is None:
        memo = {}
        compiled._stencil_reads_memo = memo
    cached = memo.get(key)
    if cached is not None:
        return cached
    alias = core.alias_map()
    main = set(core.main_input_ports()) | set(core.brch_input_ports())
    env: dict[str, set] = {}
    stream_idx = 0
    for i, p in enumerate(core.input_ports()):
        if p in main:
            dy, dx = key[stream_idx]
            stream_idx += 1
            env[p] = {(i, dy, dx)}
        else:
            env[p] = set()
    for p in core.params:
        env[p] = set()

    for node in core.toposort():
        ins = [env[alias.get(v, v)] for v in node.inputs]
        merged = set().union(*ins) if ins else set()
        if node.kind == "equ":
            env[node.outputs[0]] = merged
            continue
        mod = compiled.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            if mod.name in _STREAM_1D:
                raise CodegenError(
                    f"core {core.name}: node {node.name} uses 1-D stream "
                    f"module {mod.name}; express grid state as Stencil2D "
                    "for stream codegen"
                )
            if mod.name == "Stencil2D":
                p = mod.resolve_params(node, core.params)
                dy, dx = int(p.get("dy", 0)), int(p.get("dx", 0))
                env[node.outputs[0]] = {
                    (i, oy + dy, ox + dx) for (i, oy, ox) in ins[0]
                }
            else:
                # Library modules other than the stencil buffer are
                # pointwise over the stream (mux, comparator, fixed-
                # function units): offsets pass through unchanged.
                for o in node.outputs:
                    env[o] = merged
        else:
            # Sub-core call: compose the callee's per-output read sets
            # with this call site's argument offsets (additive).
            sub = _core_reads(mod)
            sub_outs = mod.core.output_ports()
            if len(sub_outs) != len(node.outputs):
                raise CodegenError(
                    f"node {node.name}: module {node.module} has "
                    f"{len(sub_outs)} outputs, node declares "
                    f"{len(node.outputs)}"
                )
            for o_port, o_var in zip(sub_outs, node.outputs):
                acc: set = set()
                for (i, dy, dx) in sub[o_port]:
                    acc.update(
                        (j, oy + dy, ox + dx) for (j, oy, ox) in ins[i]
                    )
                env[o_var] = acc

    reads = {p: env[alias.get(p, p)] for p in core.output_ports()}
    memo[key] = reads
    return reads


def _stencil_modes(compiled: CompiledCore) -> set:
    """Boundary modes of every Stencil2D reachable from ``compiled``."""
    core = compiled.core
    modes: set = set()
    for node in core.nodes:
        if node.kind != "hdl":
            continue
        mod = compiled.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            if mod.name == "Stencil2D":
                p = mod.resolve_params(node, core.params)
                if int(p.get("dy", 0)) or int(p.get("dx", 0)):
                    modes.add(str(p.get("mode", "zero")))
        else:
            modes |= _stencil_modes(mod)
    return modes


def stencil_summary(compiled: CompiledCore,
                    incoming=None) -> StencilSummary:
    """Infer the stencil footprint of a compiled core's DFG.

    Walks the graph once (recursing into sub-cores, memoized per
    (core, incoming extents)) and returns which input ports each output
    reads at which grid offsets, plus the halo the temporal-blocking
    kernel must carry per fused step. Cached on the compiled core:
    ``stream_halo``, ``stream_kernel()`` and direct callers all share
    one walk. ``incoming`` composes producer-edge ``(dy, dx)`` extents
    into the footprint (docs/pipeline.md §program) — a program stage's
    effective halo is its own reach *through* the edge feeding it.
    """
    core = compiled.core
    key = _normalize_incoming(
        incoming,
        len(core.main_input_ports()) + len(core.brch_input_ports()),
    )
    memo = getattr(compiled, "_stencil_summary_memo", None)
    if memo is None:
        memo = {}
        compiled._stencil_summary_memo = memo
    cached = memo.get(key)
    if cached is not None:
        return cached
    names = core.input_ports()
    reads = {
        port: frozenset((names[i], dy, dx) for (i, dy, dx) in triples)
        for port, triples in _core_reads(compiled, key).items()
    }
    offsets = frozenset(
        (dy, dx) for triples in reads.values() for (_, dy, dx) in triples
    )
    summary = StencilSummary(
        port_reads=reads,
        offsets=offsets,
        halo_y=max((abs(dy) for dy, _ in offsets), default=0),
        halo_x=max((abs(dx) for _, dx in offsets), default=0),
        modes=frozenset(_stencil_modes(compiled)),
    )
    memo[key] = summary
    return summary


# --------------------------------------------------------------------------
# Stripe-mode DFG evaluation
# --------------------------------------------------------------------------


def _stripe_shift(x, dy: int, dx: int, periodic_x: bool = True):
    """``out[y, x] = in[y-dy, x-dx]`` on a (rows, W) stripe.

    y is shifted non-periodically with zero fill — the stripe's halo rows
    hold the true neighbor values, and rows that consume the zero fill
    are exactly the rows the trapezoid retires; x is shifted
    periodically in-register (the full row width is resident).

    ``periodic_x=False`` is the column-sharded lowering (DESIGN.md §15):
    x gets the same zero-fill treatment as y, because the stripe then
    carries guard columns holding the true neighbor values — columns
    that consume the zero fill are exactly the stale guard columns the
    sharded launch crops.
    """
    if dy:
        pad = jnp.zeros((abs(dy),) + x.shape[1:], x.dtype)
        x = (
            jnp.concatenate([pad, x[:-dy]], axis=0)
            if dy > 0
            else jnp.concatenate([x[-dy:], pad], axis=0)
        )
    if not periodic_x:
        if dx:
            pad = jnp.zeros(x.shape[:-1] + (abs(dx),), x.dtype)
            x = (
                jnp.concatenate([pad, x[:, :-dx]], axis=1)
                if dx > 0
                else jnp.concatenate([x[:, -dx:], pad], axis=1)
            )
        return x
    dx %= x.shape[1]  # periodic: offsets beyond one row width wrap
    if dx:
        # With dx normalized into [1, W), this one concatenate is the
        # periodic shift out[:, x] = in[:, (x - dx) mod W].
        x = jnp.concatenate([x[:, -dx:], x[:, :-dx]], axis=1)
    return x


def _eval_stripe(compiled: CompiledCore, env: dict,
                 periodic_x: bool = True) -> list:
    """Evaluate a core's DFG over (rows, W) stripe arrays.

    Structurally identical to :meth:`CompiledCore.apply` (same casts,
    same ``eval_expr``, same node order) so the kernel's arithmetic
    bit-matches the compiler's reference function — only ``Stencil2D``
    is re-lowered to :func:`_stripe_shift` semantics, and sub-core calls
    recurse through this evaluator instead of ``apply``.
    """
    core = compiled.core
    alias = core.alias_map()
    for node in core.toposort():
        ins = [env[alias.get(v, v)] for v in node.inputs]
        if node.kind == "equ":
            local = dict(env)
            local.update({
                v: jnp.asarray(env[alias.get(v, v)], jnp.float32)
                for v in node.inputs
            })
            env[node.outputs[0]] = eval_expr(node.expr, local)
            continue
        mod = compiled.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            if mod.name in _STREAM_1D:
                raise CodegenError(
                    f"core {core.name}: node {node.name} uses 1-D stream "
                    f"module {mod.name}; not lowerable to a 2-D stripe"
                )
            if mod.name == "Stencil2D":
                p = mod.resolve_params(node, core.params)
                outs = [
                    _stripe_shift(
                        jnp.asarray(ins[0], jnp.float32),
                        int(p.get("dy", 0)), int(p.get("dx", 0)),
                        periodic_x=periodic_x,
                    )
                ]
            else:
                outs = mod.apply(ins, mod.resolve_params(node, core.params))
        else:
            sub_env: dict = dict(zip(mod.core.input_ports(), ins))
            sub_env.update({
                k: jnp.float32(v) for k, v in mod.core.params.items()
            })
            outs = _eval_stripe(mod, sub_env, periodic_x=periodic_x)
        if len(outs) != len(node.outputs):
            raise CodegenError(
                f"node {node.name}: module {node.module} returned "
                f"{len(outs)} outputs, node declares {len(node.outputs)}"
            )
        for name, val in zip(node.outputs, outs):
            env[name] = val
    out = []
    for p in core.output_ports():
        src = alias.get(p, p)
        if src not in env:
            raise CodegenError(
                f"core {core.name}: output port {p!r} undriven"
            )
        out.append(env[src])
    return out


# --------------------------------------------------------------------------
# The codegen'd kernel
# --------------------------------------------------------------------------


class StreamKernel:
    """A compiled SPD core lowered to a temporal-blocking Pallas kernel.

    Obtained via :meth:`CompiledCore.stream_kernel`. The grid state is a
    stacked ``(P, H, W)`` f32 array with one channel per main-stream port
    (in ``main_in`` order); ``Append_Reg`` values are passed as a scalar
    tuple. One fused launch (:meth:`__call__`) advances ``m`` time steps
    per HBM round-trip; :meth:`run_for_point` legalizes and runs a DSE
    design point straight from an explorer sweep
    (docs/pipeline.md §execute).
    """

    def __init__(self, compiled: CompiledCore):
        core = compiled.core
        if core.brch_input_ports() or core.brch_output_ports():
            raise CodegenError(
                f"core {core.name}: branch streams are not lowerable to a "
                "stream kernel (no per-element side channel on the grid)"
            )
        if len(core.main_input_ports()) != len(core.main_output_ports()):
            raise CodegenError(
                f"core {core.name}: |main_in| != |main_out| "
                f"({len(core.main_input_ports())} != "
                f"{len(core.main_output_ports())}); fused steps chain "
                "outputs back into inputs"
            )
        self.compiled = compiled
        self.summary = stencil_summary(compiled)
        bad = self.summary.modes - {"wrap"}
        if bad:
            raise CodegenError(
                f"core {core.name}: Stencil2D mode(s) {sorted(bad)} not "
                "supported; the stream kernel's y-halo is periodic "
                "(mode=wrap). Express walls via stream attributes."
            )
        self.halo = self.summary.halo()
        self.halo_x = self.summary.halo_x
        self._ports = core.main_input_ports()
        self._regs = list(core.regs)
        self._params = dict(core.params)
        from repro.kernels.spd_stream.spd_stream import spd_multistep
        from repro.kernels.spd_stream.streaming import spd_multistep_streamed

        # Declarative BlockSpec launch: the reference pipeline (tests
        # compare the streamed path against it bit for bit).
        self._multistep = jax.jit(
            functools.partial(spd_multistep, self._step_fn, halo=self.halo),
            static_argnames=("m", "block_h", "interpret"),
        )
        # Manually pipelined launch (docs/pipeline.md §stream): the
        # execution path, with double_buffer a real plan knob.
        self._streamed = jax.jit(
            functools.partial(
                spd_multistep_streamed, self._step_fn, halo=self.halo
            ),
            static_argnames=("m", "block_h", "double_buffer", "interpret"),
        )
        self._sharded: dict[tuple[int, int], object] = {}
        # jit'd so the steps//m launch loop compiles once per plan shape
        # and is reused across calls (an eager lax.fori_loop over a fresh
        # closure would re-lower the whole loop on every invocation —
        # which is also what makes fused vs. pipelined program walls in
        # benchmarks/dse_sweep.py §2h an apples-to-apples comparison).
        self._run_blocked = jax.jit(
            self._run_blocked_impl,
            static_argnames=("steps", "m", "block_h", "double_buffer",
                             "interpret"),
        )
        # jit'd so XLA applies the same mul-add contractions as inside the
        # kernel: this is what makes the bit-match contract hold exactly.
        self._reference = jax.jit(self._reference_impl, static_argnames=("m",))

    # ---- the lowered stripe function --------------------------------------

    def _step_fn(self, f_ext, regs):
        """One application of the core over an extended (halo'd) stripe.

        A rank-3 stripe is ``(P, rows, W)``; higher ranks carry batch
        axes in front (``(B, P, rows, W)``, docs/pipeline.md §serve) and
        are handled by vmapping this same body over each leading axis,
        so batched and unbatched launches share one lowering.
        """
        return self._apply_stripe(f_ext, regs, periodic_x=True)

    def _step_fn_guarded(self, f_ext, regs):
        """The column-sharded stripe body (DESIGN.md §15): identical
        arithmetic, but x stencil reads are non-periodic zero-fill
        shifts — the stripe's ``m·halo_x`` guard columns hold the true
        neighbor values (delivered by the mesh's column-halo exchange),
        and the columns consuming the zero fill are exactly the stale
        guard columns the sharded launch crops.
        """
        return self._apply_stripe(f_ext, regs, periodic_x=False)

    def _apply_stripe(self, f_ext, regs, *, periodic_x):
        if f_ext.ndim > 3:
            return jax.vmap(
                lambda s: self._apply_stripe(s, regs, periodic_x=periodic_x)
            )(f_ext)
        env: dict = {p: f_ext[i] for i, p in enumerate(self._ports)}
        env.update(dict(zip(self._regs, regs)))
        env.update({k: jnp.float32(v) for k, v in self._params.items()})
        outs = _eval_stripe(self.compiled, env, periodic_x=periodic_x)
        n = len(self._ports)
        return jnp.stack([jnp.asarray(o, f_ext.dtype) for o in outs[:n]])

    # ---- launches ----------------------------------------------------------

    def _scal(self, regs: Sequence) -> jnp.ndarray:
        if len(regs) != len(self._regs):
            raise CodegenError(
                f"core {self.compiled.core.name}: expected "
                f"{len(self._regs)} register values {self._regs}, "
                f"got {len(regs)}"
            )
        # SMEM refs need a non-empty shape; pad reg-less cores with one 0.
        vals = list(regs) if regs else [0.0]
        return jnp.asarray(vals, jnp.float32)

    def __call__(self, state, regs: Sequence = (), *, m: int = 1,
                 block_h: int = 32, double_buffer: bool = True,
                 interpret: bool = True):
        """One fused launch: advance ``state`` by ``m`` time steps.

        ``double_buffer`` selects the streamed launch's buffer protocol
        (ping/pong vs single-buffer, docs/pipeline.md §stream); both are
        bitwise identical to the declarative BlockSpec launch.
        """
        return self._streamed(
            state, self._scal(regs), m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )

    def run_blocked(self, state, regs: Sequence = (), *, steps: int,
                    m: int, block_h: int, double_buffer: bool = True,
                    interpret: bool = True):
        """Advance ``steps`` time steps using m-fused kernel launches."""
        return self._run_blocked(
            state, self._scal(regs), steps=int(steps), m=int(m),
            block_h=int(block_h), double_buffer=bool(double_buffer),
            interpret=bool(interpret),
        )

    def _run_blocked_impl(self, state, scal, *, steps, m, block_h,
                          double_buffer, interpret):
        from repro.kernels.spd_stream.ops import stream_run_blocked

        return stream_run_blocked(
            functools.partial(self._streamed, double_buffer=double_buffer),
            state, scal, steps=steps, m=m, block_h=block_h,
            interpret=interpret,
        )

    def sharded(self, d: int, devices: Sequence | None = None,
                dx: int = 1):
        """Decompose this kernel across ``d`` devices.

        Returns a :class:`repro.core.distribute.ShardedStreamKernel`
        running this kernel's stripe function per shard with halo
        exchange between fused launches (docs/pipeline.md §distribute).
        ``dx`` factors ``d`` into a ``(dy, dx)`` 2-D mesh
        (DESIGN.md §15): rows shard over ``dy = d / dx`` with the ring
        exchange, columns over ``dx`` with the column-halo exchange.
        ``d == 1`` is the identity wrapper (delegates straight back).
        Default-device wrappers are cached per ``(d, dx)`` so repeat
        callers (e.g. an app driver looping ``run(..., d=2)``) reuse the
        shard_map jit cache instead of recompiling every call.
        """
        from .distribute import ShardedStreamKernel

        if devices is not None:
            return ShardedStreamKernel(self, d, devices, dx=dx)
        if (d, dx) not in self._sharded:
            self._sharded[(d, dx)] = ShardedStreamKernel(self, d, dx=dx)
        return self._sharded[(d, dx)]

    def run_for_point(self, state, regs: Sequence = (), *, point,
                      steps: int | None = None, interpret: bool = True):
        """Advance the grid using a DSE design point's (block_h, m).

        The point is legalized with the shared
        :func:`repro.core.legalize.resolve_run_plan`, using this kernel's
        inferred halo and the state's concrete width for the VMEM clamp
        (with the double-buffered→single-buffered streaming fallback).
        Returns ``(result, (block_h, m, double_buffer))``. ``state`` may
        carry batch axes in front of ``(P, H, W)``; the VMEM clamp then
        prices the full ``b``-wide stripe (docs/pipeline.md §serve).
        """
        *lead, h, w = state.shape
        p = lead[-1] if lead else 1
        b = 1
        for n in lead[:-1]:
            b *= int(n)
        block_h, m, nsteps, double_buffer = resolve_run_plan(
            h, point, steps, halo=self.halo, width=w, words=p, b=b,
            dx=1,  # this is the single-device launch path
        )
        out = self.run_blocked(
            state, regs, steps=nsteps, m=m, block_h=block_h,
            double_buffer=double_buffer, interpret=interpret,
        )
        return out, (block_h, m, double_buffer)

    # ---- the compiler's reference function --------------------------------

    def reference(self, state, regs: Sequence = (), *, m: int = 1):
        """m repeated applications of the compiled core's JAX function.

        This is the semantics the kernel must reproduce bit-for-bit in
        interpret mode: :meth:`CompiledCore.apply` on the full grid
        (``Stencil2D`` fully periodic), outputs chained into inputs.
        """
        return self._reference(state, tuple(regs), m=m)

    def _reference_impl(self, state, regs, *, m: int):
        outs = [state[i] for i in range(len(self._ports))]
        for _ in range(m):
            outs = self.compiled.apply(list(outs) + list(regs))
        return jnp.stack(
            [jnp.asarray(o, state.dtype) for o in outs[:len(self._ports)]]
        )

    def pack(self, arrays: Sequence) -> jnp.ndarray:
        """Stack per-port (H, W) grids into the kernel's (P, H, W) state."""
        if len(arrays) != len(self._ports):
            raise CodegenError(
                f"expected {len(self._ports)} main-stream fields "
                f"{self._ports}, got {len(arrays)}"
            )
        return jnp.stack([jnp.asarray(a, jnp.float32) for a in arrays])

    def pack_batch(self, states: Sequence) -> jnp.ndarray:
        """Stack ``b`` packed (P, H, W) states into a (B, P, H, W) batch.

        The batch axis groups independent simulations into one launch
        (docs/pipeline.md §serve); members must share grid geometry.
        """
        if not states:
            raise CodegenError("pack_batch needs at least one state")
        arrs = [jnp.asarray(s, jnp.float32) for s in states]
        if any(a.shape != arrs[0].shape for a in arrs):
            raise CodegenError(
                "pack_batch members must share one (P, H, W) geometry; "
                f"got {[a.shape for a in arrs]}"
            )
        return jnp.stack(arrs)


__all__ = [
    "CodegenError",
    "StencilSummary",
    "StreamKernel",
    "stencil_summary",
]
