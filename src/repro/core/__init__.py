"""The paper's primary contribution: the SPD stream-computing DSL, its
compiler to JAX, (n, m) parallelism transforms, and the design-space
exploration engine."""

from .codegen import CodegenError, StencilSummary, StreamKernel, stencil_summary
from .compiler import CompiledCore, HardwareReport, Registry, SPDCompileError
from .dfg import Core, Node, SPDError, SPDGraphError, schedule
from .dse import DesignPoint, FPGAModel, StreamWorkload, TPUModel
from .explorer import Explorer, Sweep, execute_frontier, pareto_mask
from .legalize import VMEM_BYTES, blocking_plan, resolve_run_plan
from .library import LibraryModule, default_registry_modules
from .spd import SPDParseError, parse_spd, parse_spd_file
from .transforms import (
    spatial_duplicate,
    spatial_duplicate_spd,
    temporal_cascade,
    temporal_cascade_spd,
)

__all__ = [
    "CodegenError",
    "CompiledCore",
    "Core",
    "DesignPoint",
    "Explorer",
    "FPGAModel",
    "HardwareReport",
    "LibraryModule",
    "Node",
    "Registry",
    "SPDCompileError",
    "SPDError",
    "SPDGraphError",
    "SPDParseError",
    "StencilSummary",
    "StreamKernel",
    "StreamWorkload",
    "Sweep",
    "TPUModel",
    "VMEM_BYTES",
    "blocking_plan",
    "default_registry_modules",
    "execute_frontier",
    "pareto_mask",
    "parse_spd",
    "parse_spd_file",
    "resolve_run_plan",
    "schedule",
    "spatial_duplicate",
    "spatial_duplicate_spd",
    "stencil_summary",
    "temporal_cascade",
    "temporal_cascade_spd",
]
