"""The paper's primary contribution: the SPD stream-computing DSL, its
compiler to JAX, (n, m) parallelism transforms, and the design-space
exploration engine."""

from .compiler import CompiledCore, Registry, SPDCompileError
from .dfg import Core, Node, SPDError, SPDGraphError, schedule
from .library import LibraryModule, default_registry_modules
from .spd import SPDParseError, parse_spd, parse_spd_file
from .transforms import (
    spatial_duplicate,
    spatial_duplicate_spd,
    temporal_cascade,
    temporal_cascade_spd,
)

__all__ = [
    "CompiledCore",
    "Core",
    "LibraryModule",
    "Node",
    "Registry",
    "SPDCompileError",
    "SPDError",
    "SPDGraphError",
    "SPDParseError",
    "default_registry_modules",
    "parse_spd",
    "parse_spd_file",
    "schedule",
    "spatial_duplicate",
    "spatial_duplicate_spd",
    "temporal_cascade",
    "temporal_cascade_spd",
]
