"""The paper's primary contribution: the SPD stream-computing DSL, its
compiler to JAX, (n, m) parallelism transforms, and the design-space
exploration engine."""

from .compiler import CompiledCore, HardwareReport, Registry, SPDCompileError
from .dfg import Core, Node, SPDError, SPDGraphError, schedule
from .dse import DesignPoint, FPGAModel, StreamWorkload, TPUModel
from .explorer import Explorer, Sweep, execute_frontier, pareto_mask
from .library import LibraryModule, default_registry_modules
from .spd import SPDParseError, parse_spd, parse_spd_file
from .transforms import (
    spatial_duplicate,
    spatial_duplicate_spd,
    temporal_cascade,
    temporal_cascade_spd,
)

__all__ = [
    "CompiledCore",
    "Core",
    "DesignPoint",
    "Explorer",
    "FPGAModel",
    "HardwareReport",
    "LibraryModule",
    "Node",
    "Registry",
    "SPDCompileError",
    "SPDError",
    "SPDGraphError",
    "SPDParseError",
    "StreamWorkload",
    "Sweep",
    "TPUModel",
    "default_registry_modules",
    "execute_frontier",
    "pareto_mask",
    "parse_spd",
    "parse_spd_file",
    "schedule",
    "spatial_duplicate",
    "spatial_duplicate_spd",
    "temporal_cascade",
    "temporal_cascade_spd",
]
