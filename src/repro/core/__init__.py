"""The paper's primary contribution: the SPD stream-computing DSL, its
compiler to JAX, (n, m) parallelism transforms, and the design-space
exploration engine."""

from .codegen import CodegenError, StencilSummary, StreamKernel, stencil_summary
from .compiler import CompiledCore, HardwareReport, Registry, SPDCompileError
from .dfg import Core, Node, SPDError, SPDGraphError, schedule
from .distribute import ShardedStreamKernel, device_axis_values, ring_mesh
from .dse import DesignPoint, FPGAModel, StreamWorkload, TPUModel
from .explorer import Explorer, Sweep, pareto_mask
from .legalize import (
    VMEM_BYTES,
    blocking_plan,
    legal_block_values,
    resolve_run_plan,
    shard_height,
)
from .library import LibraryModule, default_registry_modules
from .measure import (
    BackendCalibration,
    MeasurementCache,
    calibrate_backend,
    calibrate_execution,
    core_fingerprint,
    time_run,
)
from .search import (
    ExecutedPoint,
    ExhaustiveSearch,
    LocalRefine,
    SearchResult,
    SearchRunner,
    SearchStrategy,
    SuccessiveHalving,
    get_strategy,
)
from .spd import SPDParseError, parse_spd, parse_spd_file
from .transforms import (
    spatial_duplicate,
    spatial_duplicate_spd,
    temporal_cascade,
    temporal_cascade_spd,
)

__all__ = [
    "BackendCalibration",
    "CodegenError",
    "CompiledCore",
    "Core",
    "DesignPoint",
    "ExecutedPoint",
    "ExhaustiveSearch",
    "Explorer",
    "FPGAModel",
    "LocalRefine",
    "HardwareReport",
    "LibraryModule",
    "MeasurementCache",
    "Node",
    "Registry",
    "SPDCompileError",
    "SPDError",
    "SPDGraphError",
    "SPDParseError",
    "SearchResult",
    "SearchRunner",
    "SearchStrategy",
    "ShardedStreamKernel",
    "StencilSummary",
    "StreamKernel",
    "StreamWorkload",
    "SuccessiveHalving",
    "Sweep",
    "TPUModel",
    "VMEM_BYTES",
    "blocking_plan",
    "calibrate_backend",
    "calibrate_execution",
    "core_fingerprint",
    "default_registry_modules",
    "device_axis_values",
    "get_strategy",
    "legal_block_values",
    "pareto_mask",
    "parse_spd",
    "parse_spd_file",
    "resolve_run_plan",
    "ring_mesh",
    "schedule",
    "shard_height",
    "spatial_duplicate",
    "spatial_duplicate_spd",
    "stencil_summary",
    "temporal_cascade",
    "temporal_cascade_spd",
    "time_run",
]
