"""Mesh planner: the paper's (spatial x temporal) trade lifted to LM fleets.

The correspondence implemented here (DESIGN.md §4):

* spatial parallelism (duplicate pipelines, n) -> **data parallelism**:
  throughput scales with dp but so does the "external bandwidth" demand —
  the per-step gradient all-reduce.
* temporal parallelism (cascade PEs, m) -> **pipeline parallelism**: layer
  groups cascade; no extra gradient traffic, but on-chip (HBM) footprint
  redistributes and the fill/drain bubble ``(S-1)/(M+S-1)`` appears, exactly
  the paper's prologue/epilogue utilization loss.
* in-pipeline fine-grained parallelism -> **tensor parallelism** inside a
  stage (the operators of one formula node).

``plan()`` enumerates (dp, tp, pp) factorizations of a chip count and ranks
them with the same three-term roofline used everywhere else in this repo.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence


@dataclass(frozen=True)
class ArchStats:
    """Minimal per-architecture numbers the planner needs."""

    name: str
    params: float  # total parameters
    active_params: float  # per-token active parameters (MoE < total)
    n_layers: int
    d_model: int
    global_batch: int
    seq_len: int
    dtype_bytes: int = 2  # bf16


@dataclass(frozen=True)
class PlannerTarget:
    peak_tflops: float = 197.0  # bf16 / chip
    hbm_gbs: float = 819.0
    ici_gbs: float = 50.0  # per link
    hbm_bytes: float = 16 * 2**30
    opt_state_bytes_per_param: float = 8.0  # adam m+v fp32


@dataclass
class MeshPlan:
    dp: int
    tp: int
    pp: int
    microbatches: int
    feasible: bool = True
    limits: list[str] = field(default_factory=list)
    step_time_s: float = 0.0
    t_compute: float = 0.0
    t_dp_allreduce: float = 0.0
    t_tp_collective: float = 0.0
    pipeline_util: float = 1.0
    hbm_per_chip: float = 0.0

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.pp


def _divisors(x: int) -> list[int]:
    return [d for d in range(1, x + 1) if x % d == 0]


def evaluate_plan(
    a: ArchStats,
    dp: int,
    tp: int,
    pp: int,
    target: PlannerTarget = PlannerTarget(),
    microbatches: int | None = None,
    training: bool = True,
) -> MeshPlan:
    chips = dp * tp * pp
    mb = microbatches or max(4 * pp, 1)
    plan = MeshPlan(dp=dp, tp=tp, pp=pp, microbatches=mb)

    tokens = a.global_batch * a.seq_len
    flops = (6.0 if training else 2.0) * a.active_params * tokens
    plan.t_compute = flops / (chips * target.peak_tflops * 1e12)

    # Spatial cost: ring all-reduce of gradients across dp (bf16 grads).
    grad_bytes = a.params / (tp * pp) * a.dtype_bytes
    plan.t_dp_allreduce = (
        2.0 * grad_bytes * (dp - 1) / dp / (target.ici_gbs * 1e9)
        if (dp > 1 and training)
        else 0.0
    )
    # TP: ~4 activation collectives per layer (fwd+bwd all-reduce pair).
    act_bytes = (
        tokens / dp * a.d_model * a.dtype_bytes / max(tp, 1)
    )
    plan.t_tp_collective = (
        4.0 * a.n_layers * act_bytes * (tp - 1) / tp / (target.ici_gbs * 1e9)
        if tp > 1
        else 0.0
    )
    # Temporal cost: the pipeline fill/drain bubble (paper's u_pipe).
    plan.pipeline_util = mb / (mb + pp - 1) if pp > 1 else 1.0

    compute_and_tp = (plan.t_compute + plan.t_tp_collective) / plan.pipeline_util
    # DP all-reduce overlaps the backward pass; it binds only if longer.
    plan.step_time_s = max(compute_and_tp, plan.t_dp_allreduce)

    # Memory feasibility: weights + optimizer states + activations/microbatch.
    wpc = a.params * a.dtype_bytes / (tp * pp)
    opt = a.params * target.opt_state_bytes_per_param / (tp * pp * dp)
    act = tokens / dp / mb * a.d_model * a.dtype_bytes * 8 / tp
    plan.hbm_per_chip = wpc + (opt if training else 0.0) + act
    if plan.hbm_per_chip > target.hbm_bytes:
        plan.feasible = False
        plan.limits.append(
            f"HBM {plan.hbm_per_chip/2**30:.1f}GiB>{target.hbm_bytes/2**30:.0f}GiB"
        )
    if a.global_batch % dp != 0:
        plan.feasible = False
        plan.limits.append("batch%dp")
    if pp > a.n_layers:
        plan.feasible = False
        plan.limits.append("pp>layers")
    dominant = max(
        ("compute", plan.t_compute),
        ("dp-allreduce", plan.t_dp_allreduce),
        ("tp-collective", plan.t_tp_collective),
        key=lambda kv: kv[1],
    )[0]
    if plan.pipeline_util < 0.9 and pp > 1:
        plan.limits.append(f"bubble={1-plan.pipeline_util:.2f}")
    plan.limits.append(f"{dominant}-bound")
    return plan


def plan(
    a: ArchStats,
    chips: int,
    target: PlannerTarget = PlannerTarget(),
    tp_max: int = 16,
    training: bool = True,
) -> list[MeshPlan]:
    """Enumerate and rank mesh factorizations for ``chips`` devices."""
    plans: list[MeshPlan] = []
    for tp in _divisors(chips):
        if tp > tp_max:
            continue
        rest = chips // tp
        for pp in _divisors(rest):
            dp = rest // pp
            plans.append(evaluate_plan(a, dp, tp, pp, target, training=training))
    return sorted(plans, key=lambda p: (not p.feasible, p.step_time_s))


def render_plans(plans: Sequence[MeshPlan], top: int = 10) -> str:
    head = (
        "| dp | tp | pp | mb | feasible | step s | compute s | dp-AR s | tp s "
        "| bubble | HBM/chip GiB | notes |\n|--|--|--|--|--|--|--|--|--|--|--|--|"
    )
    rows = [
        f"| {p.dp} | {p.tp} | {p.pp} | {p.microbatches} | "
        f"{'y' if p.feasible else 'N'} | {p.step_time_s:.4f} | "
        f"{p.t_compute:.4f} | {p.t_dp_allreduce:.4f} | {p.t_tp_collective:.4f} | "
        f"{1-p.pipeline_util:.3f} | {p.hbm_per_chip/2**30:.2f} | "
        f"{';'.join(p.limits)} |"
        for p in plans[:top]
    ]
    return "\n".join([head] + rows)
