"""SPD core -> JAX stream function compiler.

Where the paper's compiler emits a pipelined Verilog datapath, this one emits
a JAX dataflow function: EQU nodes become ``jnp`` expression trees, HDL nodes
become library-module or (recursively) sub-core calls, and DRCT lines become
wiring. The pipeline *timing* side (delay balancing, depth) is computed by
``repro.core.dfg.schedule`` and retained as the hardware performance model
that drives design-space exploration (docs/pipeline.md §compile). One
level further down, ``repro.core.codegen`` lowers the same core to an
executable Pallas stream kernel (docs/pipeline.md §codegen).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Mapping, Sequence

import jax.numpy as jnp

from .dfg import (
    Bin,
    Call,
    Core,
    Expr,
    Neg,
    Node,
    Num,
    SPDError,
    Schedule,
    Var,
    expr_op_census,
    flop_count,
    op_census,
    schedule,
)
from .library import LibraryModule, default_registry_modules


class SPDCompileError(SPDError):
    pass


# --------------------------------------------------------------------------
# Module registry
# --------------------------------------------------------------------------


class Registry:
    """Resolves HDL module names to library modules or compiled sub-cores."""

    def __init__(self, include_default_library: bool = True):
        self._lib: dict[str, LibraryModule] = {}
        self._cores: dict[str, "CompiledCore"] = {}
        if include_default_library:
            for m in default_registry_modules():
                self.register_library(m)

    def register_library(self, mod: LibraryModule) -> None:
        self._lib[mod.name] = mod

    def register_core(self, compiled: "CompiledCore") -> None:
        self._cores[compiled.core.name] = compiled

    def lookup(self, name: str):
        if name in self._cores:
            return self._cores[name]
        if name in self._lib:
            return self._lib[name]
        raise SPDCompileError(f"unknown HDL module {name!r}")

    def compile(self, core: Core) -> "CompiledCore":
        compiled = CompiledCore(core, self)
        self.register_core(compiled)
        return compiled


# --------------------------------------------------------------------------
# EQU evaluation
# --------------------------------------------------------------------------

_CALL_IMPL = {
    "sqrt": jnp.sqrt,
    "rsqrt": lambda x: 1.0 / jnp.sqrt(x),
    "abs": jnp.abs,
    "exp": jnp.exp,
    "min": jnp.minimum,
    "max": jnp.maximum,
}


def eval_expr(e: Expr, env: Mapping[str, jnp.ndarray]):
    if isinstance(e, Num):
        return jnp.float32(e.value)
    if isinstance(e, Var):
        try:
            return env[e.name]
        except KeyError:
            raise SPDCompileError(f"unbound variable {e.name!r}") from None
    if isinstance(e, Neg):
        return -eval_expr(e.arg, env)
    if isinstance(e, Bin):
        a, b = eval_expr(e.lhs, env), eval_expr(e.rhs, env)
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        return a / b
    if isinstance(e, Call):
        args = [eval_expr(a, env) for a in e.args]
        return _CALL_IMPL[e.fn](*args)
    raise TypeError(f"unknown expr {e!r}")


# --------------------------------------------------------------------------
# Compiled core
# --------------------------------------------------------------------------


@dataclass
class HardwareReport:
    """The DSE-facing summary of one core's synthesized shape."""

    name: str
    depth: int  # pipeline depth d (cycles)
    census: dict  # FP operator counts
    flops: int  # N_Flops: FP ops performed per streamed element
    balance_regs: int  # delay-balancing registers inserted (word-cycles)
    buffer_bits: int  # stencil/delay buffer bits (BRAM analogue)
    stream_in_words: int  # main-input words per element (bandwidth model)
    stream_out_words: int
    # Per-step stencil reach in rows (codegen inference, DESIGN.md §7);
    # drives the TPU model's stripe residency and the kernel legalizer.
    halo: int = 1

    def workload(self, elems: int, grid_w: int = 0):
        """Bind this report to a stream length -> DSE ``StreamWorkload``.

        This is the compile-to-explore hand-off: everything the sweep
        engine needs (flops, stream widths, depth, buffer bits) comes from
        the synthesized core; only the problem size is supplied here.
        """
        from .dse import StreamWorkload

        return StreamWorkload.from_report(self, elems=elems, grid_w=grid_w)


class CompiledCore:
    """An SPD core compiled to a callable JAX dataflow function."""

    def __init__(self, core: Core, registry: Registry):
        self.core = core
        self.registry = registry
        core.toposort()  # validate graph at compile time

    # ---- hardware model ----------------------------------------------------

    def _node_params(self, node: Node) -> dict:
        mod = self.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            return mod.resolve_params(node, self.core.params)
        return {}

    def _hdl_delay(self, node: Node) -> int:
        mod = self.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            return mod.delay_fn(self._node_params(node))
        # Sub-core: the declared delay (paper semantics: statically known).
        # Fall back to the sub-core's scheduled depth when undeclared.
        if node.delay is not None and node.delay > 0:
            return node.delay
        return mod.schedule.depth

    def _hdl_census(self, node: Node) -> dict:
        mod = self.registry.lookup(node.module)
        if isinstance(mod, LibraryModule):
            return mod.census_fn(self._node_params(node))
        return mod.census

    @cached_property
    def schedule(self) -> Schedule:
        return schedule(self.core, self._hdl_delay)

    @cached_property
    def census(self) -> dict:
        return op_census(self.core, self._hdl_census)

    @cached_property
    def flops(self) -> int:
        return flop_count(self.census)

    @cached_property
    def buffer_bits(self) -> int:
        total = self.schedule.balance_regs * 32
        for n in self.core.nodes:
            if n.kind != "hdl":
                continue
            mod = self.registry.lookup(n.module)
            if isinstance(mod, LibraryModule):
                total += mod.buffer_bits_fn(self._node_params(n))
            else:
                total += mod.buffer_bits
        return total

    @cached_property
    def stream_halo(self) -> int:
        """Per-step stencil reach in rows, from the codegen's DFG inference.

        Cores the stream codegen cannot analyze (1-D stream state and
        other docs/pipeline.md §codegen rejections) fall back to 1 — the
        LBM-like default — so DSE modeling stays available for them.
        """
        from .codegen import stencil_summary

        try:
            return stencil_summary(self).halo_y
        except SPDError:
            return 1

    @cached_property
    def hardware_report(self) -> HardwareReport:
        s = self.schedule
        return HardwareReport(
            name=self.core.name,
            depth=s.depth,
            census=dict(self.census),
            flops=self.flops,
            balance_regs=s.balance_regs,
            buffer_bits=self.buffer_bits,
            stream_in_words=len(self.core.main_input_ports()),
            stream_out_words=len(self.core.main_output_ports()),
            halo=self.stream_halo,
        )

    def stream_workload(self, elems: int, grid_w: int = 0):
        """Shorthand for ``hardware_report.workload(...)`` (DSE sweeps)."""
        return self.hardware_report.workload(elems, grid_w)

    def explorer(self, elems: int, grid_w: int = 0, **kw):
        """Design-space :class:`~repro.core.explorer.Explorer` of this core.

        The explorer keeps a reference to the core, so TPU frontier
        points can be *executed* through the codegen'd stream kernel
        (``Explorer.execute_frontier``, docs/pipeline.md §execute).
        """
        from .explorer import Explorer

        return Explorer(self, elems=elems, grid_w=grid_w, **kw)

    def stream_kernel(self):
        """Lower this core to a temporal-blocking Pallas stream kernel.

        The SPD→Pallas codegen path (docs/pipeline.md §codegen): stencil
        offsets are inferred from this core's DFG and the dataflow
        function is re-lowered over VMEM row stripes. Raises
        :class:`~repro.core.codegen.CodegenError` for cores the stream
        target cannot express (branch streams, 1-D stream state,
        non-periodic stencils).
        """
        from .codegen import StreamKernel

        return StreamKernel(self)

    # ---- execution -----------------------------------------------------------

    def apply(self, inputs: Sequence) -> list:
        """Positional call: inputs ordered main_in + brch_in + regs,
        outputs ordered main_out + brch_out (matches SPD module-call syntax).
        """
        names = self.core.input_ports()
        if len(inputs) != len(names):
            raise SPDCompileError(
                f"core {self.core.name}: expected {len(names)} inputs "
                f"({names}), got {len(inputs)}"
            )
        env: dict = dict(zip(names, inputs))
        env.update({k: jnp.float32(v) for k, v in self.core.params.items()})
        alias = self.core.alias_map()

        for node in self.core.toposort():
            ins = [env[alias.get(v, v)] for v in node.inputs]
            if node.kind == "equ":
                ins_f32 = {
                    v: jnp.asarray(env[alias.get(v, v)], jnp.float32)
                    for v in node.inputs
                }
                local = dict(env)
                local.update(ins_f32)
                env[node.outputs[0]] = eval_expr(node.expr, local)
            else:
                mod = self.registry.lookup(node.module)
                if isinstance(mod, LibraryModule):
                    outs = mod.apply(ins, mod.resolve_params(node, self.core.params))
                else:
                    outs = mod.apply(ins)
                if len(outs) != len(node.outputs):
                    raise SPDCompileError(
                        f"node {node.name}: module {node.module} returned "
                        f"{len(outs)} outputs, node declares {len(node.outputs)}"
                    )
                for name, val in zip(node.outputs, outs):
                    env[name] = val

        out_names = self.core.output_ports()
        outs = []
        for p in out_names:
            src = alias.get(p, p)
            if src not in env:
                raise SPDCompileError(
                    f"core {self.core.name}: output port {p!r} undriven"
                )
            outs.append(env[src])
        return outs

    def __call__(self, main_in: Mapping, brch_in: Mapping | None = None,
                 regs: Mapping | None = None):
        """Named call returning ``(main_out: dict, brch_out: dict)``."""
        brch_in = brch_in or {}
        regs = regs or {}
        args = []
        for p in self.core.main_input_ports():
            args.append(main_in[p])
        for p in self.core.brch_input_ports():
            args.append(brch_in[p])
        for p in self.core.regs:
            args.append(regs[p])
        outs = self.apply(args)
        mo = self.core.main_output_ports()
        main_out = dict(zip(mo, outs[: len(mo)]))
        brch_out = dict(zip(self.core.brch_output_ports(), outs[len(mo):]))
        return main_out, brch_out
