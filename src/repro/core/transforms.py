"""Structural (n, m) transforms over SPD cores.

``temporal_cascade``  — the paper's Fig. 2c / Fig. 11: chain m copies of a PE
so one pass over the stream advances m iterations. Emitted as SPD source (in
the style the paper writes by hand) and recompiled, so the transform
exercises the same front-end path a user would.

``spatial_duplicate`` — the paper's Fig. 2b / Fig. 8: n lanes processing an
n-wide stream. Generic duplication is only valid for lane-local (elementwise)
cores; cores with stream-offset modules need a lane-aware variant, exactly as
the paper wrote dedicated x1/x2/x4 translation stages (§III-B). The LBM app
provides those in ``repro.apps.lbm``.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from .compiler import CompiledCore, Registry, SPDCompileError
from .dfg import Core
from .spd import parse_spd

# Library modules that are pure per-element functions (safe to lane-split).
_ELEMENTWISE_MODULES = {"SyncMux", "Comparator", "Eliminator"}


def temporal_cascade_spd(core: Core, m: int) -> str:
    """Emit SPD source for m cascaded instances of ``core`` (Fig. 11 style)."""
    mi = core.main_input_ports()
    mo = core.main_output_ports()
    if len(mi) != len(mo):
        raise SPDCompileError(
            f"temporal cascade needs |main_in| == |main_out| "
            f"({len(mi)} != {len(mo)}) so PEs can chain"
        )
    if core.brch_input_ports() or core.brch_output_ports():
        raise SPDCompileError("temporal cascade: branch ports not chainable")
    name = f"{core.name}_t{m}"
    lines = [f"Name {name};"]
    lines.append("Main_In {Mi::" + ",".join(f"i_{p}" for p in mi) + "};")
    lines.append("Main_Out {Mo::" + ",".join(f"o_{p}" for p in mo) + "};")
    if core.regs:
        lines.append("Append_Reg {Rg::" + ",".join(core.regs) + "};")
    cur = [f"i_{p}" for p in mi]
    for s in range(1, m + 1):
        outs = [f"s{s}_{p}" for p in mo]
        call_in = ",".join(cur + list(core.regs))
        lines.append(
            f"HDL PE_{s}, 0, ({','.join(outs)}) = {core.name}({call_in});"
        )
        cur = outs
    lines.append(
        "DRCT (" + ",".join(f"o_{p}" for p in mo) + ") = (" + ",".join(cur) + ");"
    )
    return "\n".join(lines)


def temporal_cascade(compiled: CompiledCore, m: int) -> CompiledCore:
    src = temporal_cascade_spd(compiled.core, m)
    core = parse_spd(src)
    return compiled.registry.compile(core)


def spatial_duplicate_spd(core: Core, n: int) -> str:
    """Emit SPD source for an n-lane duplication of an elementwise core."""
    for node in core.nodes:
        if node.kind == "hdl" and node.module not in _ELEMENTWISE_MODULES:
            raise SPDCompileError(
                f"spatial_duplicate: node {node.name} ({node.module}) holds "
                "stream state; write a lane-aware variant (see repro.apps.lbm)"
            )
    mi = core.main_input_ports()
    mo = core.main_output_ports()
    bi = core.brch_input_ports()
    bo = core.brch_output_ports()
    name = f"{core.name}_s{n}"
    lines = [f"Name {name};"]
    lines.append(
        "Main_In {Mi::"
        + ",".join(f"{p}_l{j}" for j in range(n) for p in mi)
        + "};"
    )
    lines.append(
        "Main_Out {Mo::"
        + ",".join(f"{p}_l{j}" for j in range(n) for p in mo)
        + "};"
    )
    if bi:
        lines.append(
            "Brch_In {Bi::"
            + ",".join(f"{p}_l{j}" for j in range(n) for p in bi)
            + "};"
        )
    if bo:
        lines.append(
            "Brch_Out {Bo::"
            + ",".join(f"{p}_l{j}" for j in range(n) for p in bo)
            + "};"
        )
    if core.regs:
        lines.append("Append_Reg {Rg::" + ",".join(core.regs) + "};")
    for j in range(n):
        outs = [f"{p}_l{j}" for p in mo] + [f"{p}_l{j}" for p in bo]
        ins = [f"{p}_l{j}" for p in mi] + [f"{p}_l{j}" for p in bi] + list(core.regs)
        lines.append(
            f"HDL Lane_{j}, 0, ({','.join(outs)}) = {core.name}({','.join(ins)});"
        )
    return "\n".join(lines)


def spatial_duplicate(compiled: CompiledCore, n: int) -> CompiledCore:
    src = spatial_duplicate_spd(compiled.core, n)
    core = parse_spd(src)
    return compiled.registry.compile(core)


# --------------------------------------------------------------------------
# Stream helpers
# --------------------------------------------------------------------------


def interleave_lanes(x, n: int):
    """Split a flat stream (T, ...) into n column-interleaved lanes.

    Returns a list of n streams of length T//n: lane j holds elements
    ``j, j+n, j+2n, ...`` — the wiring of the paper's n-wide stream.
    """
    t = x.shape[0] - x.shape[0] % n
    return [x[:t][j::n] for j in range(n)]


def deinterleave_lanes(lanes: Sequence):
    """Inverse of :func:`interleave_lanes`."""
    stacked = jnp.stack(lanes, axis=1)  # (T//n, n, ...)
    return stacked.reshape((-1,) + stacked.shape[2:])


def compact_stream(x, en):
    """Host-side Eliminator compaction: keep elements where en != 0."""
    import numpy as np

    xn, en_ = np.asarray(x), np.asarray(en)
    return xn[en_ != 0]
