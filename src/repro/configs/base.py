"""Architecture config schema + the assigned input-shape suite."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    n_shared: int = 0  # shared-expert multiplier (kimi-style)
    capacity_factor: float = 1.25
    moe_start_layer: int = 0  # dense layers before the MoE stack


@dataclass(frozen=True)
class SSMConfig:
    state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    use_rope: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    sliding_window: int = 0
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_period: int = 0  # hybrid: shared attn block every k SSM layers
    block_pattern: tuple = ()  # ssm family: 'mlstm' / 'slstm' per layer
    enc_dec: bool = False  # audio: encoder-decoder
    n_frontend_tokens: int = 0  # vlm: stubbed patch embeddings
    dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    # long_500k policy (DESIGN.md §Shape-policy): sub-quadratic decode only
    supports_long_context: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    # ---- analytic parameter counts (drive the planner + roofline) --------
    def attn_params(self) -> int:
        hd = self.head_dim
        p = self.d_model * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.qkv_bias:
            p += hd * (self.n_heads + 2 * self.n_kv_heads)
        return p

    def mlp_params(self, d_ff: int | None = None) -> int:
        f = d_ff if d_ff is not None else self.d_ff
        mult = 3 if self.activation == "swiglu" else 2
        return mult * self.d_model * f

    def layer_params(self, moe_layer: bool | None = None) -> int:
        moe_layer = (self.moe is not None) if moe_layer is None else moe_layer
        p = self.attn_params() + 2 * self.d_model  # norms
        if moe_layer and self.moe:
            p += self.moe.n_experts * 3 * self.d_model * self.moe.d_ff
            p += self.d_model * self.moe.n_experts  # router
            if self.moe.n_shared:
                p += self.mlp_params(self.moe.d_ff * self.moe.n_shared)
        else:
            p += self.mlp_params()
        return p

    def num_params(self) -> float:
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.family in ("hybrid", "ssm"):
            # non-transformer blocks: count the real parameter tree once
            # (eval_shape, no allocation) and cache on the instance
            cached = getattr(self, "_np_cache", None)
            if cached is None:
                import jax

                from repro.models import registry as _registry

                shapes = jax.eval_shape(
                    _registry.build(self).init, jax.random.PRNGKey(0)
                )
                cached = float(
                    sum(
                        int(_prod(l.shape))
                        for l in jax.tree_util.tree_leaves(shapes)
                    )
                )
                object.__setattr__(self, "_np_cache", cached)
            return cached
        if self.enc_dec:
            enc = self.attn_params() + self.mlp_params() + 2 * self.d_model
            dec = 2 * self.attn_params() + self.mlp_params() + 3 * self.d_model
            return float(self.n_layers * (enc + dec) + emb)
        if self.moe:
            n_dense = self.moe.moe_start_layer
            return float(
                n_dense * self.layer_params(moe_layer=False)
                + (self.n_layers - n_dense) * self.layer_params(moe_layer=True)
                + emb
            )
        return float(self.n_layers * self.layer_params() + emb)

    def active_params(self) -> float:
        """Per-token active parameters (MoE activates top_k of n_experts)."""
        if not self.moe:
            return self.num_params()
        active_layer = (
            self.attn_params()
            + 2 * self.d_model
            + self.moe.top_k * 3 * self.d_model * self.moe.d_ff
            + (self.mlp_params(self.moe.d_ff * self.moe.n_shared)
               if self.moe.n_shared else 0)
        )
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return float(self.n_layers * active_layer + emb)

    # ---- reduced config for CPU smoke tests -------------------------------
    def reduced(self) -> "ArchConfig":
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 if not self.attn_period else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=256,
            vocab=512,
            head_dim=32,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window
            else 0,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            dtype="float32",
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff=128,
                n_shared=min(self.moe.n_shared, 1),
                moe_start_layer=min(self.moe.moe_start_layer, 1),
                # ample capacity: smoke tests assert prefill==decode, so no
                # token may drop on either path
                capacity_factor=8.0,
            )
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state=16, head_dim=16, chunk=16
            )
        if self.attn_period:
            changes["attn_period"] = 2
        if self.block_pattern:
            changes["block_pattern"] = tuple(self.block_pattern[:2]) or (
                "mlstm", "slstm",
            )
        if self.n_kv_heads == self.n_heads:  # keep MHA archs MHA
            changes["n_kv_heads"] = changes["n_heads"]
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """DESIGN.md shape policy: which (arch x shape) cells run."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 500k dense-KV decode skipped"
    return True, ""
