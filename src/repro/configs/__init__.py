"""Assigned-architecture configs (exact public numbers) + the LBM app."""

from .base import ArchConfig, MoEConfig, SSMConfig, SHAPES, ShapeConfig, shape_applicable
from . import (
    granite_34b,
    kimi_k2_1t_a32b,
    llava_next_34b,
    mixtral_8x7b,
    nemotron_4_15b,
    qwen2_5_32b,
    qwen3_8b,
    whisper_medium,
    xlstm_125m,
    zamba2_7b,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_34b,
        nemotron_4_15b,
        qwen2_5_32b,
        qwen3_8b,
        zamba2_7b,
        whisper_medium,
        xlstm_125m,
        mixtral_8x7b,
        kimi_k2_1t_a32b,
        llava_next_34b,
    )
}


def get_arch(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key in ARCHS:
        return ARCHS[key]
    for k in ARCHS:
        if k.replace(".", "-").replace("_", "-") == key:
            return ARCHS[k]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


__all__ = [
    "ARCHS",
    "ArchConfig",
    "MoEConfig",
    "SHAPES",
    "SSMConfig",
    "ShapeConfig",
    "get_arch",
    "shape_applicable",
]
