"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, 384 experts top-8 + 1 shared expert, first layer dense —
trillion-parameter MoE (paper-table config). bf16 optimizer states keep the
512-chip dry-run inside 16 GiB/chip (DESIGN.md §Arch-notes)."""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    activation="swiglu",
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1,
                  moe_start_layer=1, capacity_factor=1.25),
    opt_state_dtype="bfloat16",
    notes="384 experts / 16-way model axis = 24 experts per slice (EP)",
)
