"""whisper-medium [audio]: 24L(enc)+24L(dec) d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865 — encoder-decoder; conv frontend STUBBED to
precomputed frame embeddings per the assignment (arXiv:2212.04356).
seq_len = encoder frames; decoder length = seq_len/4 (DESIGN.md §Shapes).
RoPE replaces learned/sinusoidal positions (same shapes/FLOPs)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    activation="gelu",
    enc_dec=True,
)
