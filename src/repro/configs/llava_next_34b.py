"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling frontend STUBBED to 2880 precomputed patch
embeddings per the assignment (hf:llava-hf/llava-v1.6)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    activation="swiglu",
    n_frontend_tokens=2880,
)
