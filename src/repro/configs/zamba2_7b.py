"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32, MHA) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + one weight-tied shared
attention block applied every 6 SSM layers (arXiv:2411.15242).
Sub-quadratic decode -> runs the long_500k cell."""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    activation="swiglu",
    ssm=SSMConfig(state=64, head_dim=64, expand=2, conv=4, chunk=128,
                  n_groups=1),
    attn_period=6,
    supports_long_context=True,
    notes="shared attn block: per-site LoRA deltas omitted (DESIGN.md)",
)
