"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — mLSTM blocks
with one sLSTM every 6th block (arXiv:2405.04517). d_ff=0: blocks carry
their own up/down projections, no separate FFN. O(1) recurrent decode ->
runs the long_500k cell."""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm=SSMConfig(chunk=128),
    block_pattern=(),  # default: sLSTM at every 6th position
    supports_long_context=True,
)
