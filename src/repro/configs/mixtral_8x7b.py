"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention 4096
(arXiv:2401.04088). SWA bounds the KV cache -> runs the long_500k cell."""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    activation="swiglu",
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=14336),
    supports_long_context=True,
    notes="8 experts < 16-way model axis: TP-within-expert sharding",
)
