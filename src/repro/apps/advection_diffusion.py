"""Advection → reaction/diffusion — the 2-core stream-program application.

The LBM program (``repro.apps.lbm.lbm_program``) proves the program
layer on the paper's benchmark; this app is the second acceptance
workload (docs/pipeline.md §program, DESIGN.md §14): a genuine 2-core
chain whose stages are *both* stencil cores, so fusing them composes
halos (1 + 1 = 2 rows per temporal step) instead of merely chaining
pointwise work:

* ``Advect2D`` — first-order upwind advection with positive constant
  velocity ``(vx, vy)`` (``Append_Reg``), periodic boundaries:

      a = u - vx*(u - u[x-1]) - vy*(u - u[y-1])

* ``ReactDiffuse2D`` — explicit five-point diffusion plus a logistic
  reaction term (Fisher-KPP style), ``alpha``/``r`` as registers:

      u' = a + alpha*lap(a) + r*a*(1 - a)

``advdiff_spd`` is the hand-written monolithic single-core reference —
the same EQU formulae concatenated into one core, with the stage-2
stencils applied to the *computed* intermediate stream — which every
fusion partition of the program must reproduce bit for bit
(``tests/test_program.py``). A pure-``jnp`` oracle closes the loop.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import CompiledCore, Registry, parse_spd

#: Five-point Laplacian taps (dy, dx, port): Stencil2D(u), dy=a, dx=b
#: reads u[y-a, x-b] (the translation convention of repro.apps.lbm).
NEIGHBORS = ((1, 0, "n"), (-1, 0, "s"), (0, 1, "w"), (0, -1, "e"))


def advect_spd(width: int, mode: str = "wrap",
               name: str = "Advect2D") -> str:
    """Program stage 1: first-order upwind advection (halo 1)."""
    return "\n".join([
        f"Name {name};",
        "Main_In {mi::u};",
        "Main_Out {mo::a};",
        "Append_Reg {rg::vx,vy};",
        f"HDL Tux, 0, (uxm) = Stencil2D(u), dy=0, dx=1, "
        f"W={width}, mode={mode};",
        f"HDL Tuy, 0, (uym) = Stencil2D(u), dy=1, dx=0, "
        f"W={width}, mode={mode};",
        "EQU Nadv, a = u - vx*(u - uxm) - vy*(u - uym);",
    ])


def react_diffuse_spd(width: int, mode: str = "wrap",
                      name: str = "ReactDiffuse2D") -> str:
    """Program stage 2: five-point diffusion + logistic reaction (halo 1)."""
    L = [
        f"Name {name};",
        "Main_In {mi::a};",
        "Main_Out {mo::u2};",
        "Append_Reg {rg::alpha,r};",
    ]
    for dy, dx, port in NEIGHBORS:
        L.append(
            f"HDL T{port}, 0, (a{port}) = Stencil2D(a), "
            f"dy={dy}, dx={dx}, W={width}, mode={mode};"
        )
    L.append("EQU Nlap, lap = an + as + ae + aw - 4.0*a;")
    L.append("EQU Nnew, u2 = a + alpha*lap + r*a*(1.0 - a);")
    return "\n".join(L)


def advdiff_spd(width: int, mode: str = "wrap",
                name: str = "AdvDiff2D") -> str:
    """The monolithic single-core reference: both stages' formulae in one
    core, stage-2 stencils reading the computed intermediate ``a``
    (inferred halo 2 — the composed program halo)."""
    L = [
        f"Name {name};",
        "Main_In {mi::u};",
        "Main_Out {mo::u2};",
        "Append_Reg {rg::vx,vy,alpha,r};",
        f"HDL Tux, 0, (uxm) = Stencil2D(u), dy=0, dx=1, "
        f"W={width}, mode={mode};",
        f"HDL Tuy, 0, (uym) = Stencil2D(u), dy=1, dx=0, "
        f"W={width}, mode={mode};",
        "EQU Nadv, a = u - vx*(u - uxm) - vy*(u - uym);",
    ]
    for dy, dx, port in NEIGHBORS:
        L.append(
            f"HDL T{port}, 0, (a{port}) = Stencil2D(a), "
            f"dy={dy}, dx={dx}, W={width}, mode={mode};"
        )
    L.append("EQU Nlap, lap = an + as + ae + aw - 4.0*a;")
    L.append("EQU Nnew, u2 = a + alpha*lap + r*a*(1.0 - a);")
    return "\n".join(L)


def build_advdiff_registry(width: int, mode: str = "wrap") -> Registry:
    """Compile both stages + the monolithic reference into one registry."""
    reg = Registry()
    reg.compile(parse_spd(advect_spd(width, mode)))
    reg.compile(parse_spd(react_diffuse_spd(width, mode)))
    reg.compile(parse_spd(advdiff_spd(width, mode)))
    return reg


def advdiff_program(width: int, mode: str = "wrap"):
    """The app as a 2-core :class:`~repro.core.program.StreamProgram`:
    advect → react/diffuse, fusion partition left to the DSE."""
    from repro.core.program import StreamProgram

    return StreamProgram(
        build_advdiff_registry(width, mode),
        ["Advect2D", "ReactDiffuse2D"],
        width=width,
        name="AdvDiff_Program",
    )


# --------------------------------------------------------------------------
# Pure-jnp reference (the oracle)
# --------------------------------------------------------------------------


@jax.jit
def advdiff_ref_step(u, vx, vy, alpha, r):
    """One advect→react/diffuse step, periodic boundaries."""
    a = (
        u
        - vx * (u - jnp.roll(u, 1, axis=1))
        - vy * (u - jnp.roll(u, 1, axis=0))
    )
    lap = (
        jnp.roll(a, 1, axis=0) + jnp.roll(a, -1, axis=0)
        + jnp.roll(a, 1, axis=1) + jnp.roll(a, -1, axis=1)
        - 4.0 * a
    )
    return a + alpha * lap + r * a * (1.0 - a)


@partial(jax.jit, static_argnames=("steps",))
def advdiff_ref_run(u, vx, vy, alpha, r, steps: int):
    def body(_, g):
        return advdiff_ref_step(g, vx, vy, alpha, r)

    return jax.lax.fori_loop(0, steps, body, u)


def blob_init(h: int, w: int, amp: float = 0.8) -> jnp.ndarray:
    """A smooth periodic concentration blob in (0, amp]."""
    y, x = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(w, dtype=jnp.float32),
        indexing="ij",
    )
    import math

    return amp * (
        0.5 + 0.25 * jnp.sin(2 * math.pi * y / h)
        + 0.25 * jnp.cos(2 * math.pi * x / w)
    )


# --------------------------------------------------------------------------
# Simulation driver
# --------------------------------------------------------------------------


class AdvectionDiffusionSimulation:
    """Driver mirroring :class:`repro.apps.lbm.LBMSimulation` for the
    2-core program: holds the compiled registry, hands the explorer a
    program-backed workload (``stages`` set, so the model prices fusion
    partitions cluster by cluster), and executes points through
    :func:`repro.core.program.program_run_factory`."""

    def __init__(self, height: int, width: int, *, vx: float = 0.2,
                 vy: float = 0.1, alpha: float = 0.15, r: float = 0.05):
        if not 0.0 < alpha <= 0.25:
            raise ValueError(f"explicit scheme needs 0 < alpha <= 0.25, "
                             f"got {alpha}")
        if not (0.0 <= vx <= 1.0 and 0.0 <= vy <= 1.0):
            raise ValueError("upwind scheme needs 0 <= vx, vy <= 1")
        self.height, self.width = height, width
        self.vx, self.vy, self.alpha, self.r = vx, vy, alpha, r
        self.program = advdiff_program(width)
        self.registry = self.program.registry

    @property
    def monolithic_core(self) -> CompiledCore:
        """The hand-written single-core AdvDiff2D reference."""
        return self.registry.lookup("AdvDiff2D")

    def regs(self) -> tuple:
        """Flat program register values (``vx, vy, alpha, r`` — also the
        monolithic core's register order)."""
        return (self.vx, self.vy, self.alpha, self.r)

    def state(self, u) -> jnp.ndarray:
        return self.program.monolithic_kernel().pack([u])

    def explorer(self, **kw):
        """DSE explorer over the program (fusion axis included via
        ``sweep_tpu(fusion_values=...)``)."""
        return self.program.explorer(
            self.height * self.width, grid_w=self.width, **kw
        )

    def run(self, u, steps: int, *, fusion: str = "", m: int = 1,
            block_h: int = 32, interpret: bool = True, d: int = 1):
        """Advance ``steps`` through the program under ``fusion``."""
        out = self.program.kernel(fusion).run_blocked(
            self.state(u), self.regs(), steps=steps, m=m,
            block_h=block_h, interpret=interpret, d=d,
        )
        return out[0]
