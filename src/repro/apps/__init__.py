"""Applications built on the SPD stream-computing core."""
