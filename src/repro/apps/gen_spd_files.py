"""Regenerate the on-disk .spd artifacts (paper Figs. 6-11) from the
in-memory SPD generators in :mod:`repro.apps.lbm`.

The checked-in files under ``src/repro/apps/spd/`` are what the paper
ships as hand-written DSL sources; here they are emitted from the same
generators the simulation uses, so the artifacts can never drift from
the code. ``tests/test_spd_files.py`` compiles them and checks the
structural invariants (131 FP ops, cascade depth scaling).

    PYTHONPATH=src python -m repro.apps.gen_spd_files
"""

from __future__ import annotations

import os

from repro.core import Registry, parse_spd, temporal_cascade_spd

from .diffusion import diffusion_spd
from .lbm import bndry_spd, calc_spd, pe_spd, trans_spd

# The paper's grid: 720 x 300, periodic.
WIDTH = 720
MODE = "wrap"

SPD_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "spd")


def sources() -> dict[str, str]:
    """File name -> SPD source for every shipped artifact."""
    pe_src = pe_spd(WIDTH, MODE, name="PEx1", bndry="hdl")
    pe_core = parse_spd(pe_src)
    return {
        "ulbm_calc.spd": calc_spd(),
        "ulbm_trans2d_x1.spd": trans_spd(WIDTH, MODE),
        "ulbm_bndry.spd": bndry_spd(),
        "pe_x1.spd": pe_src,
        "pe_x1_t2.spd": temporal_cascade_spd(pe_core, 2),
        "pe_x1_t4.spd": temporal_cascade_spd(pe_core, 4),
        # The second SPD application (repro.apps.diffusion): proves the
        # SPD->Pallas codegen path on a non-LBM core.
        "diffusion2d.spd": diffusion_spd(WIDTH, MODE),
    }


def main(out_dir: str = SPD_DIR) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, src in sources().items():
        path = os.path.join(out_dir, name)
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(src.strip() + "\n")
        written.append(path)
    return written


if __name__ == "__main__":
    for path in main():
        print(path)
