"""D2Q9 lattice-Boltzmann fluid dynamics — the paper's benchmark application.

Three SPD sub-modules mirror the paper's §III-B decomposition:

* ``uLBM_calc``   — BGK collision, written as SPD ``EQU`` formulae. The
  operator census of this core is exactly **131 FP ops** (66 add, 64 mul,
  1 div), matching the paper's Table IV total of 131 (70/60/1 — the split
  differs slightly because the paper's generator commons subexpressions
  differently).
* ``uLBM_Trans2D``— translation (streaming) via ``Stencil2D`` library nodes,
  one per lattice direction: the paper's Eq. (4) offset references.
* ``uLBM_bndry``  — boundary handling: full-way bounce-back with a moving-wall
  momentum correction, built from ``Comparator``/``SyncMux`` library nodes.

``PE`` chains calc -> trans -> bndry (paper Fig. 7); temporal cascades are
produced with :func:`repro.core.transforms.temporal_cascade` (Figs. 10-12).

A pure-``jnp`` reference implementation (used as the oracle for both the SPD
path and the Pallas kernel) plus physics validation drivers (Taylor-Green
decay, Couette flow) live here too.

Lattice convention (matches the kernels and tests):
    e0=( 0, 0)  e1=( 1, 0)  e2=( 0, 1)  e3=(-1, 0)  e4=( 0,-1)
    e5=( 1, 1)  e6=(-1, 1)  e7=(-1,-1)  e8=( 1,-1)
axis 0 of a field is y, axis 1 is x; attribute 0=fluid, 1=solid wall,
2=moving wall (velocity ``u_lid`` in +x).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Registry, parse_spd, temporal_cascade

# --------------------------------------------------------------------------
# Lattice constants
# --------------------------------------------------------------------------

EX = np.array([0, 1, 0, -1, 0, 1, -1, -1, 1])
EY = np.array([0, 0, 1, 0, -1, 1, 1, -1, -1])
W = np.array([4 / 9] + [1 / 9] * 4 + [1 / 36] * 4)
OPP = np.array([0, 3, 4, 1, 2, 7, 8, 5, 6])
CS2 = 1.0 / 3.0


def viscosity(tau: float) -> float:
    return CS2 * (tau - 0.5)


# --------------------------------------------------------------------------
# Pure-jnp reference (the oracle)
# --------------------------------------------------------------------------


def collide(f: jnp.ndarray, one_tau: float) -> jnp.ndarray:
    """BGK collision on a stacked field f: (9, H, W) -> (9, H, W)."""
    rho = jnp.sum(f, axis=0)
    inv_rho = 1.0 / rho
    ux = (f[1] + f[5] + f[8] - f[3] - f[6] - f[7]) * inv_rho
    uy = (f[2] + f[5] + f[6] - f[4] - f[7] - f[8]) * inv_rho
    usq = ux * ux + uy * uy
    ex = jnp.asarray(EX, f.dtype).reshape(9, 1, 1)
    ey = jnp.asarray(EY, f.dtype).reshape(9, 1, 1)
    w = jnp.asarray(W, f.dtype).reshape(9, 1, 1)
    cu = ex * ux + ey * uy
    feq = w * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
    return f - one_tau * (f - feq)


def stream(f: jnp.ndarray, mode: str = "wrap") -> jnp.ndarray:
    """Translation: f_i(x + e_i) <- f_i(x). axis0=y, axis1=x."""
    outs = []
    for i in range(9):
        fi = f[i]
        if mode == "wrap":
            fi = jnp.roll(fi, (int(EY[i]), int(EX[i])), axis=(0, 1))
        else:
            dy, dx = int(EY[i]), int(EX[i])
            if dy:
                pad = jnp.zeros((abs(dy),) + fi.shape[1:], fi.dtype)
                fi = (
                    jnp.concatenate([pad, fi[:-dy]], 0)
                    if dy > 0
                    else jnp.concatenate([fi[-dy:], pad], 0)
                )
            if dx:
                pad = jnp.zeros((fi.shape[0], abs(dx)), fi.dtype)
                fi = (
                    jnp.concatenate([pad, fi[:, :-dx]], 1)
                    if dx > 0
                    else jnp.concatenate([fi[:, -dx:], pad], 1)
                )
        outs.append(fi)
    return jnp.stack(outs)


def bounce_back(f: jnp.ndarray, attr: jnp.ndarray, u_lid: float,
                rho0: float = 1.0) -> jnp.ndarray:
    """Full-way bounce-back at solid nodes (attr>=1); attr==2 adds the
    moving-wall momentum correction 6 w_i rho0 (e_i . u_w)."""
    solid = attr >= 0.5
    moving = attr >= 1.5
    w = jnp.asarray(W, f.dtype).reshape(9, 1, 1)
    ex = jnp.asarray(EX, f.dtype).reshape(9, 1, 1)
    reflected = f[OPP]
    corr = 6.0 * w * rho0 * ex * u_lid
    bb = jnp.where(moving[None], reflected + corr, reflected)
    return jnp.where(solid[None], bb, f)


@partial(jax.jit, static_argnames=("mode",))
def ref_step(f, attr, one_tau, u_lid=0.0, mode="wrap"):
    """One LBM time step: collide (fluid only) -> stream -> boundary."""
    fluid = attr < 0.5
    fc = jnp.where(fluid[None], collide(f, one_tau), f)
    fs = stream(fc, mode=mode)
    return bounce_back(fs, attr, u_lid)


@partial(jax.jit, static_argnames=("steps", "mode"))
def ref_run(f, attr, one_tau, steps: int, u_lid=0.0, mode="wrap"):
    def body(_, g):
        return ref_step(g, attr, one_tau, u_lid, mode)

    return jax.lax.fori_loop(0, steps, body, f)


def macroscopics(f):
    rho = jnp.sum(f, axis=0)
    ux = (f[1] + f[5] + f[8] - f[3] - f[6] - f[7]) / rho
    uy = (f[2] + f[5] + f[6] - f[4] - f[7] - f[8]) / rho
    return rho, ux, uy


# --------------------------------------------------------------------------
# SPD sources (the paper's Figs. 6-11 rebuilt)
# --------------------------------------------------------------------------

_F = [f"f{i}" for i in range(9)]


def calc_spd() -> str:
    """BGK collision as SPD EQU formulae (131 FP ops)."""
    L = [
        "Name uLBM_calc;",
        "Main_In {mi::" + ",".join(_F) + ",atr};",
        "Main_Out {mo::" + ",".join(f"g{i}" for i in range(9)) + ",oatr};",
        "Append_Reg {rg::one_tau};",
        "Param w0 = 0.444444444;",
        "Param w1 = 0.111111111;",
        "Param w5 = 0.027777778;",
        "EQU Nrho, rho = f0+f1+f2+f3+f4+f5+f6+f7+f8;",
        "EQU Nirh, irho = 1.0 / rho;",
        "EQU Nux, ux = (f1+f5+f8-f3-f6-f7)*irho;",
        "EQU Nuy, uy = (f2+f5+f6-f4-f7-f8)*irho;",
        "EQU Nusq, usq = ux*ux + uy*uy;",
        "EQU Nfe0, feq0 = w0*rho*(1.0 - 1.5*usq);",
    ]
    for i in range(1, 9):
        ex, ey = int(EX[i]), int(EY[i])
        wname = "w1" if i <= 4 else "w5"
        if ey == 0:
            cu = "ux" if ex == 1 else "-ux"  # negation is a free sign flip
        elif ex == 0:
            cu = "uy" if ey == 1 else "-uy"
        else:
            sx = "ux" if ex == 1 else "-ux"
            sy = "+uy" if ey == 1 else "-uy"
            cu = f"({sx}{sy})"
        L.append(f"EQU Ncu{i}, cu{i} = {cu};")
        L.append(
            f"EQU Nfe{i}, feq{i} = {wname}*rho*"
            f"(1.0 + 3.0*cu{i} + 4.5*cu{i}*cu{i} - 1.5*usq);"
        )
    for i in range(9):
        L.append(f"EQU Ng{i}, gc{i} = f{i} - one_tau*(f{i} - feq{i});")
    # Collision applies on fluid cells only; walls pass through untouched.
    L.append("HDL Csld, 0, (sld) = Comparator(atr, half), op=ge;")
    L.append("Param half = 0.5;")
    for i in range(9):
        L.append(f"HDL Mg{i}, 0, (g{i}) = SyncMux(sld, f{i}, gc{i});")
    L.append("DRCT (oatr) = (atr);")
    return "\n".join(L)


def trans_spd(width: int, mode: str = "wrap") -> str:
    """Translation stage: one Stencil2D offset per lattice direction."""
    L = [
        "Name uLBM_Trans2D;",
        "Main_In {mi::" + ",".join(f"g{i}" for i in range(9)) + ",atr};",
        "Main_Out {mo::" + ",".join(f"s{i}" for i in range(9)) + ",oatr};",
    ]
    for i in range(9):
        dy, dx = int(EY[i]), int(EX[i])
        L.append(
            f"HDL T{i}, 0, (s{i}) = Stencil2D(g{i}), "
            f"dy={dy}, dx={dx}, W={width}, mode={mode};"
        )
    L.append("DRCT (oatr) = (atr);")
    return "\n".join(L)


def bndry_spd() -> str:
    """Bounce-back boundary stage built from Comparator/SyncMux nodes."""
    L = [
        "Name uLBM_bndry;",
        "Main_In {mi::" + ",".join(f"s{i}" for i in range(9)) + ",atr};",
        "Main_Out {mo::" + ",".join(f"h{i}" for i in range(9)) + ",oatr};",
        "Append_Reg {rg::u_lid,rho0};",
        "Param half = 0.5;",
        "Param oneh = 1.5;",
        "HDL Csld, 0, (sld) = Comparator(atr, half), op=ge;",
        "HDL Cmov, 0, (mov) = Comparator(atr, oneh), op=ge;",
    ]
    for i in range(9):
        o = int(OPP[i])
        if EX[i] != 0:
            # moving-wall momentum correction: +6 w_i rho0 (e_i . u_w)
            coef = 6.0 * float(W[i]) * float(EX[i])
            sign = "+" if coef >= 0 else "-"
            L.append(
                f"EQU Nc{i}, corr{i} = s{o} {sign} "
                f"{abs(coef):.9f}*u_lid*rho0;"
            )
            L.append(f"HDL Mm{i}, 0, (bb{i}) = SyncMux(mov, corr{i}, s{o});")
        else:
            L.append(f"EQU Nc{i}, bb{i} = s{o};")
        L.append(f"HDL Ms{i}, 0, (h{i}) = SyncMux(sld, bb{i}, s{i});")
    L.append("DRCT (oatr) = (atr);")
    return "\n".join(L)


def _bndry_hdl_impl(ins, p):
    """Fixed-function bounce-back unit (the paper's uLBM_bndry HDL node).

    Written elementwise over per-direction streams with Python-scalar
    lattice constants (no captured constant arrays) so the same impl
    lowers both on full grids and inside codegen'd Pallas stream kernels
    (docs/pipeline.md §codegen).
    """
    f = [jnp.asarray(x, jnp.float32) for x in ins[:9]]
    attr, u_lid, rho0 = ins[9], ins[10], ins[11]
    solid = attr >= 0.5
    moving = attr >= 1.5
    out = []
    for i in range(9):
        refl = f[int(OPP[i])]
        coef = 6.0 * float(W[i]) * float(EX[i])
        bb = jnp.where(moving, refl + coef * rho0 * u_lid, refl) if coef \
            else refl
        out.append(jnp.where(solid, bb, f[i]))
    return out + [attr]


def _register_bndry_module(reg: Registry) -> None:
    from repro.core.library import LibraryModule

    reg.register_library(
        LibraryModule(
            "uLBM_bndryHDL", 12, 10, (), _bndry_hdl_impl,
            # reflect network + mux + one MAC stage of fixed-function logic
            delay_fn=lambda p: 8,
        )
    )


def pe_spd(width: int, mode: str = "wrap", name: str = "PEx1",
           bndry: str = "hdl") -> str:
    """One processing element: calc -> trans -> bndry (paper Fig. 7).

    ``bndry='hdl'`` mirrors the paper (uLBM_bndry is a fixed-function HDL
    node, so the PE's FP-operator census stays at the computation pipeline's
    131); ``bndry='spd'`` uses the SPD-described boundary stage instead.
    """
    fin = ",".join(_F)
    g = ",".join(f"g{i}" for i in range(9))
    s = ",".join(f"s{i}" for i in range(9))
    h = ",".join(f"h{i}" for i in range(9))
    bmod = "uLBM_bndryHDL" if bndry == "hdl" else "uLBM_bndry"
    return f"""
Name {name};
Main_In {{mi::{fin},atr}};
Main_Out {{mo::{h},oatr}};
Append_Reg {{rg::one_tau,u_lid,rho0}};
HDL Ucalc, 0, ({g},a1) = uLBM_calc({fin},atr,one_tau);
HDL Utrans, 0, ({s},a2) = uLBM_Trans2D({g},a1);
HDL Ubndry, 0, ({h},a3) = {bmod}({s},a2,u_lid,rho0);
DRCT (oatr) = (a3);
"""


def collide_stream_spd(width: int, mode: str = "wrap",
                       name: str = "uLBM_CollideStream") -> str:
    """Program stage 1: BGK collision chained into translation.

    The first core of the 3-core LBM stream program
    (docs/pipeline.md §program): identical to the first two HDL calls
    of :func:`pe_spd`, so the program's fused execution stays bitwise
    equal to the monolithic PE.
    """
    fin = ",".join(_F)
    g = ",".join(f"g{i}" for i in range(9))
    s = ",".join(f"s{i}" for i in range(9))
    return f"""
Name {name};
Main_In {{mi::{fin},atr}};
Main_Out {{mo::{s},oatr}};
Append_Reg {{rg::one_tau}};
HDL Ucalc, 0, ({g},a1) = uLBM_calc({fin},atr,one_tau);
HDL Utrans, 0, ({s},a2) = uLBM_Trans2D({g},a1);
DRCT (oatr) = (a2);
"""


def bndry_stage_spd(name: str = "uLBM_Bndry2D", bndry: str = "hdl") -> str:
    """Program stage 2: the bounce-back boundary unit as its own core.

    Stencil-free (halo 0): a pipelined cut before this stage costs one
    HBM round trip per step but no extra halo rows.
    """
    s = ",".join(f"s{i}" for i in range(9))
    h = ",".join(f"h{i}" for i in range(9))
    bmod = "uLBM_bndryHDL" if bndry == "hdl" else "uLBM_bndry"
    return f"""
Name {name};
Main_In {{mi::{s},atr}};
Main_Out {{mo::{h},oatr}};
Append_Reg {{rg::u_lid,rho0}};
HDL Ubndry, 0, ({h},a3) = {bmod}({s},atr,u_lid,rho0);
DRCT (oatr) = (a3);
"""


def moments_spd(name: str = "uLBM_Moments") -> str:
    """Program stage 3: macroscopic diagnostics, distributions pass through.

    Computes rho/ux/uy *inside the stripe* (the fused cluster evaluates
    every node, so the diagnostics ride the same VMEM-resident data) and
    forwards the distributions unchanged — which is what keeps every
    fusion partition of the program bitwise equal to the monolithic PE.
    """
    hin = ",".join(f"h{i}" for i in range(9))
    L = [
        f"Name {name};",
        "Main_In {mi::" + hin + ",atr};",
        "Main_Out {mo::" + ",".join(f"o{i}" for i in range(9)) + ",oatr};",
        "EQU Mrho, rho = h0+h1+h2+h3+h4+h5+h6+h7+h8;",
        "EQU Mirh, irho = 1.0 / rho;",
        "EQU Mux, ux = (h1+h5+h8-h3-h6-h7)*irho;",
        "EQU Muy, uy = (h2+h5+h6-h4-h7-h8)*irho;",
    ]
    for i in range(9):
        L.append(f"DRCT (o{i}) = (h{i});")
    L.append("DRCT (oatr) = (atr);")
    return "\n".join(L)


def build_lbm_registry(width: int, mode: str = "wrap",
                       bndry: str = "hdl") -> Registry:
    """Compile the three stages + PE into a fresh registry."""
    reg = Registry()
    _register_bndry_module(reg)
    reg.compile(parse_spd(calc_spd()))
    reg.compile(parse_spd(trans_spd(width, mode)))
    reg.compile(parse_spd(bndry_spd()))
    reg.compile(parse_spd(pe_spd(width, mode, bndry=bndry)))
    return reg


def lbm_program(width: int, mode: str = "wrap", bndry: str = "hdl"):
    """The LBM application as a genuine 3-core stream program
    (docs/pipeline.md §program, DESIGN.md §14).

    collide+stream → boundary handling → macroscopic diagnostics, with
    the fusion partition — which stages share one ``pallas_call`` —
    left to the DSE (``StreamProgram.explorer().sweep_tpu(
    fusion_values=...)``). Fully fused it is the monolithic
    :func:`pe_spd` pipeline plus in-stripe diagnostics; every partition
    is bitwise equal to it.
    """
    from repro.core.program import StreamProgram

    reg = build_lbm_registry(width, mode, bndry)
    reg.compile(parse_spd(collide_stream_spd(width, mode)))
    reg.compile(parse_spd(bndry_stage_spd(bndry=bndry)))
    reg.compile(parse_spd(moments_spd()))
    return StreamProgram(
        reg,
        ["uLBM_CollideStream", "uLBM_Bndry2D", "uLBM_Moments"],
        width=width,
        name="uLBM_Program",
    )


# --------------------------------------------------------------------------
# Simulation driver
# --------------------------------------------------------------------------


@dataclass
class LBMProblem:
    height: int
    width: int
    tau: float = 0.8
    u_lid: float = 0.0
    mode: str = "wrap"  # 'wrap' (periodic) or 'zero' (walled domains)

    @property
    def one_tau(self) -> float:
        return 1.0 / self.tau


class LBMSimulation:
    """Runs LBM via the SPD-compiled PE (optionally cascaded m times)."""

    def __init__(self, problem: LBMProblem, m: int = 1, bndry: str = "hdl"):
        self.problem = problem
        self.m = m
        self.registry = build_lbm_registry(problem.width, problem.mode, bndry)
        pe = self.registry._cores["PEx1"]
        self.pe = pe if m == 1 else temporal_cascade(pe, m)
        self._jitted = jax.jit(self._apply)
        self._stream_kernel = None

    def _apply(self, f, attr):
        p = self.problem
        ins = [f[i] for i in range(9)] + [
            attr,
            jnp.float32(p.one_tau),
            jnp.float32(p.u_lid),
            jnp.float32(1.0),
        ]
        outs = self.pe.apply(ins)
        return jnp.stack(outs[:9])

    def run(self, f, attr, steps: int):
        if steps % self.m:
            raise ValueError(f"steps ({steps}) must be a multiple of m={self.m}")
        for _ in range(steps // self.m):
            f = self._jitted(f, attr)
        return f

    @property
    def hardware_report(self):
        return self.pe.hardware_report

    def stream_workload(self):
        """DSE workload for this problem: T = H*W elements, W-wide rows."""
        p = self.problem
        return self.hardware_report.workload(
            elems=p.height * p.width, grid_w=p.width
        )

    def explorer(self, **kw):
        """Design-space :class:`~repro.core.explorer.Explorer` for this
        simulation's compiled PE on this problem size. The compiled PE is
        passed as the explorer's core, so TPU frontier points — including
        multi-device ones — execute through the codegen'd uLBM kernel
        (``Explorer.execute_frontier``, docs/pipeline.md §execute)."""
        from repro.core.explorer import Explorer

        kw.setdefault("core", self.pe)
        return Explorer(self.stream_workload(),
                        census=self.hardware_report.census, **kw)

    # ---- codegen'd-kernel surface (docs/pipeline.md §codegen) -------------

    def stream_kernel(self):
        """The PE lowered to a Pallas stream kernel (built once, cached)."""
        if self._stream_kernel is None:
            self._stream_kernel = self.pe.stream_kernel()
        return self._stream_kernel

    def stream_state(self, f, attr) -> jnp.ndarray:
        """Pack (9, H, W) populations + attr into the kernel's (10, H, W)."""
        return self.stream_kernel().pack([f[i] for i in range(9)] + [attr])

    def stream_regs(self) -> tuple:
        """``Append_Reg`` values of the PE for this problem."""
        return (self.problem.one_tau, self.problem.u_lid, 1.0)

    # ---- stream-program surface (docs/pipeline.md §program) ---------------

    def program(self, bndry: str = "hdl"):
        """This problem as the 3-core stream program (built once).

        Same state packing (:meth:`stream_state`) and register values
        (:meth:`stream_regs` — flat program order is ``one_tau, u_lid,
        rho0``, matching the PE) as the monolithic kernel, so the two
        paths are directly bit-comparable.
        """
        if getattr(self, "_program", None) is None:
            self._program = lbm_program(
                self.problem.width, self.problem.mode, bndry
            )
        return self._program


# --------------------------------------------------------------------------
# Initial conditions + analytic references
# --------------------------------------------------------------------------


def equilibrium(rho, ux, uy):
    usq = ux * ux + uy * uy
    ex = jnp.asarray(EX, rho.dtype).reshape(9, 1, 1)
    ey = jnp.asarray(EY, rho.dtype).reshape(9, 1, 1)
    w = jnp.asarray(W, rho.dtype).reshape(9, 1, 1)
    cu = ex * ux + ey * uy
    return w * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)


def taylor_green_init(h: int, w: int, u0: float = 0.05):
    """Periodic Taylor-Green vortex; returns (f, attr, decay_rate)."""
    y, x = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(w, dtype=jnp.float32),
        indexing="ij",
    )
    kx, ky = 2 * math.pi / w, 2 * math.pi / h
    ux = -u0 * jnp.cos(kx * x) * jnp.sin(ky * y)
    uy = u0 * (kx / ky) * jnp.sin(kx * x) * jnp.cos(ky * y)
    rho = jnp.ones((h, w), jnp.float32)
    attr = jnp.zeros((h, w), jnp.float32)
    return equilibrium(rho, ux, uy), attr, float(kx * kx + ky * ky)


def couette_init(h: int, w: int):
    """Channel with static bottom wall and moving top lid (+x)."""
    rho = jnp.ones((h, w), jnp.float32)
    f = equilibrium(rho, jnp.zeros_like(rho), jnp.zeros_like(rho))
    attr = jnp.zeros((h, w), jnp.float32)
    attr = attr.at[0, :].set(1.0)  # bottom: static wall
    attr = attr.at[-1, :].set(2.0)  # top: moving lid
    return f, attr


def cavity_init(h: int, w: int):
    """Lid-driven cavity: three static walls + moving top lid."""
    rho = jnp.ones((h, w), jnp.float32)
    f = equilibrium(rho, jnp.zeros_like(rho), jnp.zeros_like(rho))
    attr = jnp.zeros((h, w), jnp.float32)
    attr = attr.at[0, :].set(1.0)
    attr = attr.at[:, 0].set(1.0)
    attr = attr.at[:, -1].set(1.0)
    attr = attr.at[-1, :].set(2.0)
    return f, attr


def tgv_kinetic_energy(f):
    _, ux, uy = macroscopics(f)
    return float(jnp.mean(ux * ux + uy * uy))
