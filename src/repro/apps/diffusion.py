"""2-D diffusion (Jacobi) — the second SPD application.

The LBM case study proves the stack end to end, but the paper's claim is
a *DSL*: any stream computation written in SPD should compile, sweep its
(n, m) design space, and execute. This five-point Jacobi diffusion core
is the smallest second witness of that claim (docs/pipeline.md §execute):

    u'[y, x] = u + alpha * (u[y-1] + u[y+1] + u[x-1] + u[x+1] - 4u)

One main-stream word in and out, four ``Stencil2D`` neighbor reads
(inferred halo = 1), diffusivity ``alpha`` as an ``Append_Reg`` register
— a very different (shallow, bandwidth-lean) workload shape from the
131-FLOP LBM pipeline, which is exactly what exercises the explorer's
models off the calibration point.

Ships the SPD source generator, the compiled core, a pure-``jnp``
reference (the oracle for the codegen'd Pallas kernel), and a
sinusoidal initial condition with its exact discrete decay factor for
physics validation.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import CompiledCore, Registry, parse_spd

#: Stencil taps of the five-point Laplacian: (dy, dx, port) per neighbor.
NEIGHBORS = ((1, 0, "un"), (-1, 0, "us"), (0, 1, "uw"), (0, -1, "ue"))


def diffusion_spd(width: int, mode: str = "wrap",
                  name: str = "Diff2D") -> str:
    """SPD source of one explicit diffusion (Jacobi) time step."""
    L = [
        f"Name {name};",
        "Main_In {mi::u};",
        "Main_Out {mo::u2};",
        "Append_Reg {rg::alpha};",
    ]
    for dy, dx, port in NEIGHBORS:
        L.append(
            f"HDL T{port}, 0, ({port}) = Stencil2D(u), "
            f"dy={dy}, dx={dx}, W={width}, mode={mode};"
        )
    L.append("EQU Nlap, lap = un + us + ue + uw - 4.0*u;")
    L.append("EQU Nnew, u2 = u + alpha*lap;")
    return "\n".join(L)


def compile_diffusion(width: int, mode: str = "wrap") -> CompiledCore:
    """Parse + compile the diffusion core into a fresh registry."""
    return Registry().compile(parse_spd(diffusion_spd(width, mode)))


# --------------------------------------------------------------------------
# Pure-jnp reference (the oracle)
# --------------------------------------------------------------------------


@jax.jit
def diffusion_ref_step(u, alpha):
    """One explicit five-point diffusion step, periodic boundaries."""
    lap = (
        jnp.roll(u, 1, axis=0) + jnp.roll(u, -1, axis=0)
        + jnp.roll(u, 1, axis=1) + jnp.roll(u, -1, axis=1)
        - 4.0 * u
    )
    return u + alpha * lap


@partial(jax.jit, static_argnames=("steps",))
def diffusion_ref_run(u, alpha, steps: int):
    def body(_, g):
        return diffusion_ref_step(g, alpha)

    return jax.lax.fori_loop(0, steps, body, u)


# --------------------------------------------------------------------------
# Initial condition + analytic reference
# --------------------------------------------------------------------------


def sine_init(h: int, w: int, amp: float = 1.0):
    """Lowest sinusoidal mode; returns ``(u0, decay_per_step(alpha))``.

    For u0 = amp·sin(ky·y)·sin(kx·x) the explicit five-point scheme
    decays the mode *exactly* by
    ``g(alpha) = 1 - alpha·(4 - 2cos(kx) - 2cos(ky))`` per step, so
    kernel physics can be validated against a closed form (the
    Taylor-Green analogue for this app).
    """
    y, x = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(w, dtype=jnp.float32),
        indexing="ij",
    )
    ky, kx = 2 * math.pi / h, 2 * math.pi / w
    u0 = amp * jnp.sin(ky * y) * jnp.sin(kx * x)

    def decay_per_step(alpha: float) -> float:
        return 1.0 - alpha * (4.0 - 2.0 * math.cos(kx) - 2.0 * math.cos(ky))

    return u0, decay_per_step


class DiffusionSimulation:
    """Compiled-core driver mirroring :class:`repro.apps.lbm.LBMSimulation`.

    Holds the compiled SPD core and its problem size; hands the explorer
    a workload bound to this grid and frontier points to the codegen'd
    stream kernel (docs/pipeline.md §execute).
    """

    def __init__(self, height: int, width: int, alpha: float = 0.2):
        if not 0.0 < alpha <= 0.25:
            raise ValueError(f"explicit scheme needs 0 < alpha <= 0.25, "
                             f"got {alpha}")
        self.height, self.width, self.alpha = height, width, alpha
        self.core = compile_diffusion(width)
        self.kernel = self.core.stream_kernel()

    @property
    def hardware_report(self):
        return self.core.hardware_report

    def explorer(self, **kw):
        return self.core.explorer(
            elems=self.height * self.width, grid_w=self.width, **kw
        )

    def state(self, u) -> jnp.ndarray:
        return self.kernel.pack([u])

    def run(self, u, steps: int, *, m: int = 1, block_h: int | None = None,
            interpret: bool = True, d: int = 1):
        """Advance ``steps`` diffusion steps through the Pallas kernel.

        ``d > 1`` shards the grid across that many devices with halo
        exchange (docs/pipeline.md §distribute) — requires ``d``
        available devices and ``d | height``.
        """
        if block_h is None:
            from repro.core.legalize import blocking_plan

            block_h, m, _ = blocking_plan(
                self.height, 32, m, halo=self.kernel.halo, d=d,
            )
        kern = self.kernel if d == 1 else self.kernel.sharded(d)
        out = kern.run_blocked(
            self.state(u), (self.alpha,), steps=steps, m=m,
            block_h=block_h, interpret=interpret,
        )
        return out[0]
