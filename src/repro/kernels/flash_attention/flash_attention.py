"""Pallas TPU flash attention (blocked online softmax).

Grid: (batch, q_heads, q_blocks, k_blocks), k innermost and sequential
("arbitrary"); q/b/h axes parallel. Running max/denominator/accumulator live
in VMEM scratch across the k sweep; the output block is written once, on the
final contributing k block. Fully-masked k blocks (beyond the causal
diagonal or outside the sliding window) are skipped via ``pl.when``.

GQA is handled in the index maps: q head ``h`` reads kv head ``h // group``.
Block shapes keep the head dim D full (lane-dim multiple of 128 for f32/bf16
models used here) and tile the sequence dims — MXU-shaped matmuls of
(block_q x D) @ (D x block_k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    # Absolute positions; causal diagonal anchored to the end of KV so the
    # same kernel serves training (sq == sk) and prefill-with-prefix.
    q_off = sk - sq + qi * block_q
    k_off = ki * block_k

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Block-level reachability: skip blocks fully above the causal diagonal
    # or fully left of the sliding window.
    reachable = True
    if causal:
        reachable = jnp.asarray(q_off + block_q - 1 >= k_off)
    if window > 0:
        reachable = jnp.logical_and(
            reachable, jnp.asarray(q_off - (k_off + block_k - 1) < window)
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)
        q_idx = q_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_off + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_idx >= k_idx
        if window > 0:
            mask &= q_idx - k_idx < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 128) broadcast copies
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (bq, 1)
        p = jnp.exp(s - m_new[:, :1])
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_ref.shape
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq={hq} must be a multiple of Hkv={hkv}")
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"S ({sq},{sk}) must tile by ({block_q},{block_k})")
    scale = scale if scale is not None else d ** -0.5

    grid = (b, hq, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, sk=sk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h, qi, ki, g=group: (b_, h // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
