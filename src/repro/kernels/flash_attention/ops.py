"""Public wrappers for attention: kernel on TPU, chunked ref elsewhere."""

from __future__ import annotations

import jax

from .flash_attention import flash_attention
from .ref import attention_chunked_ref, attention_ref


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None, use_pallas: bool | None = None,
              interpret: bool = True):
    """Dispatch attention to the Pallas kernel or the jnp reference.

    ``use_pallas=None`` auto-selects: the kernel on TPU backends, the
    chunked reference otherwise (CPU dry-runs must lower through XLA).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interpret,
        )
    sk = k.shape[2]
    chunk = 512 if sk % 512 == 0 else sk
    return attention_chunked_ref(
        q, k, v, causal=causal, window=window, scale=scale, chunk=chunk
    )


__all__ = ["attention", "attention_chunked_ref", "attention_ref",
           "flash_attention"]
