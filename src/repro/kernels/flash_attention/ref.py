"""Pure-jnp oracles for blocked attention.

``attention_ref``          — direct softmax attention (small shapes).
``attention_chunked_ref``  — online-softmax over key chunks, O(S) memory;
                             this is also the CPU/dry-run attention used by
                             the models at long sequence lengths.

Both support GQA (fewer KV heads), causal masking, and sliding windows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _mask(sq, sk, q0, k0, causal: bool, window: int, dtype):
    q_idx = q0 + jnp.arange(sq)[:, None]
    k_idx = k0 + jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), dtype=jnp.bool_)
    if causal:
        m &= q_idx >= k_idx
    if window > 0:
        m &= q_idx - k_idx < window
    return m


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d
    )


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale"))
def attention_ref(q, k, v, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """Direct attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    # align the causal diagonal to the *end* of the KV (decode convention)
    mask = _mask(sq, sk, sk - sq, 0, causal, window, logits.dtype)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "chunk")
)
def attention_chunked_ref(q, k, v, causal: bool = True, window: int = 0,
                          scale: float | None = None, chunk: int = 512):
    """Online-softmax attention over key chunks (flash semantics, pure jnp)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    if sk % chunk:
        chunk = sk  # degenerate: single chunk
    nck = sk // chunk
    kc = k.reshape(b, hq, nck, chunk, d).astype(jnp.float32)
    vc = v.reshape(b, hq, nck, chunk, d).astype(jnp.float32)

    def body(carry, idx):
        acc, m_i, l_i = carry
        kb = kc[:, :, idx]
        vb = vc[:, :, idx]
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)
        msk = _mask(sq, chunk, sk - sq, idx * chunk, causal, window, s.dtype)
        s = jnp.where(msk[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, s.max(-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        l_i = l_i * alpha + p.sum(-1)
        return (acc, m_new, l_i), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, _, l_i), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nck))
    return (acc / jnp.maximum(l_i, 1e-30)[..., None]).astype(q.dtype)
