"""Manually pipelined HBM↔VMEM streaming launch for SPD stream kernels.

The BlockSpec launch in :mod:`.spd_stream` describes stripes
*declaratively* and leaves the HBM↔VMEM movement to the Pallas grid
pipeliner. This module is the explicit form (DESIGN.md §12,
docs/pipeline.md §stream): the state stays in ``pltpu.ANY`` memory (HBM
on real TPUs), a single kernel program walks the row blocks with
``jax.lax.fori_loop``, and every ``(P, block_h + 2·m·halo, W)`` stripe
is staged through VMEM scratch buffers by explicit async copies
(``pltpu.make_async_copy`` + DMA semaphores) — ``emit_pipeline``-style
manual pipelining, written out so the buffer protocol is inspectable
and the ``double_buffer`` plan knob is *real*:

* ``double_buffer=True`` — ping/pong: two stripe buffers; while block
  ``i`` computes from one, block ``i+1``'s three-piece stripe DMA (up
  halo, center, down halo) already fills the other, and the finished
  block's output drains back to HBM asynchronously. Copy and compute
  overlap; VMEM holds two stripes (the legalizer's
  ``VMEM_DOUBLE_BUFFER`` accounting).
* ``double_buffer=False`` — one stripe buffer, sequential
  start→wait→compute per block. No overlap, but the stripe budget is
  the whole VMEM: this is the *streaming fallback* the legalizer drops
  to when a ping/pong pair of minimal stripes cannot fit.

Both variants stage block rows through VMEM instead of requiring the
grid to fit anywhere in particular, so grids whose full height
overflows VMEM stream at bandwidth. Stripe assembly (up-halo tail,
center block, down-halo head) is row-for-row identical to the
BlockSpec kernel's ``jnp.concatenate``, so streamed and declarative
launches — and the two ``nbuf`` variants — are bitwise identical.

Like the BlockSpec launch, state may carry extra leading dimensions —
``(B, P, H, W)`` batches B independent simulations into one walk
(docs/pipeline.md §serve, DESIGN.md §13): rows stay on axis ``-2``,
every stripe DMA moves all leading axes whole, and the VMEM scratch
stacks scale by B exactly as the legalizer's
``stripe_vmem_bytes(..., b=B)`` prices them. The width axis is opaque
the same way: under a column-sharded mesh (``dx > 1``, DESIGN.md §15)
``W`` arrives guard-column-extended to ``W/dx + 2·m·halo_x`` and the
legalizer prices the stripes at that width
(``stripe_vmem_bytes(..., halo_x=)``); the walk itself is unchanged.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stream_kernel(scal_ref, state_ref, out_ref, buf, obuf, insem, outsem, *,
                   step_fn: Callable, m: int, block_h: int, mh: int,
                   nblk: int, nbuf: int, src_starts: Callable):
    """One-program streaming walk over ``nblk`` row blocks.

    ``buf``/``obuf`` are ``(nbuf, …)`` VMEM scratch stacks; ``insem`` /
    ``outsem`` the matching DMA semaphore stacks. ``src_starts(i)``
    maps a (traced) block index to the three source-row offsets of its
    stripe pieces in ``state_ref`` — periodic or guard-block-extended.
    Rows are addressed on axis ``-2``; any leading (batch) axes are
    copied whole per stripe piece.
    """
    regs = tuple(scal_ref[i] for i in range(scal_ref.shape[0]))
    # Full-slice prefix covering the leading axes (P, or B and P when
    # batched): state_ref is (…, H, W), buf slots are (…, rows, W).
    lead = (slice(None),) * (len(state_ref.shape) - 2)

    def rows(ref, start, size, slot=None):
        """``ref`` restricted to ``size`` rows from ``start`` on axis -2
        (optionally under a scratch-stack ``slot`` index)."""
        idx = lead + (pl.ds(start, size), slice(None))
        if slot is not None:
            idx = (slot,) + idx
        return ref.at[idx]

    def dma_in(slot, i):
        up, center, down = src_starts(i)
        copies = [
            pltpu.make_async_copy(
                rows(state_ref, center, block_h),
                rows(buf, mh, block_h, slot), insem.at[slot, 0]),
        ]
        if mh:
            copies.append(pltpu.make_async_copy(
                rows(state_ref, up, mh),
                rows(buf, 0, mh, slot), insem.at[slot, 1]))
            copies.append(pltpu.make_async_copy(
                rows(state_ref, down, mh),
                rows(buf, mh + block_h, mh, slot),
                insem.at[slot, 2]))
        return copies

    def dma_out(slot, blk):
        return pltpu.make_async_copy(
            obuf.at[slot], rows(out_ref, blk * block_h, block_h),
            outsem.at[slot])

    if nbuf > 1:
        # Prime the pipeline: block 0's stripe is in flight before the
        # block loop starts.
        for c in dma_in(0, 0):
            c.start()

    def body(i, carry):
        slot = jax.lax.rem(i, nbuf)
        if nbuf > 1:
            # Ping/pong: kick off block i+1's stripe DMA into the other
            # buffer before touching block i, so copy overlaps compute.
            nxt = jax.lax.rem(i + 1, nbuf)

            @pl.when(i + 1 < nblk)
            def _():
                for c in dma_in(nxt, i + 1):
                    c.start()
        else:
            # Single buffer: the one stripe buffer is only free once the
            # previous block fully finished, so start→wait→compute.
            for c in dma_in(slot, i):
                c.start()
        for c in dma_in(slot, i):
            c.wait()
        f_ext = buf[slot]
        for _ in range(m):
            f_ext = step_fn(f_ext, regs)

        # The output staging buffer for this slot still holds block
        # i - nbuf's rows until its drain DMA completes.
        @pl.when(i >= nbuf)
        def _():
            dma_out(slot, i - nbuf).wait()

        obuf[slot] = f_ext[..., mh:mh + block_h, :]
        dma_out(slot, i).start()
        return carry

    jax.lax.fori_loop(0, nblk, body, 0)

    # Drain: the last nbuf output copies are still in flight.
    def drain(i, carry):
        blk = nblk - nbuf + i
        slot = jax.lax.rem(jnp.maximum(blk, 0), nbuf)

        @pl.when(blk >= 0)
        def _():
            dma_out(slot, blk).wait()
        return carry

    jax.lax.fori_loop(0, nbuf, drain, 0)


def _streamed_call(step_fn, state, scal, *, m, block_h, mh, nblk, nbuf,
                   out_h, src_starts, interpret):
    *lead, _, w = state.shape
    rows = block_h + 2 * mh
    return pl.pallas_call(
        functools.partial(
            _stream_kernel, step_fn=step_fn, m=m, block_h=block_h, mh=mh,
            nblk=nblk, nbuf=nbuf, src_starts=src_starts,
        ),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((*lead, out_h, w), state.dtype),
        scratch_shapes=[
            pltpu.VMEM((nbuf, *lead, rows, w), state.dtype),
            pltpu.VMEM((nbuf, *lead, block_h, w), state.dtype),
            pltpu.SemaphoreType.DMA((nbuf, 3 if mh else 1)),
            pltpu.SemaphoreType.DMA((nbuf,)),
        ],
        interpret=interpret,
    )(scal, state)


def spd_multistep_streamed(step_fn: Callable, state, scal, *, m: int,
                           block_h: int, halo: int,
                           double_buffer: bool = True,
                           interpret: bool = True):
    """Streamed fused m-step launch, periodic in y.

    Drop-in for :func:`repro.kernels.spd_stream.spd_multistep` — same
    stripe function contract, same validation, bitwise-identical output
    — but with manual double-buffered DMA staging (docs/pipeline.md
    §stream). ``double_buffer`` picks the ping/pong (True) or
    single-buffer streaming-fallback (False) protocol.
    """
    *_, h, _ = state.shape
    if h % block_h:
        raise ValueError(f"H={h} must be divisible by block_h={block_h}")
    mh = m * halo
    if mh > block_h:
        raise ValueError(
            f"m*halo={mh} must be <= block_h={block_h} (halo source)"
        )
    nblk = h // block_h
    nbuf = 2 if double_buffer else 1

    def src_starts(i):
        # Periodic y: block i's up halo is the tail of block i-1 (mod),
        # its down halo the head of block i+1 (mod).
        up = jnp.mod(i - 1, nblk) * block_h + (block_h - mh)
        down = jnp.mod(i + 1, nblk) * block_h
        return up, i * block_h, down

    return _streamed_call(
        step_fn, state, scal, m=m, block_h=block_h, mh=mh, nblk=nblk,
        nbuf=nbuf, out_h=h, src_starts=src_starts, interpret=interpret,
    )


def spd_multistep_halo_streamed(step_fn: Callable, ext, scal, *, m: int,
                                block_h: int, halo: int,
                                double_buffer: bool = True,
                                interpret: bool = True):
    """Streamed fused m-step launch over one halo-extended shard.

    The streamed twin of
    :func:`repro.kernels.spd_stream.spd_multistep_halo`: ``ext`` is the
    ``(P, local_h + 2·block_h, W)`` guard-block-extended shard and the
    stripe source offsets are non-periodic — block i's center is ext
    block i+1, its halos come from ext blocks i / i+2 (docs/pipeline.md
    §stream).
    """
    mh = m * halo
    if mh == 0:
        return spd_multistep_streamed(
            step_fn, ext, scal, m=m, block_h=block_h, halo=0,
            double_buffer=double_buffer, interpret=interpret,
        )
    *_, rows, _ = ext.shape
    local_h = rows - 2 * block_h
    if local_h < 1 or local_h % block_h:
        raise ValueError(
            f"extended shard of {rows} rows is not local_h + 2*block_h "
            f"with block_h={block_h} dividing local_h"
        )
    if mh > block_h:
        raise ValueError(
            f"m*halo={mh} must be <= block_h={block_h} (halo source)"
        )
    nblk = local_h // block_h

    def src_starts(i):
        center = (i + 1) * block_h
        return center - mh, center, (i + 2) * block_h

    return _streamed_call(
        step_fn, ext, scal, m=m, block_h=block_h, mh=mh, nblk=nblk,
        nbuf=2 if double_buffer else 1, out_h=local_h,
        src_starts=src_starts, interpret=interpret,
    )
