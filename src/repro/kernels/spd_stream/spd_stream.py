"""Pallas TPU launch scaffolding for codegen'd SPD stream kernels.

This is the generic form of the temporal-blocking structure hand-written
in ``repro.kernels.lbm_stream`` (DESIGN.md §2, docs/pipeline.md §codegen):

* the grid state is one stacked ``(P, H, W)`` f32 array — one channel per
  main-stream port of the SPD core;
* each grid program keeps a ``(P, block_h + 2·m·halo, W)``-row stripe
  VMEM-resident, assembled from its own block plus the two neighbor
  blocks (periodic in y via modular index maps);
* ``m`` fused applications of the core's dataflow function advance the
  stripe m time steps per HBM round-trip; after each application ``halo``
  edge rows per side go stale and are simply never read again (the
  temporal-blocking trapezoid);
* periodic x is handled inside the stripe function with in-register
  shifts (the full row width is resident), so no x-halo is needed;
* spatial parallelism is grid duplication: ``H / block_h`` programs run
  the same stripe function on disjoint row blocks.

The *stripe function* itself — ``step_fn((P, rows, W), regs) → (P, rows,
W)`` — is produced by :class:`repro.core.codegen.StreamKernel` from the
core's data-flow graph; this module only owns the ``pallas_call``
plumbing, exactly mirroring ``lbm_multistep`` so the two back ends stay
comparable line for line.

The batch axis (docs/pipeline.md §serve, DESIGN.md §13): state may
carry extra *leading* dimensions — ``(B, P, H, W)`` stacks B
independent simulations — and the launch generalizes mechanically: row
blocks are tiled on axis ``-2``, leading axes ride whole through every
BlockSpec, and the stripe function must handle the batched rank (the
codegen'd ``step_fn`` vmaps itself over leading axes). The batched
launch is bitwise identical per member to B separate launches.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scal_ref, fc_ref, fu_ref, fd_ref, out_ref, *,
            step_fn: Callable, m: int, block_h: int, mh: int):
    regs = tuple(scal_ref[i] for i in range(scal_ref.shape[0]))
    if mh:
        # Assemble the (…, block_h + 2·mh, W) extended stripe from the
        # three VMEM-resident input stripes (the y-halo exchange). Rows
        # live on axis -2 so any leading (batch) axes ride through.
        f_ext = jnp.concatenate(
            [fu_ref[..., block_h - mh:, :], fc_ref[...],
             fd_ref[..., :mh, :]],
            axis=-2,
        )
    else:  # elementwise core: no neighbor rows needed
        f_ext = fc_ref[...]
    for _ in range(m):
        f_ext = step_fn(f_ext, regs)
    out_ref[...] = f_ext[..., mh:mh + block_h, :]


def spd_multistep(step_fn: Callable, state, scal, *, m: int, block_h: int,
                  halo: int, interpret: bool = True):
    """Fused m-step launch of a codegen'd stripe function.

    Args:
      step_fn: ``((P, rows, W) stripe, regs tuple) -> (P, rows, W)`` — one
        application of the SPD core's dataflow over a row stripe, with y
        stencil reads sourced from within the stripe (edge rows go stale)
        and x stencil reads periodic in-register.
      state: (P, H, W) f32 stacked main-stream state; extra leading
        dimensions batch independent simulations — ``(B, P, H, W)``
        launches B members in one call (docs/pipeline.md §serve).
      scal: (R,) f32 Append_Reg scalar values (length >= 1; padded with a
        dummy when the core has no registers — SMEM refs need a shape).
      m: fused time steps per HBM round-trip (temporal parallelism).
      block_h: rows per grid program (spatial tile).
      halo: per-step stencil reach in rows (inferred by the codegen);
        the stripe carries ``m*halo`` extra rows per side.
      interpret: run under the Pallas interpreter (CPU validation); on
        real TPU pass False.
    """
    *lead, h, w = state.shape
    if h % block_h:
        raise ValueError(f"H={h} must be divisible by block_h={block_h}")
    mh = m * halo
    if mh > block_h:
        raise ValueError(
            f"m*halo={mh} must be <= block_h={block_h} (halo source)"
        )
    nblk = h // block_h
    nlead = len(lead)
    zeros = (0,) * nlead

    fspec = lambda off: pl.BlockSpec(
        (*lead, block_h, w),
        lambda i, off=off: zeros + ((i + off) % nblk, 0),
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, step_fn=step_fn, m=m, block_h=block_h, mh=mh
        ),
        grid=(nblk,),
        in_specs=[
            # Append_Reg scalars live in SMEM (scalar memory) on TPU
            pl.BlockSpec(memory_space=pltpu.SMEM),
            fspec(0), fspec(-1), fspec(1),
        ],
        out_specs=pl.BlockSpec(
            (*lead, block_h, w), lambda i: zeros + (i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        interpret=interpret,
    )(scal, state, state, state)
