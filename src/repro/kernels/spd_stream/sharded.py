"""Pallas launch for one *shard* of a y-decomposed stream grid.

The single-device launch (:func:`repro.kernels.spd_stream.spd_multistep`)
sources every block's y-halo from its neighbor blocks with periodic
index maps — the whole grid is on one chip, so "the block above" always
exists locally. Under multi-device spatial parallelism
(docs/pipeline.md §distribute, DESIGN.md §8) each device holds only a
``(P, H/d, W)`` shard: the halo of the shard's edge blocks lives on a
*neighboring device* and is exchanged over the interconnect by
``repro.core.distribute`` before every fused launch.

This module owns the per-shard launch that consumes those exchanged
rows: :func:`spd_multistep_halo` takes an *extended* shard

    ``ext = [pad | up-halo | local rows | down-halo | pad]``

where the received ``m·halo`` neighbor rows are padded out to one full
``block_h`` guard block per side, so the interior kernel body — the
exact same ``_kernel`` as the single-device launch — assembles each
stripe from (previous block, own block, next block) with *non*-periodic
index maps: block 0's "previous block" is the up guard block, the last
block's "next block" is the down guard block. One code path, one
bit-for-bit stripe assembly, on- or off-device.

Under a 2-D device mesh (DESIGN.md §15) the launch is width-agnostic:
when columns are sharded too (``dx > 1``), ``repro.core.distribute``
hands in an extended-*width* shard ``W/dx + 2·m·halo_x`` whose guard
columns were column-exchanged, ``step_fn`` is the guarded
(``periodic_x=False``) stripe body from ``repro.core.codegen``, and
the caller crops the advanced shard back to ``W/dx`` — nothing here
changes, the guard columns ride along inside ``W``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .spd_stream import _kernel, spd_multistep


def spd_multistep_halo(step_fn: Callable, ext, scal, *, m: int, block_h: int,
                       halo: int, interpret: bool = True):
    """Fused m-step launch over one halo-extended shard.

    Args:
      step_fn: the codegen'd stripe function, as in ``spd_multistep``.
      ext: ``(P, local_h + 2·block_h, W)`` f32 array — the shard's rows
        bracketed by one guard block per side whose inner ``m·halo`` rows
        hold the exchanged neighbor values (outer rows are padding and
        are never read, since ``m·halo <= block_h``).
      scal: (R,) f32 ``Append_Reg`` scalars (SMEM).
      m / block_h / halo: as in ``spd_multistep``; ``halo == 0`` cores
        need no exchanged rows and take the plain launch.
      interpret: run under the Pallas interpreter (CPU validation).

    Returns the advanced ``(P, local_h, W)`` shard (guard blocks dropped).
    """
    mh = m * halo
    if mh == 0:
        # Elementwise core: no neighbor rows, no guard blocks expected.
        return spd_multistep(
            step_fn, ext, scal, m=m, block_h=block_h, halo=0,
            interpret=interpret,
        )
    p, rows, w = ext.shape
    local_h = rows - 2 * block_h
    if local_h < 1 or local_h % block_h:
        raise ValueError(
            f"extended shard of {rows} rows is not local_h + 2*block_h "
            f"with block_h={block_h} dividing local_h"
        )
    if mh > block_h:
        raise ValueError(
            f"m*halo={mh} must be <= block_h={block_h} (halo source)"
        )
    nblk = local_h // block_h

    # Non-periodic maps into the guard-extended array: grid program i
    # owns ext block i+1; its up/down neighbors are ext blocks i / i+2.
    fspec = lambda off: pl.BlockSpec(
        (p, block_h, w), lambda i, off=off: (0, i + 1 + off, 0)
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, step_fn=step_fn, m=m, block_h=block_h, mh=mh
        ),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            fspec(0), fspec(-1), fspec(1),
        ],
        out_specs=pl.BlockSpec((p, block_h, w), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, local_h, w), ext.dtype),
        interpret=interpret,
    )(scal, ext, ext, ext)
