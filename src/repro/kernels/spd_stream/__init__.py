"""Generic SPD→Pallas temporal-blocking stream kernels.

Where :mod:`repro.kernels.lbm_stream` is the hand-written kernel for one
application, this package is the *codegen target*: `repro.core.codegen`
lowers any compiled SPD core into the stripe-update function that
:func:`spd_multistep` launches on the TPU grid (docs/pipeline.md §codegen).
"""

from .ops import spd_multistep, stream_run_blocked

__all__ = ["spd_multistep", "stream_run_blocked"]
