"""Generic SPD→Pallas temporal-blocking stream kernels.

Where :mod:`repro.kernels.lbm_stream` is the hand-written kernel for one
application, this package is the *codegen target*: `repro.core.codegen`
lowers any compiled SPD core into the stripe-update function that
:func:`spd_multistep` launches on the TPU grid (docs/pipeline.md §codegen).
:func:`spd_multistep_halo` is the per-shard variant of the same launch
for multi-device runs, with the y-halo pre-exchanged by
``repro.core.distribute`` (docs/pipeline.md §distribute).
"""

from .ops import spd_multistep, stream_run_blocked
from .sharded import spd_multistep_halo

__all__ = ["spd_multistep", "spd_multistep_halo", "stream_run_blocked"]
