"""Generic SPD→Pallas temporal-blocking stream kernels.

Where :mod:`repro.kernels.lbm_stream` is the hand-written kernel for one
application, this package is the *codegen target*: `repro.core.codegen`
lowers any compiled SPD core into the stripe-update function that
:func:`spd_multistep` launches on the TPU grid (docs/pipeline.md §codegen).
:func:`spd_multistep_halo` is the per-shard variant of the same launch
for multi-device runs, with the y-halo pre-exchanged by
``repro.core.distribute`` (docs/pipeline.md §distribute).

:func:`spd_multistep_streamed` / :func:`spd_multistep_halo_streamed` are
the manually pipelined twins of those two launches: the state stays in
HBM and stripes are staged through ping/pong VMEM buffers by explicit
async copies, making the ``double_buffer`` plan knob real
(docs/pipeline.md §stream).
"""

from .ops import spd_multistep, stream_run_blocked
from .sharded import spd_multistep_halo
from .streaming import spd_multistep_halo_streamed, spd_multistep_streamed

__all__ = [
    "spd_multistep",
    "spd_multistep_halo",
    "spd_multistep_halo_streamed",
    "spd_multistep_streamed",
    "stream_run_blocked",
]
