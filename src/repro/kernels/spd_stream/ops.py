"""Run-plan wrappers for codegen'd SPD stream kernels.

Mirrors :mod:`repro.kernels.lbm_stream.ops`: multi-launch stepping over
the fused kernel plus the explorer hand-off, with (block_h, m) plans
legalized through the shared :mod:`repro.core.legalize`
(docs/pipeline.md §legalize). The kernel-building side lives in
:class:`repro.core.codegen.StreamKernel`, which wraps these for a
specific compiled core.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.core.legalize import blocking_plan, resolve_run_plan

from .spd_stream import spd_multistep


def stream_run_blocked(multistep: Callable, state, scal, *, steps: int,
                       m: int, block_h: int, interpret: bool = True):
    """Advance ``steps`` time steps using m-fused kernel launches.

    ``multistep`` is a (typically jitted) closure over
    :func:`spd_multistep` with the stripe function and halo bound —
    ``multistep(state, scal, m=, block_h=, interpret=)``.
    """
    if steps % m:
        raise ValueError(f"steps={steps} must be a multiple of m={m}")

    def body(_, s):
        return multistep(s, scal, m=m, block_h=block_h, interpret=interpret)

    return jax.lax.fori_loop(0, steps // m, body, state)


__all__ = [
    "blocking_plan",
    "resolve_run_plan",
    "spd_multistep",
    "stream_run_blocked",
]
