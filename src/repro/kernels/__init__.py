"""Pallas TPU kernels for the perf-critical compute layers:

* ``lbm_stream``      — fused m-step D2Q9 LBM temporal blocking (the
                        paper's cascaded-PE analogue in VMEM)
* ``spd_stream``      — the generic form of the same structure: the
                        Pallas launch target that ``repro.core.codegen``
                        lowers *any* compiled SPD core onto
                        (docs/pipeline.md §codegen)
* ``flash_attention`` — blocked online-softmax attention (causal / sliding
                        window / GQA)

Each kernel ships ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrappers), and ``ref.py`` (pure-jnp oracle); validated in interpret
mode on CPU, targeted at TPU.
"""
