"""Pure-jnp oracle for the fused m-step LBM temporal-blocking kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.apps.lbm import ref_step


@partial(jax.jit, static_argnames=("m",))
def lbm_multistep_ref(f, attr, one_tau, u_lid, m: int):
    """m periodic LBM steps: the semantics the kernel must reproduce."""

    def body(_, g):
        return ref_step(g, attr, one_tau, u_lid, mode="wrap")

    return jax.lax.fori_loop(0, m, body, f)
