"""Public jit'd wrappers for the LBM temporal-blocking kernel, plus the
explorer hand-off: :func:`lbm_run_for_point` runs a ``DesignPoint``
straight from a ``repro.core.explorer`` sweep. Legalization of
model-chosen (block_h, m) plans is shared with the generic SPD codegen
path via :mod:`repro.core.legalize` (docs/pipeline.md §legalize); the
LBM kernel's per-step stencil reach is one row, so ``halo=1`` (the
default) applies."""

from __future__ import annotations

import functools

import jax

from repro.core.legalize import blocking_plan, resolve_run_plan

from .lbm_stream import lbm_multistep
from .ref import lbm_multistep_ref


def lbm_run_for_point(f, attr, one_tau, point, *, steps: int | None = None,
                      u_lid=0.0, interpret: bool = True):
    """Advance the lattice using a DSE design point's (block_h, m).

    See :func:`resolve_run_plan` for how the point is legalized — with
    the concrete stripe geometry (the grid width and the 9 distribution
    words + 1 attribute word resident per site), so the VMEM clamp
    applies exactly as it does on the generic codegen path.
    Returns ``(result, (block_h, m))``.
    """
    # The hand-written LBM kernel predates the streamed path and ignores
    # the resolved double_buffer protocol (it always uses the BlockSpec
    # pipeline); the generic codegen path is the streamed one.
    block_h, m, nsteps, _ = resolve_run_plan(
        f.shape[1], point, steps, width=f.shape[2], words=f.shape[0] + 1,
    )
    out = lbm_run_blocked(f, attr, one_tau, u_lid, steps=nsteps, m=m,
                          block_h=block_h, interpret=interpret)
    return out, (block_h, m)


@functools.partial(jax.jit, static_argnames=("steps", "m", "block_h", "interpret"))
def lbm_run_blocked(f, attr, one_tau, u_lid=0.0, *, steps: int, m: int = 4,
                    block_h: int = 32, interpret: bool = True):
    """Advance ``steps`` LBM time steps using m-fused kernel launches."""
    if steps % m:
        raise ValueError(f"steps={steps} must be a multiple of m={m}")

    def body(_, g):
        return lbm_multistep(
            g, attr, one_tau, u_lid, m=m, block_h=block_h, interpret=interpret
        )

    return jax.lax.fori_loop(0, steps // m, body, f)


__all__ = [
    "blocking_plan",
    "lbm_multistep",
    "lbm_multistep_ref",
    "lbm_run_blocked",
    "lbm_run_for_point",
    "resolve_run_plan",
]
