"""Public jit'd wrappers for the LBM temporal-blocking kernel, plus the
explorer hand-off: :func:`blocking_plan` clamps a model-chosen
(block_h, m) onto a concrete lattice and :func:`lbm_run_for_point` runs a
``DesignPoint`` straight from a ``repro.core.explorer`` sweep."""

from __future__ import annotations

import functools

import jax

from .lbm_stream import lbm_multistep
from .ref import lbm_multistep_ref


def blocking_plan(h: int, block_h: int, m: int) -> tuple[int, int]:
    """Legalize an explorer-chosen (block_h, m) for a grid of ``h`` rows.

    The kernel requires ``block_h | h`` and ``m <= block_h`` (the halo is
    sourced from one neighbor stripe per side). The model's lattice is
    grid-agnostic, so its pick may violate either; this returns the
    closest legal plan: the largest divisor of ``h`` that is <= the
    requested block (or the smallest one >= m when the request is too
    small), with ``m`` clamped into [1, h].
    """
    if h < 1:
        raise ValueError(f"grid height must be positive, got {h}")
    m = max(1, min(int(m), h))
    divisors = [d for d in range(1, h + 1) if h % d == 0]
    legal = [d for d in divisors if d >= m]
    under = [d for d in legal if d <= block_h]
    return (max(under) if under else min(legal)), m


def resolve_run_plan(h: int, point, steps: int | None = None
                     ) -> tuple[int, int, int]:
    """Turn a DSE design point into a concrete (block_h, m, steps) plan.

    ``point`` is any object with ``m`` and ``detail['block_rows']`` (a
    :class:`repro.core.dse.DesignPoint` from a TPU sweep). The blocking is
    legalized with :func:`blocking_plan`; ``steps`` defaults to one fused
    launch (m steps) and is rounded down to a multiple of m.
    """
    block_h, m = blocking_plan(h, int(point.detail["block_rows"]),
                               int(point.m))
    nsteps = m if steps is None else max(m, (steps // m) * m)
    return block_h, m, nsteps


def lbm_run_for_point(f, attr, one_tau, point, *, steps: int | None = None,
                      u_lid=0.0, interpret: bool = True):
    """Advance the lattice using a DSE design point's (block_h, m).

    See :func:`resolve_run_plan` for how the point is legalized.
    Returns ``(result, (block_h, m))``.
    """
    block_h, m, nsteps = resolve_run_plan(f.shape[1], point, steps)
    out = lbm_run_blocked(f, attr, one_tau, u_lid, steps=nsteps, m=m,
                          block_h=block_h, interpret=interpret)
    return out, (block_h, m)


@functools.partial(jax.jit, static_argnames=("steps", "m", "block_h", "interpret"))
def lbm_run_blocked(f, attr, one_tau, u_lid=0.0, *, steps: int, m: int = 4,
                    block_h: int = 32, interpret: bool = True):
    """Advance ``steps`` LBM time steps using m-fused kernel launches."""
    if steps % m:
        raise ValueError(f"steps={steps} must be a multiple of m={m}")

    def body(_, g):
        return lbm_multistep(
            g, attr, one_tau, u_lid, m=m, block_h=block_h, interpret=interpret
        )

    return jax.lax.fori_loop(0, steps // m, body, f)


__all__ = [
    "blocking_plan",
    "lbm_multistep",
    "lbm_multistep_ref",
    "lbm_run_blocked",
    "lbm_run_for_point",
    "resolve_run_plan",
]
