"""Public jit'd wrappers for the LBM temporal-blocking kernel."""

from __future__ import annotations

import functools

import jax

from .lbm_stream import lbm_multistep
from .ref import lbm_multistep_ref


@functools.partial(jax.jit, static_argnames=("steps", "m", "block_h", "interpret"))
def lbm_run_blocked(f, attr, one_tau, u_lid=0.0, *, steps: int, m: int = 4,
                    block_h: int = 32, interpret: bool = True):
    """Advance ``steps`` LBM time steps using m-fused kernel launches."""
    if steps % m:
        raise ValueError(f"steps={steps} must be a multiple of m={m}")

    def body(_, g):
        return lbm_multistep(
            g, attr, one_tau, u_lid, m=m, block_h=block_h, interpret=interpret
        )

    return jax.lax.fori_loop(0, steps // m, body, f)


__all__ = ["lbm_multistep", "lbm_multistep_ref", "lbm_run_blocked"]
