"""Pallas TPU kernel: fused m-step D2Q9 LBM with temporal blocking.

This is the TPU-native realization of the paper's *temporal parallelism*
(cascaded PEs): one HBM round-trip advances ``m`` time steps. Where the FPGA
cascades m physical pipelines with their own line buffers, the TPU kernel
keeps a (block_h + 2m)-row stripe of the lattice resident in VMEM, applies m
collide+stream+bounce steps entirely on-chip, and writes back only the
block_h center rows — arithmetic intensity scales with m while HBM traffic
stays constant (DESIGN.md §2).

Decomposition: 1-D over rows (y). Each grid program reads its own stripe
plus its two neighbors (periodic via modular index maps) — the y-halo — and
handles x wrap-around with in-register shifts, so the result is exactly
periodic, bit-matching the reference for fluid-only lattices and lattices
with bounce-back walls alike.

VMEM budget per program (f32): 10 fields x (3*block_h) x W x 4 B for the
three input stripes + ~10 x (block_h+2m) x W x 4 B working set. BlockSpec
shapes keep W the minor (lane) dimension, a multiple of 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.apps.lbm import EX, EY, OPP, W as LATTICE_W


def _shift_x(a, dx: int):
    """Periodic shift along the minor (x) axis: out[.., x] = a[.., x-dx]."""
    if dx == 0:
        return a
    if dx == 1:
        return jnp.concatenate([a[..., -1:], a[..., :-1]], axis=-1)
    if dx == -1:
        return jnp.concatenate([a[..., 1:], a[..., :1]], axis=-1)
    raise ValueError(dx)


def _shift_y(a, dy: int):
    """Non-periodic shift along rows (halo supplies the boundary)."""
    if dy == 0:
        return a
    pad = jnp.zeros_like(a[:, :abs(dy), :])
    if dy > 0:
        return jnp.concatenate([pad, a[:, :-dy, :]], axis=1)
    return jnp.concatenate([a[:, -dy:, :], pad], axis=1)


def _step(f, attr, one_tau, u_lid):
    """One collide->stream->bounce step on an extended (halo'd) stripe.

    Rows within `halo` of the stripe edge become invalid (they consumed
    y-neighbors that this step did not have); callers shrink the valid
    region by one row per step — the temporal-blocking trapezoid.
    """
    dtype = f.dtype
    fluid = attr < 0.5
    # --- collide (BGK), gated to fluid cells --------------------------------
    rho = jnp.sum(f, axis=0)
    inv_rho = 1.0 / rho
    ux = (f[1] + f[5] + f[8] - f[3] - f[6] - f[7]) * inv_rho
    uy = (f[2] + f[5] + f[6] - f[4] - f[7] - f[8]) * inv_rho
    usq = ux * ux + uy * uy
    post = []
    for i in range(9):
        cu = EX[i] * ux + EY[i] * uy if (EX[i] or EY[i]) else 0.0
        feq = (
            LATTICE_W[i].astype(dtype) if hasattr(LATTICE_W[i], "astype")
            else jnp.asarray(LATTICE_W[i], dtype)
        ) * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq)
        gi = f[i] - one_tau * (f[i] - feq)
        post.append(jnp.where(fluid, gi, f[i]))
    # --- stream (x periodic in-register, y via halo) ------------------------
    streamed = [
        _shift_x(_shift_y(post[i][None], int(EY[i]))[0], int(EX[i]))
        for i in range(9)
    ]
    # --- bounce-back with moving-wall correction ----------------------------
    solid = attr >= 0.5
    moving = attr >= 1.5
    out = []
    for i in range(9):
        refl = streamed[int(OPP[i])]
        corr = jnp.asarray(6.0 * float(LATTICE_W[i]) * float(EX[i]), dtype)
        bb = jnp.where(moving, refl + corr * u_lid, refl)
        out.append(jnp.where(solid, bb, streamed[i]))
    return jnp.stack(out)


def _kernel(scal_ref, fc_ref, fu_ref, fd_ref, ac_ref, au_ref, ad_ref,
            out_ref, *, m: int, block_h: int):
    one_tau = scal_ref[0]
    u_lid = scal_ref[1]
    # Assemble the (9, block_h + 2m, W) extended stripe from the three
    # VMEM-resident input stripes (the y-halo exchange).
    f_ext = jnp.concatenate(
        [fu_ref[:, block_h - m:, :], fc_ref[...], fd_ref[:, :m, :]], axis=1
    )
    a_ext = jnp.concatenate(
        [au_ref[block_h - m:, :], ac_ref[...], ad_ref[:m, :]], axis=0
    )
    # m fused steps; after each, one edge row per side goes stale. We keep
    # the full extent and simply never read the stale rows again: step k
    # needs rows valid to distance m-k, satisfied inductively.
    for _ in range(m):
        f_ext = _step(f_ext, a_ext, one_tau, u_lid)
    out_ref[...] = f_ext[:, m:m + block_h, :]


@functools.partial(
    jax.jit, static_argnames=("m", "block_h", "interpret")
)
def lbm_multistep(f, attr, one_tau, u_lid=0.0, *, m: int = 4,
                  block_h: int = 32, interpret: bool = True):
    """Fused m-step periodic LBM update.

    Args:
      f: (9, H, W) f32 distributions.
      attr: (H, W) f32 cell attributes (0 fluid / 1 wall / 2 moving lid).
      one_tau: 1/tau relaxation.
      u_lid: lid velocity for attr==2 cells.
      m: fused time steps per HBM round-trip (temporal parallelism).
      block_h: rows per grid program (spatial tile).
      interpret: run in Pallas interpret mode (CPU validation); on real TPU
        pass False.
    """
    _, h, w = f.shape
    if h % block_h:
        raise ValueError(f"H={h} must be divisible by block_h={block_h}")
    if m > block_h:
        raise ValueError(f"m={m} must be <= block_h={block_h} (halo source)")
    nblk = h // block_h
    scal = jnp.asarray([one_tau, u_lid], jnp.float32)

    fspec = lambda off: pl.BlockSpec(
        (9, block_h, w), lambda i, off=off: (0, (i + off) % nblk, 0)
    )
    aspec = lambda off: pl.BlockSpec(
        (block_h, w), lambda i, off=off: ((i + off) % nblk, 0)
    )
    return pl.pallas_call(
        functools.partial(_kernel, m=m, block_h=block_h),
        grid=(nblk,),
        in_specs=[
            # physics scalars live in SMEM (scalar memory) on TPU
            pl.BlockSpec(memory_space=pltpu.SMEM),
            fspec(0), fspec(-1), fspec(1),
            aspec(0), aspec(-1), aspec(1),
        ],
        out_specs=pl.BlockSpec((9, block_h, w), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=interpret,
    )(scal, f, f, f, attr, attr, attr)
