"""Simulation-as-a-service: a multi-tenant stream-simulation engine.

The serving tier over the whole spd→codegen→legalize→distribute→
measure→search pipeline (DESIGN.md §13, docs/pipeline.md §serve, ROADMAP
item 4): clients :meth:`~SimEngine.submit` :class:`SimRequest`\\ s — an
SPD core, a packed ``(P, H, W)`` grid state, a step count — and the
engine serves them in fused ticks at each tenant's *tuned* operating
point. Three mechanisms make that work:

* **Trial-context slot table** — requests group by
  :class:`TrialContext`: the core's DFG fingerprint, the grid shape,
  the ``Append_Reg`` values and the execution mode. Only identical
  contexts may share a launch (the batched kernel broadcasts one SMEM
  scalar vector to every member, and plans tuned for one geometry mean
  nothing for another).
* **Batch axis b** — compatible requests stack into one ``(b, P, H, W)``
  launch of the codegen'd kernel (``repro.kernels.spd_stream``), which
  is bitwise identical per member to ``b`` separate launches; the
  legalizer prices the stacked stripes via
  ``stripe_vmem_bytes(..., b=b)`` so modeled and executed geometry
  agree (``repro.core.legalize``). A tick advances a group ``min(plan.m,
  members' remaining)`` fused steps in one launch.
* **Autotune-on-first-request** — the first sight of a context opens a
  :class:`PlanResolver` session: a budgeted search (default
  :class:`~repro.core.search.TPESearch`) through the shared
  :class:`~repro.core.search.SearchRunner`, journaled to a named
  per-context :class:`~repro.core.search.Study` and backed by the
  persistent :class:`~repro.core.measure.MeasurementCache`. The search
  is driven **non-blockingly** through
  :class:`~repro.core.search.SearchStepper` — one live timing per
  engine tick, interleaved with serving other tenants — under a hard
  per-context ``budget``, so a cold engine cannot stall traffic
  unboundedly. When the budget runs out mid-tune the engine falls back
  to the best measured point so far, or to the model-predicted plan
  when nothing was measured. Warm restarts replay the study journal
  into the runner's dedupe table and pin the plan with **zero** live
  timings.

Accounting mirrors ``serve/engine.py``'s tick idioms: a bounded
admission queue that rejects with backpressure when full
(:meth:`SimEngine.submit` returns ``False``), per-request queue-wait /
service / latency accounting, a batch-occupancy histogram, and
:meth:`SimEngine.run_until_drained` that raises instead of silently
truncating. ``benchmarks/serve_bench.py`` drives all of it under
open-loop Poisson load and commits the results to ``BENCH_serve.json``.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

__all__ = [
    "PlanResolver",
    "SimCompletion",
    "SimEngine",
    "SimPlan",
    "SimRequest",
    "TrialContext",
    "TuningSession",
]


# --------------------------------------------------------------------------
# Requests, contexts, plans
# --------------------------------------------------------------------------


@dataclass
class SimRequest:
    """One tenant's simulation job: advance ``state`` by ``steps``.

    ``core`` is a :class:`~repro.core.compiler.CompiledCore` or an
    already-lowered :class:`~repro.core.codegen.StreamKernel`; ``state``
    the packed ``(P, H, W)`` grid (``StreamKernel.pack``); ``regs`` the
    core's ``Append_Reg`` scalar values.
    """

    rid: int
    core: object
    state: object
    steps: int
    regs: tuple = ()


@dataclass
class SimCompletion:
    """A retired request: final state plus per-request accounting."""

    rid: int
    state: np.ndarray
    steps: int
    submitted_tick: int
    admitted_tick: int
    finished_tick: int
    submitted_s: float
    finished_s: float
    queue_wait_ticks: int = 0

    @property
    def latency_s(self) -> float:
        """Submit→retire wall latency (what the load generator reports)."""
        return self.finished_s - self.submitted_s


@dataclass(frozen=True)
class TrialContext:
    """What must match for two requests to share a launch — and for a
    serving-time tuning to be cache/study-compatible with offline sweeps
    (docs/pipeline.md §study): the core's DFG fingerprint, the concrete
    grid, the SMEM scalar values (broadcast to every batch member) and
    the execution mode."""

    fingerprint: str
    h: int
    w: int
    regs: tuple
    interpret: bool


@dataclass(frozen=True)
class SimPlan:
    """The pinned operating point a context serves at.

    ``b`` is the *maximum* batch width — a tick launches
    ``min(b, waiting members)`` wide; ``source`` records how the plan
    was won: ``"search"`` (live tuning, including study-warm-started
    runs that spent zero budget), ``"model"`` (budget exhausted before
    any measurement — the model-predicted fallback).
    """

    block_h: int
    m: int
    d: int
    double_buffer: bool
    b: int
    source: str
    budget_spent: int = 0
    replayed: int = 0

    def as_dict(self) -> dict:
        return {
            "block_h": int(self.block_h),
            "m": int(self.m),
            "d": int(self.d),
            "double_buffer": bool(self.double_buffer),
            "b": int(self.b),
            "source": self.source,
            "budget_spent": int(self.budget_spent),
            "replayed": int(self.replayed),
        }


# --------------------------------------------------------------------------
# Autotune-on-first-request
# --------------------------------------------------------------------------


class TuningSession:
    """One context's in-flight autotune: a stepper the tick loop drives.

    Wraps :class:`~repro.core.search.SearchStepper` so the engine
    advances the search one live timing per tick
    (docs/pipeline.md §serve); :meth:`advance` returns the pinned
    :class:`SimPlan` once the search converges or exhausts its budget,
    ``None`` while tuning is still in flight.
    """

    def __init__(self, stepper, sweep, study_name: str | None,
                 replayed: int):
        self.stepper = stepper  # None: budget 0, pure model-predicted
        self.sweep = sweep
        self.study_name = study_name
        self.replayed = replayed
        self.plan: SimPlan | None = None

    @property
    def live_timings(self) -> int:
        return 0 if self.stepper is None else (
            self.stepper.runner.budget_spent
        )

    def advance(self) -> SimPlan | None:
        if self.plan is not None:
            return self.plan
        if self.stepper is None:
            best, spent = None, 0
        else:
            self.stepper.step()
            if not self.stepper.done:
                return None
            best = self.stepper.best()
            spent = self.stepper.runner.budget_spent
        if best is not None:
            self.plan = SimPlan(
                block_h=best.block_h, m=best.m, d=best.d,
                double_buffer=best.double_buffer, b=best.b,
                source="search", budget_spent=spent,
                replayed=self.replayed,
            )
        else:
            # Budget exhausted (or nothing runnable) before a single
            # measurement: fall back to the model-predicted plan.
            pt = self.sweep.best(key="sustained_gflops")
            detail = pt.detail or {}
            self.plan = SimPlan(
                block_h=int(detail.get("block_rows", pt.m)),
                m=int(pt.m), d=max(1, int(pt.n)),
                double_buffer=bool(detail.get("double_buffer", True)),
                b=int(detail.get("b", 1)),
                source="model", budget_spent=spent,
                replayed=self.replayed,
            )
        return self.plan


class PlanResolver:
    """Study store → measurement cache → budgeted search, in that order.

    The resolution ladder (docs/pipeline.md §serve): a named per-context
    :class:`~repro.core.search.Study` is resumed and replayed into the
    runner's dedupe table (a fully-journaled context re-measures
    nothing), the persistent :class:`~repro.core.measure
    .MeasurementCache` serves plans other processes timed, and only
    what neither knows is measured live — at most ``budget`` timings
    per context, ever. ``timer`` injects the timing primitive for
    deterministic tests; ``cache``/``study_dir`` default to the shared
    on-disk stores.
    """

    def __init__(
        self,
        *,
        strategy="tpe",
        budget: int = 8,
        b_values: Sequence[int] = (1, 2, 4),
        bh_values: Sequence[int] = (8, 16, 32, 64),
        m_values: Sequence[int] = (1, 2, 4, 8),
        d_values: Sequence[int] = (1,),
        steps: int | None = None,
        reps: int = 1,
        warmup: int = 1,
        interpret: bool = True,
        calibrate: bool = False,
        cache=None,
        study_dir: str | None = None,
        study_prefix: str = "serve",
        timer=None,
    ):
        self.strategy = strategy
        self.budget = int(budget)
        self.b_values = tuple(int(v) for v in b_values)
        self.bh_values = tuple(int(v) for v in bh_values)
        self.m_values = tuple(int(v) for v in m_values)
        self.d_values = tuple(int(v) for v in d_values)
        self.steps = steps
        self.reps = int(reps)
        self.warmup = int(warmup)
        self.interpret = bool(interpret)
        self.calibrate = bool(calibrate)
        self.cache = cache
        self.study_dir = study_dir
        self.study_prefix = study_prefix
        self.timer = timer

    def study_name(self, ctx: TrialContext) -> str:
        """Stable per-context study identity: resuming an engine with the
        same resolver settings re-opens the same journal."""
        return (
            f"{self.study_prefix}-{ctx.fingerprint[:12]}-{ctx.h}x{ctx.w}"
        )

    def open(self, kern, state, ctx: TrialContext) -> TuningSession:
        """Start (or warm-start) this context's tuning session."""
        from repro.core.explorer import Explorer
        from repro.core.search import (
            SearchRunner,
            SearchStepper,
            Study,
            get_strategy,
            kernel_run_factory,
        )
        from repro.core.search.surrogate import TPESearch

        ex = Explorer(kern.compiled, elems=ctx.h * ctx.w, grid_w=ctx.w)
        sweep = ex.sweep_tpu(
            bh_values=self.bh_values, m_values=self.m_values,
            d_values=self.d_values, b_values=self.b_values,
        )
        if self.budget <= 0:
            # Pure model-predicted serving: no runner, no study, no
            # live measurements — advance() pins the sweep's best point
            # immediately (the same fallback an exhausted budget takes).
            return TuningSession(None, sweep, None, 0)
        strat = self.strategy
        if isinstance(strat, str) and strat == "tpe":
            # Bound *observations* at the budget so a warm-started
            # session whose journal already covers them measures zero.
            strat = TPESearch(max_trials=self.budget)
        strat = get_strategy(strat)
        runner = SearchRunner(
            workload=sweep.workload,
            grid_shape=(ctx.h, ctx.w),
            run_factory=kernel_run_factory(
                kern, state, ctx.regs, self.interpret
            ),
            model=sweep.model,
            scalar_kwargs=sweep.scalar_kwargs,
            fingerprint=ctx.fingerprint,
            halo=kern.halo,
            width=ctx.w,
            words=len(kern._ports),
            steps=self.steps,
            interpret=self.interpret,
            reps=self.reps,
            warmup=self.warmup,
            calibrate=self.calibrate,
            cache=self.cache,
            budget=self.budget,
            timer=self.timer,
        )
        study = Study.resume(self.study_name(ctx), self.study_dir)
        replayed = study.replay_into(runner)
        runner.study = study
        runner.study_meta = {
            "strategy": strat.name,
            "seed": getattr(strat, "seed", None),
        }
        stepper = SearchStepper(strat, sweep, runner)
        return TuningSession(stepper, sweep, study.name, replayed)


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------


@dataclass
class _Active:
    """One admitted request's slot-table entry."""

    req: SimRequest
    state: object  # current device array, (P, H, W)
    remaining: int
    submitted_tick: int
    submitted_s: float
    admitted_tick: int


@dataclass
class _Cohort:
    """A formed launch batch that *stays stacked* between launches.

    Stacking (``pack_batch``) and unstacking (one device→host transfer)
    happen once per cohort, not once per launch: at host-dispatch
    granularity a ``jnp.stack`` or per-member slice costs as much as a
    whole small launch, so restacking every tick would hand back the
    exact overhead the batch axis amortizes. The cohort dissolves when
    any member finishes; survivors rejoin the FIFO with host states and
    re-stack into the next cohort."""

    members: list
    stacked: object  # (b, P, H, W) device array when len > 1


@dataclass
class _Group:
    """All live state for one trial context: its kernel, its (eventual)
    pinned plan, the FIFO of admitted members, and the in-flight
    cohort."""

    kern: object
    ctx: TrialContext
    session: TuningSession | None = None
    plan: SimPlan | None = None
    members: deque = field(default_factory=deque)
    cohort: _Cohort | None = None


class SimEngine:
    """Multi-tenant stream-simulation serving engine (DESIGN.md §13).

    ``max_queue`` bounds admission — :meth:`submit` returns ``False``
    (backpressure) when full, and the rejection is counted, never
    dropped silently. ``max_active`` bounds the slot table across all
    contexts. Each :meth:`step` tick admits, advances at most one
    tuning measurement per still-cold context, and launches one fused
    batched step per warm context (docs/pipeline.md §serve).
    """

    def __init__(
        self,
        resolver: PlanResolver | None = None,
        *,
        max_queue: int = 64,
        max_active: int = 64,
        interpret: bool = True,
    ):
        self.resolver = resolver or PlanResolver(interpret=interpret)
        self.interpret = bool(interpret)
        self.max_queue = int(max_queue)
        self.max_active = int(max_active)
        self.queue: deque = deque()  # (req, submitted_tick, submitted_s)
        self.groups: dict[TrialContext, _Group] = {}
        self._kern_cache: dict[int, tuple[str, object]] = {}
        self.tick_count = 0
        # ---- accounting ---------------------------------------------------
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.launches = 0
        self.member_steps = 0  # Σ (fused steps × members) over launches
        self.launch_wall_s = 0.0
        self.occupancy: dict[int, int] = {}  # launch width -> count
        self.tuning_ticks = 0  # ticks that advanced a search instead

    def reset_counters(self) -> None:
        """Open a fresh measurement window: zero the aggregate launch
        and admission accounting while keeping every pinned plan, warm
        trace, and in-flight member. The load generator uses this to
        report *steady-state* throughput — a warmup pass absorbs the
        one-time per-shape trace/lower cost, then the window resets and
        the measured pass sees only real launch work."""
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.launches = 0
        self.member_steps = 0
        self.launch_wall_s = 0.0
        self.occupancy = {}
        self.tuning_ticks = 0

    # ---- admission ---------------------------------------------------------

    def submit(self, req: SimRequest) -> bool:
        """Enqueue a request; ``False`` = queue full (backpressure)."""
        if len(self.queue) >= self.max_queue:
            self.rejected += 1
            return False
        self.submitted += 1
        self.queue.append((req, self.tick_count, time.monotonic()))
        return True

    def _kernel_for(self, core) -> tuple[str, object]:
        """Lower (and fingerprint) a submitted core, once per object."""
        from repro.core import measure
        from repro.core.codegen import StreamKernel

        hit = self._kern_cache.get(id(core))
        if hit is not None:
            return hit
        kern = core if isinstance(core, StreamKernel) else (
            core.stream_kernel()
        )
        fp = measure.core_fingerprint(kern)
        self._kern_cache[id(core)] = (fp, kern)
        return fp, kern

    def _active_count(self) -> int:
        return sum(
            len(g.members)
            + (len(g.cohort.members) if g.cohort is not None else 0)
            for g in self.groups.values()
        )

    def _admit(self) -> None:
        while self.queue and self._active_count() < self.max_active:
            req, tick, t_s = self.queue.popleft()
            fp, kern = self._kernel_for(req.core)
            h, w = int(req.state.shape[-2]), int(req.state.shape[-1])
            ctx = TrialContext(
                fingerprint=fp, h=h, w=w,
                regs=tuple(float(r) for r in req.regs),
                interpret=self.interpret,
            )
            group = self.groups.get(ctx)
            if group is None:
                group = self.groups[ctx] = _Group(kern=kern, ctx=ctx)
            group.members.append(_Active(
                req=req, state=req.state, remaining=int(req.steps),
                submitted_tick=tick, submitted_s=t_s,
                admitted_tick=self.tick_count,
            ))

    # ---- the tick loop ------------------------------------------------------

    def step(self) -> list[SimCompletion]:
        """One engine tick: admit, tune-or-launch per context, retire."""
        self.tick_count += 1
        self._admit()
        done: list[SimCompletion] = []
        for group in self.groups.values():
            if not group.members and group.cohort is None:
                continue
            if group.plan is None:
                if group.session is None:
                    # Autotune-on-first-request: open the context's
                    # session (study replay happens here — a warm
                    # journal pins the plan with zero live timings).
                    group.session = self.resolver.open(
                        group.kern, group.members[0].state, group.ctx,
                    )
                group.plan = group.session.advance()
                if group.plan is None:
                    self.tuning_ticks += 1
                    continue  # still tuning; members wait in the slot
            done.extend(self._launch(group))
        return done

    def _launch(self, group: _Group) -> list[SimCompletion]:
        """One fused batched launch for a warm context.

        The launch drives the group's current :class:`_Cohort` (forming
        one from the member FIFO if none is in flight); the cohort's
        stacked state advances in place across ticks, and members are
        sliced back out — one host transfer — only when the cohort
        dissolves."""
        plan = group.plan
        kern = group.kern
        if group.cohort is None:
            batch = [
                group.members.popleft()
                for _ in range(min(plan.b, len(group.members)))
            ]
            stacked = (
                batch[0].state if len(batch) == 1
                else kern.pack_batch([a.state for a in batch])
            )
            group.cohort = _Cohort(batch, stacked)
        co = group.cohort
        mm = min([plan.m] + [a.remaining for a in co.members])
        t0 = time.perf_counter()
        out = kern(
            co.stacked, group.ctx.regs, m=mm, block_h=plan.block_h,
            double_buffer=plan.double_buffer, interpret=self.interpret,
        )
        out = jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        co.stacked = out
        width = len(co.members)
        self.launches += 1
        self.launch_wall_s += wall
        self.member_steps += mm * width
        self.occupancy[width] = self.occupancy.get(width, 0) + 1
        for active in co.members:
            active.remaining -= mm

        done: list[SimCompletion] = []
        if not any(a.remaining <= 0 for a in co.members):
            return done  # cohort stays stacked and in flight
        host = np.asarray(out)  # one transfer for the whole cohort
        now = time.monotonic()
        survivors = []
        for i, active in enumerate(co.members):
            state = host[i] if width > 1 else host
            if active.remaining > 0:
                active.state = state  # restacked into the next cohort
                survivors.append(active)
                continue
            self.completed += 1
            done.append(SimCompletion(
                rid=active.req.rid,
                state=state,
                steps=int(active.req.steps),
                submitted_tick=active.submitted_tick,
                admitted_tick=active.admitted_tick,
                finished_tick=self.tick_count,
                submitted_s=active.submitted_s,
                finished_s=now,
                queue_wait_ticks=(
                    active.admitted_tick - active.submitted_tick
                ),
            ))
        group.members.extend(survivors)  # back of the FIFO
        group.cohort = None
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> list[SimCompletion]:
        """Tick until every queued and admitted request retires.

        Mirrors ``serve/engine.py``: hitting ``max_ticks`` with work
        still pending raises ``RuntimeError`` naming the undrained
        request ids instead of silently truncating.
        """
        out: list[SimCompletion] = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if not self.queue and self._active_count() == 0:
                return out
        undrained = [a.req.rid for g in self.groups.values()
                     for a in g.members]
        undrained += [a.req.rid for g in self.groups.values()
                      if g.cohort is not None for a in g.cohort.members]
        undrained += [req.rid for req, _, _ in self.queue]
        raise RuntimeError(
            f"run_until_drained hit max_ticks={max_ticks} with "
            f"{len(undrained)} request(s) undrained (rids {undrained}); "
            f"{len(out)} completion(s) were produced before the bound"
        )

    # ---- reporting ----------------------------------------------------------

    @staticmethod
    def _plan_key(ctx: TrialContext) -> str:
        """Human-readable stats key covering the *whole* context —
        including the register values, which distinguish contexts that
        share a fingerprint and grid (e.g. two diffusion tenants with
        different alphas)."""
        key = f"{ctx.fingerprint[:12]}-{ctx.h}x{ctx.w}"
        if ctx.regs:
            key += "-r" + ",".join(f"{r:g}" for r in ctx.regs)
        return key

    def stats(self) -> dict:
        """Engine-level accounting: the load generator's raw material."""
        live = sum(
            g.session.live_timings
            for g in self.groups.values() if g.session is not None
        )
        return {
            "ticks": int(self.tick_count),
            "submitted": int(self.submitted),
            "rejected": int(self.rejected),
            "completed": int(self.completed),
            "launches": int(self.launches),
            "member_steps": int(self.member_steps),
            "launch_wall_s": float(self.launch_wall_s),
            "steps_per_s": (
                self.member_steps / self.launch_wall_s
                if self.launch_wall_s > 0 else 0.0
            ),
            "occupancy": {
                str(k): int(v) for k, v in sorted(self.occupancy.items())
            },
            "tuning_ticks": int(self.tuning_ticks),
            "live_timings": int(live),
            "plans": {
                self._plan_key(ctx):
                    g.plan.as_dict() if g.plan is not None else None
                for ctx, g in self.groups.items()
            },
        }
