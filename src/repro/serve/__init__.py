"""Serving substrate: batched engines over both model families.

``engine`` serves LM decode (continuous batching over a fixed-slot KV
cache); ``sim`` serves stream simulations — the multi-tenant
simulation-as-a-service tier over the SPD→codegen→search pipeline
(DESIGN.md §13, docs/pipeline.md §serve).
"""
