"""Batched serving engine: continuous batching over a fixed-slot KV cache.

Requests enter a queue; up to ``max_batch`` occupy cache slots. Each engine
tick decodes one token for every active slot (a single jitted
``decode_step`` over the whole batch — the batched-serving path the
decode_* dry-run shapes exercise). Prefill processes the prompt through the
``forward`` path and then replays the prompt into the per-slot cache via
the decode path (cache-building prefill), trading prefill latency for a
single code path; greedy or temperature sampling.
"""

from __future__ import annotations

import dataclasses
import queue
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclass
class Completion:
    rid: int
    tokens: list[int]


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params: Any, *, max_batch: int,
                 max_seq: int, seed: int = 0):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = bundle.cache_init(max_batch, max_seq)
        self._decode = jax.jit(bundle.make_decode_step())
        self.rng = np.random.default_rng(seed)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        # slot bookkeeping (host side)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos: list[int] = [0] * max_batch
        self.slot_out: list[list[int]] = [[] for _ in range(max_batch)]
        self.slot_last: list[int] = [0] * max_batch

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.put(req)

    def _admit(self) -> None:
        new: list[int] = []
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None:
                continue
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                break
            self.slot_req[slot] = req
            self.slot_pos[slot] = 0
            self.slot_out[slot] = []
            self.slot_last[slot] = req.prompt[-1]
            new.append(slot)
        if new:
            self._replay_prompts(new)

    def _replay_prompts(self, slots: list[int]) -> None:
        """Batched cache-building prefill for freshly admitted slots.

        Every new slot starts at position 0 and ``decode_step`` takes
        one shared scalar position, so slots replaying the same number
        of prompt tokens advance in lockstep: one ``max_batch``-wide
        launch per prompt *position* carrying every group member's
        token, instead of one launch per (slot, position) — admission
        cost O(prompt_len) launches per length group rather than
        O(n_slots × prompt_len). Slots with different replay lengths
        form separate lockstep groups (the shared scalar position
        cannot advance past a shorter prompt's end).
        """
        by_len: dict[int, list[int]] = {}
        for slot in slots:
            n = len(self.slot_req[slot].prompt) - 1
            if n > 0:
                by_len.setdefault(n, []).append(slot)
        for n, group in sorted(by_len.items()):
            for t in range(n):
                token = jnp.zeros((self.max_batch, 1), jnp.int32)
                for slot in group:
                    token = token.at[slot, 0].set(
                        self.slot_req[slot].prompt[t]
                    )
                _, self.cache = self._decode(
                    self.params, token, self.cache,
                    jnp.asarray(t, jnp.int32),
                )
                for slot in group:
                    self.slot_pos[slot] = t + 1

    def _step_slot(self, slot: int, tok: int) -> np.ndarray:
        """Single-slot cache update. Batched across slots in step(); this
        per-slot path is used for prompt replay."""
        token = jnp.zeros((self.max_batch, 1), jnp.int32).at[slot, 0].set(tok)
        logits, self.cache = self._decode(
            self.params, token, self.cache,
            jnp.asarray(self.slot_pos[slot], jnp.int32),
        )
        self.slot_pos[slot] += 1
        return np.asarray(logits[slot, 0])

    # ------------------------------------------------------------------
    def step(self) -> list[Completion]:
        """One engine tick: admit, decode one token for all active slots,
        retire finished requests."""
        self._admit()
        active = [s for s in range(self.max_batch) if self.slot_req[s]]
        done: list[Completion] = []
        if not active:
            return done
        # all active slots share one batched decode per tick; slots advance
        # in lockstep (same pos) when admitted together, else per-slot.
        for slot in active:
            logits = self._step_slot(slot, self.slot_last[slot])
            req = self.slot_req[slot]
            if req.temperature > 0:
                z = logits.astype(np.float64) / req.temperature
                z -= z.max()
                p = np.exp(z) / np.exp(z).sum()
                nxt = int(self.rng.choice(len(p), p=p))
            else:
                nxt = int(np.argmax(logits))
            self.slot_out[slot].append(nxt)
            self.slot_last[slot] = nxt
            if (
                len(self.slot_out[slot]) >= req.max_new_tokens
                or self.slot_pos[slot] >= self.max_seq - 1
            ):
                done.append(Completion(req.rid, list(self.slot_out[slot])))
                self.slot_req[slot] = None
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Completion]:
        """Tick until every queued and in-flight request completes.

        ``max_ticks`` bounds the loop; hitting the bound with work still
        pending raises ``RuntimeError`` naming the undrained request
        ids rather than silently returning a partial completion list
        (regression-tested in ``tests/test_substrate.py``).
        """
        out: list[Completion] = []
        for _ in range(max_ticks):
            out.extend(self.step())
            if self.queue.empty() and all(r is None for r in self.slot_req):
                return out
        undrained = [r.rid for r in self.slot_req if r is not None]
        undrained += [r.rid for r in list(self.queue.queue)]
        raise RuntimeError(
            f"run_until_drained hit max_ticks={max_ticks} with "
            f"{len(undrained)} request(s) undrained (rids {undrained}); "
            f"{len(out)} completion(s) were produced before the bound"
        )
