"""Mamba2 (SSD) block in pure JAX: chunked parallel scan for training /
prefill, O(1)-state recurrent step for decode.

Chunked SSD (Dao & Gu 2024): within a chunk of length Q the output is a
masked quadratic form (the "matrix transformer" view); across chunks a
(heads, P, N) state carries the recurrence:

  h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t  (x)  x_t)
  y_t = C_t . h_t + D * x_t

All cumulative products run in log space (dA <= 0, numerically safe).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm


def mamba2_init(cfg, key) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state
    ks = jax.random.split(key, 4)
    dtype = cfg.param_dtype
    return {
        "in_proj": dense_init(
            ks[0], cfg.d_model,
            2 * d_in + 2 * s.n_groups * s.state + n_heads, dtype,
        ),
        "conv_w": (jax.random.normal(ks[1], (s.conv, conv_ch), jnp.float32)
                   / math.sqrt(s.conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, cfg.d_model, dtype,
                               scale=1.0 / math.sqrt(d_in)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,C), w (K,C) -> (B,S,C)."""
    k = w.shape[0]
    out = x * w[k - 1]
    for j in range(1, k):
        pad = jnp.zeros_like(x[:, :j])
        out = out + jnp.concatenate([pad, x[:, :-j]], axis=1) * w[k - 1 - j]
    return out + b


def _split_zxbcdt(p, cfg, zxbcdt):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    gn = s.n_groups * s.state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * gn]
    dt = zxbcdt[..., -(d_in // s.head_dim):]
    return z, xbc, dt


def _ssd_chunked(xh, dt, dA, Bm, Cm, s, h0=None):
    """Chunked SSD.

    xh: (B,S,H,P) inputs; dt: (B,S,H); dA: (B,S,H) = dt*A (<=0)
    Bm/Cm: (B,S,G,N); state h0: (B,H,P,N) or None.
    Returns y (B,S,H,P), h_final.
    """
    b, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(s.chunk, S)
    if S % Q:
        raise ValueError(f"seq {S} must tile by chunk {Q}")
    nc = S // Q
    rep = H // G

    def to_chunks(a):
        return a.reshape((b, nc, Q) + a.shape[2:])

    xh, dt, dA, Bm, Cm = map(to_chunks, (xh, dt, dA, Bm, Cm))
    # broadcast groups to heads
    Bh = jnp.repeat(Bm, rep, axis=3) if rep > 1 else Bm  # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cm, rep, axis=3) if rep > 1 else Cm

    cum = jnp.cumsum(dA, axis=2)  # (b,nc,Q,H)
    # intra-chunk attention-like term: att[t,s] = exp(cum_t - cum_s), t>=s
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,t,s,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    att = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores_{t,s} = (C_t . B_s) att u_s  with u_s = dt_s x_s
    cb = jnp.einsum("bcthn,bcshn->bctsh", Ch, Bh)  # (b,nc,t,s,H)
    u = xh * dt[..., None]  # (b,nc,Q,H,P)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", cb * att, u)

    # cross-chunk: scan the state
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,Q,H)
    chunk_state = jnp.einsum("bcshn,bcshp->bchpn", Bh * decay_out[..., None], u)
    chunk_gain = jnp.exp(cum[:, :, -1, :])  # (b,nc,H)

    def scan_body(h, c):
        st, g = c
        h_new = h * g[:, :, None, None] + st
        return h_new, h

    h_init = (
        h0 if h0 is not None else jnp.zeros((b, H, P, N), jnp.float32)
    )
    h_final, h_prevs = jax.lax.scan(
        scan_body,
        h_init,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_gain.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (b,nc,H,P,N)
    y_inter = jnp.einsum(
        "bcthn,bchpn->bcthp", Ch * jnp.exp(cum)[..., None], h_prevs
    )
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, h_final


def mamba2_apply(p, x, cfg, state=None):
    """Train/prefill path. x: (B,S,d_model) -> (B,S,d_model)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_zxbcdt(p, cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    gn = s.n_groups * s.state
    xs = xbc[..., :d_in]
    Bm = xbc[..., d_in:d_in + gn]
    Cm = xbc[..., d_in + gn:]
    b, S, _ = x.shape
    xh = xs.reshape(b, S, H, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(b, S, s.n_groups, s.state).astype(jnp.float32)
    Cm = Cm.reshape(b, S, s.n_groups, s.state).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dtf * A
    y, _ = _ssd_chunked(xh, dtf, dA, Bm, Cm, s)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


# ---------------------------- decode ----------------------------


def mamba2_state_init(cfg, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.state), jnp.float32),
        "conv": jnp.zeros((batch, s.conv - 1, conv_ch), cfg.param_dtype),
    }


def mamba2_decode(p, x, cfg, state):
    """x: (B,1,d_model), recurrent state update -> (y, new_state)."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_zxbcdt(p, cfg, zxbcdt)
    # conv over the rolling buffer
    hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B, conv, C)
    w = p["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    new_conv = hist[:, 1:]
    gn = s.n_groups * s.state
    xs = xbc1[..., :d_in]
    Bm = xbc1[..., d_in:d_in + gn]
    Cm = xbc1[..., d_in + gn:]
    b = x.shape[0]
    xh = xs.reshape(b, H, s.head_dim).astype(jnp.float32)
    Bm = Bm.reshape(b, s.n_groups, s.state).astype(jnp.float32)
    Cm = Cm.reshape(b, s.n_groups, s.state).astype(jnp.float32)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1) if rep > 1 else Bm  # (b,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1) if rep > 1 else Cm
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    alpha = jnp.exp(dtf * -jnp.exp(p["A_log"]))  # (B,H)
    u = xh * dtf[..., None]  # (b,H,P)
    h = state["h"] * alpha[..., None, None] + jnp.einsum("bhp,bhn->bhpn", u, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], {"h": h, "conv": new_conv}
