"""Decoder-only and encoder-decoder transformer LMs (dense / MoE / VLM /
audio backbones), scan-over-layers with per-layer remat.

Entry points (all shape-driven, usable under ``jax.eval_shape``):
  init_params(cfg, key)                      -> params
  forward(params, cfg, tokens, embeds, ...)  -> logits       (train/prefill)
  init_cache(cfg, batch, seq)                -> cache
  decode_step(params, cfg, token, cache, pos)-> (logits, cache)
  encode(params, cfg, frames)                -> encoder states   (enc_dec)
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layers import (
    attention_block,
    attn_init,
    attn_qkv,
    cross_entropy,
    decode_attention,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    moe_apply,
    moe_init,
    rms_norm,
    _merge_heads,
    _split_heads,
)

# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def _init_layer(cfg, key, moe: bool):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn_init(k1, cfg),
    }
    p["moe" if moe else "mlp"] = (
        moe_init(k2, cfg) if moe else mlp_init(k2, cfg)
    )
    return p


def _init_cross_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_x": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn_init(k1, cfg),
        "xattn": attn_init(k2, cfg),
        "mlp": mlp_init(k3, cfg),
    }


def _stack_init(fn, keys):
    return jax.vmap(fn)(keys)


def init_params(cfg, key) -> dict:
    keys = jax.random.split(key, 8)
    p: dict = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            keys[1], cfg.d_model, cfg.vocab, cfg.param_dtype
        )
    moe_start = cfg.moe.moe_start_layer if cfg.moe else 0
    if cfg.enc_dec:
        ek = jax.random.split(keys[2], cfg.n_layers)
        dk = jax.random.split(keys[3], cfg.n_layers)
        p["enc_layers"] = _stack_init(
            lambda k: _init_layer(cfg, k, moe=False), ek
        )
        p["dec_layers"] = _stack_init(lambda k: _init_cross_layer(cfg, k), dk)
        p["ln_enc"] = jnp.ones((cfg.d_model,), cfg.param_dtype)
    else:
        n_moe = cfg.n_layers - moe_start if cfg.moe else 0
        n_dense = cfg.n_layers - n_moe
        if n_dense:
            lk = jax.random.split(keys[4], n_dense)
            p["layers"] = _stack_init(
                lambda k: _init_layer(cfg, k, moe=False), lk
            )
        if n_moe:
            mk = jax.random.split(keys[5], n_moe)
            p["moe_layers"] = _stack_init(
                lambda k: _init_layer(cfg, k, moe=True), mk
            )
    return p


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------


def _sp_spec(h):
    """Residual-stream spec: (batch=dp, seq=sp-or-None, d=None).

    With sp=None this pins the residual to (dp, None, None) — forcing the
    TP all-reduce to land on the bf16 matmul output instead of a
    post-f32-convert tensor (GSPMD otherwise decomposes the AR into RS+AG
    around the norm's f32 internals, doubling wire bytes). Full sequence
    parallelism (sp='model') was tried and REFUTED for attention archs:
    the chunked-attention scan dynamic-slices the seq dim, which under
    seq-sharding becomes per-chunk cross-device gathers (EXPERIMENTS.md
    §Perf granite it.1)."""
    if not (h.get("dp") or h.get("sp")):
        return None
    from jax.sharding import PartitionSpec as P

    return P(h.get("dp"), h.get("sp"), None)


def _layer_apply(p, x, cfg, positions, *, causal: bool, moe: bool):
    from jax.ad_checkpoint import checkpoint_name

    from repro.parallel.hints import constrain

    h = constrain(rms_norm(x, p["ln1"]), _sp_spec)
    attn_out = attention_block(p["attn"], h, cfg, positions, causal=causal)
    # the post-TP-collective tensors: saving exactly these two lets the
    # backward pass skip re-running the forward all-reduces ('sublayers'
    # remat policy) at ~2 sharded activations/layer of memory
    attn_out = checkpoint_name(attn_out, "attn_out")
    x = constrain(x + attn_out, _sp_spec)
    h = constrain(rms_norm(x, p["ln2"]), _sp_spec)
    ff_out = moe_apply(p["moe"], h, cfg) if moe else mlp_apply(p["mlp"], h, cfg)
    ff_out = checkpoint_name(ff_out, "ff_out")
    x = constrain(x + ff_out, _sp_spec)
    return x


def _remat_policy():
    """Remat policy, selectable via the 'remat' sharding hint:
    'none' (save nothing, max recompute) | 'dots' (save weight-matmul
    outputs: backward skips recomputing the forward's TP collectives at
    the cost of saved activations)."""
    from repro.parallel.hints import hint

    name = hint("remat", "none")
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "sublayers":
        return jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ff_out"
        )
    return jax.checkpoint_policies.nothing_saveable


def _scan_layers(stacked, x, cfg, positions, *, causal: bool, moe: bool):
    @partial(jax.checkpoint, policy=_remat_policy())
    def body(carry, lp):
        return _layer_apply(lp, carry, cfg, positions, causal=causal,
                            moe=moe), None

    x, _ = jax.lax.scan(body, x, stacked)
    return x


def embed_tokens(params, cfg, tokens, embeds=None):
    """Token embedding with optional frontend (VLM patches / audio frames)
    prepended. embeds: (B, T_front, d_model)."""
    x = params["embed"][tokens]
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def forward(params, cfg, tokens, embeds=None, positions=None):
    """-> logits (B, S_total, vocab). Decoder-only path."""
    x = embed_tokens(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if "layers" in params:
        x = _scan_layers(params["layers"], x, cfg, positions,
                         causal=True, moe=False)
    if "moe_layers" in params:
        x = _scan_layers(params["moe_layers"], x, cfg, positions,
                         causal=True, moe=True)
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def encode(params, cfg, frames):
    """Encoder stack over stubbed frame embeddings (B, T, d) -> states."""
    x = frames.astype(cfg.param_dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
    x = _scan_layers(params["enc_layers"], x, cfg, positions,
                     causal=False, moe=False)
    return rms_norm(x, params["ln_enc"])


def _cross_layer_apply(p, x, cfg, positions, enc_kv):
    x = x + attention_block(p["attn"], rms_norm(x, p["ln1"]), cfg, positions,
                            causal=True)
    x = x + attention_block(p["xattn"], rms_norm(x, p["ln_x"]), cfg, positions,
                            causal=False, kv_override=enc_kv)
    x = x + mlp_apply(p["mlp"], rms_norm(x, p["ln2"]), cfg)
    return x


def _enc_kv(p_layer, cfg, enc_states):
    """Precompute cross-attention K/V from encoder states for one layer."""
    kx = enc_states @ p_layer["xattn"]["wk"]
    vx = enc_states @ p_layer["xattn"]["wv"]
    if cfg.qkv_bias:
        kx, vx = kx + p_layer["xattn"]["bk"], vx + p_layer["xattn"]["bv"]
    return _split_heads(kx, cfg.n_kv_heads), _split_heads(vx, cfg.n_kv_heads)


def forward_enc_dec(params, cfg, frames, tokens):
    """Whisper-style: encode frames, decode tokens with cross-attention."""
    enc = encode(params, cfg, frames)
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, lp):
        kv = _enc_kv(lp, cfg, enc)
        return _cross_layer_apply(lp, carry, cfg, positions, kv), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


# --------------------------------------------------------------------------
# KV-cache decode
# --------------------------------------------------------------------------


def init_cache(cfg, batch: int, seq: int, enc_len: int | None = None) -> dict:
    hd = cfg.head_dim
    kv = lambda s: jnp.zeros(
        (cfg.n_layers, batch, cfg.n_kv_heads, s, hd), cfg.param_dtype
    )
    cache = {"k": kv(seq), "v": kv(seq)}
    if cfg.enc_dec:
        # cross-attention K/V: computed ONCE from encoder states (prefill),
        # then read-only during decode — never recomputed per token
        enc_len = enc_len if enc_len is not None else seq * 4
        cache["xk"] = kv(enc_len)
        cache["xv"] = kv(enc_len)
    return cache


def prime_cross_cache(params, cfg, cache: dict, enc_states) -> dict:
    """Fill the cross-attention K/V cache from encoder states (one-time)."""

    def per_layer(lp):
        return _enc_kv(lp, cfg, enc_states)

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    cache = dict(cache)
    cache["xk"], cache["xv"] = xk, xv
    return cache


def decode_step(params, cfg, token, cache, pos):
    """token: (B, 1) int32; pos: scalar int32 -> (logits (B,1,V), cache)."""
    x = params["embed"][token]

    def body_fn(moe):
        def body(carry, scanned):
            xc, = carry
            lp, ck, cv = scanned
            h = rms_norm(xc, lp["ln1"])
            o, ck, cv = decode_attention(lp["attn"], h, cfg, ck, cv, pos)
            xc = xc + o
            h = rms_norm(xc, lp["ln2"])
            xc = xc + (
                moe_apply(lp["moe"], h, cfg) if moe
                else mlp_apply(lp["mlp"], h, cfg)
            )
            return (xc,), (ck, cv)

        return body

    new_k, new_v = [], []
    off = 0
    for group, moe in (("layers", False), ("moe_layers", True)):
        if group not in params:
            continue
        n = jax.tree_util.tree_leaves(params[group])[0].shape[0]
        ck = jax.lax.dynamic_slice_in_dim(cache["k"], off, n, axis=0)
        cv = jax.lax.dynamic_slice_in_dim(cache["v"], off, n, axis=0)
        (x,), (ck, cv) = jax.lax.scan(
            body_fn(moe), (x,), (params[group], ck, cv)
        )
        new_k.append(ck)
        new_v.append(cv)
        off += n
    cache = {"k": jnp.concatenate(new_k, 0), "v": jnp.concatenate(new_v, 0)}
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, cache


def decode_step_enc_dec(params, cfg, token, cache, pos, enc_states=None):
    """Decoder step with self-attn cache + cached cross-attention K/V.

    ``enc_states`` is only needed when the cache was not primed (it then
    primes on the fly — the slow path kept for API compatibility)."""
    if enc_states is not None and "xk" not in cache:
        cache = prime_cross_cache(params, cfg, cache, enc_states)
    x = params["embed"][token]

    def body(carry, scanned):
        xc, = carry
        lp, ck, cv, xk, xv = scanned
        h = rms_norm(xc, lp["ln1"])
        o, ck, cv = decode_attention(lp["attn"], h, cfg, ck, cv, pos)
        xc = xc + o
        b = xc.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        xc = xc + attention_block(
            lp["xattn"], rms_norm(xc, lp["ln_x"]), cfg, positions,
            causal=False, kv_override=(xk, xv),
        )
        xc = xc + mlp_apply(lp["mlp"], rms_norm(xc, lp["ln2"]), cfg)
        return (xc,), (ck, cv)

    (x,), (nk, nv) = jax.lax.scan(
        body, (x,),
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]),
    )
    cache = {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, cache


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------


def lm_loss(params, cfg, batch):
    """batch: {tokens, labels, [embeds], [frames]} -> scalar loss."""
    if cfg.enc_dec:
        logits = forward_enc_dec(params, cfg, batch["frames"], batch["tokens"])
    else:
        logits = forward(params, cfg, batch["tokens"], batch.get("embeds"))
        if batch.get("embeds") is not None:
            logits = logits[:, batch["embeds"].shape[1]:]
    return cross_entropy(logits, batch["labels"])
