"""Zamba2-style hybrid: a Mamba2 backbone with a single *shared* (weight-
tied) attention+MLP block applied every ``attn_period`` SSM layers.

The shared block is the architecture's signature (one set of transformer
weights reused at every site, giving attention quality at SSM cost). Each
application site gets its own KV cache at decode time even though weights
are shared. Per-site LoRA deltas from the released model are omitted
(DESIGN.md §Arch-notes).

Layer schedule for n_layers=81, attn_period=6:
  13 groups of [6 x mamba2 -> shared-attn-block] + 3 trailing mamba2 layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import mlp_apply, rms_norm
from .mamba2 import (
    mamba2_apply,
    mamba2_decode,
    mamba2_init,
    mamba2_state_init,
)
from .transformer import _init_layer, _layer_apply
from .layers import decode_attention, mlp_init, attn_init


def schedule(cfg) -> tuple[int, int, int]:
    """-> (n_groups, group_len, n_tail)."""
    g = cfg.attn_period
    n_groups = cfg.n_layers // g
    return n_groups, g, cfg.n_layers - n_groups * g


def init_params(cfg, key) -> dict:
    n_groups, g, tail = schedule(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    mk = jax.random.split(k1, cfg.n_layers)
    mamba = jax.vmap(lambda k: mamba2_init(cfg, k))(mk)
    p = {
        "embed": (jax.random.normal(k3, (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.param_dtype),
        "mamba_layers": mamba,  # stacked (n_layers, ...)
        "shared_attn": _init_layer(cfg, k2, moe=False),  # ONE shared block
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        from .layers import dense_init

        p["lm_head"] = dense_init(k4, cfg.d_model, cfg.vocab, cfg.param_dtype)
    return p


def _take(stacked, lo: int, n: int):
    return jax.tree_util.tree_map(lambda a: a[lo:lo + n], stacked)


def forward(params, cfg, tokens, embeds=None):
    n_groups, g, tail = schedule(cfg)
    x = params["embed"][tokens]
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    shared = params["shared_attn"]

    def group_body(carry, group_params):
        xc = carry

        def mamba_body(xi, lp):
            return xi + mamba2_apply(lp, xi, cfg), None

        xc, _ = jax.lax.scan(mamba_body, xc, group_params)
        xc = _layer_apply(shared, xc, cfg, positions, causal=True, moe=False)
        return xc, None

    grouped = jax.tree_util.tree_map(
        lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]),
        params["mamba_layers"],
    )
    body = jax.checkpoint(
        group_body, policy=jax.checkpoint_policies.nothing_saveable
    )
    x, _ = jax.lax.scan(body, x, grouped)
    if tail:
        tail_params = _take(params["mamba_layers"], n_groups * g, tail)

        def tail_body(xc, lp):
            return xc + mamba2_apply(lp, xc, cfg), None

        x, _ = jax.lax.scan(jax.checkpoint(tail_body), x, tail_params)
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def init_cache(cfg, batch: int, seq: int) -> dict:
    n_groups, _, _ = schedule(cfg)
    hd = cfg.head_dim
    return {
        "ssm": jax.vmap(lambda _: mamba2_state_init(cfg, batch))(
            jnp.arange(cfg.n_layers)
        ),
        "k": jnp.zeros((n_groups, batch, cfg.n_kv_heads, seq, hd),
                       cfg.param_dtype),
        "v": jnp.zeros((n_groups, batch, cfg.n_kv_heads, seq, hd),
                       cfg.param_dtype),
    }


def decode_step(params, cfg, token, cache, pos):
    n_groups, g, tail = schedule(cfg)
    x = params["embed"][token]
    shared = params["shared_attn"]
    grouped_ssm = jax.tree_util.tree_map(
        lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]),
        cache["ssm"],
    )
    grouped_params = jax.tree_util.tree_map(
        lambda a: a[: n_groups * g].reshape((n_groups, g) + a.shape[1:]),
        params["mamba_layers"],
    )

    def group_body(carry, scanned):
        xc = carry
        gp, gs, ck, cv = scanned

        def mamba_body(xi, sc):
            lp, st = sc
            y, st2 = mamba2_decode(lp, xi, cfg, st)
            return xi + y, st2

        xc, gs2 = jax.lax.scan(mamba_body, xc, (gp, gs))
        h = rms_norm(xc, shared["ln1"])
        o, ck, cv = decode_attention(shared["attn"], h, cfg, ck, cv, pos)
        xc = xc + o
        xc = xc + mlp_apply(shared["mlp"], rms_norm(xc, shared["ln2"]), cfg)
        return xc, (gs2, ck, cv)

    x, (new_ssm_g, nk, nv) = jax.lax.scan(
        group_body, x, (grouped_params, grouped_ssm, cache["k"], cache["v"])
    )
    new_ssm = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups * g,) + a.shape[2:]), new_ssm_g
    )
    if tail:
        tail_params = _take(params["mamba_layers"], n_groups * g, tail)
        tail_ssm = jax.tree_util.tree_map(
            lambda a: a[n_groups * g:], cache["ssm"]
        )

        def tail_body(xc, sc):
            lp, st = sc
            y, st2 = mamba2_decode(lp, xc, cfg, st)
            return xc + y, st2

        x, tail_ssm2 = jax.lax.scan(tail_body, x, (tail_params, tail_ssm))
        new_ssm = jax.tree_util.tree_map(
            lambda a, t: jnp.concatenate([a, t], 0), new_ssm, tail_ssm2
        )
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, {"ssm": new_ssm, "k": nk, "v": nv}
