"""Shared neural layers (pure JAX, dict-pytree parameters).

Conventions:
* params are nested dicts of jnp arrays; per-layer stacks carry a leading L
  axis and are consumed by ``lax.scan``.
* weights live in the model dtype (bf16 by default); norms/softmax/rope run
  in f32.
* every init function has a matching shape so ``jax.eval_shape`` can produce
  parameter ShapeDtypeStructs without allocating (the dry-run path).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as _attention

# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (projection + position + masking wrapper over the kernel/ref)
# --------------------------------------------------------------------------


def attn_init(key, cfg) -> dict:
    ks = jax.random.split(key, 6)
    hd = cfg.head_dim
    dtype = cfg.param_dtype
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _split_heads(x, n_heads: int):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)  # (B,H,S,D)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def attn_qkv(p, x, cfg, positions):
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg, positions, *, causal=True, kv_override=None):
    """Full-sequence attention (train/prefill). kv_override supplies
    cross-attention K/V (already head-split, e.g. encoder states)."""
    q, k, v = attn_qkv(p, x, cfg, positions)
    if kv_override is not None:
        k, v = kv_override
    o = _attention(
        q, k, v, causal=causal, window=cfg.sliding_window, use_pallas=False
    )
    return _merge_heads(o) @ p["wo"]


def _kv_decode_spec(cfg):
    """Decode-time KV-cache spec: heads over 'model' when they divide, else
    *sequence*-sharded over 'model' (flash-decoding layout): scores are
    computed on local KV chunks and only the (B,H,1,D) partial output is
    reduced — instead of all-gathering the whole cache every layer
    (EXPERIMENTS.md §Perf decode iterations)."""
    from jax.sharding import PartitionSpec as P

    def spec(h):
        ep, nep = h.get("ep"), h.get("ep_size", 1) or 1
        if not ep:
            return None
        if cfg.n_kv_heads % nep == 0:
            return P(h.get("dp"), ep, None, None)
        return P(h.get("dp"), None, ep, None)

    return spec


def decode_attention(p, x, cfg, cache_k, cache_v, pos):
    """Single-token decode against a (B, Hkv, S, D) cache; pos: scalar index
    of the new token. Returns (out, new_k, new_v)."""
    from repro.parallel.hints import constrain

    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = attn_qkv(p, x, cfg, positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, pos, axis=2)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, pos, axis=2)
    kv_spec = _kv_decode_spec(cfg)
    cache_k = constrain(cache_k, kv_spec)
    cache_v = constrain(cache_v, kv_spec)
    s = cache_k.shape[2]
    group = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(cache_k, group, axis=1) if group > 1 else cache_k
    vv = jnp.repeat(cache_v, group, axis=1) if group > 1 else cache_v

    from jax.sharding import PartitionSpec as P

    def _seq_sharded(h):
        ep, nep = h.get("ep"), h.get("ep_size", 1) or 1
        return bool(ep) and cfg.n_kv_heads % nep != 0

    # flash-decoding: when the KV cache is seq-sharded, replicate the tiny
    # (B,H,1,D) q across the TP axis and keep the score matrix seq-sharded;
    # otherwise the einsum's head-sharded q forces a full KV all-gather
    q = constrain(
        q, lambda h: P(h.get("dp"), None, None, None)
        if _seq_sharded(h) else None
    )
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (cfg.head_dim ** -0.5)
    logits = constrain(
        logits, lambda h: P(h.get("dp"), None, None, h["ep"])
        if _seq_sharded(h) else None
    )
    idx = jnp.arange(s)
    valid = idx <= pos
    if cfg.sliding_window > 0:
        valid &= idx > pos - cfg.sliding_window
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    pr = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", pr, vv.astype(jnp.float32)).astype(x.dtype)
    return _merge_heads(o) @ p["wo"], cache_k, cache_v


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, cfg, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dtype = cfg.param_dtype
    if cfg.activation == "swiglu":
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dtype,
                                 scale=1.0 / math.sqrt(d_ff)),
        }
    return {
        "w_up": dense_init(ks[0], cfg.d_model, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, cfg.d_model, dtype,
                             scale=1.0 / math.sqrt(d_ff)),
    }


def mlp_apply(p, x, cfg):
    if cfg.activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-based scatter dispatch, EP-shardable)
# --------------------------------------------------------------------------


def moe_init(key, cfg) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 5)
    dtype = cfg.param_dtype
    e, d, f = m.n_experts, cfg.d_model, m.d_ff
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   / math.sqrt(d)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 / math.sqrt(d)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=m.d_ff * m.n_shared)
    return p


def moe_apply(p, x, cfg):
    """Token-choice top-k MoE with capacity; two-stage block-local dispatch.

    Tokens are processed in ``S`` dp-aligned blocks (S = number of
    data-parallel shards from the ambient sharding hints, 1 when unmeshed).
    Stage 1 scatters each block's tokens into its OWN capacity buffer --
    purely shard-local work. Stage 2 reshards the (S, E, C_loc, d) buffer
    from block-sharded (dp on dim 0) to expert-sharded ('model' on dim 1):
    an axis-aligned transition GSPMD can lower as all-to-all instead of
    replicating token activations across the model axis (EXPERIMENTS.md
    SS Perf, kimi iterations)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.hints import constrain, hint

    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    nblk = hint("dp_size", 1) or 1
    if n % nblk:
        nblk = 1
    n_loc = n // nblk
    xt = x.reshape(n, d)
    logits = (xt.astype(jnp.float32) @ p["router"])  # (N, E)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), m.top_k)  # (N,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(max(m.top_k, m.capacity_factor * n_loc * m.top_k / m.n_experts))

    # ---- explicit all-to-all dispatch (shard_map), via the 'a2a' hint -----
    a2a_mesh = hint("a2a")
    if (
        a2a_mesh is not None
        and hint("ep")
        and m.n_experts % (hint("ep_size", 1) or 1) == 0
        # tokens split over dp AND ep axes inside the dispatch
        and n % max((hint("dp_size", 1) or 1) * (hint("ep_size", 1) or 1), 1)
        == 0
    ):
        from repro.parallel.moe_ep import moe_ep_apply

        out = moe_ep_apply(
            xt, idx, gates, p["w_gate"], p["w_up"], p["w_down"],
            mesh=a2a_mesh, dp_axes=hint("dp"), ep_axis=hint("ep"),
            fsdp_axes=hint("fsdp"), capacity_factor=m.capacity_factor,
            top_k=m.top_k, n_experts=m.n_experts,
        )
        if m.n_shared:
            out = out + mlp_apply(p["shared"], xt, cfg)
        return out.reshape(b, s, d)

    # ---- stage 1: block-local capacity scatter ----------------------------
    xb = xt.reshape(nblk, n_loc, d)
    eb = idx.reshape(nblk, n_loc * m.top_k)
    onehot = jax.nn.one_hot(eb, m.n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (S, NK_loc)
    keep = pos < cap
    srcb = jnp.repeat(xb, m.top_k, axis=1)  # (S, NK_loc, d)

    def scatter_block(buf0, fe, ps, kp, src):
        return buf0.at[
            jnp.where(kp, fe, 0), jnp.where(kp, ps, cap - 1)
        ].add(jnp.where(kp[:, None], src, 0), mode="drop")

    buf = jax.vmap(scatter_block)(
        jnp.zeros((nblk, m.n_experts, cap, d), x.dtype), eb, pos, keep, srcb
    )  # (S, E, C_loc, d)
    blk_spec = lambda h: (
        P(h.get("dp"), None, None, None) if h.get("dp") else None
    )
    # expert stage keeps dim0 (blocks) dp-sharded: the blk->ep transition
    # then only moves dim1 (experts) across 'model' — a pure all-to-all
    ep_spec = lambda h: (
        P(h.get("dp"), h["ep"], None, None)
        if h.get("ep") and m.n_experts % h.get("ep_size", 1) == 0
        else None
    )
    buf = constrain(buf, blk_spec)

    # ---- stage 2: expert-sharded compute (dp->ep reshard, all-to-all-able)
    buf = constrain(buf, ep_spec)
    hh = jnp.einsum("secd,edf->secf", buf, p["w_gate"])
    uu = jnp.einsum("secd,edf->secf", buf, p["w_up"])
    y = jnp.einsum("secf,efd->secd", jax.nn.silu(hh) * uu, p["w_down"])
    y = constrain(y, ep_spec)

    # ---- return trip + combine ---------------------------------------------
    y = constrain(y, blk_spec)

    def gather_block(yb, fe, ps):
        return yb[fe, jnp.clip(ps, 0, cap - 1)]

    gathered = jax.vmap(gather_block)(y, eb, pos)  # (S, NK_loc, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = (
        gathered.reshape(n, m.top_k, d)
        * gates[..., None].astype(x.dtype)
    ).sum(1)
    if m.n_shared:
        out = out + mlp_apply(p["shared"], xt, cfg)
    return out.reshape(b, s, d)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def cross_entropy(logits, labels, ignore_index: int = -100):
    """logits: (..., V) f32/bf16; labels int32. Mean over non-ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_index).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
