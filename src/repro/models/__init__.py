"""Model substrate: the assigned-architecture families in pure JAX."""
