"""xLSTM blocks in pure JAX: chunked-parallel mLSTM (matrix memory) and
recurrent sLSTM (scalar memory), per Beck et al. 2024.

mLSTM state:  C (B,H,dk,dv), n (B,H,dk), m (B,H)   [exp-gate stabilizer]
  C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
  h_t = (q_t C_t) / max(|q_t n_t|, exp(-m_t))
Training/prefill runs chunkwise (log-space gate cumsums + carried state),
decode runs the recurrence directly.

sLSTM is a strict recurrence (scan over time) with per-head recurrent
weights — the paper's architecture choice that resists parallelization.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def mlstm_init(cfg, key) -> dict:
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    dtype = cfg.param_dtype
    return {
        "ln": jnp.ones((d,), dtype),
        "w_in": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (4, d_in), jnp.float32) * 0.5)
        .astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "wq": dense_init(ks[2], d_in, d_in, dtype),
        "wk": dense_init(ks[3], d_in, d_in, dtype),
        "wv": dense_init(ks[4], d_in, d_in, dtype),
        "w_if": dense_init(ks[5], d_in, 2 * H, dtype, scale=0.02),
        "norm": jnp.ones((d_in,), dtype),
        "w_down": dense_init(ks[6], d_in, d, dtype,
                             scale=1.0 / math.sqrt(d_in)),
    }


def _conv4(x, w, b):
    out = x * w[3]
    for j in range(1, 4):
        pad = jnp.zeros_like(x[:, :j])
        out = out + jnp.concatenate([pad, x[:, :-j]], axis=1) * w[3 - j]
    return out + b


def _mlstm_chunked(q, k, v, i_raw, f_raw, chunk: int, state=None):
    """q/k/v: (B,S,H,D) f32; i_raw/f_raw: (B,S,H). Returns (h, state)."""
    b, S, H, D = q.shape
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"seq {S} must tile by chunk {Q}")
    nc = S // Q
    scale = D ** -0.5

    ch = lambda a: a.reshape((b, nc, Q) + a.shape[2:])
    q, k, v, i_raw, f_raw = map(ch, (q, k, v, i_raw, f_raw))
    logf = jax.nn.log_sigmoid(f_raw)  # (b,nc,Q,H)
    cumf = jnp.cumsum(logf, axis=2)  # inclusive

    # intra-chunk logD[t,s] = cumf_t - cumf_s + i_s  (s <= t)
    diff = cumf[:, :, :, None, :] - cumf[:, :, None, :, :]
    logD = diff + i_raw[:, :, None, :, :]  # (b,nc,t,s,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    logD = jnp.where(tri, logD, -jnp.inf)
    m_intra = jnp.max(logD, axis=3)  # (b,nc,t,H)

    if state is None:
        C0 = jnp.zeros((b, H, D, D), jnp.float32)
        n0 = jnp.zeros((b, H, D), jnp.float32)
        m0 = jnp.full((b, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def scan_chunk(carry, c):
        C, n, m_run = carry
        (qc, kc, vc, cumf_c, logD_c, m_intra_c, i_c) = c
        # stabilizer per position: vs carried state decayed to t
        m_inter = cumf_c + m_run[:, None, :]  # (b,Q,H)
        m_t = jnp.maximum(m_intra_c, m_inter)
        m_t = jnp.maximum(m_t, -1e30)  # keep finite
        w_intra = jnp.exp(logD_c - m_t[:, :, None, :])  # (b,t,s,H)
        w_inter = jnp.exp(m_inter - m_t)  # (b,t,H)
        qk = jnp.einsum("btHd,bsHd->btsH", qc, kc) * scale
        num = (
            jnp.einsum("btsH,btsH,bsHd->btHd", qk, w_intra, vc)
            + jnp.einsum("btHk,bHkd->btHd", qc * w_inter[..., None], C)
            * scale
        )
        den = (
            jnp.einsum("btsH,btsH->btH", qk, w_intra)
            + jnp.einsum("btHk,bHk->btH", qc * w_inter[..., None], n) * scale
        )
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # chunk-end state update
        f_all = cumf_c[:, -1]  # (b,H)
        m_new = jnp.maximum(
            f_all + m_run,
            jnp.max(f_all[:, None, :] - cumf_c + i_c, axis=1),
        )
        decay_s = jnp.exp(f_all[:, None, :] - cumf_c + i_c - m_new[:, None, :])
        C = (
            C * jnp.exp(f_all + m_run - m_new)[..., None, None]
            + jnp.einsum("bsH,bsHk,bsHd->bHkd", decay_s, kc, vc)
        )
        n = (
            n * jnp.exp(f_all + m_run - m_new)[..., None]
            + jnp.einsum("bsH,bsHk->bHk", decay_s, kc)
        )
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3, 4),
        k.transpose(1, 0, 2, 3, 4),
        v.transpose(1, 0, 2, 3, 4),
        cumf.transpose(1, 0, 2, 3),
        logD.transpose(1, 0, 2, 3, 4),
        m_intra.transpose(1, 0, 2, 3),
        i_raw.transpose(1, 0, 2, 3),
    )
    (C, n, m_run), hs = jax.lax.scan(scan_chunk, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, S, H, D)
    return h, {"C": C, "n": n, "m": m_run}


def mlstm_block_apply(p, x, cfg, state=None, return_state: bool = False):
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.n_heads
    D = d_in // H
    h_in = rms_norm(x, p["ln"])
    xp = h_in @ p["w_in"]
    xm, z = xp[..., :d_in], xp[..., d_in:]
    xc = jax.nn.silu(_conv4(xm, p["conv_w"], p["conv_b"]))
    b, S, _ = x.shape
    q = (xc @ p["wq"]).reshape(b, S, H, D).astype(jnp.float32)
    k = (xc @ p["wk"]).reshape(b, S, H, D).astype(jnp.float32)
    v = (xm @ p["wv"]).reshape(b, S, H, D).astype(jnp.float32)
    if_g = (xc @ p["w_if"]).astype(jnp.float32)
    i_raw, f_raw = if_g[..., :H], if_g[..., H:]
    hh, new_state = _mlstm_chunked(q, k, v, i_raw, f_raw, cfg.ssm.chunk, state)
    hh = hh.reshape(b, S, d_in).astype(x.dtype)
    out = rms_norm(hh, p["norm"]) * jax.nn.silu(z)
    out = x + out @ p["w_down"]
    return (out, new_state) if return_state else out


def mlstm_state_init(cfg, batch: int):
    d_in = 2 * cfg.d_model
    H = cfg.n_heads
    D = d_in // H
    return {
        "C": jnp.zeros((batch, H, D, D), jnp.float32),
        "n": jnp.zeros((batch, H, D), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_in), cfg.param_dtype),
    }


def mlstm_block_decode(p, x, cfg, state):
    """x: (B,1,d). Recurrent mLSTM step."""
    d = cfg.d_model
    d_in = 2 * d
    H = cfg.n_heads
    D = d_in // H
    h_in = rms_norm(x, p["ln"])
    xp = h_in @ p["w_in"]
    xm, z = xp[..., :d_in], xp[..., d_in:]
    hist = jnp.concatenate([state["conv"], xm], axis=1)  # (B,4,d_in)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )[:, None].astype(x.dtype)
    b = x.shape[0]
    q = (xc @ p["wq"]).reshape(b, H, D).astype(jnp.float32)
    k = (xc @ p["wk"]).reshape(b, H, D).astype(jnp.float32)
    v = (xm @ p["wv"]).reshape(b, H, D).astype(jnp.float32)
    if_g = (xc @ p["w_if"]).astype(jnp.float32)[:, 0]
    i_raw, f_raw = if_g[..., :H], if_g[..., H:]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + state["m"], i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    C = state["C"] * f_s[..., None, None] + jnp.einsum(
        "bHk,bHd->bHkd", i_s[..., None] * k, v
    )
    n = state["n"] * f_s[..., None] + i_s[..., None] * k
    scale = D ** -0.5
    num = jnp.einsum("bHk,bHkd->bHd", q, C) * scale
    den = jnp.einsum("bHk,bHk->bH", q, n) * scale
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    hh = h.reshape(b, 1, d_in).astype(x.dtype)
    out = rms_norm(hh, p["norm"]) * jax.nn.silu(z)
    new_state = {"C": C, "n": n, "m": m_new, "conv": hist[:, 1:]}
    return x + out @ p["w_down"], new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def slstm_init(cfg, key) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    dtype = cfg.param_dtype
    return {
        "ln": jnp.ones((d,), dtype),
        "w_zifo": dense_init(ks[0], d, 4 * d, dtype),
        "r_zifo": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
                   / math.sqrt(dh)).astype(dtype),
        "w_out": dense_init(ks[2], d, d, dtype),
    }


def slstm_state_init(cfg, batch: int):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_cell(p, cfg, zifo_x, state):
    """zifo_x: (B, 4d) pre-activations from the input path."""
    H, d = cfg.n_heads, cfg.d_model
    dh = d // H
    b = zifo_x.shape[0]
    h_prev = state["h"].reshape(b, H, dh)
    rec = jnp.einsum(
        "bHk,Hkf->bHf", h_prev, p["r_zifo"].astype(jnp.float32)
    ).reshape(b, 4 * d)
    zifo = zifo_x + rec
    zr, ir, fr, orr = jnp.split(zifo, 4, axis=-1)
    m_new = jnp.maximum(fr + state["m"], ir)
    i_g = jnp.exp(ir - m_new)
    f_g = jnp.exp(fr + state["m"] - m_new)
    c = f_g * state["c"] + i_g * jnp.tanh(zr)
    n = f_g * state["n"] + i_g
    h = jax.nn.sigmoid(orr) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}


def slstm_block_apply(p, x, cfg, state=None, return_state: bool = False):
    b, S, d = x.shape
    h_in = rms_norm(x, p["ln"])
    zifo_x = (h_in @ p["w_zifo"]).astype(jnp.float32)  # (B,S,4d)
    st = state or slstm_state_init(cfg, b)

    def body(carry, zx):
        new = _slstm_cell(p, cfg, zx, carry)
        return new, new["h"]

    st_new, hs = jax.lax.scan(body, st, zifo_x.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)  # (B,S,d)
    out = x + hs @ p["w_out"]
    return (out, st_new) if return_state else out


def slstm_block_decode(p, x, cfg, state):
    h_in = rms_norm(x, p["ln"])
    zifo_x = (h_in[:, 0] @ p["w_zifo"]).astype(jnp.float32)
    new = _slstm_cell(p, cfg, zifo_x, state)
    out = x + new["h"][:, None].astype(x.dtype) @ p["w_out"]
    return out, new
