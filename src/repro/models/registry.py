"""Architecture registry: config -> (init, loss, prefill, decode,
input_specs) bundles consumed by the launcher, dry-run, and tests.

Every function here is shape-driven and safe under ``jax.eval_shape`` — the
dry-run never materializes full-size parameters.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

from . import transformer as tfm
from . import xlstm as xl
from . import zamba2 as zb
from .layers import cross_entropy


# --------------------------------------------------------------------------
# xLSTM model assembly (heterogeneous block list)
# --------------------------------------------------------------------------


def _xlstm_pattern(cfg) -> tuple:
    if cfg.block_pattern:
        pat = list(cfg.block_pattern)
        if len(pat) < cfg.n_layers:  # tile the declared pattern
            pat = (pat * cfg.n_layers)[: cfg.n_layers]
        return tuple(pat)
    # default xLSTM[7:1]-style: one sLSTM every 6th block
    return tuple(
        "slstm" if (i % 6 == 5) else "mlstm" for i in range(cfg.n_layers)
    )


def xlstm_init(cfg, key):
    pat = _xlstm_pattern(cfg)
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i, kind in enumerate(pat):
        blocks.append(
            xl.mlstm_init(cfg, keys[i]) if kind == "mlstm"
            else xl.slstm_init(cfg, keys[i])
        )
    p = {
        "embed": (jax.random.normal(keys[-2], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(cfg.param_dtype),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        from .layers import dense_init

        p["lm_head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab,
                                  cfg.param_dtype)
    return p


def xlstm_forward(params, cfg, tokens, embeds=None):
    pat = _xlstm_pattern(cfg)
    x = params["embed"][tokens]
    for bp, kind in zip(params["blocks"], pat):
        x = (xl.mlstm_block_apply(bp, x, cfg) if kind == "mlstm"
             else xl.slstm_block_apply(bp, x, cfg))
    x = xl.rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def xlstm_cache_init(cfg, batch: int, seq: int):
    pat = _xlstm_pattern(cfg)
    return [
        xl.mlstm_state_init(cfg, batch) if k == "mlstm"
        else xl.slstm_state_init(cfg, batch)
        for k in pat
    ]


def xlstm_decode(params, cfg, token, cache, pos):
    pat = _xlstm_pattern(cfg)
    x = params["embed"][token]
    new_cache = []
    for bp, st, kind in zip(params["blocks"], cache, pat):
        if kind == "mlstm":
            x, st2 = xl.mlstm_block_decode(bp, x, cfg, st)
        else:
            x, st2 = xl.slstm_block_decode(bp, x, cfg, st)
        new_cache.append(st2)
    x = xl.rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


# --------------------------------------------------------------------------
# Model bundle
# --------------------------------------------------------------------------


@dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable  # key -> params
    loss: Callable  # (params, batch) -> scalar
    forward: Callable  # (params, batch) -> logits
    cache_init: Callable | None  # (batch, seq) -> cache
    decode: Callable | None  # (params, token, cache, pos, [aux]) -> (logits, cache)

    # ---- step factories ---------------------------------------------------
    def make_train_step(self, opt_cfg: AdamWConfig, num_microbatches: int = 1,
                        dp_axes=None):
        """num_microbatches > 1: gradient accumulation via lax.scan over
        batch splits — bounds peak activation/logit memory (the (B,S,V)
        logits of a 1M-token global batch never materialize at once).

        ``dp_axes``: mesh axes carrying the batch dim. The reshaped
        (microbatch, batch/mb, ...) array is explicitly constrained to keep
        dim 1 on those axes — otherwise GSPMD is free to shard the
        *microbatch* axis across data devices, which serializes the scan
        into cross-device dynamic slices."""

        def train_step(params, opt_state, batch):
            if num_microbatches == 1:
                loss, grads = jax.value_and_grad(self.loss)(params, batch)
            else:
                from jax.sharding import PartitionSpec as P

                def split(x):
                    b = x.shape[0]
                    if b % num_microbatches:
                        raise ValueError(
                            f"batch {b} % microbatches {num_microbatches}"
                        )
                    y = x.reshape(
                        (num_microbatches, b // num_microbatches) + x.shape[1:]
                    )
                    if dp_axes is not None:
                        spec = P(None, dp_axes, *([None] * (y.ndim - 2)))
                        y = jax.lax.with_sharding_constraint(y, spec)
                    return y

                micro = {k: split(v) for k, v in batch.items()}
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def body(carry, mb):
                    loss_acc, g_acc = carry
                    loss, grads = jax.value_and_grad(self.loss)(params, mb)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                    )
                    return (loss_acc + loss, g_acc), None

                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), g0), micro
                )
                loss = loss / num_microbatches
                grads = jax.tree_util.tree_map(
                    lambda g: g / num_microbatches, grads
                )
            params, opt_state, metrics = apply_updates(
                opt_cfg, params, grads, opt_state
            )
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step

    def make_prefill_step(self):
        def prefill_step(params, batch):
            logits = self.forward(params, batch)
            return logits[:, -1]  # next-token logits

        return prefill_step

    def make_decode_step(self):
        def decode_step(params, token, cache, pos, aux=None):
            if aux is not None:
                return self.decode(params, token, cache, pos, aux)
            return self.decode(params, token, cache, pos)

        return decode_step


def build(cfg: ArchConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def loss(params, batch):
            return tfm.lm_loss(params, cfg, batch)

        def fwd(params, batch):
            logits = tfm.forward(params, cfg, batch["tokens"],
                                 batch.get("embeds"))
            return logits

        return ModelBundle(
            cfg=cfg,
            init=lambda key: tfm.init_params(cfg, key),
            loss=loss,
            forward=fwd,
            cache_init=lambda b, s: tfm.init_cache(cfg, b, s),
            decode=lambda params, tok, cache, pos: tfm.decode_step(
                params, cfg, tok, cache, pos
            ),
        )
    if fam == "audio":
        def loss(params, batch):
            return tfm.lm_loss(params, cfg, batch)

        def fwd(params, batch):
            return tfm.forward_enc_dec(params, cfg, batch["frames"],
                                       batch["tokens"])

        def dec(params, tok, cache, pos, enc_states=None):
            return tfm.decode_step_enc_dec(params, cfg, tok, cache, pos,
                                           enc_states)

        return ModelBundle(
            cfg=cfg,
            init=lambda key: tfm.init_params(cfg, key),
            loss=loss,
            forward=fwd,
            # self-cache of length s; cross K/V cache over 4*s encoder frames
            cache_init=lambda b, s: tfm.init_cache(cfg, b, s, enc_len=4 * s),
            decode=dec,
        )
    if fam == "hybrid":
        def loss(params, batch):
            logits = zb.forward(params, cfg, batch["tokens"])
            return cross_entropy(logits, batch["labels"])

        return ModelBundle(
            cfg=cfg,
            init=lambda key: zb.init_params(cfg, key),
            loss=loss,
            forward=lambda params, batch: zb.forward(
                params, cfg, batch["tokens"]
            ),
            cache_init=lambda b, s: zb.init_cache(cfg, b, s),
            decode=lambda params, tok, cache, pos: zb.decode_step(
                params, cfg, tok, cache, pos
            ),
        )
    if fam == "ssm":
        def loss(params, batch):
            logits = xlstm_forward(params, cfg, batch["tokens"])
            return cross_entropy(logits, batch["labels"])

        return ModelBundle(
            cfg=cfg,
            init=lambda key: xlstm_init(cfg, key),
            loss=loss,
            forward=lambda params, batch: xlstm_forward(
                params, cfg, batch["tokens"]
            ),
            cache_init=lambda b, s: xlstm_cache_init(cfg, b, s),
            decode=lambda params, tok, cache, pos: xlstm_decode(
                params, cfg, tok, cache, pos
            ),
        )
    raise ValueError(f"unknown family {fam!r}")


# --------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs for the dry-run; concrete arrays for tests)
# --------------------------------------------------------------------------


def _tok_spec(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.param_dtype
    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                "tokens": _tok_spec(b, s // 4),
                "labels": _tok_spec(b, s // 4),
            }
        if cfg.family == "vlm":
            nf = cfg.n_frontend_tokens
            return {
                "embeds": jax.ShapeDtypeStruct((b, nf, cfg.d_model), dt),
                "tokens": _tok_spec(b, s - nf),
                "labels": _tok_spec(b, s - nf),
            }
        return {"tokens": _tok_spec(b, s), "labels": _tok_spec(b, s)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                "tokens": _tok_spec(b, s // 4),
            }
        if cfg.family == "vlm":
            nf = cfg.n_frontend_tokens
            return {
                "embeds": jax.ShapeDtypeStruct((b, nf, cfg.d_model), dt),
                "tokens": _tok_spec(b, s - nf),
            }
        return {"tokens": _tok_spec(b, s)}
    # decode: one new token against a seq_len-deep cache (audio: the cross
    # K/V lives in the cache, primed once at prefill — no per-token input)
    return {"token": _tok_spec(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def make_batch(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    out = {}
    for k, v in input_specs(cfg, shape).items():
        if v.dtype == jnp.int32:
            if k == "pos":
                out[k] = jnp.asarray(shape.seq_len // 2, jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab, v.shape), jnp.int32
                )
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(v.shape).astype(np.float32), v.dtype
            )
    return out
