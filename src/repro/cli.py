"""Console entry points (`repro-explore`, see pyproject.toml).

The design-space-exploration walkthrough lives here (importable after
``pip install``); ``examples/dse_explore.py`` is a thin wrapper for
running it straight from a checkout. The flow is the paper's workflow as
a tool — compile SPD cores, sweep both target models in batched NumPy
(including the device axis ``d``, docs/pipeline.md §distribute), extract
Pareto frontiers, and execute TPU frontier points through real Pallas
kernels via the pluggable search subsystem, ``Explorer.search``
(docs/pipeline.md §execute, §search): ``--strategy`` picks how the
measurement budget is spent — ``exhaustive`` walks the Pareto frontier
top-down (the default), ``refine`` hill-climbs the (block_h, m, d)
neighborhood of the model's best points, ``halving`` races a wide
model-ranked pool with cheap screening reps and full-rep finals —
``tpe`` learns where to measure next with a seeded Tree-structured
Parzen Estimator (docs/pipeline.md §study) — and ``--budget N`` caps
live measurements hard. ``--study NAME`` journals every trial into a
durable study (``--study-dir``, default ``~/.cache/repro/studies``):
re-running with the same name replays completed trials into the plan
dedupe table, so an interrupted search resumes with zero
re-measurement; ``--seed`` fixes the TPE sampler's RNG and ``--trials``
bounds its total observations. Single-device points
run the codegen'd kernel directly, ``d > 1`` points run sharded with
halo exchange when the platform has the devices. ``--devices N`` caps
the swept d axis, ``--json PATH`` dumps the machine-readable results
(including ``strategy``, ``budget_spent``, and per-candidate
measurement counts) for scripting.

Measurement policy (docs/pipeline.md §measure): runs are timed with the
honest harness (``--reps`` median-of-reps, every rep synchronized), the
platform is calibrated so ``rel err`` diffs against the backend actually
running (``--no-calibrate`` to compare against raw TPU-v5e roofline
constants instead), and wall times persist in the on-disk measurement
cache (``--no-cache`` to always re-time).
"""

from __future__ import annotations

import argparse
import json


def _point_dict(p) -> dict:
    return {
        "d": int(p.n),
        "m": int(p.m),
        "block_h": int(p.detail.get("block_rows", 0)) or None,
        "feasible": bool(p.feasible),
        "sustained_gflops": float(p.sustained_gflops),
        "perf_per_watt": float(p.perf_per_watt),
        "limits": list(p.limits),
    }


def explore_main(argv: list[str] | None = None) -> None:
    """The `repro-explore` command: DSE walkthrough, end to end."""
    from repro.apps import diffusion as dif
    from repro.apps import lbm
    from repro.configs import get_arch
    from repro.core.distribute import device_axis_values
    from repro.core.explorer import render_executed
    from repro.core.planner import ArchStats, plan, render_plans
    from repro.core.search import STRATEGIES, ExhaustiveSearch

    ap = argparse.ArgumentParser(prog="repro-explore", description=__doc__)
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--topk", type=int, default=2,
                    help="frontier points to execute with --strategy "
                         "exhaustive; refine/halving choose their own "
                         "candidate counts (bound them with --budget)")
    ap.add_argument("--devices", type=int, default=4, metavar="N",
                    help="sweep the device axis d over powers of two up to "
                         "N (execution shards onto real devices; off-TPU "
                         "force host devices with XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N)")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write the sweep/execution results as JSON")
    ap.add_argument("--no-execute", action="store_true",
                    help="skip the (host-speed) interpret-mode Pallas runs")
    ap.add_argument("--strategy", default="exhaustive",
                    choices=sorted(STRATEGIES),
                    help="search strategy for the measured sweep "
                         "(docs/pipeline.md §search): exhaustive = walk "
                         "the Pareto frontier top-down, refine = "
                         "model-seeded (block_h, m, d) hill-climb, "
                         "halving = budgeted successive halving")
    ap.add_argument("--budget", type=int, default=None, metavar="N",
                    help="hard cap on live measurements per app search "
                         "(cache hits are free; default: unbudgeted)")
    ap.add_argument("--reps", type=int, default=3, metavar="N",
                    help="measured timing reps per executed point (median "
                         "is reported; every rep is synchronized)")
    ap.add_argument("--calibrate", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="calibrate predictions against the live backend's "
                         "measured throughput/bandwidth so rel err is a "
                         "model-fidelity signal (--no-calibrate diffs "
                         "against raw TPU-v5e roofline constants)")
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the persistent measurement cache and "
                         "re-time every point")
    ap.add_argument("--double-buffer", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="stream stripes through ping/pong VMEM buffers "
                         "(DMA/compute overlap, docs/pipeline.md §stream); "
                         "--no-double-buffer requests the single-buffer "
                         "streaming fallback (half the VMEM, no overlap). "
                         "The legalizer may still fall back per point when "
                         "the ping/pong pair cannot fit")
    ap.add_argument("--study", type=str, default=None, metavar="NAME",
                    help="journal every trial into a durable named study "
                         "(docs/pipeline.md §study); re-running with the "
                         "same name resumes it, replaying completed "
                         "trials with zero re-measurement")
    ap.add_argument("--study-dir", type=str, default=None, metavar="PATH",
                    help="directory holding study journals (default: "
                         "$REPRO_STUDY_DIR or ~/.cache/repro/studies)")
    ap.add_argument("--seed", type=int, default=0, metavar="N",
                    help="RNG seed for --strategy tpe (a seeded search "
                         "reproduces the identical trial sequence)")
    ap.add_argument("--trials", type=int, default=None, metavar="N",
                    help="cap on total tpe observations, replayed + "
                         "measured (a resumed study whose replays cover "
                         "N spends zero budget)")
    args = ap.parse_args(argv)
    d_values = device_axis_values(args.devices)
    report: dict = {"d_values": list(d_values)}

    print("=" * 72)
    print("1) The paper's case study: LBM on the Stratix V model")
    print("=" * 72)
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    print(sweep.table(k=10))
    print()
    print("Pareto frontier (max throughput, max perf/W, min resources):")
    print(sweep.table(frontier_only=True))
    best = sweep.best("perf_per_watt")
    print(f"-> best configuration: (n, m) = ({best.n}, {best.m})  "
          f"[paper §III: (1, 4)]")
    report["fpga"] = {
        "best": {"n": int(best.n), "m": int(best.m),
                 "perf_per_watt": float(best.perf_per_watt)},
    }

    print()
    print("=" * 72)
    print("2) Hardware adaptation: temporal blocking on TPU v5e,")
    print(f"   device axis d ∈ {d_values} (sharding + halo exchange)")
    print("=" * 72)
    tsweep = ex.sweep_tpu(d_values=d_values,
                          double_buffer=args.double_buffer)
    print(tsweep.table(k=8))
    print()
    print("TPU Pareto frontier:")
    print(tsweep.table(frontier_only=True, k=6))
    tbest = tsweep.best("sustained_gflops")
    report["tpu"] = {
        "best": _point_dict(tbest),
        "frontier": [_point_dict(p) for p in tsweep.frontier()],
    }

    if not args.no_execute:
        import jax

        from repro.core.measure import MeasurementCache

        mcache = None if args.no_cache else MeasurementCache()
        # Only propose device counts the platform can run: on the tall
        # measurement grid the model drops d=1 off the frontier, so an
        # uncapped sweep leaves a single-device machine nothing to time.
        exec_d = device_axis_values(min(args.devices, jax.device_count()))
        # The default strategy reproduces the original behavior: walk
        # the Pareto frontier until --topk points executed. The others
        # (--strategy refine/halving) search measured-in-the-loop under
        # the --budget cap (docs/pipeline.md §search).
        if args.strategy == "exhaustive":
            strategy = ExhaustiveSearch(k=args.topk, frontier_only=True)
        elif args.strategy == "tpe":
            from repro.core.search import TPESearch

            strategy = TPESearch(seed=args.seed, max_trials=args.trials)
        else:
            strategy = args.strategy
        # One named study can hold both app searches: trials are keyed
        # by core fingerprint, so each search replays only its own.
        study_kw = dict(study=args.study, study_dir=args.study_dir)
        print()
        print("=" * 72)
        print(f"3) Model -> measurement: --strategy {args.strategy} "
              f"(budget: {args.budget if args.budget else 'none'}) over the")
        print("   codegen'd uLBM Pallas kernel (interpret mode, 256x128; "
              "d>1 points run")
        print("   sharded — the grid is tall enough that sharding beats "
              "the halo exchange)")
        print("=" * 72)
        msim = lbm.LBMSimulation(lbm.LBMProblem(256, 128, mode="wrap"))
        mex = msim.explorer()
        msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8), d_values=exec_d,
                               double_buffer=args.double_buffer)
        f0, attr, _ = lbm.taylor_green_init(256, 128)
        mres = mex.search(
            msweep, msim.stream_state(f0, attr), msim.stream_regs(),
            strategy=strategy, budget=args.budget, interpret=True,
            reps=args.reps, calibrate=args.calibrate, cache=mcache,
            **study_kw,
        )
        print(render_executed(mres.executed))
        print(f"(strategy={mres.strategy}: {mres.budget_spent} live "
              f"measurement(s), {len(mres.executed)} point(s) executed"
              + (f", {mres.replayed} replayed from study "
                 f"{mres.study!r}" if mres.study else "") + ")")
        report["lbm"] = mres.as_dict()

        print()
        print("=" * 72)
        print("3b) Any SPD core on the frontier: 2-D diffusion through the")
        print("    generic SPD->Pallas codegen (docs/pipeline.md, 256x128)")
        print("=" * 72)
        dsim = dif.DiffusionSimulation(256, 128, alpha=0.2)
        dex = dsim.explorer()
        dsweep = dex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8), d_values=exec_d,
                               double_buffer=args.double_buffer)
        u0, _ = dif.sine_init(256, 128)
        dres = dex.search(dsweep, dsim.state(u0), (dsim.alpha,),
                          strategy=strategy, budget=args.budget,
                          interpret=True, reps=args.reps,
                          calibrate=args.calibrate, cache=mcache,
                          **study_kw)
        print(render_executed(dres.executed))
        print(f"(strategy={dres.strategy}: {dres.budget_spent} live "
              f"measurement(s), {len(dres.executed)} point(s) executed"
              + (f", {dres.replayed} replayed from study "
                 f"{dres.study!r}" if dres.study else "") + ")")
        halo = dsim.kernel.summary
        print(f"(inferred stencil: {len(halo.offsets)} offsets, "
              f"halo = {halo.halo_y} row/step — no hand-written kernel)")
        report["diffusion"] = dres.as_dict()
        report["measure"] = {
            "reps": args.reps,
            "calibrate": bool(args.calibrate),
            "double_buffer": bool(args.double_buffer),
            "strategy": args.strategy,
            "budget": args.budget,
            "cache": None if mcache is None else mcache.stats(),
            "study": args.study,
            "seed": args.seed,
            "trials": args.trials,
        }
        if mcache is not None:
            s = mcache.stats()
            print(f"(measurement cache: {s['hits']} hit(s), "
                  f"{s['misses']} miss(es) — {s['path']})")

    print()
    print("=" * 72)
    print(f"4) The same trade on an LM fleet: {args.arch} on "
          f"{args.chips} chips")
    print("   (spatial n -> dp, temporal m -> pp, in-PE -> tp)")
    print("=" * 72)
    cfg = get_arch(args.arch)
    stats = ArchStats(
        name=cfg.name, params=cfg.num_params(),
        active_params=cfg.active_params(), n_layers=cfg.n_layers,
        d_model=cfg.d_model, global_batch=args.batch, seq_len=args.seq,
    )
    print(render_plans(plan(stats, args.chips), top=10))

    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"\n[wrote {args.json}]")


if __name__ == "__main__":
    explore_main()
