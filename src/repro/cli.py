"""Console entry points (`repro-explore`, see pyproject.toml).

The design-space-exploration walkthrough lives here (importable after
``pip install``); ``examples/dse_explore.py`` is a thin wrapper for
running it straight from a checkout. The flow is the paper's workflow as
a tool — compile SPD cores, sweep both target models in batched NumPy
(including the device axis ``d``, docs/pipeline.md §distribute), extract
Pareto frontiers, and execute TPU frontier points through real Pallas
kernels via the pluggable search subsystem, ``Explorer.search``
(docs/pipeline.md §execute, §search): ``--strategy`` picks how the
measurement budget is spent — ``exhaustive`` walks the Pareto frontier
top-down (the default), ``refine`` hill-climbs the (block_h, m, d)
neighborhood of the model's best points, ``halving`` races a wide
model-ranked pool with cheap screening reps and full-rep finals —
``tpe`` learns where to measure next with a seeded Tree-structured
Parzen Estimator (docs/pipeline.md §study) — and ``--budget N`` caps
live measurements hard. ``--study NAME`` journals every trial into a
durable study (``--study-dir``, default ``~/.cache/repro/studies``):
re-running with the same name replays completed trials into the plan
dedupe table, so an interrupted search resumes with zero
re-measurement; ``--seed`` fixes the TPE sampler's RNG and ``--trials``
bounds its total observations. Single-device points
run the codegen'd kernel directly, ``d > 1`` points run sharded with
halo exchange when the platform has the devices. ``--devices N`` caps
the swept d axis; ``--mesh DYxDX`` pins a 2-D device mesh (rows shard
across DY, columns across DX — DESIGN.md §15) and ``--mesh auto``
sweeps the column axis so the search enumerates factorizations of the
device count. ``--json PATH`` dumps the machine-readable results
(including ``strategy``, ``budget_spent``, and per-candidate
measurement counts) for scripting.

Measurement policy (docs/pipeline.md §measure): runs are timed with the
honest harness (``--reps`` median-of-reps, every rep synchronized), the
platform is calibrated so ``rel err`` diffs against the backend actually
running (``--no-calibrate`` to compare against raw TPU-v5e roofline
constants instead), and wall times persist in the on-disk measurement
cache (``--no-cache`` to always re-time).
"""

from __future__ import annotations

import argparse
import json


def _point_dict(p) -> dict:
    return {
        "d": int(p.n),
        "dx": int(p.detail.get("dx", 1)),
        "dy": int(p.detail.get("dy", p.n)),
        "m": int(p.m),
        "block_h": int(p.detail.get("block_rows", 0)) or None,
        "feasible": bool(p.feasible),
        "sustained_gflops": float(p.sustained_gflops),
        "perf_per_watt": float(p.perf_per_watt),
        "limits": list(p.limits),
    }


def explore_main(argv: list[str] | None = None) -> None:
    """The `repro-explore` command: DSE walkthrough, end to end."""
    from repro.apps import diffusion as dif
    from repro.apps import lbm
    from repro.configs import get_arch
    from repro.core.distribute import device_axis_values
    from repro.core.explorer import render_executed
    from repro.core.planner import ArchStats, plan, render_plans
    from repro.core.search import STRATEGIES, ExhaustiveSearch

    ap = argparse.ArgumentParser(prog="repro-explore", description=__doc__)
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--topk", type=int, default=2,
                    help="frontier points to execute with --strategy "
                         "exhaustive; refine/halving choose their own "
                         "candidate counts (bound them with --budget)")
    ap.add_argument("--devices", type=int, default=4, metavar="N",
                    help="sweep the device axis d over powers of two up to "
                         "N (execution shards onto real devices; off-TPU "
                         "force host devices with XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N)")
    ap.add_argument("--mesh", type=str, default=None, metavar="DYxDX",
                    help="2-D device mesh for the TPU sweeps (DESIGN.md "
                         "§15): 'DYxDX' pins the mesh shape (d = DY*DX; "
                         "rows shard across DY, columns across DX with "
                         "ppermute column-halo exchange), 'auto' sweeps "
                         "every power-of-two column count up to --devices "
                         "so the search enumerates the legal "
                         "factorizations of each device count")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write the sweep/execution results as JSON")
    ap.add_argument("--no-execute", action="store_true",
                    help="skip the (host-speed) interpret-mode Pallas runs")
    ap.add_argument("--strategy", default="exhaustive",
                    choices=sorted(STRATEGIES),
                    help="search strategy for the measured sweep "
                         "(docs/pipeline.md §search): exhaustive = walk "
                         "the Pareto frontier top-down, refine = "
                         "model-seeded (block_h, m, d) hill-climb, "
                         "halving = budgeted successive halving")
    ap.add_argument("--budget", type=int, default=None, metavar="N",
                    help="hard cap on live measurements per app search "
                         "(cache hits are free; default: unbudgeted)")
    ap.add_argument("--reps", type=int, default=3, metavar="N",
                    help="measured timing reps per executed point (median "
                         "is reported; every rep is synchronized)")
    ap.add_argument("--calibrate", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="calibrate predictions against the live backend's "
                         "measured throughput/bandwidth so rel err is a "
                         "model-fidelity signal (--no-calibrate diffs "
                         "against raw TPU-v5e roofline constants)")
    ap.add_argument("--no-cache", action="store_true",
                    help="skip the persistent measurement cache and "
                         "re-time every point")
    ap.add_argument("--double-buffer", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="stream stripes through ping/pong VMEM buffers "
                         "(DMA/compute overlap, docs/pipeline.md §stream); "
                         "--no-double-buffer requests the single-buffer "
                         "streaming fallback (half the VMEM, no overlap). "
                         "The legalizer may still fall back per point when "
                         "the ping/pong pair cannot fit")
    ap.add_argument("--study", type=str, default=None, metavar="NAME",
                    help="journal every trial into a durable named study "
                         "(docs/pipeline.md §study); re-running with the "
                         "same name resumes it, replaying completed "
                         "trials with zero re-measurement")
    ap.add_argument("--study-dir", type=str, default=None, metavar="PATH",
                    help="directory holding study journals (default: "
                         "$REPRO_STUDY_DIR or ~/.cache/repro/studies)")
    ap.add_argument("--seed", type=int, default=0, metavar="N",
                    help="RNG seed for --strategy tpe (a seeded search "
                         "reproduces the identical trial sequence)")
    ap.add_argument("--trials", type=int, default=None, metavar="N",
                    help="cap on total tpe observations, replayed + "
                         "measured (a resumed study whose replays cover "
                         "N spends zero budget)")
    ap.add_argument("--program", action="store_true",
                    help="also search the multi-core stream programs "
                         "(docs/pipeline.md §program): LBM as a 3-core "
                         "collide+stream -> boundary -> moments chain "
                         "and the 2-core advection-diffusion app, with "
                         "the fusion partition (which stages share one "
                         "pallas_call) swept as a lattice axis — the "
                         "report table gains a `fuse` column and --json "
                         "carries the partition per executed point")
    args = ap.parse_args(argv)
    d_values = device_axis_values(args.devices)
    dx_values: tuple[int, ...] = (1,)
    if args.mesh:
        if args.mesh.strip().lower() == "auto":
            # Sweep every power-of-two column count; evaluate_batch
            # marks the non-factorizations (d % dx != 0) infeasible, so
            # the cross product enumerates exactly the legal meshes.
            dx_values = d_values
        else:
            try:
                dy_s, dx_s = args.mesh.strip().lower().split("x")
                mesh_dy, mesh_dx = int(dy_s), int(dx_s)
            except ValueError:
                ap.error(f"--mesh {args.mesh!r}: expected DYxDX "
                         "(e.g. 2x4) or auto")
            if mesh_dy < 1 or mesh_dx < 1:
                ap.error("--mesh: DY and DX must be >= 1")
            d_values = (mesh_dy * mesh_dx,)
            dx_values = (mesh_dx,)
    report: dict = {"d_values": list(d_values),
                    "dx_values": list(dx_values), "mesh": args.mesh}

    print("=" * 72)
    print("1) The paper's case study: LBM on the Stratix V model")
    print("=" * 72)
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    print(sweep.table(k=10))
    print()
    print("Pareto frontier (max throughput, max perf/W, min resources):")
    print(sweep.table(frontier_only=True))
    best = sweep.best("perf_per_watt")
    print(f"-> best configuration: (n, m) = ({best.n}, {best.m})  "
          f"[paper §III: (1, 4)]")
    report["fpga"] = {
        "best": {"n": int(best.n), "m": int(best.m),
                 "perf_per_watt": float(best.perf_per_watt)},
    }

    print()
    print("=" * 72)
    print("2) Hardware adaptation: temporal blocking on TPU v5e,")
    print(f"   device axis d ∈ {d_values} (sharding + halo exchange)")
    print("=" * 72)
    tsweep = ex.sweep_tpu(d_values=d_values, dx_values=dx_values,
                          double_buffer=args.double_buffer)
    print(tsweep.table(k=8))
    print()
    print("TPU Pareto frontier:")
    print(tsweep.table(frontier_only=True, k=6))
    tbest = tsweep.best("sustained_gflops")
    report["tpu"] = {
        "best": _point_dict(tbest),
        "frontier": [_point_dict(p) for p in tsweep.frontier()],
    }

    if not args.no_execute:
        import jax

        from repro.core.measure import MeasurementCache

        mcache = None if args.no_cache else MeasurementCache()
        # Only propose device counts the platform can run: on the tall
        # measurement grid the model drops d=1 off the frontier, so an
        # uncapped sweep leaves a single-device machine nothing to time.
        exec_d = device_axis_values(min(args.devices, jax.device_count()))
        if args.mesh and args.mesh.strip().lower() != "auto":
            exec_d = tuple(
                d for d in d_values if d <= jax.device_count()
            ) or exec_d
        exec_dx = tuple(
            x for x in dx_values if x <= jax.device_count()
        ) or (1,)
        # The default strategy reproduces the original behavior: walk
        # the Pareto frontier until --topk points executed. The others
        # (--strategy refine/halving) search measured-in-the-loop under
        # the --budget cap (docs/pipeline.md §search).
        if args.strategy == "exhaustive":
            strategy = ExhaustiveSearch(k=args.topk, frontier_only=True)
        elif args.strategy == "tpe":
            from repro.core.search import TPESearch

            strategy = TPESearch(seed=args.seed, max_trials=args.trials)
        else:
            strategy = args.strategy
        # One named study can hold both app searches: trials are keyed
        # by core fingerprint, so each search replays only its own.
        study_kw = dict(study=args.study, study_dir=args.study_dir)
        print()
        print("=" * 72)
        print(f"3) Model -> measurement: --strategy {args.strategy} "
              f"(budget: {args.budget if args.budget else 'none'}) over the")
        print("   codegen'd uLBM Pallas kernel (interpret mode, 256x128; "
              "d>1 points run")
        print("   sharded — the grid is tall enough that sharding beats "
              "the halo exchange)")
        print("=" * 72)
        msim = lbm.LBMSimulation(lbm.LBMProblem(256, 128, mode="wrap"))
        mex = msim.explorer()
        msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8), d_values=exec_d,
                               dx_values=exec_dx,
                               double_buffer=args.double_buffer)
        f0, attr, _ = lbm.taylor_green_init(256, 128)
        mres = mex.search(
            msweep, msim.stream_state(f0, attr), msim.stream_regs(),
            strategy=strategy, budget=args.budget, interpret=True,
            reps=args.reps, calibrate=args.calibrate, cache=mcache,
            **study_kw,
        )
        print(render_executed(mres.executed))
        print(f"(strategy={mres.strategy}: {mres.budget_spent} live "
              f"measurement(s), {len(mres.executed)} point(s) executed"
              + (f", {mres.replayed} replayed from study "
                 f"{mres.study!r}" if mres.study else "") + ")")
        report["lbm"] = mres.as_dict()

        print()
        print("=" * 72)
        print("3b) Any SPD core on the frontier: 2-D diffusion through the")
        print("    generic SPD->Pallas codegen (docs/pipeline.md, 256x128)")
        print("=" * 72)
        dsim = dif.DiffusionSimulation(256, 128, alpha=0.2)
        dex = dsim.explorer()
        dsweep = dex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8), d_values=exec_d,
                               dx_values=exec_dx,
                               double_buffer=args.double_buffer)
        u0, _ = dif.sine_init(256, 128)
        dres = dex.search(dsweep, dsim.state(u0), (dsim.alpha,),
                          strategy=strategy, budget=args.budget,
                          interpret=True, reps=args.reps,
                          calibrate=args.calibrate, cache=mcache,
                          **study_kw)
        print(render_executed(dres.executed))
        print(f"(strategy={dres.strategy}: {dres.budget_spent} live "
              f"measurement(s), {len(dres.executed)} point(s) executed"
              + (f", {dres.replayed} replayed from study "
                 f"{dres.study!r}" if dres.study else "") + ")")
        halo = dsim.kernel.summary
        print(f"(inferred stencil: {len(halo.offsets)} offsets, "
              f"halo = {halo.halo_y} row/step — no hand-written kernel)")
        report["diffusion"] = dres.as_dict()

        if args.program:
            from repro.apps.advection_diffusion import (
                AdvectionDiffusionSimulation, blob_init)
            from repro.core.program import fusion_partitions

            print()
            print("=" * 72)
            print("3c) Stream programs: the fusion partition as a "
                  "search axis")
            print("    (docs/pipeline.md §program; `fuse` column = "
                  "cluster sizes, e.g. 2+1)")
            print("=" * 72)
            report["program"] = {}
            psim = lbm.LBMSimulation(lbm.LBMProblem(128, 128, mode="wrap"))
            pprog = psim.program()
            pf, pattr, _ = lbm.taylor_green_init(128, 128)
            asim = AdvectionDiffusionSimulation(128, 128)
            for label, prog, state, regs in (
                ("lbm_program", pprog,
                 psim.stream_state(pf, pattr), psim.stream_regs()),
                ("advection_diffusion", asim.program,
                 asim.state(blob_init(128, 128)), asim.regs()),
            ):
                pex = prog.explorer(128 * 128, grid_w=128)
                psweep = pex.sweep_tpu(
                    bh_values=(8, 16, 32), m_values=(1, 2, 4),
                    d_values=exec_d, dx_values=exec_dx,
                    double_buffer=args.double_buffer,
                    fusion_values=fusion_partitions(prog.nstages),
                )
                pres = pex.search(
                    psweep, state, regs, strategy=strategy,
                    budget=args.budget, interpret=True, reps=args.reps,
                    calibrate=args.calibrate, cache=mcache, **study_kw,
                )
                print(f"-- {label} ({prog.nstages} stages, partitions: "
                      f"{', '.join(fusion_partitions(prog.nstages))})")
                print(render_executed(pres.executed))
                print(f"(strategy={pres.strategy}: {pres.budget_spent} "
                      f"live measurement(s), {len(pres.executed)} "
                      f"point(s) executed)")
                report["program"][label] = pres.as_dict()

        report["measure"] = {
            "reps": args.reps,
            "calibrate": bool(args.calibrate),
            "double_buffer": bool(args.double_buffer),
            "strategy": args.strategy,
            "budget": args.budget,
            "mesh": args.mesh,
            "cache": None if mcache is None else mcache.stats(),
            "study": args.study,
            "seed": args.seed,
            "trials": args.trials,
        }
        if mcache is not None:
            s = mcache.stats()
            print(f"(measurement cache: {s['hits']} hit(s), "
                  f"{s['misses']} miss(es) — {s['path']})")

    print()
    print("=" * 72)
    print(f"4) The same trade on an LM fleet: {args.arch} on "
          f"{args.chips} chips")
    print("   (spatial n -> dp, temporal m -> pp, in-PE -> tp)")
    print("=" * 72)
    cfg = get_arch(args.arch)
    stats = ArchStats(
        name=cfg.name, params=cfg.num_params(),
        active_params=cfg.active_params(), n_layers=cfg.n_layers,
        d_model=cfg.d_model, global_batch=args.batch, seq_len=args.seq,
    )
    print(render_plans(plan(stats, args.chips), top=10))

    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"\n[wrote {args.json}]")


def serve_main(argv: list[str] | None = None) -> None:
    """The `repro-serve` command: the multi-tenant simulation-serving
    engine (DESIGN.md §13, docs/pipeline.md §serve) under open-loop
    Poisson load.

    Builds a tenant mix (2-D diffusion at two grid sizes plus the uLBM
    core), submits ``--requests`` jobs per tenant at ``--arrival-rate``
    expected arrivals per engine tick, and serves them through
    :class:`repro.serve.sim.SimEngine`: requests sharing a trial
    context stack along the batch axis ``b``, each context autotunes on
    first request under a hard ``--budget`` of live measurements, and
    ``--study-dir`` makes the tuning durable — a second invocation with
    the same directory warm-starts every plan with zero live timings.
    """
    import numpy as np

    from repro.apps import diffusion as dif
    from repro.apps import lbm
    from repro.serve.sim import PlanResolver, SimEngine, SimRequest

    ap = argparse.ArgumentParser(prog="repro-serve", description=__doc__)
    ap.add_argument("--tenants", type=int, default=3, metavar="N",
                    help="tenant contexts in the mix, drawn cyclically "
                         "from the built-in set (diffusion 32x32 / "
                         "64x64, lbm 32x32); each is a distinct trial "
                         "context with its own autotuned plan")
    ap.add_argument("--requests", type=int, default=8, metavar="N",
                    help="requests submitted per tenant")
    ap.add_argument("--steps", type=int, default=16, metavar="N",
                    help="simulation steps per request")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    metavar="R",
                    help="open-loop Poisson intensity: expected "
                         "arrivals per engine tick (saturating rates "
                         "build the backlog that fills the batch axis)")
    ap.add_argument("--budget", type=int, default=4, metavar="N",
                    help="hard cap on live tuning measurements per "
                         "trial context (autotune-on-first-request; "
                         "exhaustion falls back to the model's plan)")
    ap.add_argument("--study-dir", type=str, default=None, metavar="PATH",
                    help="directory for the per-context tuning studies "
                         "(default: $REPRO_STUDY_DIR or ~/.cache/repro/"
                         "studies); reuse it to warm-start with zero "
                         "live timings")
    ap.add_argument("--max-queue", type=int, default=64, metavar="N",
                    help="admission queue bound — submissions beyond it "
                         "are rejected with backpressure, never dropped "
                         "silently")
    ap.add_argument("--seed", type=int, default=0, metavar="N",
                    help="RNG seed for the arrival schedule")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="write the engine stats as JSON")
    args = ap.parse_args(argv)

    mix = []
    for h, w, alpha in ((32, 32, 0.2), (64, 64, 0.1)):
        sim = dif.DiffusionSimulation(h, w, alpha=alpha)
        u0, _ = dif.sine_init(h, w)
        mix.append((f"diffusion-{h}x{w}", sim.kernel, sim.state(u0),
                    (sim.alpha,)))
    lsim = lbm.LBMSimulation(lbm.LBMProblem(32, 32, mode="wrap"))
    f0, attr, _ = lbm.taylor_green_init(32, 32)
    mix.append(("lbm-32x32", lsim.stream_kernel(),
                lsim.stream_state(f0, attr), lsim.stream_regs()))
    tenants = [mix[i % len(mix)] for i in range(args.tenants)]

    engine = SimEngine(
        PlanResolver(budget=args.budget, study_dir=args.study_dir),
        max_queue=args.max_queue,
    )
    rng = np.random.default_rng(args.seed)
    total = args.requests * len(tenants)
    ticks = np.floor(np.cumsum(
        rng.exponential(1.0 / args.arrival_rate, size=total)
    )).astype(int)
    order = rng.permutation(
        np.repeat(np.arange(len(tenants)), args.requests)
    )
    schedule = list(zip(ticks.tolist(), order.tolist()))

    print("=" * 72)
    print(f"simulation-as-a-service: {total} request(s) over "
          f"{len(tenants)} tenant(s),")
    print(f"rate {args.arrival_rate}/tick, {args.steps} steps/request, "
          f"tuning budget {args.budget}")
    print("=" * 72)
    completions = []
    rid = 0
    i = 0
    while i < len(schedule) or engine.queue or engine._active_count():
        while i < len(schedule) and schedule[i][0] <= engine.tick_count:
            name, core, state, regs = tenants[schedule[i][1]]
            engine.submit(SimRequest(rid=rid, core=core, state=state,
                                     steps=args.steps, regs=regs))
            rid += 1
            i += 1
        completions.extend(engine.step())
    stats = engine.stats()
    lat = sorted(c.latency_s for c in completions)

    def pct(p):
        return lat[min(len(lat) - 1, int(p / 100 * len(lat)))] if lat else 0.0

    print(f"{stats['completed']}/{stats['submitted']} completed "
          f"({stats['rejected']} rejected with backpressure), "
          f"{stats['launches']} launch(es) in {stats['ticks']} tick(s)")
    print(f"steady-state {stats['steps_per_s']:.1f} member-steps/s; "
          f"latency p50 {pct(50) * 1e3:.1f} ms / p95 {pct(95) * 1e3:.1f} "
          f"ms / p99 {pct(99) * 1e3:.1f} ms")
    print("batch occupancy: " + ", ".join(
        f"b={k}: {v}" for k, v in stats["occupancy"].items()))
    print(f"tuning: {stats['live_timings']} live timing(s), "
          f"{stats['tuning_ticks']} tuning tick(s)"
          + (" — warm start" if stats["live_timings"] == 0 else ""))
    for key, plan in sorted(stats["plans"].items()):
        print(f"  {key}: block_h={plan['block_h']} m={plan['m']} "
              f"b={plan['b']} db={plan['double_buffer']} "
              f"[{plan['source']}, {plan['budget_spent']} timed, "
              f"{plan['replayed']} replayed]")

    if args.json:
        stats["latency"] = {"p50_s": pct(50), "p95_s": pct(95),
                            "p99_s": pct(99)}
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(stats, fh, indent=2, sort_keys=True)
        print(f"\n[wrote {args.json}]")


if __name__ == "__main__":
    explore_main()
