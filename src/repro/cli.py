"""Console entry points (`repro-explore`, see pyproject.toml).

The design-space-exploration walkthrough lives here (importable after
``pip install``); ``examples/dse_explore.py`` is a thin wrapper for
running it straight from a checkout. The flow is the paper's workflow as
a tool — compile SPD cores, sweep both target models in batched NumPy,
extract Pareto frontiers, and execute TPU frontier points through real
Pallas kernels: the hand-written ``lbm_stream`` for the LBM case study
and the generic codegen'd kernel for the diffusion app
(docs/pipeline.md §execute).
"""

from __future__ import annotations

import argparse


def explore_main(argv: list[str] | None = None) -> None:
    """The `repro-explore` command: DSE walkthrough, end to end."""
    from repro.apps import diffusion as dif
    from repro.apps import lbm
    from repro.configs import get_arch
    from repro.core.explorer import execute_frontier, render_executed
    from repro.core.planner import ArchStats, plan, render_plans

    ap = argparse.ArgumentParser(prog="repro-explore", description=__doc__)
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--topk", type=int, default=2)
    ap.add_argument("--no-execute", action="store_true",
                    help="skip the (host-speed) interpret-mode Pallas runs")
    args = ap.parse_args(argv)

    print("=" * 72)
    print("1) The paper's case study: LBM on the Stratix V model")
    print("=" * 72)
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    print(sweep.table(k=10))
    print()
    print("Pareto frontier (max throughput, max perf/W, min resources):")
    print(sweep.table(frontier_only=True))
    best = sweep.best("perf_per_watt")
    print(f"-> best configuration: (n, m) = ({best.n}, {best.m})  "
          f"[paper §III: (1, 4)]")

    print()
    print("=" * 72)
    print("2) Hardware adaptation: temporal blocking on TPU v5e")
    print("=" * 72)
    tsweep = ex.sweep_tpu()
    print(tsweep.table(k=8))
    print()
    print("TPU Pareto frontier:")
    print(tsweep.table(frontier_only=True, k=6))

    if not args.no_execute:
        print()
        print("=" * 72)
        print(f"3) Model -> measurement: top-{args.topk} frontier points "
              f"through the Pallas kernel (interpret mode, 64x128)")
        print("=" * 72)
        mex = lbm.LBMSimulation(lbm.LBMProblem(64, 128, mode="wrap")).explorer()
        msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8))
        f0, attr, _ = lbm.taylor_green_init(64, 128)
        runs = execute_frontier(msweep, f0, attr, one_tau=1 / 0.8,
                                k=args.topk, interpret=True)
        print(render_executed(runs))

        print()
        print("=" * 72)
        print("3b) Any SPD core on the frontier: 2-D diffusion through the")
        print("    generic SPD->Pallas codegen (docs/pipeline.md, 64x128)")
        print("=" * 72)
        dsim = dif.DiffusionSimulation(64, 128, alpha=0.2)
        dex = dsim.explorer()
        dsweep = dex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8))
        u0, _ = dif.sine_init(64, 128)
        druns = dex.execute_frontier(dsweep, dsim.state(u0), (dsim.alpha,),
                                     k=args.topk, interpret=True)
        print(render_executed(druns))
        halo = dsim.kernel.summary
        print(f"(inferred stencil: {len(halo.offsets)} offsets, "
              f"halo = {halo.halo_y} row/step — no hand-written kernel)")

    print()
    print("=" * 72)
    print(f"4) The same trade on an LM fleet: {args.arch} on "
          f"{args.chips} chips")
    print("   (spatial n -> dp, temporal m -> pp, in-PE -> tp)")
    print("=" * 72)
    cfg = get_arch(args.arch)
    stats = ArchStats(
        name=cfg.name, params=cfg.num_params(),
        active_params=cfg.active_params(), n_layers=cfg.n_layers,
        d_model=cfg.d_model, global_batch=args.batch, seq_len=args.seq,
    )
    print(render_plans(plan(stats, args.chips), top=10))


if __name__ == "__main__":
    explore_main()
