"""repro: DSL-based design-space exploration for temporal x spatial
parallel stream computing (Sano 2015), as a multi-pod JAX/Pallas framework.

Subpackages: core (SPD DSL + DSE), apps (LBM), kernels (Pallas),
models (assigned architectures), parallel (sharding/PP/compression),
train, serve, configs, launch."""

__version__ = "1.0.0"
