"""Deterministic search test harness (docs/pipeline.md §study).

Shared by ``tests/test_search.py`` and ``tests/test_study.py`` (via the
``search_harness`` fixture in ``conftest.py``): a seeded fake timer that
derives wall times from the analytic model of the *legalized* plan, so
whole strategies — including the stochastic :class:`TPESearch` — run
without executing a kernel and without host-timing noise, and every
assertion about budgets, trial sequences, and resume behavior is exact.

The timer's optional ``noise`` is a pure function of ``(seed,
plan.key())`` — NOT of call order — so a resumed search that replays
some plans and re-times others still sees the identical wall for any
given plan. That is what makes the ISSUE 6 determinism assertions
(same seed ⇒ same trial sequence; resume ⇒ zero re-measurement) sharp
rather than statistical.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from pathlib import Path

from repro.core.dse import StreamWorkload, TPUModel
from repro.core.explorer import Explorer
from repro.core.search import RunPlan

H, W = 64, 64

#: A light synthetic workload on a 64x64 grid: every (block_h, m) lattice
#: point below legalizes to a distinct concrete plan (h = 64 has many
#: divisors), so candidate counts are easy to reason about.
TOY = StreamWorkload("toy", 8, 2, 2, 50, 40_000, H * W, grid_w=W, halo=1)

#: The CI measurement lattice shape (benchmarks/dse_sweep.py uses the
#: same bh/m values on its 256-row grid).
BH_VALUES = (8, 16, 32, 64)
M_VALUES = (1, 2, 4, 8)


def plan_noise(seed: int, key: tuple, scale: float) -> float:
    """Deterministic multiplicative jitter in [1-scale, 1+scale].

    A pure function of (seed, plan key): the same plan always gets the
    same jitter within a seed, so measured rankings are stable across
    interrupted/resumed searches — and different across seeds, which is
    what the model-vs-measurement disagreement tests need.
    """
    if not scale:
        return 1.0
    digest = hashlib.sha256(
        f"{seed}:{key}".encode("utf-8")
    ).digest()
    u = int.from_bytes(digest[:8], "big") / float(1 << 64)  # [0, 1)
    return 1.0 + scale * (2.0 * u - 1.0)


class ModelTimer:
    """Deterministic fake timer: wall time from the analytic model.

    measured_gflops then equals the model's prediction for the
    *legalized* plan, so strategy decisions follow the model ranking
    exactly — unless a plan is listed in ``boost``, which divides its
    wall time (the "model mis-ranks this point" scenario), or ``noise``
    is set, which applies :func:`plan_noise` jitter keyed by (seed,
    plan). Every live timing is recorded in ``calls``.
    """

    def __init__(self, workload=TOY, h=H, w=W, boost=(),
                 noise: float = 0.0, seed: int = 0):
        self.model = TPUModel()
        self.workload, self.h, self.w = workload, h, w
        self.boost = dict(boost)  # (block_h, m, d) -> speedup factor
        self.noise = float(noise)
        self.seed = int(seed)
        self.calls: list[RunPlan] = []

    def __call__(self, plan, run, reps, warmup):
        self.calls.append(plan)
        pred = self.model.evaluate(
            self.workload, plan.block_h, plan.m, d=plan.d,
            double_buffer=plan.double_buffer, b=getattr(plan, "b", 1),
        ).sustained_gflops
        sites = self.h * self.w * plan.steps * getattr(plan, "b", 1)
        wall = sites * self.workload.flops_per_elem / (pred * 1e9)
        wall *= plan_noise(self.seed, plan.key(), self.noise)
        return wall / self.boost.get((plan.block_h, plan.m, plan.d), 1.0)


def _rf(nsteps, m, block_h, d, double_buffer=True):
    return lambda: None  # never called: the fake timer ignores `run`


@dataclass
class SearchHarness:
    """One deterministic search context: explorer + timer + study dir.

    ``search`` defaults every measurement knob to the deterministic
    path (fake-timer back end, no calibration probes, no persistent
    cache) so tests only spell what they assert about.
    """

    study_dir: Path
    workload: StreamWorkload = TOY
    h: int = H
    w: int = W
    seed: int = 0
    explorer: Explorer = None
    _timers: list = field(default_factory=list)

    def __post_init__(self):
        if self.explorer is None:
            self.explorer = Explorer(self.workload)

    def sweep(self, bh_values=BH_VALUES, m_values=M_VALUES, d_values=(1,)):
        return self.explorer.sweep_tpu(
            bh_values=bh_values, m_values=m_values, d_values=d_values
        )

    def timer(self, boost=(), noise: float = 0.0) -> ModelTimer:
        t = ModelTimer(self.workload, self.h, self.w, boost=boost,
                       noise=noise, seed=self.seed)
        self._timers.append(t)
        return t

    def search(self, sweep, timer=None, **kw):
        if timer is None and "timer" not in kw:
            timer = self.timer()
        kw.setdefault("run_factory", _rf)
        kw.setdefault("grid_shape", (self.h, self.w))
        kw.setdefault("calibrate", False)
        kw.setdefault("cache", False)
        if kw.get("study") is not None:
            kw.setdefault("study_dir", str(self.study_dir))
            kw.setdefault("cache_tag", self.workload.name)
        return self.explorer.search(sweep, timer=timer, **kw)
