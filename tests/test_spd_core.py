"""SPD parser + compiler + transform tests (paper Figs. 3-5 examples)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.core import (
    Registry,
    SPDCompileError,
    SPDParseError,
    parse_spd,
    spatial_duplicate,
    temporal_cascade,
)
from repro.core.dfg import expr_depth, expr_op_census
from repro.core.spd import parse_formula

# The paper's Fig. 4 source, verbatim in structure (Eqs. 5-9).
FIG4 = """
Name  core;                         # name of this core
Main_In  {main_i::x1,x2,x3,x4};     # main stream in
Main_Out {main_o::z1,z2};           # main stream out
Brch_In  {brch_i::bin1};            # branch inputs
Brch_Out {brch_o::bout1};           # branch outputs

Param cnst = 123.456;               # define parameter
EQU Node1, t1 = x1 * x2;            # eq (5) (Node1)
EQU Node2, t2 = x3 + x4;            # eq (6) (Node2)
EQU Node3, z1 = t1 - t2 * bin1;     # eq (7) (Node3)
EQU Node4, z2 = t1 / t2 + cnst;     # eq (8) (Node4)
DRCT (bout1) = (t2);                # port connection
"""


@pytest.fixture
def fig4_compiled():
    reg = Registry()
    return reg.compile(parse_spd(FIG4))


def _fig4_oracle(x1, x2, x3, x4, bin1):
    t1 = x1 * x2
    t2 = x3 + x4
    return t1 - t2 * bin1, t1 / t2 + np.float32(123.456), t2


def test_fig4_parse(fig4_compiled):
    core = fig4_compiled.core
    assert core.name == "core"
    assert core.main_input_ports() == ["x1", "x2", "x3", "x4"]
    assert core.main_output_ports() == ["z1", "z2"]
    assert core.brch_input_ports() == ["bin1"]
    assert core.brch_output_ports() == ["bout1"]
    assert core.params["cnst"] == pytest.approx(123.456)
    assert len(core.nodes) == 4


def test_fig4_semantics(fig4_compiled):
    rng = np.random.default_rng(0)
    T = 64
    x = {k: rng.standard_normal(T).astype(np.float32) for k in "abcd"}
    bin1 = rng.standard_normal(T).astype(np.float32)
    x3 = np.abs(x["c"]) + 1.0  # keep divisor away from 0
    x4 = np.abs(x["d"]) + 1.0
    main, brch = fig4_compiled(
        {"x1": x["a"], "x2": x["b"], "x3": x3, "x4": x4}, {"bin1": bin1}
    )
    z1, z2, bout1 = _fig4_oracle(x["a"], x["b"], x3, x4, bin1)
    np.testing.assert_allclose(main["z1"], z1, rtol=1e-6)
    np.testing.assert_allclose(main["z2"], z2, rtol=1e-6)
    np.testing.assert_allclose(brch["bout1"], bout1, rtol=1e-6)


def test_fig4_hardware_report(fig4_compiled):
    rep = fig4_compiled.hardware_report
    # Ops: mul(N1), add(N2), sub+mul(N3), div+add(N4) = 3 add, 2 mul, 1 div
    assert rep.census == {"add": 3, "mul": 2, "div": 1}
    assert rep.flops == 6
    assert rep.depth > 0
    assert rep.stream_in_words == 4
    assert rep.stream_out_words == 2


def test_fig5_hierarchy():
    """The paper's Fig. 5: three module calls + one EQU at a higher level."""
    reg = Registry()
    inner = reg.compile(parse_spd("""
        Name core;
        Main_In {main_i::a,b};
        Main_Out {main_o::p,q};
        EQU N1, p = a + b;
        EQU N2, q = a * b;
    """))
    outer = reg.compile(parse_spd("""
        Name Array;
        Main_In {main_i::i1,i2,i3,i4};
        Main_Out {main_o::o1,o2,o3};
        HDL Node_a, 0, (t1,t2) = core(i1,i2);
        HDL Node_b, 0, (t3,t4) = core(i3,i4);
        HDL Node_c, 0, (o1,o2) = core(t1,t3);
        EQU Node_d, o3 = t2 * t4;
    """))
    x = [jnp.arange(8, dtype=jnp.float32) + k for k in range(4)]
    main, _ = outer({"i1": x[0], "i2": x[1], "i3": x[2], "i4": x[3]})
    np.testing.assert_allclose(main["o1"], (x[0] + x[1]) + (x[2] + x[3]))
    np.testing.assert_allclose(main["o2"], (x[0] + x[1]) * (x[2] + x[3]))
    np.testing.assert_allclose(main["o3"], (x[0] * x[1]) * (x[2] * x[3]))
    # outer depth >= inner depth twice (chained a->c) and census sums
    assert outer.hardware_report.depth >= 2 * inner.hardware_report.depth
    assert outer.census == {"add": 3, "mul": 4}


def test_temporal_cascade_equals_repeated_application():
    reg = Registry()
    pe = reg.compile(parse_spd("""
        Name PE;
        Main_In {mi::u,v};
        Main_Out {mo::u2,v2};
        Param k = 0.5;
        EQU N1, u2 = u + k * ( v - u );
        EQU N2, v2 = v - k * ( v - u );
    """))
    casc = temporal_cascade(pe, 4)
    rng = np.random.default_rng(1)
    u = rng.standard_normal(32).astype(np.float32)
    v = rng.standard_normal(32).astype(np.float32)
    got, _ = casc({"i_u2": u, "i_v2": v} if False else dict(zip(
        casc.core.main_input_ports(), [u, v])))
    uu, vv = u, v
    for _ in range(4):
        m, _ = pe({"u": uu, "v": vv})
        uu, vv = np.asarray(m["u2"]), np.asarray(m["v2"])
    outs = list(got.values())
    np.testing.assert_allclose(outs[0], uu, rtol=1e-5)
    np.testing.assert_allclose(outs[1], vv, rtol=1e-5)
    # depth multiplies, flops multiply (paper: m x d, m x NFlops)
    assert casc.hardware_report.depth == 4 * pe.hardware_report.depth
    assert casc.flops == 4 * pe.flops


def test_spatial_duplicate_lanes():
    reg = Registry()
    pe = reg.compile(parse_spd("""
        Name PE;
        Main_In {mi::x};
        Main_Out {mo::y};
        EQU N1, y = x * x + 1.0;
    """))
    dup = spatial_duplicate(pe, 4)
    assert len(dup.core.main_input_ports()) == 4
    x = np.arange(16, dtype=np.float32)
    lanes = [x[j::4] for j in range(4)]
    main, _ = dup(dict(zip(dup.core.main_input_ports(), lanes)))
    for j, out in enumerate(main.values()):
        np.testing.assert_allclose(out, lanes[j] ** 2 + 1.0)
    assert dup.flops == 4 * pe.flops
    assert dup.hardware_report.depth == pe.hardware_report.depth


def test_spatial_duplicate_rejects_stateful():
    reg = Registry()
    pe = reg.compile(parse_spd("""
        Name PE;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL D1, 0, (y) = Delay(x), 3;
    """))
    with pytest.raises(SPDCompileError):
        spatial_duplicate(pe, 2)


def test_library_modules():
    reg = Registry()
    c = reg.compile(parse_spd("""
        Name LibTest;
        Main_In {mi::x,sel,a,b};
        Main_Out {mo::xd,xf,m,cmp};
        HDL D1, 0, (xd) = Delay(x), 2;
        HDL F1, 0, (xf) = StreamForward(x), 1;
        HDL M1, 0, (m) = SyncMux(sel,a,b);
        HDL C1, 0, (cmp) = Comparator(a,b), op=gt;
    """))
    x = jnp.arange(6, dtype=jnp.float32)
    sel = jnp.array([1, 0, 1, 0, 1, 0], jnp.float32)
    a = jnp.ones(6, jnp.float32) * 5
    b = jnp.arange(6, dtype=jnp.float32)
    main, _ = c({"x": x, "sel": sel, "a": a, "b": b})
    np.testing.assert_allclose(main["xd"], [0, 0, 0, 1, 2, 3])
    np.testing.assert_allclose(main["xf"], [1, 2, 3, 4, 5, 0])
    np.testing.assert_allclose(main["m"], [5, 1, 5, 3, 5, 5])
    np.testing.assert_allclose(main["cmp"], [1, 1, 1, 1, 1, 0])


# ---------------- formula parser properties ----------------


def test_formula_precedence():
    e = parse_formula("a + b * c")
    from repro.core.dfg import Bin

    assert isinstance(e, Bin) and e.op == "+"
    assert isinstance(e.rhs, Bin) and e.rhs.op == "*"


def test_formula_errors():
    with pytest.raises(SPDParseError):
        parse_formula("a + ")
    with pytest.raises(SPDParseError):
        parse_formula("foo(a)")  # unknown function
    with pytest.raises(SPDParseError):
        parse_spd("Main_In {m::x};")  # missing Name


@st.composite
def _rand_expr(draw, depth=0):
    if depth > 3 or draw(st.booleans()):
        if draw(st.booleans()):
            return draw(
                st.sampled_from(["va", "vb", "vc"])
            )
        return str(draw(st.floats(0.1, 9.9).map(lambda f: round(f, 3))))
    op = draw(st.sampled_from(["+", "-", "*", "/"]))
    l = draw(_rand_expr(depth=depth + 1))
    r = draw(_rand_expr(depth=depth + 1))
    return f"( {l} {op} {r} )"


@given(_rand_expr())
@settings(max_examples=50, deadline=None)
def test_formula_roundtrip_eval(src):
    """Parsed formulae evaluate identically to Python eval."""
    e = parse_formula(src)
    env = {"va": np.float32(1.5), "vb": np.float32(-2.25), "vc": np.float32(3.0)}
    try:
        expected = eval(src, {}, dict(env))
    except ZeroDivisionError:
        return
    from repro.core.compiler import eval_expr

    got = eval_expr(e, {k: jnp.float32(v) for k, v in env.items()})
    if np.isfinite(expected):
        np.testing.assert_allclose(np.asarray(got), np.float32(expected),
                                   rtol=2e-5, atol=1e-6)
    # depth/census never crash and are consistent
    assert expr_depth(e) >= 0
    assert all(v > 0 for v in expr_op_census(e).values())
