"""Explorer engine: batched sweeps, Pareto frontiers, and the
model -> Pallas-kernel measurement loop.

The load-bearing assertions (ISSUE 1 acceptance criteria):
* the FPGA Pareto sweep recovers the paper's best configuration (1, 4);
* batched evaluation agrees with the scalar model point-for-point;
* no point returned by ``frontier()`` is dominated by any feasible point.
"""

import numpy as np
import pytest

from repro.core.dse import FPGAModel, StreamWorkload, TPUModel
from repro.core.explorer import (
    DEFAULT_MAXIMIZE,
    DEFAULT_OBJECTIVES,
    Explorer,
    pareto_mask,
)
from repro.kernels.lbm_stream.ops import blocking_plan

# The paper's LBM pipeline (same literal as tests/test_dse.py).
LBM_W = StreamWorkload(
    name="lbm-x1",
    flops_per_elem=131,
    words_in=10,
    words_out=10,
    depth=855,
    buffer_bits=573_370 - 80_000,
    elems=720 * 300,
    grid_w=720,
)
LBM_CENSUS = {"add": 70, "mul": 60, "div": 1}

# A small family of synthetic workloads for property-style frontier checks:
# light/heavy compute, narrow/wide streams, shallow/deep pipelines.
WORKLOADS = [
    LBM_W,
    StreamWorkload("light", 16, 2, 2, 64, 40_000, 100_000, grid_w=500),
    StreamWorkload("wide-io", 200, 24, 24, 1200, 900_000, 720 * 300, grid_w=720),
    StreamWorkload("deep", 64, 6, 6, 4000, 200_000, 50_000, grid_w=250),
]


@pytest.fixture(scope="module")
def explorer():
    return Explorer(LBM_W, census=LBM_CENSUS)


# ----------------------- pareto_mask primitive -----------------------


def test_pareto_mask_hand_case():
    # (throughput up, cost down): c dominated by a; d dominated by b.
    pts = np.array([[10, 5], [8, 2], [9, 5], [7, 3]], dtype=float)
    mask = pareto_mask(pts, maximize=(True, False))
    assert mask.tolist() == [True, True, False, False]


def test_pareto_mask_duplicates_survive():
    pts = np.array([[1.0, 1.0], [1.0, 1.0]])
    assert pareto_mask(pts, maximize=(True, True)).all()


def test_pareto_mask_single_objective_is_argmax():
    v = np.array([3.0, 9.0, 9.0, 1.0])
    assert pareto_mask(v[:, None], maximize=(True,)).tolist() == [
        False, True, True, False,
    ]


def test_pareto_mask_excludes_non_finite_rows():
    """NaN compares False against everything, so a NaN row used to be
    'never dominated' and polluted the frontier; non-finite rows must be
    masked out up front — even an inf row that would dominate."""
    pts = np.array(
        [[np.nan, 1.0], [1.0, 2.0], [np.inf, 0.0], [2.0, 1.0]]
    )
    mask = pareto_mask(pts, maximize=(True, True))
    assert mask.tolist() == [False, True, False, True]
    assert not pareto_mask(np.full((3, 2), np.nan)).any()
    # all-finite behavior is unchanged
    ok = np.array([[1.0, 2.0], [2.0, 1.0], [0.5, 0.5]])
    assert pareto_mask(ok, maximize=(True, True)).tolist() == [
        True, True, False,
    ]


# ----------------------- batched == scalar -----------------------


def test_fpga_batched_matches_scalar_point_for_point(explorer):
    sweep = explorer.sweep_fpga(
        n_values=(1, 2, 3, 4, 6, 8), m_values=(1, 2, 3, 4, 6, 8)
    )
    model = FPGAModel()
    assert len(sweep) == 36
    for i in range(len(sweep)):
        n, m = int(sweep.data["n"][i]), int(sweep.data["m"][i])
        pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
        assert pt.feasible == bool(sweep.data["feasible"][i])
        for key, want in [
            ("peak_gflops", pt.peak_gflops),
            ("utilization", pt.utilization),
            ("sustained_gflops", pt.sustained_gflops),
            ("power_w", pt.power_w),
            ("perf_per_watt", pt.perf_per_watt),
            ("alms", pt.detail["alms"]),
            ("dsps", pt.detail["dsps"]),
            ("bram_bits", pt.detail["bram_bits"]),
            ("u_bw", pt.detail["u_bw"]),
            ("depth", pt.detail["depth"]),
        ]:
            assert sweep.data[key][i] == pytest.approx(want, rel=1e-12), (
                key, n, m,
            )


def test_fpga_batched_matches_scalar_non_overlapped(explorer):
    sweep = explorer.sweep_fpga(
        n_values=(1, 2), m_values=(1, 8), overlapped_passes=False
    )
    model = FPGAModel()
    for i in range(len(sweep)):
        n, m = int(sweep.data["n"][i]), int(sweep.data["m"][i])
        pt = model.evaluate(LBM_W, n, m, LBM_CENSUS, overlapped_passes=False)
        assert sweep.data["utilization"][i] == pytest.approx(
            pt.utilization, rel=1e-12
        )
        # point() materialization must thread the flag through too
        assert sweep.point(i).utilization == pytest.approx(
            pt.utilization, rel=1e-12
        )


def test_tpu_batched_matches_scalar_point_for_point(explorer):
    sweep = explorer.sweep_tpu(
        bh_values=(8, 32, 256, 4096),
        m_values=(1, 4, 64),
        d_values=(1, 4),
    )
    model = TPUModel()
    assert len(sweep) == 24
    for i in range(len(sweep)):
        bh = int(sweep.data["block_rows"][i])
        m = int(sweep.data["m"][i])
        chips = int(sweep.data["n"][i])
        pt = model.evaluate(LBM_W, bh, m, d=chips)
        assert pt.feasible == bool(sweep.data["feasible"][i])
        for key, want in [
            ("peak_gflops", pt.peak_gflops),
            ("utilization", pt.utilization),
            ("sustained_gflops", pt.sustained_gflops),
            ("power_w", pt.power_w),
            ("perf_per_watt", pt.perf_per_watt),
            ("vmem_bytes", pt.detail["vmem_bytes"]),
            ("t_compute_s", pt.detail["t_compute_s"]),
            ("t_memory_s", pt.detail["t_memory_s"]),
            ("t_collective_s", pt.detail["t_collective_s"]),
            ("arithmetic_intensity", pt.detail["arithmetic_intensity"]),
        ]:
            assert sweep.data[key][i] == pytest.approx(want, rel=1e-12), (
                key, bh, m, chips,
            )
        # one spelling for the binding resource, scalar ≡ batch verbatim
        bound = str(sweep.data["bound"][i])
        assert bound.endswith("-bound")
        assert bound in pt.limits


# ----------------------- frontier properties -----------------------


def _dominates(a, b, maximize) -> bool:
    better_eq = all(
        (x >= y) if mx else (x <= y) for x, y, mx in zip(a, b, maximize)
    )
    strictly = any(
        (x > y) if mx else (x < y) for x, y, mx in zip(a, b, maximize)
    )
    return better_eq and strictly


@pytest.mark.parametrize("w", WORKLOADS, ids=lambda w: w.name)
@pytest.mark.parametrize("target", ["fpga", "tpu"])
def test_no_frontier_point_is_dominated(w, target):
    ex = Explorer(w, census=LBM_CENSUS if w is LBM_W else None)
    sweep = ex.sweep(target)
    mask = sweep.pareto_mask()
    X = sweep.metrics(DEFAULT_OBJECTIVES)
    feas = sweep.feasible
    for i in np.flatnonzero(mask):
        for j in np.flatnonzero(feas):
            assert not _dominates(X[j], X[i], DEFAULT_MAXIMIZE), (
                f"frontier point {i} dominated by {j}"
            )


@pytest.mark.parametrize("w", WORKLOADS, ids=lambda w: w.name)
def test_every_off_frontier_point_is_dominated(w):
    """Completeness: a feasible point off the frontier has a dominator."""
    ex = Explorer(w, census=LBM_CENSUS if w is LBM_W else None)
    sweep = ex.sweep_fpga()
    mask = sweep.pareto_mask()
    X = sweep.metrics(DEFAULT_OBJECTIVES)
    feas = sweep.feasible
    for i in np.flatnonzero(feas & ~mask):
        assert any(
            _dominates(X[j], X[i], DEFAULT_MAXIMIZE)
            for j in np.flatnonzero(feas)
        ), f"off-frontier point {i} has no dominator"


def test_fpga_frontier_recovers_paper_winner(explorer):
    """The paper's 'best among them': (n, m) = (1, 4) on the Stratix V."""
    sweep = explorer.sweep_fpga(
        n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8)
    )
    frontier_keys = {p.key() for p in sweep.frontier()}
    assert (1, 4) in frontier_keys
    best = sweep.best("perf_per_watt")
    assert best.key() == (1, 4)
    assert best.perf_per_watt == pytest.approx(2.416, rel=0.03)
    assert sweep.best("sustained_gflops").key() == (1, 4)


def test_frontier_sorted_and_feasible(explorer):
    pts = explorer.sweep_fpga().frontier()
    assert all(p.feasible for p in pts)
    sus = [p.sustained_gflops for p in pts]
    assert sus == sorted(sus, reverse=True)


def test_tpu_frontier_prefers_temporal_blocking(explorer):
    """m=1 (no temporal reuse) is memory-bound and never frontier-best."""
    sweep = explorer.sweep_tpu(d_values=(1,))
    best = sweep.best("sustained_gflops")
    assert best.m > 1
    assert "compute-bound" in best.limits


def test_deprecated_spellings_are_gone(explorer):
    """The PR-3-era deprecated spellings (chip_values on the sweep,
    n_chips on the model, the module-level execute_frontier wrapper)
    have completed their deprecation cycle and are removed."""
    with pytest.raises(TypeError, match="chip_values"):
        explorer.sweep_tpu(bh_values=(8,), m_values=(1,), chip_values=(1, 2))
    with pytest.raises(TypeError, match="n_chips"):
        TPUModel().evaluate(LBM_W, 8, 1, n_chips=2)
    import repro.core.explorer as exp_mod

    assert not hasattr(exp_mod, "execute_frontier")


def test_tpu_default_sweep_enumerates_device_axis(explorer):
    """The default TPU lattice carries the device axis d ∈ {1, 2, 4} and
    scaling out stays on the frontier (more chips, more throughput)."""
    sweep = explorer.sweep_tpu()
    assert set(np.unique(sweep.data["d"])) == {1, 2, 4}
    np.testing.assert_array_equal(sweep.data["d"], sweep.data["n"])
    frontier = sweep.frontier()
    assert any(p.n > 1 for p in frontier)
    best = sweep.best("sustained_gflops")
    assert best.n == 4  # throughput scales with the device axis
    assert best.m > 1  # ...but temporal blocking still pays


def test_tpu_sweep_point_threads_d_and_scalar_kwargs(explorer):
    """Sweep.point must re-materialize TPU points via the d= spelling
    and thread scalar kwargs (double_buffer) like the FPGA branch does
    — it used to drop both, silently diverging from the batch arrays."""
    sweep = explorer.sweep_tpu(
        bh_values=(8, 16), m_values=(2,), d_values=(1, 2),
        double_buffer=False,
    )
    assert sweep.scalar_kwargs == {"double_buffer": False}
    model = TPUModel()
    for i in range(len(sweep)):
        pt = sweep.point(i)
        d = int(sweep.data["d"][i])
        assert pt.n == d and pt.detail["d"] == d  # device axis preserved
        want = model.evaluate(
            LBM_W,
            int(sweep.data["block_rows"][i]),
            int(sweep.data["m"][i]),
            d=d,
            double_buffer=False,
        )
        # double_buffer reached both the batch arrays and the scalar path
        assert pt.detail["vmem_bytes"] == want.detail["vmem_bytes"]
        assert sweep.data["vmem_bytes"][i] == want.detail["vmem_bytes"]


def test_top_returns_k_best_feasible(explorer):
    sweep = explorer.sweep_fpga()
    top2 = sweep.top(2, key="perf_per_watt")
    assert len(top2) == 2
    assert top2[0].perf_per_watt >= top2[1].perf_per_watt
    assert all(p.feasible for p in top2)


# ----------------------- compile -> explore plumbing -----------------------


def test_explorer_from_compiled_core():
    from repro.apps import lbm

    sim = lbm.LBMSimulation(lbm.LBMProblem(32, 64, mode="wrap"))
    w = sim.stream_workload()
    assert w.elems == 32 * 64 and w.grid_w == 64
    assert w.flops_per_elem == sim.hardware_report.flops
    ex = sim.explorer()
    assert ex.census == sim.hardware_report.census
    best = ex.sweep_fpga().best("perf_per_watt")
    assert best.feasible


def test_hardware_report_workload_roundtrip():
    from repro.apps import lbm

    sim = lbm.LBMSimulation(lbm.LBMProblem(32, 64, mode="wrap"))
    w1 = sim.hardware_report.workload(elems=2048, grid_w=64)
    w2 = StreamWorkload.from_report(sim.hardware_report, elems=2048, grid_w=64)
    assert w1 == w2


# ----------------------- blocking legalization -----------------------


def test_blocking_plan_legalizes():
    assert blocking_plan(64, 64, 4) == (64, 4, True)
    assert blocking_plan(64, 256, 4) == (64, 4, True)  # clamp to grid
    assert blocking_plan(64, 24, 4) == (16, 4, True)  # nearest divisor below
    assert blocking_plan(48, 8, 12) == (12, 12, True)  # m forces block up
    bh, m, _ = blocking_plan(30, 7, 4)
    assert 30 % bh == 0 and m <= bh


# ----------------------- execution loop (interpret mode) -----------------------


def test_run_factory_path_gets_vmem_stripe_check(explorer):
    """Regression (ISSUE 4): the custom run_factory path used to call
    resolve_run_plan with width=0, words=0, silently skipping the VMEM
    stripe clamp the codegen path gets. On a 30000-wide grid the
    (64, 8) stripe is over budget, so both paths must legalize it down
    identically."""
    from repro.core.legalize import resolve_run_plan, stripe_vmem_bytes

    sweep = explorer.sweep_tpu(
        bh_values=(64,), m_values=(8,), d_values=(1,)
    )
    seen = []

    def rf(nsteps, m, block_h, d, double_buffer=True):
        seen.append((block_h, m, nsteps, d))
        return lambda: None

    h, w = 256, 30_000
    runs = explorer.__class__(sweep.workload).execute_frontier(
        sweep, run_factory=rf, grid_shape=(h, w), k=1, reps=1,
        calibrate=False,
    )
    assert len(runs) == 1 and seen
    r = runs[0]
    assert r.block_h < 64  # the over-budget stripe was clamped
    from repro.core.legalize import VMEM_BYTES

    assert stripe_vmem_bytes(
        r.block_h, r.m, w, sweep.workload.words_in, sweep.workload.halo
    ) <= VMEM_BYTES
    want = resolve_run_plan(
        h, r.point, None, halo=sweep.workload.halo, width=w,
        words=sweep.workload.words_in, d=1,
    )
    # identical to codegen path (incl. the buffer protocol)
    assert (r.block_h, r.m, r.steps, r.double_buffer) == want
    assert seen[-1] == (r.block_h, r.m, r.steps, 1)


def test_execute_frontier_closes_the_loop_hand_written_kernel():
    """The hand-written lbm_stream kernel plugs into the one timing path
    via run_factory (the former module-level wrapper's job, now a
    caller-side four-liner). Single-device only: d > 1 plans return
    None and are skipped."""
    from repro.apps import lbm
    from repro.kernels.lbm_stream.ops import lbm_run_blocked

    sim = lbm.LBMSimulation(lbm.LBMProblem(16, 32, mode="wrap"))
    sweep = sim.explorer().sweep_tpu(bh_values=(8, 16), m_values=(1, 2))
    f, attr, _ = lbm.taylor_green_init(16, 32)

    def run_factory(nsteps, m, block_h, d, double_buffer=True):
        if d != 1:
            return None  # the hand-written kernel has no sharded form
        return lambda: lbm_run_blocked(
            f, attr, 1 / 0.8, 0.0,
            steps=nsteps, m=m, block_h=block_h, interpret=True,
        )

    runs = Explorer(sweep.workload).execute_frontier(
        sweep, k=2, interpret=True, run_factory=run_factory,
        grid_shape=(16, 32), cache_tag="lbm_stream",
    )
    assert 1 <= len(runs) <= 2
    for r in runs:
        assert r.d == 1
        assert 16 % r.block_h == 0 and r.m <= r.block_h
        assert r.wall_s > 0 and r.measured_mlups > 0
        assert np.isfinite(r.rel_error)
        assert r.predicted_gflops == pytest.approx(
            r.point.sustained_gflops
        )


def test_execute_frontier_rejects_fpga_sweep(explorer):
    import jax.numpy as jnp

    sweep = explorer.sweep_fpga()
    dummy = jnp.zeros((9, 8, 16), jnp.float32)
    with pytest.raises(ValueError, match="TPU sweep"):
        explorer.execute_frontier(sweep, dummy, dummy[0])


def test_lbm_run_for_point_matches_reference():
    from repro.apps import lbm
    from repro.kernels.lbm_stream.ops import (
        lbm_multistep_ref,
        lbm_run_for_point,
    )

    sim = lbm.LBMSimulation(lbm.LBMProblem(16, 32, mode="wrap"))
    pt = sim.explorer().sweep_tpu(
        bh_values=(8, 16), m_values=(2, 4)
    ).best("sustained_gflops")
    f, attr, _ = lbm.taylor_green_init(16, 32)
    out, (bh, m) = lbm_run_for_point(f, attr, 1 / 0.8, pt, interpret=True)
    assert 16 % bh == 0 and m == pt.m
    want = lbm_multistep_ref(f, attr, 1 / 0.8, 0.0, m=m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)
