"""DSE model validation against the paper's own measurements (Table III)."""

import numpy as np
import pytest

from repro.core.dse import (
    DesignPoint,
    FPGAModel,
    FPGATarget,
    StreamWorkload,
    TABLE3_MEASURED,
    TPUModel,
    TPUTarget,
)
from repro.core.planner import ArchStats, evaluate_plan, plan

# The paper's LBM pipeline: 131 FP ops (70 add / 60 mul / 1 div), 10-word
# stream each way (9 distributions + attribute), depth 855, 720x300 grid.
LBM_W = StreamWorkload(
    name="lbm-x1",
    flops_per_elem=131,
    words_in=10,
    words_out=10,
    depth=855,
    buffer_bits=573_370 - 80_000,  # PE buffer (BRAM minus pipeline FIFOs)
    elems=720 * 300,
    grid_w=720,
)
LBM_CENSUS = {"add": 70, "mul": 60, "div": 1}


@pytest.fixture(scope="module")
def model():
    return FPGAModel()


@pytest.mark.parametrize("nm", sorted(TABLE3_MEASURED))
def test_table3_sustained_performance(model, nm):
    """Sustained GFlop/s must match the paper's Table III within 1%."""
    n, m = nm
    meas = TABLE3_MEASURED[nm]
    pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
    assert pt.sustained_gflops == pytest.approx(meas[5], rel=0.01)


@pytest.mark.parametrize("nm", sorted(TABLE3_MEASURED))
def test_table3_utilization(model, nm):
    n, m = nm
    meas = TABLE3_MEASURED[nm]
    pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
    assert pt.utilization == pytest.approx(meas[4], abs=0.005)


@pytest.mark.parametrize("nm", sorted(TABLE3_MEASURED))
def test_table3_dsps_exact(model, nm):
    n, m = nm
    meas = TABLE3_MEASURED[nm]
    pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
    assert pt.detail["dsps"] == meas[3]


@pytest.mark.parametrize("nm", sorted(TABLE3_MEASURED))
def test_table3_alms_within_20pct(model, nm):
    n, m = nm
    meas = TABLE3_MEASURED[nm]
    pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
    # core ALMs = total - SoC share; model should land within 20%
    assert pt.detail["alms"] - model.target.soc_alms == pytest.approx(
        meas[0], rel=0.20
    )


def test_table3_power_fit(model):
    """The fitted power model explains the six measurements well."""
    assert model.power_r2 > 0.95
    for (n, m), meas in TABLE3_MEASURED.items():
        w = model.power_w(n, m, meas[5])
        assert w == pytest.approx(meas[6], rel=0.06)


def test_peak_is_eq10(model):
    # Eq. (10): P = n*m*131*0.18 GFlop/s; (1,4) -> 94.32
    pt = model.evaluate(LBM_W, 1, 4, LBM_CENSUS)
    assert pt.peak_gflops == pytest.approx(94.32, rel=1e-6)


def test_best_config_is_1_4(model):
    """The paper's headline: (n,m)=(1,4) wins on perf and perf/W."""
    pts = model.explore(LBM_W, census=LBM_CENSUS)
    feasible = [p for p in pts if p.feasible]
    best = max(feasible, key=lambda p: p.perf_per_watt)
    assert best.key() == (1, 4)
    assert best.perf_per_watt == pytest.approx(2.416, rel=0.03)
    best_perf = max(feasible, key=lambda p: p.sustained_gflops)
    assert best_perf.key() == (1, 4)
    assert best_perf.sustained_gflops == pytest.approx(94.2, rel=0.01)


def test_nm8_infeasible_on_dsps(model):
    """nm=8 would need 384 DSPs > 256 — matches the paper stopping at nm=4."""
    for n, m in [(1, 8), (2, 4), (8, 1), (4, 2)]:
        pt = model.evaluate(LBM_W, n, m, LBM_CENSUS)
        assert not pt.feasible and any("DSP" in l for l in pt.limits)


def test_bandwidth_bound_only_when_n_gt_1(model):
    for n, m in [(1, 1), (1, 4)]:
        assert "bandwidth-bound" not in model.evaluate(LBM_W, n, m).limits
    for n, m in [(2, 1), (4, 1)]:
        assert "bandwidth-bound" in model.evaluate(LBM_W, n, m).limits


def test_short_stream_pipeline_penalty(model):
    """Non-overlapped short streams suffer the prologue/epilogue loss."""
    short = StreamWorkload(
        name="short", flops_per_elem=131, words_in=10, words_out=10,
        depth=855, buffer_bits=100_000, elems=2_000, grid_w=100,
    )
    u1 = model.evaluate(short, 1, 1, overlapped_passes=False).utilization
    u8 = model.evaluate(short, 1, 8, overlapped_passes=False).utilization
    assert u8 < u1 < 1.0
    assert u8 == pytest.approx(2_000 / (2_000 + 8 * 855), rel=1e-6)


# ----------------------- TPU model -----------------------


def test_tpu_temporal_blocking_raises_intensity():
    m1 = TPUModel().evaluate(LBM_W, bh=64, m=1)
    m8 = TPUModel().evaluate(LBM_W, bh=64, m=8)
    ai1 = m1.detail["arithmetic_intensity"]
    ai8 = m8.detail["arithmetic_intensity"]
    assert ai8 == pytest.approx(8 * ai1, rel=1e-6)
    # memory-bound at m=1; more sustained at m=8
    assert "memory-bound" in m1.limits
    assert m8.sustained_gflops > 2 * m1.sustained_gflops


def test_tpu_vmem_constraint():
    pts = TPUModel().explore(LBM_W, bh_values=(4096,), m_values=(64,))
    assert not pts[0].feasible
    assert any("VMEM" in l for l in pts[0].limits)


def test_tpu_best_point_is_compute_bound():
    best = TPUModel().explore(LBM_W)[0]
    assert best.feasible
    assert "compute-bound" in best.limits
    # and reaches a solid fraction of the VPU roof
    assert best.utilization > 0.5


# ----------------------- planner -----------------------

GRANITE = ArchStats(
    name="granite-34b", params=34e9, active_params=34e9, n_layers=88,
    d_model=6144, global_batch=256, seq_len=4096,
)


def test_planner_enumerates_factorizations():
    plans = plan(GRANITE, 256)
    assert {p.chips for p in plans} == {256}
    keys = {(p.dp, p.tp, p.pp) for p in plans}
    assert (16, 16, 1) in keys and (256, 1, 1) in keys


def test_planner_pure_dp_infeasible_for_34b():
    """34B params + adam states don't fit a 16GiB chip without sharding."""
    p = evaluate_plan(GRANITE, 256, 1, 1)
    assert not p.feasible  # weights alone = 68GB/chip


def test_planner_bubble_matches_formula():
    p = evaluate_plan(GRANITE, 8, 4, 8, microbatches=16)
    assert p.pipeline_util == pytest.approx(16 / (16 + 7))


def test_planner_dp_is_bandwidth_spatial():
    """More dp -> more gradient all-reduce time (the paper's spatial cost)."""
    t2 = evaluate_plan(GRANITE, 2, 16, 8).t_dp_allreduce
    t8 = evaluate_plan(GRANITE, 8, 16, 2).t_dp_allreduce
    assert t8 > t2 > 0


def test_planner_best_is_feasible_and_sane():
    best = plan(GRANITE, 256)[0]
    assert best.feasible
    assert best.tp >= 2 or best.pp >= 2  # pure-DP can't fit
