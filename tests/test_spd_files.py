"""The on-disk .spd sources (paper Figs. 6-11 artifacts) parse, compile,
and match the in-memory generators."""

import glob
import os

import pytest

from repro.core import Registry, parse_spd_file

SPD_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro", "apps", "spd",
)


def test_spd_files_exist():
    files = sorted(glob.glob(os.path.join(SPD_DIR, "*.spd")))
    names = {os.path.basename(f) for f in files}
    assert {"ulbm_calc.spd", "ulbm_trans2d_x1.spd", "ulbm_bndry.spd",
            "pe_x1.spd", "pe_x1_t2.spd", "pe_x1_t4.spd"} <= names


def test_spd_files_parse_and_compile():
    from repro.apps.lbm import _register_bndry_module

    reg = Registry()
    _register_bndry_module(reg)
    order = ["ulbm_calc.spd", "ulbm_trans2d_x1.spd", "ulbm_bndry.spd",
             "pe_x1.spd", "pe_x1_t2.spd", "pe_x1_t4.spd"]
    for name in order:
        core = parse_spd_file(os.path.join(SPD_DIR, name))
        compiled = reg.compile(core)
        assert compiled.schedule.depth > 0


def test_spd_calc_file_has_131_ops():
    reg = Registry()
    calc = reg.compile(parse_spd_file(os.path.join(SPD_DIR, "ulbm_calc.spd")))
    assert calc.flops == 131


def test_cascade_files_scale_depth():
    from repro.apps.lbm import _register_bndry_module

    reg = Registry()
    _register_bndry_module(reg)
    for name in ["ulbm_calc.spd", "ulbm_trans2d_x1.spd", "ulbm_bndry.spd",
                 "pe_x1.spd", "pe_x1_t4.spd"]:
        reg.compile(parse_spd_file(os.path.join(SPD_DIR, name)))
    pe = reg._cores["PEx1"]
    t4 = reg._cores["PEx1_t4"]
    assert t4.schedule.depth == 4 * pe.schedule.depth
    assert t4.flops == 4 * pe.flops
