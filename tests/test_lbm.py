"""LBM application: physics validation + SPD-path equivalence (paper §III)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lbm
from repro.core.dse import FPGAModel, StreamWorkload


def test_collision_conserves_mass_momentum():
    rng = np.random.default_rng(0)
    f = jnp.asarray(
        rng.uniform(0.01, 0.2, size=(9, 16, 16)).astype(np.float32)
    )
    fc = lbm.collide(f, one_tau=1.0 / 0.8)
    rho0, ux0, uy0 = lbm.macroscopics(f)
    rho1, ux1, uy1 = lbm.macroscopics(fc)
    np.testing.assert_allclose(rho1, rho0, rtol=1e-5)
    np.testing.assert_allclose(ux1, ux0, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(uy1, uy0, rtol=1e-4, atol=1e-6)


def test_periodic_step_conserves_mass():
    f, attr, _ = lbm.taylor_green_init(32, 48)
    f2 = lbm.ref_run(f, attr, 1.0 / 0.8, steps=20)
    np.testing.assert_allclose(
        float(jnp.sum(f2)), float(jnp.sum(f)), rtol=1e-5
    )


def test_taylor_green_decay_matches_analytic():
    """Kinetic energy decays as exp(-2 nu k^2 t) — the physics gate."""
    h = w = 64
    tau = 0.8
    f, attr, ksq = lbm.taylor_green_init(h, w, u0=0.02)
    nu = lbm.viscosity(tau)
    e0 = lbm.tgv_kinetic_energy(f)
    steps = 200
    f2 = lbm.ref_run(f, attr, 1.0 / tau, steps=steps)
    e1 = lbm.tgv_kinetic_energy(f2)
    expected = e0 * math.exp(-2.0 * nu * ksq * steps)
    assert e1 == pytest.approx(expected, rel=0.02)


def test_couette_linear_profile():
    """Steady Couette flow between a static and a moving wall is linear."""
    h, w = 18, 8
    u_lid = 0.05
    f, attr = lbm.couette_init(h, w)
    f = lbm.ref_run(f, attr, 1.0 / 0.9, steps=4000, u_lid=u_lid, mode="wrap")
    _, ux, _ = lbm.macroscopics(f)
    prof = np.asarray(jnp.mean(ux, axis=1))[1:-1]  # fluid rows
    # walls sit half a cell outside the first/last fluid rows
    y = (np.arange(1, h - 1) - 0.5) / (h - 2)
    expected = u_lid * y
    np.testing.assert_allclose(prof, expected, atol=2.5e-3)


def test_cavity_smoke():
    f, attr = lbm.cavity_init(24, 24)
    f = lbm.ref_run(f, attr, 1.0 / 0.7, steps=300, u_lid=0.1, mode="zero")
    rho, ux, uy = lbm.macroscopics(f)
    assert np.isfinite(np.asarray(rho)).all()
    # lid drags the top fluid row in +x
    assert float(jnp.mean(ux[-2])) > 1e-3


# ----------------- SPD path == reference -----------------


def _spd_step(sim, f, attr):
    return sim._jitted(f, attr)


def test_spd_pe_equals_reference_periodic():
    prob = lbm.LBMProblem(16, 24, tau=0.8, mode="wrap")
    sim = lbm.LBMSimulation(prob)
    f, attr, _ = lbm.taylor_green_init(16, 24)
    got = _spd_step(sim, f, attr)
    want = lbm.ref_step(f, attr, prob.one_tau, mode="wrap")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=1e-7)


def test_spd_pe_equals_reference_walls():
    prob = lbm.LBMProblem(12, 10, tau=0.9, u_lid=0.07, mode="zero")
    sim = lbm.LBMSimulation(prob)
    f, attr = lbm.couette_init(12, 10)
    got, want = f, f
    for _ in range(5):
        got = _spd_step(sim, got, attr)
        want = lbm.ref_step(want, attr, prob.one_tau, prob.u_lid, mode="zero")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=1e-7)


def test_spd_bndry_variant_equals_hdl_variant():
    """The SPD-described boundary stage == the fixed-function HDL node."""
    prob = lbm.LBMProblem(10, 8, tau=0.9, u_lid=0.06, mode="zero")
    sim_h = lbm.LBMSimulation(prob, bndry="hdl")
    sim_s = lbm.LBMSimulation(prob, bndry="spd")
    f, attr = lbm.couette_init(10, 8)
    np.testing.assert_allclose(
        np.asarray(sim_h._jitted(f, attr)),
        np.asarray(sim_s._jitted(f, attr)),
        rtol=2e-5, atol=1e-7,
    )


def test_cascade_m_equals_m_steps():
    """Paper Figs. 10-12: m cascaded PEs == m sequential applications."""
    prob = lbm.LBMProblem(16, 16, tau=0.8, mode="wrap")
    sim1 = lbm.LBMSimulation(prob, m=1)
    sim4 = lbm.LBMSimulation(prob, m=4)
    f, attr, _ = lbm.taylor_green_init(16, 16)
    out4 = sim4.run(f, attr, 4)
    out1 = sim1.run(f, attr, 4)
    np.testing.assert_allclose(
        np.asarray(out4), np.asarray(out1), rtol=2e-5, atol=1e-7
    )
    # hardware model: depth and flops scale with m
    assert sim4.hardware_report.depth == 4 * sim1.hardware_report.depth
    assert sim4.hardware_report.flops == 4 * sim1.hardware_report.flops


def test_collision_census_is_131_flops():
    """The paper's Table IV: 131 FP operators per pipeline."""
    from repro.core import Registry, parse_spd

    reg = Registry()
    calc = reg.compile(parse_spd(lbm.calc_spd()))
    assert calc.flops == 131
    assert calc.census["div"] == 1
    assert calc.census["add"] + calc.census["mul"] == 130


def test_pe_workload_feeds_dse():
    """End-to-end: compiled PE -> StreamWorkload -> Table-III-scale numbers."""
    prob = lbm.LBMProblem(300, 720, mode="wrap")
    sim = lbm.LBMSimulation(prob)
    rep = sim.hardware_report
    w = StreamWorkload.from_report(rep, elems=720 * 300, grid_w=720)
    assert w.flops_per_elem == 131
    assert w.words_in == 10 and w.words_out == 10
    pt = FPGAModel().evaluate(w, 1, 4, rep.census)
    # the compiled PE reproduces the paper's winning configuration numbers
    assert pt.sustained_gflops == pytest.approx(94.2, rel=0.01)
    assert pt.feasible
