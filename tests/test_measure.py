"""Measure subsystem: timing harness, backend calibration, measurement
cache (docs/pipeline.md §measure, DESIGN.md §9).

The load-bearing assertions (ISSUE 4 acceptance criteria):

* the timing harness blocks *every* rep (the old loop synchronized only
  the final async dispatch, under-counting wall time) and is monotone in
  the amount of work timed;
* the measurement cache round-trips: an identical (core, grid, plan,
  backend) measurement is served from disk, any key ingredient change
  misses;
* calibration makes ``rel_error`` a model-fidelity signal: on the
  256×128 interpret-mode grid the calibrated error is |e| < 0.5 where
  the uncalibrated model-vs-interpreter diff is ≈ 1.0.
"""

import itertools
import time

import numpy as np
import pytest

from repro.core.dse import TPUModel, TPUTarget
from repro.core.measure import (
    BackendCalibration,
    MeasurementCache,
    core_fingerprint,
    measure_elementwise_gflops,
    measure_memory_bandwidth_gbs,
    measured_run,
    resolve_cache,
    time_run,
    timer_overhead,
)


def _spin(n: int) -> int:
    return sum(range(n))


# ----------------------- timing harness -----------------------


def test_time_run_validates_arguments():
    with pytest.raises(ValueError, match="reps"):
        time_run(lambda: None, reps=0)
    with pytest.raises(ValueError, match="warmup"):
        time_run(lambda: None, warmup=-1)


def test_time_run_monotone_in_work():
    ident = lambda r: r
    small = time_run(lambda: _spin(5_000), reps=3, warmup=1, block=ident)
    large = time_run(lambda: _spin(2_000_000), reps=3, warmup=1, block=ident)
    assert large.wall_s > small.wall_s
    assert small.wall_s >= 1e-9  # overhead-subtracted but floored
    assert len(small.times_s) == 3 and small.reps == 3


def test_time_run_blocks_every_rep():
    """Regression (ISSUE 4): the old loop dispatched ``reps`` async runs
    and blocked only the last, so overlapping dispatches under-counted
    wall time. Every rep must pay its own synchronization, inside the
    timed region."""

    blocked = []

    class Fut:  # simulates an async dispatch: work happens at block time
        pass

    def block(r):
        blocked.append(r)
        time.sleep(0.005)
        return r

    t = time_run(Fut, reps=3, warmup=1, block=block)
    assert len(blocked) == 4  # warmup + all three reps, not just the last
    assert all(dt >= 0.004 for dt in t.times_s)  # each rep paid the sync
    assert t.wall_s >= 0.004


def test_time_run_reports_median_not_mean():
    durations = itertools.chain([0.0, 0.001, 0.05, 0.001], itertools.repeat(0.0))

    def block(r):
        time.sleep(next(durations))
        return r

    t = time_run(lambda: None, reps=3, warmup=1, block=block)
    # sample ≈ (1ms, 50ms, 1ms): the median shrugs off the outlier
    assert t.wall_s < 0.02


def test_timer_overhead_is_small_and_nonnegative():
    oh = timer_overhead()
    assert 0.0 <= oh < 1e-3


# ----------------------- core fingerprints -----------------------


def test_core_fingerprint_stable_and_structure_sensitive():
    from repro.apps.diffusion import compile_diffusion

    a = compile_diffusion(64)
    b = compile_diffusion(64)
    assert core_fingerprint(a) == core_fingerprint(b)  # same structure
    assert core_fingerprint(a) == core_fingerprint(a.stream_kernel())
    c = compile_diffusion(128)  # different stencil width parameter
    assert core_fingerprint(a) != core_fingerprint(c)
    assert core_fingerprint("lbm_stream") == "tag:lbm_stream"


# ----------------------- measurement cache -----------------------


def _key(**over):
    kw = dict(
        fingerprint="spd:abc",
        grid_shape=(256, 128),
        plan=(32, 4, 4, 1),
        backend="cpu",
        interpret=True,
        reps=3,
        warmup=1,
    )
    kw.update(over)
    return MeasurementCache.make_key(**kw)


def test_cache_key_deterministic_and_ingredient_sensitive():
    assert _key() == _key()
    assert _key(plan=(16, 4, 4, 1)) != _key()  # plan change
    assert _key(grid_shape=(128, 128)) != _key()
    assert _key(fingerprint="spd:def") != _key()
    assert _key(backend="tpu") != _key()
    assert _key(interpret=False) != _key()
    assert _key(reps=5) != _key()


def test_cache_key_carries_code_salt():
    """A kernel-implementation or jax change must invalidate every
    entry even though no core's DFG changed — the salt is part of the
    key, so swapping it swaps the key."""
    from repro.core import measure

    assert measure.code_salt() == measure.code_salt()  # process-stable
    before = _key()
    real = measure._CODE_SALT[:]
    try:
        measure._CODE_SALT[:] = ["different-kernel-code"]
        assert _key() != before
    finally:
        measure._CODE_SALT[:] = real


def test_cache_round_trip_on_disk(tmp_path):
    path = tmp_path / "measure.json"
    c1 = MeasurementCache(path)
    assert c1.get(_key()) is None and c1.misses == 1
    c1.put(_key(), {"wall_s": 0.125, "reps": 3})
    # a fresh process (new instance) sees the persisted entry
    c2 = MeasurementCache(path)
    rec = c2.get(_key())
    assert rec is not None and rec["wall_s"] == 0.125
    assert c2.hits == 1 and c2.misses == 0
    assert c2.get(_key(plan=(16, 4, 4, 1))) is None  # plan change misses
    assert c2.stats()["entries"] == 1


def test_resolve_cache_policies(tmp_path):
    assert resolve_cache(None) is None
    assert resolve_cache(False) is None
    c = MeasurementCache(tmp_path / "c.json")
    assert resolve_cache(c) is c
    p = resolve_cache(str(tmp_path / "other.json"))
    assert isinstance(p, MeasurementCache)
    assert p.path == str(tmp_path / "other.json")
    d = resolve_cache(True)
    assert isinstance(d, MeasurementCache)


def test_measured_run_skips_rerun_on_hit(tmp_path):
    cache = MeasurementCache(tmp_path / "m.json")
    calls = []

    def fn():
        calls.append(1)
        time.sleep(0.002)

    wall1, cached1 = measured_run(
        fn, key=_key(), cache=cache, reps=2, warmup=1
    )
    assert not cached1 and len(calls) == 3  # warmup + 2 reps
    wall2, cached2 = measured_run(
        fn, key=_key(), cache=cache, reps=2, warmup=1
    )
    assert cached2 and wall2 == wall1 and len(calls) == 3  # no re-run
    # a different plan is a different key: runs again
    _, cached3 = measured_run(
        fn, key=_key(plan=(8, 2, 2, 1)), cache=cache, reps=2, warmup=1
    )
    assert not cached3 and len(calls) == 6


# ----------------------- calibration -----------------------


def test_backend_calibration_target_folds_measured_constants():
    cal = BackendCalibration(
        backend="cpu", interpret=True, elem_gflops=10.0, mem_gbs=5.0,
        by_d=((1, 10.0), (2, 16.0)),
    )
    t1 = cal.target(d=1)
    assert t1.vpu_f32_tflops == pytest.approx(0.01)  # 10 GF/s measured
    assert t1.hbm_gbs == pytest.approx(5.0)
    assert "measured[cpu:interpret]" in t1.name
    # aggregate/d per chip: the model's ×d recovers the measured 16 GF/s
    t2 = cal.target(d=2)
    assert 2 * t2.vpu_f32_tflops * 1e3 == pytest.approx(16.0)
    assert cal.gflops(4) == pytest.approx(10.0)  # unprobed d: no assumed scaling
    model = TPUModel.calibrated(cal)
    assert isinstance(model, TPUModel)
    assert model.target.vpu_f32_tflops == pytest.approx(0.01)
    # base target overrides pass through untouched fields
    base = TPUTarget(ici_gbs_per_link=25.0)
    assert cal.target(base=base).ici_gbs_per_link == 25.0


def test_generic_probes_return_finite_positive_rates():
    bw = measure_memory_bandwidth_gbs(mbytes=4, reps=1, warmup=1)
    assert np.isfinite(bw) and bw > 0
    gf = measure_elementwise_gflops(
        True, chain=4, shape=(32, 64), reps=1, warmup=1
    )
    assert np.isfinite(gf) and gf > 0


def test_calibration_sanity_on_interpret_grid():
    """ISSUE 4 acceptance: on the 256×128 interpret-mode measurement
    grid the *calibrated* rel_error is a real model-fidelity signal
    (|e| < 0.5) where the uncalibrated model-vs-interpreter diff is
    ≈ 1.0 (the old, meaningless number).

    Live host timings on a shared machine see occasional load bursts,
    so the band is checked over up to three independent measurement
    attempts (probes and points are re-timed together each attempt) —
    systematic miscalibration fails all of them.
    """
    from repro.apps import diffusion as dif

    sim = dif.DiffusionSimulation(256, 128, alpha=0.2)
    ex = sim.explorer()
    sweep = ex.sweep_tpu(
        bh_values=(8, 16, 32, 64), m_values=(1, 2, 4, 8), d_values=(1,)
    )
    u0, _ = dif.sine_init(256, 128)
    worst: list = []
    for _ in range(3):
        runs = ex.execute_frontier(
            sweep, sim.state(u0), (sim.alpha,), k=2, reps=3, calibrate=True,
        )
        assert runs
        for r in runs:
            assert r.calibrated_gflops is not None and r.calibrated_gflops > 0
            # the uncalibrated diff still shows the host↔TPU gulf
            assert abs(r.rel_error_model) > 0.9
        worst.append([(r.block_h, r.m, round(r.rel_error, 3)) for r in runs])
        if all(abs(r.rel_error) < 0.5 for r in runs):
            break
    else:
        pytest.fail(f"calibrated rel_error out of band in 3 attempts: {worst}")


def test_execute_frontier_cache_round_trip(tmp_path):
    """Second identical sweep is served from the measurement cache; a
    changed timing policy (part of the key) re-measures."""
    from repro.apps import diffusion as dif

    sim = dif.DiffusionSimulation(32, 64, alpha=0.2)
    ex = sim.explorer()
    sweep = ex.sweep_tpu(bh_values=(8, 16), m_values=(1, 2), d_values=(1,))
    u0, _ = dif.sine_init(32, 64)
    cache = MeasurementCache(tmp_path / "m.json")
    args = (sweep, sim.state(u0), (sim.alpha,))
    first = ex.execute_frontier(*args, k=2, reps=1, cache=cache,
                                calibrate=False)
    assert first and not any(r.cached for r in first)
    second = ex.execute_frontier(*args, k=2, reps=1, cache=cache,
                                 calibrate=False)
    assert [r.cached for r in second] == [True] * len(second)
    assert [(r.block_h, r.m, r.wall_s) for r in second] == [
        (r.block_h, r.m, r.wall_s) for r in first
    ]
    # reps is a key ingredient: a different timing policy re-measures
    third = ex.execute_frontier(*args, k=1, reps=2, cache=cache,
                                calibrate=False)
    assert not third[0].cached


def test_calibration_falls_back_when_probe_anchors_are_infeasible():
    """On a VMEM-tight grid none of the default PROBE_PLANS anchors may
    have a legal plan even though the frontier point itself runs;
    calibration must fall back to anchoring on the point's own plan
    instead of crashing the frontier walk."""
    from repro.core.dse import StreamWorkload
    from repro.core.explorer import Explorer

    w = StreamWorkload(
        "wide", 4, 10, 10, 10, 1000, 256 * 100_000, grid_w=100_000
    )
    ex = Explorer(w)
    sweep = ex.sweep_tpu(bh_values=(8,), m_values=(1,), d_values=(1,))

    def rf(nsteps, m, bh, d):
        return lambda: None

    runs = ex.execute_frontier(
        sweep, run_factory=rf, grid_shape=(256, 100_000), k=1, reps=1,
        calibrate=True,
    )
    assert len(runs) == 1
    assert runs[0].calibrated_gflops is not None
    assert runs[0].block_h == 8 and runs[0].m == 1  # the VMEM-legal plan


def test_calibration_target_bandwidth_not_split_on_real_accelerators():
    """Forced host 'devices' split one machine's bandwidth; real chips
    each have their own HBM — the per-chip constant must not be divided
    by d there."""
    host = BackendCalibration(
        backend="cpu", interpret=True, elem_gflops=8.0, mem_gbs=6.0,
        by_d=((1, 8.0), (2, 12.0)),
    )
    assert host.target(d=2).hbm_gbs == pytest.approx(3.0)  # shared host
    tpu = BackendCalibration(
        backend="tpu", interpret=False, elem_gflops=4000.0, mem_gbs=800.0,
        by_d=((1, 4000.0), (2, 8000.0)),
    )
    assert tpu.target(d=2).hbm_gbs == pytest.approx(800.0)  # per-chip HBM


def test_execute_frontier_run_factory_needs_cache_tag():
    """A custom back end has no SPD core to fingerprint: caching is
    disabled (with a warning) unless the caller passes cache_tag."""
    from repro.core.dse import StreamWorkload
    from repro.core.explorer import Explorer

    w = StreamWorkload("toy", 4, 1, 1, 10, 1000, 64 * 64, grid_w=64)
    ex = Explorer(w)
    sweep = ex.sweep_tpu(bh_values=(8,), m_values=(1,), d_values=(1,))

    def rf(nsteps, m, bh, d):
        return lambda: None

    with pytest.warns(RuntimeWarning, match="cache_tag"):
        ex.execute_frontier(
            sweep, run_factory=rf, grid_shape=(64, 64), k=1, reps=1,
            cache=True, calibrate=False,
        )
