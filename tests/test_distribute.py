"""Multi-device spatial parallelism (`repro.core.distribute`): the device
axis d through model, legalizer, kernels, and explorer.

Load-bearing assertions (ISSUE 3 acceptance criteria):
* the sharded kernel ≡ the single-device kernel, *bitwise*, for
  d ∈ {1, 2, 4} × m ∈ {1, 2} on both shipped apps (lbm, diffusion);
* `Explorer.sweep_tpu` enumerates d ∈ {1, 2, 4} and at least one d > 1
  point sits on the Pareto frontier under the inter-chip bandwidth model;
* `execute_frontier` times multi-device points (and skips points the
  platform has too few devices for);
* legalization is per-shard (halo + VMEM accounted against H/d) and an
  indivisible height is a hard error, in the legalizer and as a model
  infeasibility alike.

The d > 1 cases need real (host) devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
distribution job sets it; under a plain single-device run they skip.
"""

import numpy as np
import pytest

import jax

from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.core.distribute import (
    ShardedStreamKernel,
    device_axis_values,
    ring_mesh,
)
from repro.core.dse import StreamWorkload, TPUModel
from repro.core.legalize import (
    blocking_plan,
    resolve_run_plan,
    shard_height,
    stripe_vmem_bytes,
)

LBM_REGS = (1 / 0.8, 0.0, 1.0)


def _needs_devices(d: int):
    return pytest.mark.skipif(
        jax.device_count() < d,
        reason=f"needs {d} devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
    )


@pytest.fixture(scope="module")
def lbm_sim():
    return lbm.LBMSimulation(lbm.LBMProblem(16, 64, mode="wrap"))


@pytest.fixture(scope="module")
def dif_sim():
    return dif.DiffusionSimulation(16, 64, alpha=0.2)


# ----------------------- per-shard legalization -----------------------


def test_shard_height_and_indivisible_error():
    assert shard_height(64, 4) == 16
    assert shard_height(64, 1) == 64
    with pytest.raises(ValueError, match="shards"):
        shard_height(30, 4)
    with pytest.raises(ValueError, match="device axis"):
        shard_height(30, 0)


def test_blocking_plan_is_per_shard():
    # d=4 shards of 16 rows: the block must divide the *shard*, not the grid.
    assert blocking_plan(64, 64, 2, d=4) == (16, 2, True)
    assert blocking_plan(64, 12, 2, d=4) == (8, 2, True)  # divisor of 16
    # halo floor applies within the shard: m*halo <= block_h <= h/d.
    bh, m, _ = blocking_plan(64, 4, 8, halo=2, d=4)
    assert bh <= 16 and 16 % bh == 0 and m * 2 <= bh
    # d=1 keeps the exact single-device behavior.
    assert blocking_plan(64, 24, 4) == (16, 4, True)


def test_blocking_plan_indivisible_height_is_an_error():
    with pytest.raises(ValueError, match="shards"):
        blocking_plan(300, 32, 4, d=7)


def test_blocking_plan_vmem_clamp_is_per_shard():
    # A stripe that fits the shard but would not have fit the full grid
    # is irrelevant — VMEM is per chip, accounted against h/d divisors.
    h, width, words = 4096, 720, 10
    bh, m, db = blocking_plan(h, 4096, 4, width=width, words=words, d=4)
    assert 1024 % bh == 0  # a divisor of the shard height
    assert stripe_vmem_bytes(bh, m, width, words,
                             double_buffer=db) <= 128 * 1024 * 1024
    # An over-budget smallest stripe still fails loudly per shard —
    # even the single-buffer streaming fallback cannot fit this one.
    with pytest.raises(ValueError, match="VMEM"):
        blocking_plan(502, 251, 1, width=100_000, words=200, d=2)


def test_resolve_run_plan_threads_d():
    w = StreamWorkload("t", 7, 1, 1, 100, 1000, 64 * 64, grid_w=64)
    pt = TPUModel().evaluate(w, bh=64, m=2, d=4)
    block_h, m, nsteps, db = resolve_run_plan(64, pt, d=4)
    assert 16 % block_h == 0 and m == 2 and nsteps == m and db is True


def test_device_axis_values():
    assert device_axis_values(1) == (1,)
    assert device_axis_values(4) == (1, 2, 4)
    assert device_axis_values(6) == (1, 2, 4)
    assert device_axis_values(8) == (1, 2, 4, 8)
    with pytest.raises(ValueError):
        device_axis_values(0)


# ----------------------- the model's device axis -----------------------


def test_model_marks_indivisible_shards_infeasible():
    w = StreamWorkload("t", 7, 1, 1, 100, 1000, 30 * 64, grid_w=64)  # h=30
    model = TPUModel()
    assert model.evaluate(w, 8, 2, d=2).feasible  # 30 % 2 == 0
    bad = model.evaluate(w, 8, 2, d=4)  # 30 % 4 != 0
    assert not bad.feasible
    assert any("shard" in lim for lim in bad.limits)
    batch = model.evaluate_batch(w, [8, 8], [2, 2], d=[2, 4])
    assert batch["feasible"].tolist() == [True, False]


@pytest.mark.parametrize("make_sim", [
    pytest.param(lambda: lbm.LBMSimulation(lbm.LBMProblem(64, 128)),
                 id="lbm"),
    pytest.param(lambda: dif.DiffusionSimulation(64, 128, alpha=0.2),
                 id="diffusion"),
])
def test_device_axis_reaches_both_apps_frontiers(make_sim):
    """ISSUE 3 acceptance: for both apps the default sweep enumerates
    d ∈ {1, 2, 4} and a d > 1 point is Pareto-optimal under the
    inter-chip bandwidth model."""
    sweep = make_sim().explorer().sweep_tpu(
        bh_values=(8, 16, 32), m_values=(1, 2, 4)
    )
    assert set(np.unique(sweep.data["d"])) == {1, 2, 4}
    frontier = sweep.frontier()
    assert any(p.n > 1 for p in frontier), "no multi-device frontier point"
    assert any(p.n == 1 for p in frontier), "single-device fell off"
    # The collective term prices the halo exchange: d>1 points carry it.
    multi = next(p for p in frontier if p.n > 1)
    assert multi.detail["t_collective_s"] > 0.0


# ----------------------- mesh / kernel plumbing -----------------------


def test_ring_mesh_needs_enough_devices():
    with pytest.raises(ValueError, match="device"):
        ring_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError, match="device axis"):
        ring_mesh(0)


def test_sharded_d1_delegates(dif_sim):
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    kern = dif_sim.kernel
    sk = kern.sharded(1)
    assert isinstance(sk, ShardedStreamKernel) and sk.mesh is None
    got = sk.run_blocked(state, (0.2,), steps=2, m=2, block_h=8)
    want = kern.run_blocked(state, (0.2,), steps=2, m=2, block_h=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@_needs_devices(2)
def test_sharded_rejects_illegal_plans(dif_sim):
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    sk = dif_sim.kernel.sharded(2)
    with pytest.raises(ValueError, match="shards"):
        # 16 rows over d=2 is fine, but a 15-row grid is not.
        sk.run_blocked(state[:, :15, :], (0.2,), steps=1, m=1, block_h=5)
    with pytest.raises(ValueError, match="divisible"):
        sk.run_blocked(state, (0.2,), steps=1, m=1, block_h=3)  # 8 % 3
    with pytest.raises(ValueError, match="halo"):
        sk.run_blocked(state, (0.2,), steps=8, m=8, block_h=4)  # m*halo > bh
    with pytest.raises(ValueError, match="multiple"):
        sk.run_blocked(state, (0.2,), steps=3, m=2, block_h=8)


# ----------------------- sharded ≡ single device, bitwise ------------------


@pytest.mark.parametrize("d", [1, 2, 4])
@pytest.mark.parametrize("m", [1, 2])
def test_diffusion_sharded_bitmatch(dif_sim, d, m):
    """ISSUE 3 correctness contract, diffusion: sharded ≡ single-device,
    bit for bit, across fused launches (halo re-exchanged every m)."""
    if jax.device_count() < d:
        pytest.skip(f"needs {d} devices (force host devices in XLA_FLAGS)")
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    kern = dif_sim.kernel
    single = kern.run_blocked(state, (0.2,), steps=2 * m, m=m, block_h=4)
    shard = kern.sharded(d).run_blocked(
        state, (0.2,), steps=2 * m, m=m, block_h=4
    )
    np.testing.assert_array_equal(np.asarray(shard), np.asarray(single))


@pytest.mark.parametrize("d", [1, 2, 4])
@pytest.mark.parametrize("m", [1, 2])
def test_lbm_sharded_bitmatch(lbm_sim, d, m):
    """ISSUE 3 correctness contract, lbm (all nine D2Q9 stencils cross
    the shard boundary, fluid lattice)."""
    if jax.device_count() < d:
        pytest.skip(f"needs {d} devices (force host devices in XLA_FLAGS)")
    kern = lbm_sim.stream_kernel()
    f, attr, _ = lbm.taylor_green_init(16, 64)
    state = lbm_sim.stream_state(f, attr)
    single = kern.run_blocked(state, LBM_REGS, steps=2 * m, m=m, block_h=4)
    shard = kern.sharded(d).run_blocked(
        state, LBM_REGS, steps=2 * m, m=m, block_h=4
    )
    np.testing.assert_array_equal(np.asarray(shard), np.asarray(single))


@_needs_devices(4)
def test_lbm_sharded_bitmatch_walls(lbm_sim):
    """Walls + moving lid: the bounce-back mux also crosses shards."""
    kern = lbm_sim.stream_kernel()
    f, attr = lbm.couette_init(16, 64)
    state = lbm_sim.stream_state(f, attr)
    regs = (1 / 0.9, 0.07, 1.0)
    single = kern.run_blocked(state, regs, steps=4, m=2, block_h=4)
    shard = kern.sharded(4).run_blocked(state, regs, steps=4, m=2, block_h=4)
    np.testing.assert_array_equal(np.asarray(shard), np.asarray(single))


# ----------------------- overlapped halo exchange ---------------------------


@_needs_devices(2)
@pytest.mark.parametrize("m", [1, 2])
def test_overlapped_exchange_bitmatch_diffusion(dif_sim, m):
    """ISSUE 7 satellite: overlapping the ppermute halo exchange with
    interior compute (docs/pipeline.md §overlap) is a scheduling choice,
    not a numerics choice — overlapped ≡ non-overlapped ≡ single-device,
    bit for bit. block_h=2 gives each 8-row shard nblk=4 ≥ 3, so the
    interior/edge decomposition actually engages."""
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    kern = dif_sim.kernel
    single = kern.run_blocked(state, (0.2,), steps=2 * m, m=m, block_h=2)
    sk = kern.sharded(2)
    on = sk.run_blocked(state, (0.2,), steps=2 * m, m=m, block_h=2,
                        overlap=True)
    off = sk.run_blocked(state, (0.2,), steps=2 * m, m=m, block_h=2,
                         overlap=False)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on), np.asarray(single))


@_needs_devices(2)
def test_overlapped_exchange_bitmatch_lbm(lbm_sim):
    """Same contract on the codegen'd uLBM core (nine crossing
    stencils), in both buffer protocols."""
    kern = lbm_sim.stream_kernel()
    f, attr, _ = lbm.taylor_green_init(16, 64)
    state = lbm_sim.stream_state(f, attr)
    single = kern.run_blocked(state, LBM_REGS, steps=2, m=1, block_h=2)
    sk = kern.sharded(2)
    for db in (True, False):
        on = sk.run_blocked(state, LBM_REGS, steps=2, m=1, block_h=2,
                            overlap=True, double_buffer=db)
        off = sk.run_blocked(state, LBM_REGS, steps=2, m=1, block_h=2,
                             overlap=False, double_buffer=db)
        np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
        np.testing.assert_array_equal(np.asarray(on), np.asarray(single))


@_needs_devices(2)
def test_overlap_falls_back_below_three_blocks(dif_sim):
    """nblk < 3 leaves no exchange-free interior: the overlapped path
    must quietly use the monolithic launch and still match."""
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    kern = dif_sim.kernel
    single = kern.run_blocked(state, (0.2,), steps=2, m=1, block_h=4)
    on = kern.sharded(2).run_blocked(  # 8-row shards, nblk=2
        state, (0.2,), steps=2, m=1, block_h=4, overlap=True
    )
    np.testing.assert_array_equal(np.asarray(on), np.asarray(single))


@_needs_devices(2)
def test_diffusion_app_runs_end_to_end_sharded(dif_sim):
    """The app-level driver runs sharded and keeps the right physics
    (jnp oracle), not just kernel-vs-kernel equality."""
    u0, _ = dif.sine_init(16, 64)
    got = dif_sim.run(u0, 4, m=2, d=2)
    want = dif.diffusion_ref_run(u0, 0.2, 4)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6
    )
    # ...and bit-matches the single-device app run.
    single = dif_sim.run(u0, 4, m=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(single))


@_needs_devices(2)
def test_sharded_run_for_point_legalizes_per_shard(dif_sim):
    """run_for_point legalizes against the shard height and the result
    still bit-matches the single-device run of the same plan."""
    ex = dif_sim.explorer()
    sweep = ex.sweep_tpu(bh_values=(8, 16), m_values=(1, 2), d_values=(2,))
    pt = sweep.best("sustained_gflops")
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    sk = dif_sim.kernel.sharded(2)
    out, (bh, m, db) = sk.run_for_point(state, (0.2,), point=pt)
    assert 8 % bh == 0  # divisor of the shard height 16/2
    want = dif_sim.kernel.run_blocked(
        state, (0.2,), steps=m, m=m, block_h=bh, double_buffer=db
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ----------------------- explorer: timing multi-device points ---------------


@_needs_devices(4)
def test_execute_frontier_times_multi_device_points():
    """ISSUE 3 acceptance: execute_frontier runs d > 1 frontier points
    through the sharded kernel on forced host devices. The grid is tall
    enough (256 rows) that sharding beats the halo-exchange cost in the
    model — on a toy grid d > 1 is *correctly* dominated and never
    reaches the frontier."""
    sim = dif.DiffusionSimulation(256, 64, alpha=0.2)
    ex = sim.explorer()
    sweep = ex.sweep_tpu(bh_values=(32, 64), m_values=(1, 2))
    u0, _ = dif.sine_init(256, 64)
    runs = ex.execute_frontier(sweep, sim.state(u0), (0.2,), k=3)
    assert runs, "no frontier point executed"
    assert any(r.d > 1 for r in runs), "no multi-device point was timed"
    for r in runs:
        assert (256 // r.d) % r.block_h == 0  # per-shard legal plan
        assert r.wall_s > 0 and np.isfinite(r.rel_error)


def test_execute_frontier_warns_when_device_starved():
    """On a tall grid the frontier can be all-d>1; a platform without
    the devices gets an explanatory warning, not a silent empty list."""
    sim = dif.DiffusionSimulation(256, 64, alpha=0.2)
    ex = sim.explorer()
    sweep = ex.sweep_tpu(bh_values=(32, 64), m_values=(1, 2))
    assert all(p.n > 1 for p in sweep.frontier())  # the starved scenario
    u0, _ = dif.sine_init(256, 64)
    with pytest.warns(RuntimeWarning, match="device"):
        runs = ex.execute_frontier(
            sweep, sim.state(u0), (0.2,), k=2, max_devices=1
        )
    assert runs == []


def test_execute_frontier_skips_points_beyond_device_count(dif_sim):
    """Points needing more shards than the platform has devices are
    skipped, not fatal — the walk continues down the frontier."""
    ex = dif_sim.explorer()
    sweep = ex.sweep_tpu(bh_values=(4, 8), m_values=(1, 2))
    u0, _ = dif.sine_init(16, 64)
    runs = ex.execute_frontier(
        sweep, dif_sim.state(u0), (0.2,), k=2, max_devices=1
    )
    assert runs and all(r.d == 1 for r in runs)
