"""2-D device mesh (dy × dx): the mesh shape through kernels, model,
legalizer, search, and study identity (DESIGN.md §15).

Load-bearing assertions (ISSUE 10 acceptance criteria):

* 2-D-sharded execution ≡ single-device execution, *bitwise*, across
  the mesh matrix {(1,2), (2,1), (2,2), (1,4), (4,1), (2,4)} ×
  m ∈ {1, 2} × double_buffer ∈ {on, off} on both shipped apps
  (diffusion; lbm fluid and couette walls) — the column-halo
  ``ppermute`` exchange plus corner second hop is a scheduling choice,
  never a numerics choice;
* model and legalizer price the same ``(H/dy, W/dx)`` shard geometry
  (one ``stripe_vmem_bytes``, guard columns included) so the two
  cannot drift;
* pre-mesh study journals (``d``-only trial records) resume into the
  ``(dy, dx)`` identity with **zero** re-measurement;
* the minimal parallel-trial seam: ``SearchRunner.prefetch`` warms the
  next candidate on idle devices and ``measure`` joins the warm-up
  before its timed reps start (timings never overlap).

The d > 1 cases need real (host) devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
distribution job sets it; under a plain single-device run they skip.
"""

import json
from pathlib import Path

import numpy as np
import pytest

import jax

from _search_harness import TOY, ModelTimer, _rf

from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.core.dse import StreamWorkload, TPUModel
from repro.core.explorer import Explorer
from repro.core.legalize import (
    VMEM_BYTES,
    blocking_plan,
    legal_block_values,
    mesh_shape,
    shard_width,
    stripe_vmem_bytes,
)
from repro.core.search import (
    BudgetExhausted,
    ExhaustiveSearch,
    RunPlan,
    SearchRunner,
    SearchStepper,
)

#: The ISSUE 10 mesh matrix: row-only, column-only, and genuinely 2-D
#: factorizations, up to the CI job's 8 forced host devices.
MESHES = ((1, 2), (2, 1), (2, 2), (1, 4), (4, 1), (2, 4))

LBM_FLUID_REGS = (1 / 0.8, 0.0, 1.0)
LBM_COUETTE_REGS = (1 / 0.9, 0.07, 1.0)


@pytest.fixture(scope="module")
def lbm_sim():
    return lbm.LBMSimulation(lbm.LBMProblem(16, 64, mode="wrap"))


@pytest.fixture(scope="module")
def dif_sim():
    return dif.DiffusionSimulation(16, 64, alpha=0.2)


def _mesh_case(kern, state, regs, dy, dx, m, db):
    """sharded((dy, dx)) ≡ single-device, bit for bit, same plan."""
    d = dy * dx
    if jax.device_count() < d:
        pytest.skip(f"needs {d} devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    single = kern.run_blocked(state, regs, steps=2 * m, m=m, block_h=2,
                              double_buffer=db)
    meshed = kern.sharded(d, dx=dx).run_blocked(
        state, regs, steps=2 * m, m=m, block_h=2, double_buffer=db
    )
    np.testing.assert_array_equal(np.asarray(meshed), np.asarray(single))


# ----------------------- the bit-match matrix -----------------------


@pytest.mark.parametrize("db", [True, False], ids=["db", "single"])
@pytest.mark.parametrize("m", [1, 2])
@pytest.mark.parametrize("dy,dx", MESHES, ids=[f"{a}x{b}" for a, b in MESHES])
def test_diffusion_mesh_bitmatch(dif_sim, dy, dx, m, db):
    u0, _ = dif.sine_init(16, 64)
    _mesh_case(dif_sim.kernel, dif_sim.state(u0), (0.2,), dy, dx, m, db)


@pytest.mark.parametrize("db", [True, False], ids=["db", "single"])
@pytest.mark.parametrize("m", [1, 2])
@pytest.mark.parametrize("dy,dx", MESHES, ids=[f"{a}x{b}" for a, b in MESHES])
def test_lbm_fluid_mesh_bitmatch(lbm_sim, dy, dx, m, db):
    """All nine D2Q9 stencils cross both shard boundaries — the corner
    second hop is load-bearing for every diagonal population."""
    f, attr, _ = lbm.taylor_green_init(16, 64)
    _mesh_case(lbm_sim.stream_kernel(), lbm_sim.stream_state(f, attr),
               LBM_FLUID_REGS, dy, dx, m, db)


@pytest.mark.parametrize("db", [True, False], ids=["db", "single"])
@pytest.mark.parametrize("m", [1, 2])
@pytest.mark.parametrize("dy,dx", MESHES, ids=[f"{a}x{b}" for a, b in MESHES])
def test_lbm_couette_mesh_bitmatch(lbm_sim, dy, dx, m, db):
    """Walls + moving lid: the bounce-back mux crosses column shards."""
    f, attr = lbm.couette_init(16, 64)
    _mesh_case(lbm_sim.stream_kernel(), lbm_sim.stream_state(f, attr),
               LBM_COUETTE_REGS, dy, dx, m, db)


@pytest.mark.parametrize("dy,dx", [(1, 2), (2, 2)])
def test_mesh_overlap_bitmatch(dif_sim, dy, dx):
    """The PR-7 interior/edge overlap generalizes to both exchanges:
    overlapped ≡ monolithic ≡ single-device under a column-sharded
    mesh too."""
    d = dy * dx
    if jax.device_count() < d:
        pytest.skip("needs forced host devices")
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    kern = dif_sim.kernel
    single = kern.run_blocked(state, (0.2,), steps=4, m=2, block_h=2)
    sk = kern.sharded(d, dx=dx)
    on = sk.run_blocked(state, (0.2,), steps=4, m=2, block_h=2,
                        overlap=True)
    off = sk.run_blocked(state, (0.2,), steps=4, m=2, block_h=2,
                         overlap=False)
    np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
    np.testing.assert_array_equal(np.asarray(on), np.asarray(single))


# ----------------------- legalizer mesh geometry -----------------------


def test_shard_width_and_mesh_shape():
    assert shard_width(64, 4) == 16
    assert shard_width(64, 1) == 64
    with pytest.raises(ValueError, match="shards"):
        shard_width(30, 4)
    with pytest.raises(ValueError, match="column device axis"):
        shard_width(30, 0)
    assert mesh_shape(8, 4) == (2, 4)
    assert mesh_shape(4, 1) == (4, 1)
    assert mesh_shape(1, 1) == (1, 1)
    with pytest.raises(ValueError, match="mesh"):
        mesh_shape(8, 3)


def test_run_plan_from_dict_defaults_the_mesh_axis():
    """Pre-mesh plan dicts (PR-6/PR-9 journals) parse as the 1-D ring."""
    plan = RunPlan.from_dict({"block_h": 8, "m": 2, "steps": 2, "d": 4,
                              "reps": 3, "double_buffer": True})
    assert plan.dx == 1
    assert plan.key() == RunPlan(8, 2, 2, 4, 3, True, 1, "", 1).key()


# ----------------------- model ↔ legalizer drift -----------------------


def test_model_and_legalizer_agree_on_shard_geometry():
    """ISSUE 10 satellite: both account the same (H/dy, W/dx) shard —
    one stripe_vmem_bytes, guard columns included, so dse.py and
    legalize.py cannot drift on the mesh geometry."""
    model = TPUModel()
    w = StreamWorkload("t", 7, 3, 3, 100, 1000, 256 * 640,
                       grid_w=640, halo=1)
    for d, dx in ((2, 1), (4, 2), (8, 4), (4, 4), (8, 8)):
        dy = d // dx
        for bh, m in ((8, 1), (32, 4)):
            pt = model.evaluate(w, bh, m, d=d, dx=dx)
            assert pt.detail["dy"] == dy and pt.detail["dx"] == dx
            guard = w.halo if dx > 1 else 0
            assert pt.detail["vmem_bytes"] == stripe_vmem_bytes(
                bh, m, shard_width(640, dx), 3, halo=1,
                double_buffer=True, halo_x=guard,
            )
            # The legalizer's divisor chain runs over the same shard
            # height and prices the same guarded stripe.
            legal = legal_block_values(256, m, halo=1, width=640,
                                       words=3, d=d, dx=dx, halo_x=1)
            assert legal and all((256 // dy) % v == 0 for v in legal)
            bh2, m2, db2 = blocking_plan(256, bh, m, width=640, words=3,
                                         d=d, dx=dx, halo_x=1)
            assert (256 // dy) % bh2 == 0
            assert stripe_vmem_bytes(
                bh2, m2, shard_width(640, dx), 3, 1, db2, halo_x=guard
            ) <= VMEM_BYTES


def test_model_marks_bad_meshes_infeasible():
    model = TPUModel()
    w = StreamWorkload("t", 7, 1, 1, 100, 1000, 64 * 70, grid_w=70)
    bad = model.evaluate(w, 8, 1, d=4, dx=3)  # 4 % 3 != 0
    assert not bad.feasible
    assert any("mesh" in s for s in bad.limits)
    badw = model.evaluate(w, 8, 1, d=4, dx=4)  # 70 % 4 != 0
    assert not badw.feasible
    assert any("colshard" in s for s in badw.limits)


def test_mesh_scalar_and_batch_models_agree():
    """evaluate ≡ evaluate_batch on the mesh axis, bit for bit."""
    model = TPUModel()
    w = StreamWorkload("t", 7, 1, 1, 100, 1000, 256 * 128, grid_w=128)
    cases = [(8, 1, 4, 2), (16, 2, 8, 4), (32, 2, 8, 8),
             (8, 1, 4, 1), (64, 2, 8, 3)]
    bhs, ms, ds, dxs = (list(t) for t in zip(*cases))
    batch = model.evaluate_batch(w, bhs, ms, d=ds, dx=dxs)
    for i, (bh, m, d, dx) in enumerate(cases):
        pt = model.evaluate(w, bh, m, d=d, dx=dx)
        assert bool(batch["feasible"][i]) == pt.feasible
        assert float(batch["sustained_gflops"][i]) == pt.sustained_gflops
        assert int(batch["dx"][i]) == pt.detail["dx"]
        assert int(batch["dy"][i]) == pt.detail["dy"]


def test_sweep_tpu_enumerates_the_mesh_axis():
    """The dx lattice axis reaches Sweep.point: a swept point carries
    its (dy, dx) in detail, and d stays the total device count."""
    ex = Explorer(StreamWorkload("t", 7, 1, 1, 100, 1000, 256 * 128,
                                 grid_w=128))
    sweep = ex.sweep_tpu(bh_values=(8, 16), m_values=(1, 2),
                         d_values=(8,), dx_values=(1, 2, 4, 8))
    assert set(np.unique(sweep.data["dx"]).tolist()) == {1, 2, 4, 8}
    i = int(np.argmax(sweep.data["dx"] == 4))
    pt = sweep.point(i)
    assert pt.n == 8
    assert pt.detail["dx"] == 4 and pt.detail["dy"] == 2


def test_wide_grid_prefers_columns_tall_prefers_rows():
    """The mesh axis earns its place in the search: at a fixed device
    count the model matches the mesh to the grid's aspect — a wide grid
    picks a column-heavy mesh, a tall grid the row ring (mirrored)."""
    model = TPUModel()
    wide = StreamWorkload("w", 7, 1, 1, 100, 1000, 128 * 512, grid_w=512)
    tall = StreamWorkload("t", 7, 1, 1, 100, 1000, 512 * 128, grid_w=128)

    def best_dx(w):
        return max(
            (1, 2, 4, 8),
            key=lambda dx: model.evaluate(w, 16, 2, d=8, dx=dx)
            .sustained_gflops,
        )

    assert best_dx(wide) == 8
    assert best_dx(tall) == 1


# ----------------------- old journals replay -----------------------


def test_premesh_journal_replays_with_zero_remeasurement(search_harness):
    """ISSUE 10 acceptance: a PR-6/PR-9-era journal (trial points with
    no ``dx`` field) resumes into the (dy, dx) study identity and plan
    keys with zero re-measurement."""
    hz = search_harness
    strat = ExhaustiveSearch(k=4, frontier_only=False)
    t1 = hz.timer()
    first = hz.search(hz.sweep(), timer=t1, strategy=strat, budget=4,
                      study="premesh")
    assert first.budget_spent == 4 == len(t1.calls)

    # Rewrite the journal as its pre-mesh ancestor: strip the dx plan
    # dimension from every trial record (exactly what a journal written
    # before DESIGN.md §15 contains).
    path = Path(hz.study_dir) / "premesh.jsonl"
    lines = []
    stripped = 0
    for line in path.read_text(encoding="utf-8").splitlines():
        rec = json.loads(line)
        if isinstance(rec.get("point"), dict) and "dx" in rec["point"]:
            del rec["point"]["dx"]
            stripped += 1
        lines.append(json.dumps(rec, sort_keys=True))
    assert stripped == 4
    path.write_text("\n".join(lines) + "\n", encoding="utf-8")

    # Resume: every trial must come back replayed — zero live timings,
    # zero budget spent, and the timer records no calls.
    t2 = hz.timer()
    resumed = hz.search(hz.sweep(), timer=t2, strategy=strat, budget=1,
                        study="premesh")
    assert resumed.replayed == 4
    assert resumed.budget_spent == 0 and not t2.calls
    assert len(resumed.executed) == 4
    assert all(e.cached and e.dx == 1 for e in resumed.executed)


# ----------------------- parallel trials: prefetch -----------------------


def test_prefetch_warms_candidate_and_never_overlaps_timing():
    """Satellite 1: a sub-mesh trial leaves devices idle — the next
    candidate's warm-up runs on a background thread, and measure joins
    it before the timed reps start (per-trial isolation)."""
    import threading
    import time as _time

    done = threading.Event()

    def rf(nsteps, m, block_h, d, double_buffer=True, b=1, dx=1):
        def run():
            if not done.is_set():
                _time.sleep(0.02)
                done.set()
        return run

    def timer(plan, run, reps, warmup):
        # Isolation contract: by the time the clock starts, no warm-up
        # thread is in flight.
        assert runner._prefetch is None
        return 1e-3

    runner = SearchRunner(
        workload=TOY, grid_shape=(64, 64), run_factory=rf,
        model=TPUModel(), fingerprint="mesh-prefetch", calibrate=False,
        cache=False, timer=timer, max_devices=4,
    )
    first = runner.point(8, 1)
    nxt = runner.point(16, 1)
    assert runner.measure(first) is not None
    assert runner.prefetch(nxt) is True
    assert runner.prefetched == 1
    assert runner.measure(nxt) is not None
    assert done.is_set()
    assert runner._prefetch is None


def test_prefetch_gates_on_idle_devices():
    """A trial meshing every device leaves nothing idle: no dispatch."""
    runner = SearchRunner(
        workload=TOY, grid_shape=(64, 64), run_factory=_rf,
        model=TPUModel(), fingerprint="mesh-prefetch-gate",
        calibrate=False, cache=False, timer=ModelTimer(), max_devices=1,
    )
    assert runner.prefetch(runner.point(8, 1)) is False
    assert runner.prefetched == 0


def test_budget_cutoff_records_the_blocked_candidate():
    """BudgetExhausted remembers the candidate it cut off — exactly the
    point the stepper will ask for next — and prefetch() consumes it."""
    runner = SearchRunner(
        workload=TOY, grid_shape=(64, 64), run_factory=_rf,
        model=TPUModel(), fingerprint="mesh-prefetch-cutoff",
        calibrate=False, cache=False, timer=ModelTimer(),
        budget=1, max_devices=4,
    )
    first = runner.point(8, 1)
    nxt = runner.point(16, 1)
    assert runner.measure(first) is not None
    with pytest.raises(BudgetExhausted):
        runner.measure(nxt)
    assert runner.last_blocked is nxt
    assert runner.prefetch() is True
    assert runner.last_blocked is None
    runner._join_prefetch()


def test_stepper_prefetches_between_steps():
    """The SearchStepper wires the seam: after each fresh measurement
    the cut-off candidate's compile/warm-up dispatches in background."""
    runner = SearchRunner(
        workload=TOY, grid_shape=(64, 64), run_factory=_rf,
        model=TPUModel(), fingerprint="mesh-stepper", calibrate=False,
        cache=False, timer=ModelTimer(), budget=8, max_devices=4,
    )
    sweep = Explorer(TOY).sweep_tpu(bh_values=(8, 16, 32),
                                    m_values=(1, 2))
    stepper = SearchStepper(
        ExhaustiveSearch(frontier_only=False), sweep, runner
    )
    assert stepper.step() is not None
    assert runner.prefetched >= 1
    runner._join_prefetch()
