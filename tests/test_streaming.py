"""The manually pipelined streaming path (`repro.kernels.spd_stream.
streaming`): double_buffer as a real, end-to-end plan dimension.

Load-bearing assertions (ISSUE 7 acceptance criteria):
* **differential bit-match matrix** — the ping/pong streamed launch
  (``double_buffer=True``), the single-buffer streamed launch
  (``double_buffer=False``), and the declarative BlockSpec reference
  produce identical bits across (block_h, m ∈ {1, 2, 4}, d ∈ {1, 2})
  for both shipped apps (lbm fluid + walls, diffusion);
* **VMEM-overflow fallback** — a grid whose minimal double-buffered
  stripe exceeds the VMEM budget legalizes onto the single-buffer
  streaming path instead of raising, executes bit-matched against the
  jnp oracle, and the clamp error names the fallback when even one
  buffer cannot fit;
* **no duplicated accounting** — ``TPUModel`` prices VMEM with the
  legalizer's own :func:`~repro.core.legalize.stripe_vmem_bytes`
  (drift test over both buffer protocols);
* a hypothesis property: every legal double-buffered plan costs exactly
  twice its single-buffered twin and still bit-matches.

The d = 2 cases need real (host) devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under a plain
single-device run they skip.
"""

import numpy as np
import pytest

import jax

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st  # noqa: F401
from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.core.dse import StreamWorkload, TPUModel
from repro.core.legalize import (
    VMEM_BYTES,
    blocking_plan,
    legal_block_values,
    resolve_run_plan,
    stripe_vmem_bytes,
)

LBM_REGS = (1 / 0.8, 0.0, 1.0)


def _needs_devices(d: int):
    return pytest.mark.skipif(
        jax.device_count() < d,
        reason=f"needs {d} devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
    )


@pytest.fixture(scope="module")
def dif_sim():
    return dif.DiffusionSimulation(16, 64, alpha=0.2)


@pytest.fixture(scope="module")
def lbm_sim():
    return lbm.LBMSimulation(lbm.LBMProblem(16, 64, mode="wrap"))


# ----------------- differential matrix: ping/pong ≡ single-buffer -----------


def _run_both(kern, state, regs, *, m, block_h, d):
    """(double-buffered, single-buffered) outputs of the same plan."""
    launcher = kern if d == 1 else kern.sharded(d)
    outs = []
    for db in (True, False):
        outs.append(launcher.run_blocked(
            state, regs, steps=2 * m, m=m, block_h=block_h,
            double_buffer=db,
        ))
    return outs


@pytest.mark.parametrize("d", [1, 2])
@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("block_h", [4, 8])
def test_diffusion_double_vs_single_buffer_bitmatch(dif_sim, block_h, m, d):
    """ISSUE 7 matrix, diffusion: nbuf is a protocol choice, never a
    numerics choice — and both match the declarative reference."""
    if jax.device_count() < d:
        pytest.skip(f"needs {d} devices (force host devices in XLA_FLAGS)")
    if m > block_h or (d > 1 and m * dif_sim.kernel.halo > 16 // d):
        pytest.skip("halo does not fit this (block_h, m, d) cell")
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    pp, sb = _run_both(dif_sim.kernel, state, (0.2,),
                       m=m, block_h=block_h, d=d)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(sb))
    if d == 1:
        ref = dif_sim.kernel._multistep(
            state, dif_sim.kernel._scal((0.2,)), m=m, block_h=block_h
        )
        ref = dif_sim.kernel._multistep(
            ref, dif_sim.kernel._scal((0.2,)), m=m, block_h=block_h
        )
        np.testing.assert_array_equal(np.asarray(pp), np.asarray(ref))


@pytest.mark.parametrize("d", [1, 2])
@pytest.mark.parametrize("m", [1, 2, 4])
def test_lbm_fluid_double_vs_single_buffer_bitmatch(lbm_sim, m, d):
    """ISSUE 7 matrix, lbm fluid lattice (all nine D2Q9 stencils cross
    every stripe boundary)."""
    if jax.device_count() < d:
        pytest.skip(f"needs {d} devices (force host devices in XLA_FLAGS)")
    kern = lbm_sim.stream_kernel()
    if d > 1 and m * kern.halo > 16 // d // 2:
        pytest.skip("halo does not fit this (m, d) cell")
    f, attr, _ = lbm.taylor_green_init(16, 64)
    state = lbm_sim.stream_state(f, attr)
    pp, sb = _run_both(kern, state, LBM_REGS, m=m, block_h=4, d=d)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(sb))


@pytest.mark.parametrize("m", [1, 2])
def test_lbm_walls_double_vs_single_buffer_bitmatch(lbm_sim, m):
    """Walls + moving lid: the bounce-back mux rides the same stripes."""
    kern = lbm_sim.stream_kernel()
    f, attr = lbm.couette_init(16, 64)
    state = lbm_sim.stream_state(f, attr)
    regs = (1 / 0.9, 0.07, 1.0)
    pp, sb = _run_both(kern, state, regs, m=m, block_h=4, d=1)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(sb))


def test_single_block_grid_streams(dif_sim):
    """nblk == 1 (block_h == h): the stream loop degenerates to one
    prefetch + drain pair and still matches, both protocols."""
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    pp, sb = _run_both(dif_sim.kernel, state, (0.2,), m=2, block_h=16, d=1)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(sb))
    want = dif.diffusion_ref_run(u0, 0.2, 4)
    np.testing.assert_allclose(np.asarray(pp[0]), np.asarray(want),
                               rtol=2e-5, atol=1e-6)


# ----------------- VMEM overflow: the streaming fallback ---------------------


def test_blocking_plan_falls_back_to_single_buffer():
    """A minimal stripe that overflows double-buffered but fits
    single-buffered legalizes onto the fallback instead of raising."""
    # smallest stripe (bh=2, m=2, halo=1): 6 rows × 64 × 1 word × 4 B
    #   = 1536 B single-buffered, 3072 B ping/pong.
    bh, m, db = blocking_plan(16, 8, 2, width=64, words=1, vmem_bytes=2000)
    assert db is False
    assert stripe_vmem_bytes(bh, m, 64, 1, 1, False) <= 2000
    # With the room, the requested ping/pong protocol is honored.
    assert blocking_plan(16, 8, 2, width=64, words=1,
                         vmem_bytes=10**9) == (8, 2, True)
    # An explicit single-buffer request is never upgraded.
    assert blocking_plan(16, 8, 2, width=64, words=1, vmem_bytes=10**9,
                         double_buffer=False) == (8, 2, False)


def test_clamp_error_names_the_streaming_fallback():
    """When even one buffer cannot fit, the error says the fallback was
    tried — the actionable half of the ISSUE 7 contract."""
    with pytest.raises(ValueError) as ei:
        blocking_plan(16, 8, 2, width=64, words=1, vmem_bytes=100)
    msg = str(ei.value)
    assert "single-buffer streaming fallback" in msg
    assert "double_buffer=False" in msg


def test_vmem_overflow_grid_executes_via_streaming(dif_sim):
    """ISSUE 7 acceptance: a grid that is VMEM-infeasible double-buffered
    legalizes (double_buffer=False), executes through the streamed
    kernel, and matches the jnp oracle — where the seed's blocking_plan
    raised."""
    u0, _ = dif.sine_init(16, 64)
    state = dif_sim.state(u0)
    pt = TPUModel().evaluate(
        dif_sim.explorer().workload, bh=8, m=2, double_buffer=True
    )
    budget = 2000  # fits (2, 2) single-buffered only (1536 B vs 3072 B)
    with pytest.raises(ValueError, match="fallback"):
        # sanity: with the fallback forbidden this budget is hopeless
        blocking_plan(16, 8, 2, width=64, words=1, vmem_bytes=budget // 2)
    block_h, m, nsteps, db = resolve_run_plan(
        16, pt, halo=dif_sim.kernel.halo, width=64, words=1,
        vmem_bytes=budget,
    )
    assert db is False and stripe_vmem_bytes(
        block_h, m, 64, 1, dif_sim.kernel.halo, db
    ) <= budget
    out = dif_sim.kernel.run_blocked(
        state, (0.2,), steps=nsteps, m=m, block_h=block_h, double_buffer=db
    )
    want = dif.diffusion_ref_run(u0, 0.2, nsteps)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               rtol=2e-5, atol=1e-6)
    # ...and bitwise against the unconstrained ping/pong run of the
    # same plan: the fallback changed the protocol, not the numerics.
    full = dif_sim.kernel.run_blocked(
        state, (0.2,), steps=nsteps, m=m, block_h=block_h,
        double_buffer=True,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(full))


# ----------------- accounting: one source of truth ---------------------------


@pytest.mark.parametrize("double_buffer", [True, False])
def test_model_vmem_accounting_is_the_legalizers(double_buffer):
    """ISSUE 7 satellite: the model's VMEM price IS
    legalize.stripe_vmem_bytes — for both protocols, any halo — so the
    multiplier cannot drift between dse.py and legalize.py again."""
    model = TPUModel()
    for halo in (0, 1, 2):
        w = StreamWorkload("t", 7, 3, 3, 100, 1000, 256 * 640,
                           grid_w=640, halo=halo)
        for bh, m in ((8, 1), (32, 4), (256, 8)):
            pt = model.evaluate(w, bh, m, double_buffer=double_buffer)
            assert pt.detail["vmem_bytes"] == stripe_vmem_bytes(
                bh, m, 640, 3, halo, double_buffer
            )
            assert pt.detail["double_buffer"] is double_buffer
            batch = model.evaluate_batch(
                w, [bh], [m], double_buffer=double_buffer
            )
            assert int(batch["vmem_bytes"][0]) == pt.detail["vmem_bytes"]


def test_single_buffer_halves_the_budget_and_widens_feasibility():
    """The fallback exists to buy headroom: a stripe priced infeasible
    ping/pong can be feasible single-buffered, at exactly half."""
    w = StreamWorkload("t", 7, 8, 8, 100, 1000, 4096 * 1440,
                       grid_w=1440, halo=1)
    model = TPUModel()
    over = next(
        bh for bh in (512, 1024, 2048, 4096)
        if stripe_vmem_bytes(bh, 4, 1440, 8, 1, True) > VMEM_BYTES
        and stripe_vmem_bytes(bh, 4, 1440, 8, 1, False) <= VMEM_BYTES
    )
    assert not model.evaluate(w, over, 4, double_buffer=True).feasible
    assert model.evaluate(w, over, 4, double_buffer=False).feasible


# ----------------- property: legal ⇒ half the budget, same bits --------------


@given(
    block_h=st.sampled_from([2, 4, 8, 16]),
    m=st.integers(min_value=1, max_value=4),
    words=st.integers(min_value=1, max_value=16),
    width=st.integers(min_value=1, max_value=400_000),
)
@settings(max_examples=40, deadline=None)
def test_prop_double_buffer_costs_exactly_double(block_h, m, words, width):
    """Any legal double-buffered plan needs exactly twice the VMEM of
    its single-buffered twin — the invariant the fallback banks on."""
    try:
        bh, mm, db = blocking_plan(16, block_h, m, width=width, words=words)
    except ValueError:
        return
    assert stripe_vmem_bytes(bh, mm, width, words, 1, True) == (
        2 * stripe_vmem_bytes(bh, mm, width, words, 1, False)
    )
    if db:
        # the honored ping/pong plan fits; its fallback twin fits in half
        assert stripe_vmem_bytes(bh, mm, width, words, 1, False) * 2 \
            <= VMEM_BYTES


@given(
    block_h=st.sampled_from([2, 4, 8, 16]),
    m=st.integers(min_value=1, max_value=4),
)
@settings(max_examples=10, deadline=None)
def test_prop_legal_plans_bitmatch_across_protocols(block_h, m):
    """Executable property (ISSUE 7): every legal (block_h, m) plan on
    the diffusion grid produces identical bits under both protocols."""
    sim = _prop_sim()
    if block_h not in legal_block_values(16, m, halo=sim.kernel.halo):
        return
    u0, _ = dif.sine_init(16, 64)
    state = sim.state(u0)
    pp, sb = _run_both(sim.kernel, state, (0.2,), m=m, block_h=block_h, d=1)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(sb))


_PROP_SIM = []


def _prop_sim():
    if not _PROP_SIM:
        _PROP_SIM.append(dif.DiffusionSimulation(16, 64, alpha=0.2))
    return _PROP_SIM[0]
