"""Multi-device (8 fake CPU devices) distribution tests.

Each case runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the main pytest process keeps seeing exactly one device
(required by the dry-run isolation policy)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, timeout: int = 900) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pjit_sharded_train_step_matches_single_device():
    run_in_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import set_mesh
        from repro.configs import get_arch
        from repro.configs.base import ShapeConfig
        from repro.models import registry
        from repro.parallel.sharding import build_param_specs
        from repro.train.optimizer import AdamWConfig, init_state

        cfg = dataclasses.replace(get_arch('qwen3-8b').reduced(),
                                  n_layers=2, d_model=64, vocab=128,
                                  n_heads=4, n_kv_heads=2, head_dim=16)
        bundle = registry.build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=100)
        opt = init_state(opt_cfg, params)
        step = bundle.make_train_step(opt_cfg)
        shape = ShapeConfig('t', 32, 4, 'train')
        batch = registry.make_batch(cfg, shape)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        # sharded: mesh (data=2, model=4)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        pspecs = build_param_specs(
            jax.eval_shape(bundle.init, jax.random.PRNGKey(0)),
            model_axis_size=4)
        with set_mesh(mesh):
            sh = lambda spec: NamedSharding(mesh, spec)
            params_s = jax.tree.map(
                lambda x, s: jax.device_put(x, sh(s)), params, pspecs)
            batch_s = {k: jax.device_put(v, sh(P('data', None)))
                       for k, v in batch.items()}
            opt_s = jax.device_put(opt, None)
            p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-4, \
            (float(m1['loss']), float(m2['loss']))
        a = jax.tree.leaves(p1)[0]; b = jax.tree.leaves(p2)[0]
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)
        print('pjit OK')
    """)


def test_pipeline_parallel_matches_sequential():
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import (pipelined_forward,
            stack_stage_params, pipeline_utilization)

        mesh = jax.make_mesh((8,), ('stage',))
        L, D, M, MB = 16, 32, 6, 4
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (L, D, D)) * (D ** -0.5)

        def layer(wl, x):
            return jnp.tanh(x @ wl)

        def stage_fn(stage_w, x):
            def body(c, wl):
                return layer(wl, c), None
            y, _ = jax.lax.scan(body, x, stage_w)
            return y

        micro = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
        stage_w = stack_stage_params(w, 8)
        run = pipelined_forward(mesh, stage_fn)
        got = run(stage_w, micro)

        want = micro
        for l in range(L):
            want = jax.vmap(lambda x: layer(w[l], x))(want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)
        assert abs(pipeline_utilization(6, 8) - 6/13) < 1e-9
        print('pipeline OK')
    """)


def test_compressed_psum_across_devices():
    run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.parallel.compression import (CompressionConfig,
            compressed_psum, init_residuals)

        mesh = jax.make_mesh((8,), ('data',))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.1
        grads = {'w': g}
        res = {'w': jnp.zeros((8, 64))}

        def body(gs, rs):
            return compressed_psum(gs, rs, 'data',
                                   CompressionConfig('int8_ef'))

        f = jax.jit(shard_map(body, mesh=mesh,
                    in_specs=(P('data', None), P('data', None)),
                    out_specs=(P(None), P('data', None))))
        # shard_map splits axis0; each worker sees (1, 64)
        mean_c, new_r = f(grads, res)
        want = np.asarray(g, np.float32).mean(axis=0, keepdims=True)
        got = np.asarray(mean_c['w'], np.float32)
        np.testing.assert_allclose(got, want, atol=2e-3)
        # error feedback residual = local grad - local dequantized
        assert float(np.abs(np.asarray(new_r['w'])).max()) < 2e-3
        # exact scheme is exact
        f0 = jax.jit(shard_map(
            lambda gs, rs: compressed_psum(gs, rs, 'data',
                                           CompressionConfig('none')),
            mesh=mesh, in_specs=(P('data', None), P('data', None)),
            out_specs=(P(None), P('data', None))))
        mean_e, _ = f0(grads, res)
        np.testing.assert_allclose(np.asarray(mean_e['w'], np.float32),
                                   want, rtol=1e-6)
        print('compression OK')
    """)


def test_dryrun_machinery_small_mesh():
    """De-risks the production dry-run: AOT lower/compile + cost analysis
    on an 8-device mesh for a reduced arch."""
    run_in_subprocess("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import set_mesh
        from repro.configs import get_arch
        from repro.configs.base import ShapeConfig
        from repro.models import registry
        from repro.parallel.sharding import build_param_specs
        from repro.train.optimizer import AdamWConfig, init_state

        cfg = dataclasses.replace(get_arch('mixtral-8x7b').reduced(),
                                  n_layers=2)
        bundle = registry.build(cfg)
        opt_cfg = AdamWConfig()
        step = bundle.make_train_step(opt_cfg)
        shape = ShapeConfig('t', 32, 8, 'train')

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        params_shape = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(lambda p: init_state(opt_cfg, p),
                                   params_shape)
        pspecs = build_param_specs(params_shape, n_experts=4,
                                   model_axis_size=4)
        ospecs = {'m': pspecs, 'v': pspecs, 'step': P()}
        from repro.models.registry import input_specs
        batch = input_specs(cfg, shape)
        sh = lambda s: NamedSharding(mesh, s)
        in_sh = (
            jax.tree.map(sh, pspecs),
            jax.tree.map(sh, ospecs),
            {k: sh(P('data', None)) for k in batch},
        )
        with set_mesh(mesh):
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                params_shape, opt_shape, batch)
            compiled = lowered.compile()
        from repro.compat import cost_analysis
        ca = cost_analysis(compiled)
        ma = compiled.memory_analysis()
        assert ca.get('flops', 0) > 0
        txt = compiled.as_text()
        assert 'all-reduce' in txt or 'all-gather' in txt
        print('dryrun-small OK, flops=%.3e' % ca['flops'])
    """)
