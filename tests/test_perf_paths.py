"""Correctness of the performance-path restructurings (EXPERIMENTS.md
§Perf): the two-stage MoE dispatch must be block-count invariant, and the
hints machinery must be a strict no-op when unmeshed."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import registry
from repro.models.layers import moe_apply, moe_init
from repro.parallel.hints import constrain, hint, hints_active, sharding_hints


def _moe_cfg():
    cfg = get_arch("mixtral-8x7b").reduced()
    return dataclasses.replace(cfg, d_model=64, n_heads=2, n_kv_heads=2,
                               head_dim=32)


def test_moe_dispatch_block_count_invariant():
    """nblk = 1 vs 4 must give identical outputs when capacity is ample:
    the two-stage dispatch is a layout change, not a semantics change."""
    cfg = _moe_cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)).astype(np.float32))
    y1 = moe_apply(p, x, cfg)  # nblk=1 (no hints)
    with sharding_hints(dp_size=4):
        y4 = moe_apply(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=2e-5,
                               atol=1e-6)


def test_moe_dispatch_nondivisible_blocks_fall_back():
    cfg = _moe_cfg()
    p = moe_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, 5, cfg.d_model)).astype(np.float32))
    with sharding_hints(dp_size=7):  # 15 tokens % 7 != 0 -> single block
        y = moe_apply(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(moe_apply(p, x, cfg)),
                               rtol=2e-5, atol=1e-6)


def test_moe_capacity_drops_per_block():
    """With tight capacity, drops are per-block: a hot expert in one block
    cannot starve another block's tokens."""
    cfg = _moe_cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
    )
    p = moe_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 4, cfg.d_model)).astype(np.float32))
    with sharding_hints(dp_size=8):
        y = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_hints_noop_when_inactive():
    assert not hints_active()
    assert hint("ep") is None
    x = jnp.ones((4, 4))
    assert constrain(x, lambda h: 1 / 0) is x  # spec_fn never called


def test_hints_nesting_restores():
    with sharding_hints(ep="model"):
        assert hint("ep") == "model"
        with sharding_hints(ep="other"):
            assert hint("ep") == "other"
        assert hint("ep") == "model"
    assert not hints_active()


def test_decode_consistency_survives_layout_hints():
    """Decode == teacher-forced forward even with dp/ep hints active (the
    flash-decoding constraints must not change semantics; single device =
    constraints are no-ops sharding-wise but the graph is the hinted one)."""
    cfg = dataclasses.replace(
        get_arch("granite-34b").reduced(), n_layers=2, d_model=64, vocab=97,
        n_heads=4, n_kv_heads=1, head_dim=16,
    )
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    full = bundle.forward(params, {"tokens": tokens})
    cache = bundle.cache_init(2, 8)
    with sharding_hints(dp_size=1):
        dec = bundle.make_decode_step()
        outs = []
        for t in range(8):
            lg, cache = dec(params, tokens[:, t:t + 1], cache,
                            jnp.asarray(t, jnp.int32))
            outs.append(lg[:, 0])
    got = jnp.stack(outs, 1).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full, np.float32), rtol=2e-2, atol=2e-2
    )
