"""SPD→Pallas stream codegen: stencil inference, bit-match vs the
compiler's reference function, equivalence with the hand-written
lbm_stream kernel, and the second-app explorer loop.

Load-bearing assertions (ISSUE 2 acceptance criteria):
* the codegen'd kernel ≡ m repeated applications of the compiled core's
  reference JAX function, *bitwise*, in interpret mode — for m ∈ {1,2,4}
  on fluid-only and walled lattices;
* the generated uLBM PE kernel ≡ the hand-written ``lbm_stream`` kernel;
* a second, non-LBM SPD app (2-D diffusion) sweeps, Pareto-filters, and
  executes its top-k TPU frontier points through its codegen'd kernel;
* the inferred halo is >= the largest stencil offset in the core
  (property test, hypothesis-optional).
"""

import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.core import (
    CodegenError,
    Registry,
    parse_spd,
    stencil_summary,
)
from repro.core.legalize import (
    VMEM_BYTES,
    blocking_plan,
    resolve_run_plan,
    stripe_vmem_bytes,
)

ONE_TAU = 1 / 0.8
LBM_REGS = (ONE_TAU, 0.0, 1.0)


@pytest.fixture(scope="module")
def lbm_kernel():
    sim = lbm.LBMSimulation(lbm.LBMProblem(16, 128, mode="wrap"))
    return sim.pe.stream_kernel()


def _lbm_state(kern, f, attr):
    return kern.pack([f[i] for i in range(9)] + [attr])


# ----------------------- stencil-offset inference -----------------------


def test_lbm_pe_stencil_inference(lbm_kernel):
    """The D2Q9 PE reads all 9 lattice directions; halo is one row."""
    s = lbm_kernel.summary
    want = {(int(lbm.EY[i]), int(lbm.EX[i])) for i in range(9)}
    assert set(s.offsets) == want
    assert s.halo_y == 1 and s.halo_x == 1
    assert s.modes == {"wrap"}


def test_offsets_compose_through_subcores():
    """Offsets accumulate additively along sub-core call chains."""
    reg = Registry()
    reg.compile(parse_spd("""
        Name ShiftY;
        Main_In {mi::a};
        Main_Out {mo::b};
        HDL S1, 0, (b) = Stencil2D(a), dy=1, dx=0, W=64, mode=wrap;
    """))
    outer = reg.compile(parse_spd("""
        Name Twice;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL N1, 0, (t) = ShiftY(x);
        HDL N2, 0, (y) = ShiftY(t);
    """))
    s = stencil_summary(outer)
    assert s.offsets == frozenset({(2, 0)})
    assert s.halo_y == 2 and s.halo_x == 0
    assert s.port_reads["y"] == frozenset({("x", 2, 0)})


def test_inference_rejects_1d_stream_state():
    reg = Registry()
    c = reg.compile(parse_spd("""
        Name HasDelay;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL D1, 0, (y) = Delay(x), 3;
    """))
    with pytest.raises(CodegenError, match="1-D stream"):
        stencil_summary(c)


def test_codegen_rejects_zero_mode_and_branch_ports():
    reg = Registry()
    zero = reg.compile(parse_spd("""
        Name ZeroMode;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL S1, 0, (y) = Stencil2D(x), dy=1, dx=0, W=64, mode=zero;
    """))
    with pytest.raises(CodegenError, match="mode"):
        zero.stream_kernel()
    brch = reg.compile(parse_spd("""
        Name HasBranch;
        Main_In {mi::x};
        Main_Out {mo::y};
        Brch_Out {bo::t};
        EQU N1, y = x + 1.0;
        DRCT (t) = (y);
    """))
    with pytest.raises(CodegenError, match="branch"):
        brch.stream_kernel()


def test_codegen_rejects_unchainable_port_counts():
    reg = Registry()
    c = reg.compile(parse_spd("""
        Name TwoToOne;
        Main_In {mi::a,b};
        Main_Out {mo::y};
        EQU N1, y = a + b;
    """))
    with pytest.raises(CodegenError, match="main_out"):
        c.stream_kernel()


@st.composite
def _rand_offsets(draw):
    n = draw(st.integers(1, 4))
    return [
        (draw(st.integers(-3, 3)), draw(st.integers(-3, 3)))
        for _ in range(n)
    ]


@given(_rand_offsets())
@settings(max_examples=30, deadline=None)
def test_inferred_halo_covers_max_offset(offsets):
    """Property: inferred halo >= the largest stencil offset in the DFG."""
    L = ["Name Rand;", "Main_In {mi::u};", "Main_Out {mo::v};"]
    terms = []
    for k, (dy, dx) in enumerate(offsets):
        L.append(
            f"HDL S{k}, 0, (t{k}) = Stencil2D(u), "
            f"dy={dy}, dx={dx}, W=32, mode=wrap;"
        )
        terms.append(f"t{k}")
    L.append(f"EQU N1, v = {' + '.join(terms)};")
    s = stencil_summary(Registry().compile(parse_spd("\n".join(L))))
    assert s.halo_y >= max(abs(dy) for dy, _ in offsets)
    assert s.halo_x >= max(abs(dx) for _, dx in offsets)
    assert s.offsets == frozenset(offsets)


# ----------------------- kernel ≡ compiler reference -----------------------


@pytest.mark.parametrize("m,block_h", [(1, 8), (2, 8), (4, 16)])
def test_kernel_bitmatches_reference_fluid(lbm_kernel, m, block_h):
    """Interpret-mode kernel == m applications of CompiledCore.apply,
    bit for bit, on a fluid-only (Taylor-Green) lattice."""
    f, attr, _ = lbm.taylor_green_init(16, 128)
    state = _lbm_state(lbm_kernel, f, attr)
    got = lbm_kernel(state, LBM_REGS, m=m, block_h=block_h, interpret=True)
    want = lbm_kernel.reference(state, LBM_REGS, m=m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m", [1, 2, 4])
def test_kernel_bitmatches_reference_walls(lbm_kernel, m):
    """Same contract on a walled lattice with a moving lid (Couette)."""
    f, attr = lbm.couette_init(16, 128)
    regs = (1 / 0.9, 0.07, 1.0)
    state = _lbm_state(lbm_kernel, f, attr)
    got = lbm_kernel(state, regs, m=m, block_h=8, interpret=True)
    want = lbm_kernel.reference(state, regs, m=m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_block_decomposition_independence(lbm_kernel):
    f, attr, _ = lbm.taylor_green_init(16, 128)
    state = _lbm_state(lbm_kernel, f, attr)
    a = lbm_kernel(state, LBM_REGS, m=2, block_h=8, interpret=True)
    b = lbm_kernel(state, LBM_REGS, m=2, block_h=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_blocked_multi_launch(lbm_kernel):
    f, attr, _ = lbm.taylor_green_init(16, 128)
    state = _lbm_state(lbm_kernel, f, attr)
    got = lbm_kernel.run_blocked(
        state, LBM_REGS, steps=8, m=4, block_h=8, interpret=True
    )
    want = lbm_kernel.reference(state, LBM_REGS, m=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_rejects_illegal_plans(lbm_kernel):
    f, attr, _ = lbm.taylor_green_init(16, 128)
    state = _lbm_state(lbm_kernel, f, attr)
    with pytest.raises(ValueError):
        lbm_kernel(state, LBM_REGS, m=1, block_h=5)  # 16 % 5 != 0
    with pytest.raises(ValueError):
        lbm_kernel(state, LBM_REGS, m=16, block_h=8)  # m*halo > block_h
    with pytest.raises(CodegenError):
        lbm_kernel(state, (1.0,), m=1, block_h=8)  # wrong register count


def test_x_offsets_beyond_row_width_wrap_modularly():
    """A dx larger than the concrete grid width must wrap like roll."""
    reg = Registry()
    big = reg.compile(parse_spd("""
        Name BigDX;
        Main_In {mi::u};
        Main_Out {mo::v};
        HDL S1, 0, (t) = Stencil2D(u), dy=0, dx=11, W=8, mode=wrap;
        EQU N1, v = t + 0.0;
    """))
    kern = big.stream_kernel()
    rng = np.random.default_rng(0)
    state = kern.pack([rng.standard_normal((8, 8)).astype(np.float32)])
    got = kern(state, m=1, block_h=8, interpret=True)
    want = kern.reference(state, m=1)  # fully periodic (jnp.roll)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_inference_rejects_output_arity_mismatch():
    """A call site declaring fewer outputs than the callee produces must
    error, not silently truncate."""
    reg = Registry()
    reg.compile(parse_spd("""
        Name TwoOut;
        Main_In {mi::a};
        Main_Out {mo::p,q};
        EQU N1, p = a + 1.0;
        EQU N2, q = a + 2.0;
    """))
    outer = reg.compile(parse_spd("""
        Name Truncates;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL N1, 0, (y) = TwoOut(x);
    """))
    with pytest.raises(CodegenError, match="declares"):
        stencil_summary(outer)


# ----------------------- generated ulbm ≡ hand-written kernel ---------------


@pytest.mark.parametrize("m,block_h", [(1, 8), (4, 8)])
def test_codegen_matches_handwritten_lbm_stream(lbm_kernel, m, block_h):
    """The generated uLBM kernel reproduces repro.kernels.lbm_stream."""
    from repro.kernels.lbm_stream.ops import lbm_multistep

    f, attr = lbm.couette_init(16, 128)
    state = _lbm_state(lbm_kernel, f, attr)
    got = lbm_kernel(
        state, (1 / 0.9, 0.07, 1.0), m=m, block_h=block_h, interpret=True
    )
    hand = lbm_multistep(f, attr, 1 / 0.9, 0.07, m=m, block_h=block_h)
    np.testing.assert_allclose(
        np.asarray(got[:9]), np.asarray(hand), rtol=2e-5, atol=1e-7
    )


# ----------------------- the second SPD app -----------------------


def test_diffusion_kernel_bitmatches_reference():
    sim = dif.DiffusionSimulation(32, 128, alpha=0.2)
    u0, _ = dif.sine_init(32, 128)
    state = sim.state(u0)
    for m in (1, 2, 4):
        got = sim.kernel(state, (0.2,), m=m, block_h=8, interpret=True)
        want = sim.kernel.reference(state, (0.2,), m=m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_diffusion_kernel_matches_jnp_oracle():
    sim = dif.DiffusionSimulation(16, 128, alpha=0.15)
    u0, _ = dif.sine_init(16, 128)
    got = sim.run(u0, 8, m=4, block_h=8)
    want = dif.diffusion_ref_run(u0, 0.15, 8)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6
    )


def test_diffusion_run_legalizes_default_block():
    """Default block_h must be legal for grids 32 does not divide."""
    sim = dif.DiffusionSimulation(30, 64, alpha=0.2)
    u0, _ = dif.sine_init(30, 64)
    got = sim.run(u0, 2, m=2)
    want = dif.diffusion_ref_run(u0, 0.2, 2)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6
    )


def test_diffusion_physics_decay():
    """Sinusoidal mode decays by the exact discrete factor per step."""
    sim = dif.DiffusionSimulation(32, 128, alpha=0.2)
    u0, decay = dif.sine_init(32, 128)
    steps = 40
    u = sim.run(u0, steps, m=4, block_h=8)
    ratio = float(jnp.linalg.norm(u) / jnp.linalg.norm(u0))
    assert ratio == pytest.approx(decay(0.2) ** steps, rel=1e-4)


def test_second_app_sweeps_and_executes_frontier():
    """ISSUE 2 acceptance: a non-LBM SPD core sweeps, Pareto-filters, and
    executes its top-k TPU frontier points through its codegen'd kernel."""
    sim = dif.DiffusionSimulation(32, 64, alpha=0.2)
    ex = sim.explorer()
    assert ex.core is sim.core  # compile -> explore plumbing
    sweep = ex.sweep_tpu(bh_values=(8, 16, 32), m_values=(1, 2, 4))
    frontier = sweep.frontier()
    assert frontier, "diffusion sweep produced an empty frontier"
    u0, _ = dif.sine_init(32, 64)
    state = sim.state(u0)
    runs = ex.execute_frontier(sweep, state, (0.2,), k=2)
    assert 1 <= len(runs) <= 2
    for r in runs:
        assert 32 % r.block_h == 0 and r.m <= r.block_h
        assert r.wall_s > 0 and np.isfinite(r.rel_error)
        assert r.predicted_gflops == pytest.approx(r.point.sustained_gflops)
    # ... and the executed state is the right physics, not just timed.
    out, (bh, m, _) = sim.kernel.run_for_point(
        state, (0.2,), point=frontier[0], interpret=True
    )
    want = dif.diffusion_ref_run(u0, 0.2, m)
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(want), rtol=2e-5, atol=1e-6
    )


# ----------------------- shared legalization -----------------------


def test_blocking_plan_halo_aware():
    # halo=2 doubles the per-step row consumption: m=4 needs block >= 8.
    assert blocking_plan(64, 64, 4, halo=2) == (64, 4, True)
    assert blocking_plan(64, 4, 4, halo=2) == (8, 4, True)  # up to m*halo
    # halo=0 (elementwise core): any divisor works.
    assert blocking_plan(64, 7, 64, halo=0) == (4, 64, True)
    # m*halo larger than the whole grid: m shrinks until sourceable...
    bh, m, _ = blocking_plan(8, 8, 8, halo=4)
    assert m >= 1 and m * 4 <= bh <= 8
    # ...but never below one step: an unsourceable halo is an error,
    # not a silent (bh, 0) plan.
    with pytest.raises(ValueError, match="halo"):
        blocking_plan(4, 8, 1, halo=8)


def test_model_and_legalizer_agree_on_stripe_geometry():
    """A model-feasible point is never shrunk by the VMEM clamp: both
    sides account the same (bh + 2·m·halo)-row stripe, for any halo."""
    from repro.core.dse import StreamWorkload, TPUModel

    for halo in (0, 1, 2):
        w = StreamWorkload("t", 7, 10, 10, 100, 1000, 4096 * 1440,
                           grid_w=1440, halo=halo)
        pt = TPUModel().evaluate(w, bh=512, m=8)
        assert pt.detail["vmem_bytes"] == stripe_vmem_bytes(
            512, 8, 1440, 10, halo=halo
        )
        if pt.feasible:
            bh, m, db = blocking_plan(4096, 512, 8, halo=halo,
                                      width=1440, words=10)
            assert (bh, m, db) == (512, 8, True), (
                f"feasible point shrunk at halo={halo}"
            )


def test_report_halo_propagates_to_workload():
    """Composed dy=1 sub-cores infer halo 2, and it reaches the DSE
    workload through HardwareReport (no implicit halo=1 anywhere)."""
    reg = Registry()
    reg.compile(parse_spd("""
        Name ShiftY1;
        Main_In {mi::a};
        Main_Out {mo::b};
        HDL S1, 0, (b) = Stencil2D(a), dy=1, dx=0, W=64, mode=wrap;
    """))
    outer = reg.compile(parse_spd("""
        Name Chain2;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL N1, 0, (t) = ShiftY1(x);
        HDL N2, 0, (y) = ShiftY1(t);
    """))
    assert outer.hardware_report.halo == 2
    assert outer.hardware_report.workload(elems=64 * 64, grid_w=64).halo == 2
    # Cores the codegen rejects (1-D stream state) fall back to halo=1.
    delayed = reg.compile(parse_spd("""
        Name HasDelay1;
        Main_In {mi::x};
        Main_Out {mo::y};
        HDL D1, 0, (y) = Delay(x), 3;
    """))
    assert delayed.hardware_report.halo == 1


def test_blocking_plan_vmem_clamp():
    # A stripe of 10 f32 words x 720 columns: huge blocks blow VMEM, so
    # the legalizer must come down to a divisor whose stripe fits.
    h, width, words = 4096, 720, 10
    bh, m, db = blocking_plan(h, 4096, 4, width=width, words=words)
    assert stripe_vmem_bytes(bh, m, width, words,
                             double_buffer=db) <= VMEM_BYTES
    assert h % bh == 0
    # Without the clamp the request would have been honored.
    assert blocking_plan(h, 4096, 4) == (4096, 4, True)
    # When no legal block fits the budget — not even the single-buffer
    # streaming fallback — fail loudly rather than hand back a plan
    # that dies with an on-device allocation error.
    with pytest.raises(ValueError, match="VMEM"):
        blocking_plan(251, 251, 1, width=100_000, words=200)


def test_resolve_run_plan_threads_halo():
    from repro.core.dse import TPUModel, StreamWorkload

    w = StreamWorkload("t", 7, 1, 1, 100, 1000, 32 * 64, grid_w=64)
    pt = TPUModel().evaluate(w, bh=16, m=8)
    block_h, m, nsteps, db = resolve_run_plan(32, pt, halo=2)
    assert 32 % block_h == 0 and m * 2 <= block_h
    assert nsteps == m and db is True


@given(
    h=st.sampled_from([32, 64, 256, 4096]),
    block_h=st.integers(min_value=1, max_value=8192),
    m=st.integers(min_value=1, max_value=64),
    halo=st.integers(min_value=1, max_value=3),
    width=st.integers(min_value=1, max_value=200_000),
    words=st.integers(min_value=1, max_value=32),
)
@settings(max_examples=80, deadline=None)
def test_prop_blocking_plan_never_exceeds_vmem(h, block_h, m, halo,
                                               width, words):
    """ISSUE 6 satellite property: any plan blocking_plan hands back
    fits the shared VMEM budget — the same invariant the codegen'd
    kernels rely on to never die with an on-device allocation error."""
    from repro.core.legalize import constraint_violation

    try:
        bh, mm, db = blocking_plan(h, block_h, m, halo=halo, width=width,
                                   words=words)
    except ValueError:
        # infeasible request: the continuous distance must agree
        assert constraint_violation(
            h, block_h, m, halo=halo, width=width, words=words
        ) > 0.0
        return
    assert h % bh == 0
    assert stripe_vmem_bytes(bh, mm, width, words, halo, db) <= VMEM_BYTES
