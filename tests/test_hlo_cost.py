"""Trip-count-aware HLO cost analyzer: scan == unroll == analytic truth."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo

X = jax.ShapeDtypeStruct((128, 256), jnp.float32)
W = jax.ShapeDtypeStruct((256, 256), jnp.float32)
WANT10 = 2 * 128 * 256 * 256 * 10


def _flops(f):
    return analyze_hlo(jax.jit(f).lower(X, W).compile().as_text()).flops


def test_scan_trip_scaling():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    assert abs(_flops(f) - WANT10) / WANT10 < 0.01


def test_unrolled_matches_scan():
    def f(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    assert abs(_flops(f) - WANT10) / WANT10 < 0.01


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    want = 2 * 128 * 256 * 256 * 20
    assert abs(_flops(f) - want) / want < 0.01


def test_collectives_counted_inside_loops():
    """psum inside a scan must scale by the trip count."""
    import numpy as np
    from jax.sharding import PartitionSpec as P

    if jax.device_count() < 1:
        return

    from repro.compat import shard_map

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "i"), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    mesh = jax.make_mesh((1,), ("i",))
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P(), out_specs=P()))
    hlo = g.lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile().as_text()
    hc = analyze_hlo(hlo)
    # 7 iterations x 64 floats x 4B (device_count=1 may elide the op; accept
    # either exact scaling or elision)
    assert hc.coll_bytes in (0, 7 * 64 * 4) or hc.coll_bytes % (64 * 4) == 0
