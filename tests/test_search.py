"""Search subsystem: strategies, budget accounting, plan dedupe, cache
composition (docs/pipeline.md §search, DESIGN.md §10).

The load-bearing assertions (ISSUE 5 acceptance criteria):

* on the CI lattice, LocalRefine and SuccessiveHalving each find a
  point whose *measured* GFLOPS is >= 95% of the exhaustively-measured
  best while spending strictly fewer measurements than exhaustive;
* the hard budget is never exceeded (asserted with a deterministic
  fake timer that counts every live timing);
* successive halving promotes the *measured* best even when the model
  mis-ranks it;
* measurement-cache hits carry across strategy re-runs, so strategies
  compose.

All strategy-logic tests run with an injected deterministic timer
(wall time derived from the analytic model of the legalized plan), so
no kernel executes and no host-timing noise can flake the assertions;
one end-to-end test drives a real codegen'd kernel through
``Explorer.search``.
"""

import numpy as np
import pytest
from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st
from _search_harness import (
    BH_VALUES,
    H,
    M_VALUES,
    TOY,
    W,
    ModelTimer,
    _rf,
)

from repro.core.explorer import Explorer
from repro.core.legalize import (
    VMEM_BYTES,
    blocking_plan,
    constraint_violation,
    legal_block_values,
    shard_height,
    stripe_vmem_bytes,
)
from repro.core.measure import MeasurementCache
from repro.core.search import (
    PLAN_FIELDS,
    BudgetExhausted,
    ExhaustiveSearch,
    LocalRefine,
    SearchResult,
    SuccessiveHalving,
    TPESearch,
    get_strategy,
)


@pytest.fixture()
def ex():
    return Explorer(TOY)


@pytest.fixture()
def sweep(ex):
    return ex.sweep_tpu(
        bh_values=BH_VALUES, m_values=M_VALUES, d_values=(1,)
    )


def _search(ex, sweep, timer, **kw):
    kw.setdefault("run_factory", _rf)
    kw.setdefault("grid_shape", (H, W))
    kw.setdefault("calibrate", False)
    return ex.search(sweep, timer=timer, **kw)


# ----------------------- strategy registry -----------------------


def test_get_strategy_registry():
    assert isinstance(get_strategy("exhaustive"), ExhaustiveSearch)
    assert isinstance(get_strategy("refine"), LocalRefine)
    assert isinstance(get_strategy("halving"), SuccessiveHalving)
    assert isinstance(get_strategy("tpe"), TPESearch)
    inst = SuccessiveHalving(eta=2)
    assert get_strategy(inst) is inst
    assert isinstance(get_strategy(LocalRefine), LocalRefine)
    with pytest.raises(ValueError, match="unknown search strategy"):
        get_strategy("simulated-annealing")
    with pytest.raises(TypeError, match="SearchStrategy"):
        get_strategy(object())


# ----------------------- acceptance: strategies vs exhaustive ---------------


def test_budgeted_strategies_match_exhaustive_best(ex, sweep):
    """ISSUE 5 acceptance: on the CI lattice, refine and halving each
    find a point whose measured GFLOPS is >= 95% of the exhaustively-
    measured best while spending strictly fewer measurements."""
    timer = ModelTimer()
    exhaustive = _search(
        ex, sweep, timer, strategy=ExhaustiveSearch(frontier_only=False)
    )
    n_candidates = len({
        (e.block_h, e.m, e.steps, e.d) for e in exhaustive.executed
    })
    assert n_candidates > 12  # wide enough that budgeting means something
    assert exhaustive.budget_spent == n_candidates
    best = exhaustive.best.measured_gflops

    for strat in ("refine", "halving"):
        timer_s = ModelTimer()
        res = _search(ex, sweep, timer_s, strategy=strat, budget=12)
        assert res.strategy == strat
        assert res.best is not None
        assert res.best.measured_gflops >= 0.95 * best, strat
        assert res.budget_spent < exhaustive.budget_spent, strat
        assert res.budget_spent == len(timer_s.calls), strat


def test_exhaustive_frontier_only_reproduces_execute_frontier(ex, sweep):
    """The facade strategy walks the frontier top-down and stops at k."""
    timer = ModelTimer()
    res = _search(
        ex, sweep, timer,
        strategy=ExhaustiveSearch(k=2, frontier_only=True),
    )
    frontier = sweep.frontier()
    assert len(res.executed) == 2
    assert [e.point.key() for e in res.executed] == [
        p.key() for p in frontier[:2]
    ]


# ----------------------- budget: hard, never exceeded -----------------------


@pytest.mark.parametrize("strat", ["exhaustive", "refine", "halving", "tpe"])
def test_budget_never_exceeded(ex, sweep, strat):
    for budget in (1, 3, 7):
        timer = ModelTimer()
        res = _search(ex, sweep, timer, strategy=strat, budget=budget)
        assert res.budget == budget
        assert res.budget_spent <= budget, (strat, budget)
        assert len(timer.calls) == res.budget_spent, (strat, budget)
        # the ledger agrees with the timer's own count
        assert sum(m["count"] for m in res.measurements) == res.budget_spent


def test_budget_validation_and_exhaustion(ex, sweep):
    with pytest.raises(ValueError, match="budget"):
        _search(ex, sweep, ModelTimer(), budget=0)

    class Greedy:
        name = "greedy"

        def search(self, sweep, runner):
            # a buggy strategy that ignores exhaustion must be stopped
            with pytest.raises(BudgetExhausted):
                for pt in sweep.frontier() * 50:
                    runner.measure(pt)
            return []

    timer = ModelTimer()
    res = _search(ex, sweep, timer, strategy=Greedy(), budget=2)
    assert res.budget_spent == 2 and len(timer.calls) == 2


# ----------------------- successive halving -----------------------


def test_halving_promotes_the_measured_best(ex, sweep):
    """When measurement disagrees with the model, the measured winner
    must survive every rung and come out full-rep at the top."""
    # model rank of (8, 1) is near the bottom (memory-bound, m=1) —
    # boost it 16x so it *measures* fastest (the model's spread across
    # this lattice is ~8x, so 16x puts it clear of every prediction).
    timer = ModelTimer(boost={(8, 1, 1): 16.0})
    res = _search(
        ex, sweep, timer, strategy=SuccessiveHalving(eta=2), reps=3,
    )
    b = res.best
    assert (b.block_h, b.m, b.d) == (8, 1, 1)
    assert b.reps == 3  # full-rep final, not the 1-rep screening number
    # ... and the runner really did screen cheap first
    assert any(p.reps == 1 for p in timer.calls)
    assert any(
        p.reps == 3 and (p.block_h, p.m) == (8, 1) for p in timer.calls
    )


def test_best_ignores_lucky_screening_rep(ex, sweep):
    """A 1-rep screening fluke on a plan must not outrank that same
    plan's honest full-rep final in ``SearchResult.best``."""
    base = ModelTimer()

    def flaky(plan, run, reps, warmup):
        wall = base(plan, run, reps, warmup)
        if reps == 1:  # screening runs get a lucky 10x-short wall
            wall /= 10.0
        return wall

    res = _search(
        ex, sweep, flaky, strategy=SuccessiveHalving(eta=2), reps=3,
    )
    b = res.best
    assert b.reps == 3  # the honest final, not the flukey screening
    # the same plan's screening measurement is in `executed` and looks
    # 10x better — best must have skipped past it
    screened = [
        e for e in res.executed
        if (e.block_h, e.m, e.d) == (b.block_h, b.m, b.d) and e.reps == 1
    ]
    assert screened and screened[0].measured_gflops > b.measured_gflops


def test_injected_timer_walls_never_serve_honest_runs(ex, sweep, tmp_path):
    """Synthetic walls from a fake timer live in their own cache-key
    namespace: an honest search over the same plans must re-time, not
    inherit fabricated numbers."""
    cache = MeasurementCache(tmp_path / "m.json")
    fake = _search(
        ex, sweep, ModelTimer(),
        strategy=ExhaustiveSearch(k=2, frontier_only=True),
        cache=cache, cache_tag="toy",
    )
    assert fake.budget_spent > 0
    # identical reps/plans: only the key namespace separates the runs
    honest = _search(
        ex, sweep, None,  # timer=None: the real harness
        strategy=ExhaustiveSearch(k=2, frontier_only=True),
        cache=cache, cache_tag="toy",
    )
    assert honest.budget_spent > 0  # not served the fabricated walls
    assert not any(e.cached for e in honest.executed)


def test_halving_sizes_rung0_to_the_budget(ex, sweep):
    """With budget B and eta, rung 0 takes ~B(eta-1)/eta candidates so
    the whole geometric schedule fits inside B."""
    timer = ModelTimer()
    res = _search(
        ex, sweep, timer, strategy=SuccessiveHalving(eta=3), budget=12,
    )
    rung0 = [p for p in timer.calls if p.reps == 1]
    assert len(rung0) <= 8  # 12 * (3-1)/3
    assert res.budget_spent <= 12


# ----------------------- local refine -----------------------


def test_refine_walks_block_h_off_the_lattice(ex):
    """block_h is first-class: refine reaches divisors of h the sweep
    lattice never proposed when they measure faster."""
    # Lattice only offers bh in {16, 64}; on h=64 the divisor chain has
    # 32 between them. Boost 32 so measurement pulls the climb there.
    sweep = ex.sweep_tpu(bh_values=(16, 64), m_values=(2,), d_values=(1,))
    best_m = 2
    timer = ModelTimer(boost={(32, best_m, 1): 10.0})
    res = _search(ex, sweep, timer, strategy=LocalRefine(seeds=1))
    assert res.best.block_h == 32  # not a lattice value
    assert 32 in legal_block_values(H, best_m, halo=TOY.halo)


def test_refine_improves_on_a_mis_ranked_seed(ex, sweep):
    """Hill-climb: when a neighbor measures better than the model-best
    seed, refine moves to it."""
    timer = ModelTimer(boost={(32, 8, 1): 6.0})
    res = _search(ex, sweep, timer, strategy=LocalRefine(seeds=1))
    assert (res.best.block_h, res.best.m) == (32, 8)


# ----------------------- plan dedupe -----------------------


def test_distinct_lattice_points_same_plan_timed_once(ex):
    """Satellite (ISSUE 5): lattice points that legalize to the same
    concrete plan are measured once per search even with the cache
    off."""
    # On h=64, requests 64/128/256 with m=2 all legalize to block 64.
    sweep = ex.sweep_tpu(
        bh_values=(64, 128, 256), m_values=(2,), d_values=(1,)
    )
    assert all(
        blocking_plan(H, int(bh), 2) == (64, 2, True)
        for bh in (64, 128, 256)
    )
    timer = ModelTimer()
    res = _search(
        ex, sweep, timer, strategy=ExhaustiveSearch(frontier_only=False)
    )
    assert len(timer.calls) == 1  # one concrete plan -> one live timing
    assert res.budget_spent == 1


# ----------------------- cache composition across strategies ----------------


def test_cache_hits_carry_across_strategy_reruns(ex, sweep, tmp_path):
    """Satellite (ISSUE 5): a second strategy (and a repeated search)
    over the same lattice is served from the measurement cache — its
    budget goes only to plans nobody timed yet."""
    cache = MeasurementCache(tmp_path / "m.json")
    t1 = ModelTimer()
    first = _search(
        ex, sweep, t1, strategy=ExhaustiveSearch(frontier_only=False),
        cache=cache, cache_tag="toy",
    )
    assert first.budget_spent == len(t1.calls) > 12
    assert not any(e.cached for e in first.executed)

    # identical exhaustive re-run: all hits, zero spent
    t2 = ModelTimer()
    again = _search(
        ex, sweep, t2, strategy=ExhaustiveSearch(frontier_only=False),
        cache=cache, cache_tag="toy",
    )
    assert again.budget_spent == 0 and not t2.calls
    assert all(e.cached for e in again.executed)

    # a different strategy at the same reps pays only for new plans
    t3 = ModelTimer()
    refined = _search(
        ex, sweep, t3, strategy="refine", cache=cache, cache_tag="toy",
    )
    hits = sum(1 for e in refined.executed if e.cached)
    assert hits > 0  # the seeds were already timed by the exhaustive pass
    assert refined.budget_spent < first.budget_spent
    assert refined.budget_spent == len(t3.calls)


# ----------------------- result schema -----------------------


def test_search_result_schema(ex, sweep):
    res = _search(ex, sweep, ModelTimer(), strategy="halving", budget=6)
    assert isinstance(res, SearchResult)
    d = res.as_dict()
    for key in ("strategy", "budget", "budget_spent", "measurements",
                "best", "executed", "skipped_devices", "skipped_illegal"):
        assert key in d
    assert d["strategy"] == "halving" and d["budget"] == 6
    assert d["budget_spent"] == res.budget_spent
    for m in d["measurements"]:
        assert set(m) == set(PLAN_FIELDS) | {"count"}
        assert m["count"] >= 1
    assert d["best"] == res.best.as_dict()


# ----------------------- legalize: deterministic properties -----------------


def test_constraint_violation_zero_iff_feasible():
    """ISSUE 6 satellite: the continuous distance is 0 exactly when
    blocking_plan would produce a legal plan — over a dense grid of
    (h, block_h, m, d, width) requests, including VMEM-tight ones."""
    words = 8
    for h in (7, 16, 60, 64):
        for m in (1, 2, 4, 16):
            for d in (1, 2, 3):
                for width in (0, 64, 600_000, 3_000_000):
                    v = constraint_violation(
                        h, 16, m, halo=1, width=width, words=words, d=d
                    )
                    try:
                        blocking_plan(
                            h, 16, m, halo=1, width=width, words=words, d=d
                        )
                        legal = True
                    except ValueError:
                        legal = False
                    assert (v == 0.0) == legal, (h, m, d, width)
                    assert v >= 0.0


def test_constraint_violation_monotone_in_vmem_overshoot():
    """The deeper the smallest legal stripe overflows VMEM, the larger
    the distance — the gradient surrogate samplers follow."""
    words = 8
    widths = (1_000_000, 2_000_000, 4_000_000, 8_000_000)
    vals = [
        constraint_violation(64, 64, 2, halo=1, width=w, words=words)
        for w in widths
    ]
    assert vals[0] > 0.0  # all of these overflow the budget
    assert all(b > a for a, b in zip(vals, vals[1:]))  # strictly monotone
    # ... and scale-free: violation is the fractional overshoot of the
    # *single-buffer streaming fallback* — the last protocol blocking_plan
    # tries before giving up, so distance-to-feasible is measured from it.
    need = min(
        stripe_vmem_bytes(v, 2, widths[0], words, 1, double_buffer=False)
        for v in legal_block_values(64, 2, halo=1, double_buffer=False)
    )
    assert vals[0] == pytest.approx((need - VMEM_BYTES) / VMEM_BYTES)


def test_constraint_violation_unshardable_and_unsourceable():
    # h % d != 0: no closest legal plan at all — above every VMEM case
    assert constraint_violation(64, 16, 2, d=3) > 1.0
    # halo taller than the shard: the m-shrink loop cannot save it
    assert constraint_violation(4, 4, 1, halo=8) > 1.0
    with pytest.raises(ValueError):
        constraint_violation(0, 8, 1)
    with pytest.raises(ValueError):
        constraint_violation(64, 8, 1, d=0)


# ----------------------- legalize: hypothesis properties ---------------------


@given(
    h=st.integers(min_value=1, max_value=512),
    m=st.integers(min_value=1, max_value=64),
    halo=st.integers(min_value=0, max_value=4),
    d=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=80, deadline=None)
def test_prop_legal_block_values_divide_the_shard(h, m, halo, d):
    if h % d:
        with pytest.raises(ValueError, match="shards"):
            legal_block_values(h, m, halo=halo, d=d)
        return
    chain = legal_block_values(h, m, halo=halo, d=d)
    local_h = shard_height(h, d)
    for v in chain:
        assert local_h % v == 0
        assert v >= max(1, min(m, local_h) * halo) or halo == 0
    assert list(chain) == sorted(chain)


@given(
    h=st.sampled_from([16, 64, 120, 256]),
    block_h=st.integers(min_value=1, max_value=512),
    m=st.integers(min_value=1, max_value=32),
    width=st.integers(min_value=1, max_value=400_000),
    words=st.integers(min_value=1, max_value=16),
)
@settings(max_examples=80, deadline=None)
def test_prop_blocking_plan_respects_vmem(h, block_h, m, width, words):
    """Whenever blocking_plan returns, its stripe fits the VMEM budget
    — and constraint_violation agrees it is feasible."""
    try:
        bh, mm, db = blocking_plan(h, block_h, m, halo=1, width=width,
                                   words=words)
    except ValueError:
        assert constraint_violation(
            h, block_h, m, halo=1, width=width, words=words
        ) > 0.0
        return
    assert h % bh == 0 and mm * 1 <= bh * mm  # legal divisor, sane m
    assert stripe_vmem_bytes(bh, mm, width, words, 1, db) <= VMEM_BYTES
    assert constraint_violation(
        h, block_h, m, halo=1, width=width, words=words
    ) == 0.0


def test_hypothesis_stub_contract():
    """The shim must expose the four names whether or not hypothesis is
    installed (so this module always collects)."""
    assert isinstance(HAVE_HYPOTHESIS, bool)
    assert callable(given) and callable(settings)


def test_legal_block_values_units():
    # divisor chain of 64 that can source m*halo rows
    assert legal_block_values(64, 4, halo=1) == (4, 8, 16, 32, 64)
    assert legal_block_values(64, 1, halo=0) == (1, 2, 4, 8, 16, 32, 64)
    # per-shard: chain over 64/2 = 32 rows
    assert legal_block_values(64, 2, halo=1, d=2) == (2, 4, 8, 16, 32)
    # VMEM clamp prunes the top of the chain like blocking_plan does
    wide = legal_block_values(64, 2, halo=1, width=100_000, words=10)
    assert wide and max(wide) < 64
    with pytest.raises(ValueError, match="shards"):
        legal_block_values(64, 2, d=3)


# ----------------------- end to end: a real kernel -----------------------


def test_search_executes_real_codegen_kernel():
    """One honest pass: LocalRefine drives the real diffusion Pallas
    kernel (interpret mode) through Explorer.search."""
    from repro.apps import diffusion as dif

    sim = dif.DiffusionSimulation(32, 64, alpha=0.2)
    ex = sim.explorer()
    sweep = ex.sweep_tpu(
        bh_values=(8, 16, 32), m_values=(1, 2, 4), d_values=(1,)
    )
    u0, _ = dif.sine_init(32, 64)
    res = ex.search(
        sweep, sim.state(u0), (sim.alpha,), strategy="refine",
        budget=8, reps=1, calibrate=False,
    )
    assert res.budget_spent <= 8
    assert res.executed and res.best.wall_s > 0
    for e in res.executed:
        assert 32 % e.block_h == 0 and e.m <= e.block_h
        assert np.isfinite(e.measured_gflops) and e.measured_gflops > 0
