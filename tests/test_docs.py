"""Docs stay wired to the code: every ``DESIGN.md §…`` reference in src/
must resolve to a real section anchor in DESIGN.md."""

import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REF_RE = re.compile(r"DESIGN\.md\s+(§[\w-]+)")
ANCHOR_RE = re.compile(r"^#+\s+(§[\w-]+)", re.MULTILINE)


def _src_refs():
    refs = []
    for dirpath, _, files in os.walk(os.path.join(ROOT, "src")):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path, encoding="utf-8") as fh:
                for anchor in REF_RE.findall(fh.read()):
                    refs.append((os.path.relpath(path, ROOT), anchor))
    return refs


def test_design_md_exists():
    assert os.path.exists(os.path.join(ROOT, "DESIGN.md"))


def test_every_design_ref_resolves():
    with open(os.path.join(ROOT, "DESIGN.md"), encoding="utf-8") as fh:
        anchors = set(ANCHOR_RE.findall(fh.read()))
    assert anchors, "DESIGN.md has no § section anchors"
    refs = _src_refs()
    assert refs, "expected DESIGN.md references in src/ docstrings"
    missing = [(f, a) for f, a in refs if a not in anchors]
    assert not missing, f"unresolved DESIGN.md references: {missing}"


def test_readme_quickstart_matches_roadmap():
    """README's quickstart must carry the tier-1 command from ROADMAP.md."""
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as fh:
        readme = fh.read()
    assert "python -m pytest -x -q" in readme
    assert "PYTHONPATH=src" in readme
