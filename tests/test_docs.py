"""Docs stay wired to the code.

* every ``DESIGN.md §…`` / ``docs/<name>.md §…`` reference in a src/
  docstring must resolve to a real section anchor in that file;
* every anchor docs/pipeline.md defines must be *cited* by at least one
  src/ docstring (the pipeline doc describes real stages, not vapor);
* every fenced ``spd`` snippet in docs/*.md must parse via the real
  parser, ``repro.core.spd`` (fragments get a ``Name`` prepended).
"""

import glob
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REF_RE = re.compile(r"(DESIGN\.md|docs/[\w-]+\.md)\s+(§[\w-]+)")
ANCHOR_RE = re.compile(r"^#+\s+(§[\w-]+)", re.MULTILINE)
SPD_SNIPPET_RE = re.compile(r"```spd\n(.*?)```", re.DOTALL)


def _doc_files() -> list[str]:
    """Anchor-bearing docs, as repo-relative paths (the citation form)."""
    docs = ["DESIGN.md"] + sorted(
        os.path.relpath(p, ROOT).replace(os.sep, "/")
        for p in glob.glob(os.path.join(ROOT, "docs", "*.md"))
    )
    return docs


def _read(rel: str) -> str:
    with open(os.path.join(ROOT, rel), encoding="utf-8") as fh:
        return fh.read()


def _anchors(rel: str) -> set[str]:
    return set(ANCHOR_RE.findall(_read(rel)))


def _src_refs():
    """All (src file, doc, anchor) citations found under src/."""
    refs = []
    for dirpath, _, files in os.walk(os.path.join(ROOT, "src")):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path, encoding="utf-8") as fh:
                for doc, anchor in REF_RE.findall(fh.read()):
                    refs.append((os.path.relpath(path, ROOT), doc, anchor))
    return refs


def test_doc_files_exist():
    for rel in ["DESIGN.md", "docs/pipeline.md", "docs/spd_reference.md"]:
        assert os.path.exists(os.path.join(ROOT, rel)), rel


def test_every_doc_ref_resolves():
    """src/ docstrings may only cite anchors that actually exist."""
    anchors = {rel: _anchors(rel) for rel in _doc_files()}
    assert anchors["DESIGN.md"], "DESIGN.md has no § section anchors"
    refs = _src_refs()
    assert refs, "expected doc references in src/ docstrings"
    missing = [
        (f, doc, a)
        for f, doc, a in refs
        if a not in anchors.get(doc, set())
    ]
    assert not missing, f"unresolved doc references: {missing}"


def test_pipeline_anchors_all_cited_from_src():
    """docs/pipeline.md describes the real pipeline: every stage anchor
    it defines is cited by at least one src/ docstring."""
    defined = _anchors("docs/pipeline.md")
    assert defined, "docs/pipeline.md has no § stage anchors"
    cited = {a for _, doc, a in _src_refs() if doc == "docs/pipeline.md"}
    uncited = defined - cited
    assert not uncited, (
        f"docs/pipeline.md anchors never cited from src/: {sorted(uncited)}"
    )


def test_spd_reference_snippets_parse():
    """Every ```spd fence in docs/ parses through the real front end."""
    from repro.core.spd import parse_spd

    total = 0
    for rel in _doc_files():
        if not rel.startswith("docs/"):
            continue
        for i, snippet in enumerate(SPD_SNIPPET_RE.findall(_read(rel))):
            if not re.search(r"^\s*Name\b", snippet, re.MULTILINE):
                snippet = "Name snippet;\n" + snippet  # statement fragment
            try:
                core = parse_spd(snippet)
            except Exception as e:  # pragma: no cover - failure reporting
                raise AssertionError(
                    f"{rel} spd snippet #{i} does not parse: {e}\n{snippet}"
                ) from e
            assert core.name
            total += 1
    assert total >= 10, f"expected a real grammar reference, got {total} snippets"


def test_readme_quickstart_matches_roadmap():
    """README's quickstart must carry the tier-1 command from ROADMAP.md."""
    readme = _read("README.md")
    assert "python -m pytest -x -q" in readme
    assert "PYTHONPATH=src" in readme


def test_readme_links_pipeline_docs():
    readme = _read("README.md")
    assert "docs/pipeline.md" in readme
    assert "docs/spd_reference.md" in readme
