"""Hypothesis import shim so the tier-1 suite degrades gracefully.

``hypothesis`` is an optional dependency (see requirements.txt). Modules
that mix property tests with plain pytest tests import through this shim::

    from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed, these are the real objects. When it is not,
``@given(...)`` marks the test skipped and ``st`` absorbs any
strategy-building expression at module scope, so the plain tests in the
same file still collect and run instead of the whole module erroring out.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs strategy construction: every attribute/call returns self."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
