"""Model-internal correctness: chunked scans vs sequential oracles."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ArchConfig, SSMConfig
from repro.models import mamba2 as m2
from repro.models import xlstm as xl


def _mamba_cfg(chunk=8, head_dim=16, state=16, d_model=96):
    return dataclasses.replace(
        get_arch("zamba2-7b").reduced(),
        d_model=d_model,
        ssm=SSMConfig(state=state, head_dim=head_dim, expand=2, conv=4,
                      chunk=chunk),
    )


@pytest.mark.parametrize("chunk,s", [(8, 32), (16, 16), (4, 24)])
def test_mamba2_chunked_equals_sequential(chunk, s):
    """Heads != chunk length on purpose (catches axis-order bugs)."""
    cfg = _mamba_cfg(chunk=chunk)
    p = m2.mamba2_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 2
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    y_full = m2.mamba2_apply(p, x, cfg)
    st = m2.mamba2_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, st = m2.mamba2_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y[:, 0])
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_dec), rtol=2e-4, atol=2e-5
    )


def test_mamba2_chunk_boundary_invariance():
    cfg8 = _mamba_cfg(chunk=8)
    cfg16 = _mamba_cfg(chunk=16)
    p = m2.mamba2_init(cfg8, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg8.d_model)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(m2.mamba2_apply(p, x, cfg8)),
        np.asarray(m2.mamba2_apply(p, x, cfg16)),
        rtol=2e-4, atol=2e-5,
    )


def _xlstm_cfg(chunk=8):
    return dataclasses.replace(
        get_arch("xlstm-125m").reduced(),
        d_model=96, n_heads=4, n_kv_heads=4,
        ssm=SSMConfig(chunk=chunk),
    )


@pytest.mark.parametrize("chunk,s", [(8, 32), (16, 16)])
def test_mlstm_chunked_equals_decode(chunk, s):
    cfg = _xlstm_cfg(chunk=chunk)
    p = xl.mlstm_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 2
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    y_full = xl.mlstm_block_apply(p, x, cfg)
    st = xl.mlstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, st = xl.mlstm_block_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y[:, 0])
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(y_dec), rtol=2e-3, atol=2e-4
    )


def test_mlstm_chunk_boundary_invariance():
    cfg8, cfg16 = _xlstm_cfg(8), _xlstm_cfg(16)
    p = xl.mlstm_init(cfg8, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg8.d_model)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(xl.mlstm_block_apply(p, x, cfg8)),
        np.asarray(xl.mlstm_block_apply(p, x, cfg16)),
        rtol=2e-3, atol=2e-4,
    )


def test_slstm_apply_equals_decode():
    cfg = _xlstm_cfg()
    p = xl.slstm_init(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    b, s = 2, 12
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    y_full = xl.slstm_block_apply(p, x, cfg)
    st = xl.slstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, st = xl.slstm_block_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y[:, 0])
    np.testing.assert_allclose(
        np.asarray(y_full), np.asarray(jnp.stack(ys, 1)), rtol=1e-4, atol=1e-5
    )


def test_mamba2_state_continuation():
    """Prefill-then-continue: h0 state threading across calls."""
    cfg = _mamba_cfg(chunk=8)
    p = m2.mamba2_init(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)).astype(np.float32))
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    H = d_in // s_cfg.head_dim
    # run the ssd core directly in two halves with state threading
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = m2._split_zxbcdt(p, cfg, zxbcdt)
    xbc = jax.nn.silu(m2._causal_conv(xbc, p["conv_w"], p["conv_b"]))
    gn = s_cfg.n_groups * s_cfg.state
    xh = xbc[..., :d_in].reshape(1, 32, H, s_cfg.head_dim).astype(jnp.float32)
    Bm = xbc[..., d_in:d_in + gn].reshape(1, 32, 1, s_cfg.state).astype(jnp.float32)
    Cm = xbc[..., d_in + gn:].reshape(1, 32, 1, s_cfg.state).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dA = dtf * -jnp.exp(p["A_log"])
    y_all, h_all = m2._ssd_chunked(xh, dtf, dA, Bm, Cm, s_cfg)
    y1, h1 = m2._ssd_chunked(xh[:, :16], dtf[:, :16], dA[:, :16],
                             Bm[:, :16], Cm[:, :16], s_cfg)
    y2, h2 = m2._ssd_chunked(xh[:, 16:], dtf[:, 16:], dA[:, 16:],
                             Bm[:, 16:], Cm[:, 16:], s_cfg, h0=h1)
    np.testing.assert_allclose(np.asarray(y_all[:, 16:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h2),
                               rtol=2e-4, atol=2e-5)
