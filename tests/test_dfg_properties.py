"""Hypothesis property tests on the DFG scheduler's invariants:

1. delay balancing: every node's inputs arrive at the same cycle (the
   balancing-register count exactly closes every skew);
2. pipeline depth == critical path through the DFG;
3. cascade composition: depth/flops/buffer strictly additive;
4. semantics: random elementwise DFGs compute the same thing as direct
   Python evaluation regardless of topology.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # whole module is property tests

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import Registry, parse_spd
from repro.core.dfg import schedule


@st.composite
def random_dfg(draw):
    """A random layered SSA DFG over +,-,*: returns SPD source text."""
    n_inputs = draw(st.integers(2, 4))
    n_nodes = draw(st.integers(1, 8))
    inputs = [f"x{i}" for i in range(n_inputs)]
    avail = list(inputs)
    lines = []
    for i in range(n_nodes):
        a = draw(st.sampled_from(avail))
        b = draw(st.sampled_from(avail))
        op = draw(st.sampled_from(["+", "-", "*"]))
        v = f"t{i}"
        lines.append(f"EQU N{i}, {v} = {a} {op} {b};")
        avail.append(v)
    out = avail[-1]
    src = (
        "Name Rand;\n"
        "Main_In {mi::" + ",".join(inputs) + "};\n"
        "Main_Out {mo::z};\n"
        + "\n".join(lines)
        + f"\nDRCT (z) = ({out});\n"
    )
    return src, inputs, lines, out


@given(random_dfg())
@settings(max_examples=40, deadline=None)
def test_delay_balance_closes_all_skew(data):
    src, inputs, lines, out = data
    core = parse_spd(src)
    reg = Registry()
    compiled = reg.compile(core)
    sched = compiled.schedule
    # invariant 1: for every node, all input-ready times <= node start, and
    # the balancing registers account exactly for the total skew
    total_skew = 0
    alias = core.alias_map()
    for node in core.toposort():
        start = sched.node_start[node.name]
        for v in node.inputs:
            t = sched.ready[alias.get(v, v)]
            assert t <= start
            total_skew += start - t
    # plus output alignment padding
    outs = [sched.ready[alias.get(p, p)] for p in core.output_ports()]
    total_skew += sum(max(outs) - t for t in outs)
    assert sched.balance_regs == total_skew
    # invariant 2: depth equals the max ready time over outputs
    assert sched.depth == max(outs)


@given(random_dfg(), st.integers(2, 4))
@settings(max_examples=20, deadline=None)
def test_cascade_additivity(data, m):
    src, *_ = data
    core = parse_spd(src)
    if len(core.main_input_ports()) != len(core.main_output_ports()):
        return  # not chainable
    reg = Registry()
    compiled = reg.compile(core)
    from repro.core import temporal_cascade

    casc = temporal_cascade(compiled, m)
    assert casc.schedule.depth == m * compiled.schedule.depth
    assert casc.flops == m * compiled.flops


@given(random_dfg(), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_random_dfg_semantics(data, seed):
    src, inputs, lines, out = data
    reg = Registry()
    compiled = reg.compile(parse_spd(src))
    rng = np.random.default_rng(seed)
    T = 8
    vals = {
        x: rng.uniform(-2, 2, T).astype(np.float32) for x in inputs
    }
    main, _ = compiled({k: jnp.asarray(v) for k, v in vals.items()})
    # direct evaluation
    env = dict(vals)
    for i, line in enumerate(lines):
        expr = line.split("=", 1)[1].rstrip(";").strip()
        a, op, b = expr.split()
        env[f"t{i}"] = {
            "+": np.add, "-": np.subtract, "*": np.multiply
        }[op](env[a], env[b])
    np.testing.assert_allclose(
        np.asarray(main["z"]), env[out], rtol=1e-5, atol=1e-6
    )
