"""Shared fixtures: the deterministic search harness (ISSUE 6).

``search_harness`` gives every test the same deterministic search
context — a seeded fake :class:`~_search_harness.ModelTimer` and a tmp
study directory — so strategy/study assertions are exact, never
statistical (see ``tests/_search_harness.py``).
"""

import pytest

from _search_harness import SearchHarness


@pytest.fixture()
def search_harness(tmp_path) -> SearchHarness:
    return SearchHarness(study_dir=tmp_path / "studies")
