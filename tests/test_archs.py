"""Per-architecture smoke tests: reduced configs, one forward + train step +
decode step on CPU, asserting shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.train.optimizer import AdamWConfig, init_state

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = ShapeConfig("smoke_dec", seq_len=64, global_batch=2,
                           kind="decode")

ARCH_NAMES = sorted(ARCHS)


def _smoke_cfg(name):
    cfg = get_arch(name).reduced()
    return cfg


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg = _smoke_cfg(name)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = registry.make_batch(cfg, SMOKE_SHAPE)
    logits = jax.jit(bundle.forward)(params, batch)
    n_text = batch["tokens"].shape[1]
    total = logits.shape[1]
    assert logits.shape[0] == 2 and logits.shape[2] == cfg.vocab
    assert total >= n_text  # frontends prepend tokens
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_loss(name):
    cfg = _smoke_cfg(name)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=100,
                          state_dtype=cfg.opt_state_dtype)
    opt_state = init_state(opt_cfg, params)
    step = jax.jit(bundle.make_train_step(opt_cfg))
    batch = registry.make_batch(cfg, SMOKE_SHAPE)
    losses = []
    for _ in range(5):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(metrics["grad_norm"]))
    # memorizing one small batch must reduce loss
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_runs_and_is_causal_consistent(name):
    """Prefill logits at position t must match step-by-step decode."""
    cfg = _smoke_cfg(name)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((b, 4 * s, cfg.d_model)).astype(np.float32),
            cfg.param_dtype,
        )
        from repro.models import transformer as tfm

        enc = jax.jit(lambda p, f: tfm.encode(p, cfg, f))(params, frames)
        full = jax.jit(lambda p, f, t: tfm.forward_enc_dec(p, cfg, f, t))(
            params, frames, tokens
        )
        cache = bundle.cache_init(b, s)
        cache = tfm.prime_cross_cache(params, cfg, cache, enc)
        dec = jax.jit(bundle.make_decode_step())
        logits_steps = []
        for t in range(s):
            lg, cache = dec(params, tokens[:, t:t + 1], cache,
                            jnp.asarray(t, jnp.int32))
            logits_steps.append(lg[:, 0])
    else:
        batch = {"tokens": tokens}
        full = jax.jit(bundle.forward)(params, batch)
        cache = bundle.cache_init(b, s)
        dec = jax.jit(bundle.make_decode_step())
        logits_steps = []
        for t in range(s):
            lg, cache = dec(params, tokens[:, t:t + 1], cache,
                            jnp.asarray(t, jnp.int32))
            logits_steps.append(lg[:, 0])

    got = jnp.stack(logits_steps, axis=1).astype(jnp.float32)
    want = full.astype(jnp.float32)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_formula_matches_actual(name):
    """Analytic num_params (drives the planner/roofline) vs real leaves."""
    cfg = _smoke_cfg(name)
    if cfg.family in ("hybrid", "ssm"):
        pytest.skip("analytic formula covers transformer families")
    bundle = registry.build(cfg)
    shapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    actual = sum(
        np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes)
    )
    assert actual == pytest.approx(cfg.num_params(), rel=0.05)


def test_full_config_parameter_counts():
    """Full-size configs land near their nameplate parameter counts."""
    expected = {
        "granite-34b": 34e9,
        "nemotron-4-15b": 15e9,
        "qwen2.5-32b": 32e9,
        "qwen3-8b": 8e9,
        "mixtral-8x7b": 46.7e9,
        "kimi-k2-1t-a32b": 1.03e12,
        "llava-next-34b": 34e9,
    }
    for name, want in expected.items():
        got = get_arch(name).num_params()
        assert got == pytest.approx(want, rel=0.12), (name, got)


def test_moe_active_params():
    kimi = get_arch("kimi-k2-1t-a32b")
    assert kimi.active_params() < 0.05 * kimi.num_params()
    assert kimi.active_params() == pytest.approx(32e9, rel=0.25)
