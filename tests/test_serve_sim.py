"""Simulation-serving engine tests (DESIGN.md §13, docs/pipeline.md
§serve): admission backpressure, trial-context grouping, batched
member-wise bit-exactness against sequential runs, autotune-once via
shared studies (zero live timings on the warm path, asserted with the
injected deterministic timer), drain completeness, and the
``SearchStepper`` non-blocking search contract.
"""

from __future__ import annotations

import numpy as np
import pytest

from _search_harness import ModelTimer, _rf
from repro.apps import diffusion as dif
from repro.apps import lbm
from repro.serve.sim import PlanResolver, SimEngine, SimRequest

STEPS = 8


def _diffusion_tenant(h=32, w=32, alpha=0.2):
    """(kernel, per-member state factory, regs) for a diffusion tenant."""
    sim = dif.DiffusionSimulation(h, w, alpha=alpha)
    u0, _ = dif.sine_init(h, w)
    return (
        sim.kernel,
        lambda i: sim.state(u0 * (1.0 + 0.01 * i)),
        (sim.alpha,),
    )


def _lbm_tenant(h=32, w=32):
    sim = lbm.LBMSimulation(lbm.LBMProblem(h, w, mode="wrap"))
    f0, attr, _ = lbm.taylor_green_init(h, w)
    return (
        sim.stream_kernel(),
        lambda i: sim.stream_state(f0 * (1.0 + 0.01 * i), attr),
        sim.stream_regs(),
    )


def _resolver(study_dir=None, **kw) -> PlanResolver:
    """Small-lattice resolver; ``budget=0`` (the default here) pins the
    model-predicted plan without a single live timing, so engine tests
    spend no wall clock tuning unless they ask to."""
    kw.setdefault("budget", 0)
    kw.setdefault("b_values", (1, 2, 4))
    kw.setdefault("bh_values", (8, 16, 32))
    kw.setdefault("m_values", (1, 2, 4))
    if study_dir is not None:
        kw.setdefault("study_dir", str(study_dir))
    return PlanResolver(**kw)


# ---------------------- admission / backpressure ----------------------


def test_submit_rejects_with_backpressure_when_queue_full():
    kern, mk, regs = _diffusion_tenant()
    eng = SimEngine(_resolver(), max_queue=2)
    reqs = [
        SimRequest(rid=i, core=kern, state=mk(i), steps=STEPS, regs=regs)
        for i in range(4)
    ]
    assert eng.submit(reqs[0]) and eng.submit(reqs[1])
    # queue full: rejected, counted, never silently dropped
    assert not eng.submit(reqs[2]) and not eng.submit(reqs[3])
    assert eng.rejected == 2 and eng.submitted == 2
    done = eng.run_until_drained()
    assert sorted(c.rid for c in done) == [0, 1]
    stats = eng.stats()
    assert stats["completed"] == stats["submitted"] == 2


def test_drain_returns_every_accepted_request():
    kern, mk, regs = _diffusion_tenant()
    eng = SimEngine(_resolver())
    for i in range(5):
        assert eng.submit(SimRequest(rid=i, core=kern, state=mk(i),
                                     steps=STEPS, regs=regs))
    done = eng.run_until_drained()
    assert sorted(c.rid for c in done) == list(range(5))
    assert all(c.steps == STEPS for c in done)
    assert eng._active_count() == 0 and not eng.queue


def test_run_until_drained_raises_instead_of_truncating():
    kern, mk, regs = _diffusion_tenant()
    # m=1 forces one fused step per tick: 8 steps cannot drain in 2.
    eng = SimEngine(_resolver(m_values=(1,)))
    eng.submit(SimRequest(rid=7, core=kern, state=mk(0), steps=STEPS,
                          regs=regs))
    with pytest.raises(RuntimeError, match=r"undrained.*\[7\]"):
        eng.run_until_drained(max_ticks=2)


# ------------------------- context grouping ---------------------------


def test_only_identical_contexts_share_a_launch():
    """Same core fingerprint + grid but different Append_Reg values must
    never stack into one launch (the SMEM scalars broadcast to every
    batch member)."""
    ka, mka, ra = _diffusion_tenant(alpha=0.2)
    kb, mkb, rb = _diffusion_tenant(alpha=0.05)
    eng = SimEngine(_resolver())
    eng.submit(SimRequest(rid=0, core=ka, state=mka(0), steps=STEPS,
                          regs=ra))
    eng.submit(SimRequest(rid=1, core=ka, state=mka(0), steps=STEPS,
                          regs=ra))
    eng.submit(SimRequest(rid=2, core=kb, state=mkb(0), steps=STEPS,
                          regs=rb))
    eng.submit(SimRequest(rid=3, core=kb, state=mkb(0), steps=STEPS,
                          regs=rb))
    done = {c.rid: c for c in eng.run_until_drained()}
    assert len(eng.groups) == 2  # one group per (fingerprint, regs)
    assert len(eng.stats()["plans"]) == 2  # regs distinguish the keys
    # b=4 was allowed, but no launch may ever exceed a context's own
    # member count of 2
    assert max(int(k) for k in eng.stats()["occupancy"]) <= 2
    # identical initial states, different alpha: results must differ
    # (no cross-context contamination), and same-context twins agree
    assert np.array_equal(done[0].state, done[1].state)
    assert not np.array_equal(done[0].state, done[2].state)


# -------------------- batched bitwise correctness ---------------------


@pytest.mark.parametrize("app", ["diffusion", "lbm"])
@pytest.mark.parametrize("b", [1, 2, 4])
def test_batched_members_bitmatch_sequential(app, b):
    """Every member of a width-b engine launch retires with exactly the
    state an independent ``run_blocked`` produces — the batch axis is
    bitwise invisible (tests/test_streaming.py proves the kernel-level
    half; this is the engine-path half, through cohort stacking, fused
    chunking, and the single retirement transfer)."""
    kern, mk, regs = (
        _diffusion_tenant() if app == "diffusion" else _lbm_tenant()
    )
    eng = SimEngine(_resolver(b_values=(b,)))
    for i in range(b):
        eng.submit(SimRequest(rid=i, core=kern, state=mk(i),
                              steps=STEPS, regs=regs))
    done = {c.rid: c for c in eng.run_until_drained()}
    assert len(done) == b
    (plan,) = eng.stats()["plans"].values()
    assert plan["b"] == b
    # all members admitted before the first launch: full-width cohort
    assert str(b) in eng.stats()["occupancy"]
    for i in range(b):
        ref = kern.run_blocked(
            mk(i), regs, steps=STEPS, m=plan["m"],
            block_h=plan["block_h"],
            double_buffer=plan["double_buffer"], interpret=True,
        )
        assert np.array_equal(done[i].state, np.asarray(ref)), (
            f"member {i}/{b} diverged from its sequential reference"
        )


# ----------------------- autotune-on-first-request --------------------


def test_autotune_once_warm_engine_times_nothing(tmp_path):
    """First engine tunes under its budget; a second engine over the
    same study directory replays the journal and pins the identical
    plan with zero live timings (the injected deterministic timer makes
    'zero' exact, not statistical)."""
    kern, mk, regs = _diffusion_tenant()

    def engine(timer):
        return SimEngine(_resolver(tmp_path, budget=3, timer=timer))

    t1 = ModelTimer(h=32, w=32)
    eng1 = engine(t1)
    for i in range(2):
        eng1.submit(SimRequest(rid=i, core=kern, state=mk(i),
                               steps=STEPS, regs=regs))
    eng1.run_until_drained()
    s1 = eng1.stats()
    assert 0 < s1["live_timings"] <= 3
    assert len(t1.calls) == s1["live_timings"]
    assert s1["tuning_ticks"] > 0

    t2 = ModelTimer(h=32, w=32)
    eng2 = engine(t2)
    for i in range(2):
        eng2.submit(SimRequest(rid=10 + i, core=kern, state=mk(i),
                               steps=STEPS, regs=regs))
    eng2.run_until_drained()
    s2 = eng2.stats()
    assert s2["live_timings"] == 0 and not t2.calls
    assert s2["tuning_ticks"] == 0

    (p1,) = s1["plans"].values()
    (p2,) = s2["plans"].values()
    assert p2["replayed"] > 0 and p2["budget_spent"] == 0
    for field in ("block_h", "m", "d", "double_buffer", "b", "source"):
        assert p1[field] == p2[field], field


def test_budget_zero_falls_back_to_model_plan():
    kern, mk, regs = _diffusion_tenant()
    eng = SimEngine(_resolver(budget=0))
    eng.submit(SimRequest(rid=0, core=kern, state=mk(0), steps=STEPS,
                          regs=regs))
    eng.run_until_drained()
    (plan,) = eng.stats()["plans"].values()
    assert plan["source"] == "model" and plan["budget_spent"] == 0
    assert eng.stats()["live_timings"] == 0


def test_reset_counters_opens_fresh_window_keeping_plans():
    kern, mk, regs = _diffusion_tenant()
    eng = SimEngine(_resolver())
    eng.submit(SimRequest(rid=0, core=kern, state=mk(0), steps=STEPS,
                          regs=regs))
    eng.run_until_drained()
    assert eng.stats()["launches"] > 0
    eng.reset_counters()
    s = eng.stats()
    assert s["launches"] == s["member_steps"] == s["completed"] == 0
    (plan,) = s["plans"].values()
    assert plan is not None  # pinned plans survive the window reset


# ------------------- model/legalizer batch-axis agreement -------------


def test_vmem_pricing_and_model_agree_on_b():
    from _search_harness import TOY
    from repro.core.dse import TPUModel
    from repro.core.legalize import stripe_vmem_bytes

    v1 = stripe_vmem_bytes(16, 2, 128, 3, halo=1, double_buffer=True)
    v4 = stripe_vmem_bytes(16, 2, 128, 3, halo=1, double_buffer=True,
                           b=4)
    assert v4 == 4 * v1  # stacked stripes price linearly in b

    model = TPUModel()
    p1 = model.evaluate(TOY, 8, 2)
    p4 = model.evaluate(TOY, 8, 2, b=4)
    assert p4.detail["b"] == 4
    assert p4.detail["vmem_bytes"] == 4 * p1.detail["vmem_bytes"]

    # batched + sharded geometry is declared infeasible, not mispriced
    pd = model.evaluate(TOY, 8, 2, d=2, b=2)
    assert not pd.feasible
    assert any("batched" in lim for lim in pd.limits)


# -------------------------- SearchStepper -----------------------------


def _stepper_runner(hz, timer, budget):
    from repro.core.dse import TPUModel
    from repro.core.search import SearchRunner

    return SearchRunner(
        workload=hz.workload, grid_shape=(hz.h, hz.w), run_factory=_rf,
        model=TPUModel(), fingerprint="toy", calibrate=False,
        cache=False, timer=timer, budget=budget, max_devices=1,
    )


def test_search_stepper_nonblocking_contract(search_harness):
    """The non-blocking contract the engine's tick loop relies on:
    every step spends at most ONE live timing, the hard budget is never
    exceeded, the loop terminates, and ``best()`` is the measured
    argmax of everything explored. (The trial *sequence* may differ
    from a blocking run — the trampoline replays prior measurements
    from the dedupe table between steps — but it spends the identical
    total budget.)"""
    from repro.core.search import SearchStepper, TPESearch

    hz = search_harness
    sweep = hz.sweep()
    budget = 5

    blocking = _stepper_runner(hz, hz.timer(), budget)
    TPESearch(seed=0, max_trials=budget).search(sweep, blocking)

    timer = hz.timer()
    stepped = _stepper_runner(hz, timer, budget)
    stepper = SearchStepper(
        TPESearch(seed=0, max_trials=budget), sweep, stepped
    )
    per_step = []
    while not stepper.done:
        before = stepped.budget_spent
        stepper.step()
        per_step.append(stepped.budget_spent - before)
    assert all(n <= 1 for n in per_step)
    assert stepped.budget_spent <= budget
    assert stepped.budget_spent == blocking.budget_spent
    assert len(timer.calls) == stepped.budget_spent

    best = stepper.best()
    assert best.measured_gflops == max(
        e.measured_gflops for e in stepper.executed
    )
