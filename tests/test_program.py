"""Streaming program graphs (`repro.core.program`): the fusion axis.

Load-bearing assertions (ISSUE 9 acceptance criteria):

* every fusion partition of both program apps — fused, partial splits,
  fully pipelined — is **bitwise** identical to the app's monolithic
  single-core kernel, across m ∈ {1, 2, 4} × double_buffer on/off
  (and d ∈ {1, 2} where the platform has the devices), and matches the
  pure-jnp oracle to f32 tolerance;
* pipelined cluster intermediates never round-trip to host: the
  pipelined launch runs clean under ``jax.transfer_guard("disallow")``
  while the unfused baseline (which syncs every intermediate) trips it;
* fusion legality is the legalizer's job: partitions that fit stripe
  their clusters within ``VMEM_BYTES`` at the resolved plan, partitions
  that don't raise naming the offending cluster (hypothesis-optional
  property test over random stage chains);
* the plan tuple is single-sourced: ``RunPlan`` mirrors ``PLAN_FIELDS``
  exactly and tolerates pre-fusion records (drift test);
* stencil inference is memoized per (core, incoming-edge extents) — the
  same sub-core summarized under two different extents gets two
  summaries, each cached;
* the fusion partition rides the whole search stack: sweep lattice →
  executed points → measurement-cache keys.

The d = 2 cases need real (host) devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
program job sets it; under a plain single-device run they skip.
"""

import numpy as np
import pytest

import jax

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st

from repro.apps import lbm
from repro.apps.advection_diffusion import (
    AdvectionDiffusionSimulation,
    advdiff_ref_run,
    blob_init,
)
from repro.core.legalize import (
    PLAN_FIELDS,
    RunPlan,
    VMEM_BYTES,
    cluster_vmem_bytes,
    parse_fusion,
    program_blocking_plan,
)
from repro.core.program import (
    ProgramError,
    StreamProgram,
    fusion_partitions,
)

H, W = 16, 64
STEPS = 4


def _needs_devices(d: int):
    return pytest.mark.skipif(
        jax.device_count() < d,
        reason=f"needs {d} devices "
               f"(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
    )


@pytest.fixture(scope="module")
def lbm_app():
    sim = lbm.LBMSimulation(lbm.LBMProblem(H, W, mode="wrap"))
    f0, attr, _ = lbm.taylor_green_init(H, W)
    return {
        "prog": sim.program(),
        "mono": sim.stream_kernel(),  # the pre-program single-core path
        "state": sim.stream_state(f0, attr),
        "regs": sim.stream_regs(),
    }


@pytest.fixture(scope="module")
def ad_app():
    sim = AdvectionDiffusionSimulation(H, W)
    return {
        "sim": sim,
        "prog": sim.program,
        "mono": sim.monolithic_core.stream_kernel(),
        "state": sim.state(blob_init(H, W)),
        "regs": sim.regs(),
    }


# --------------------------------------------------------------------------
# Partition structure
# --------------------------------------------------------------------------


def test_fusion_partitions_enumeration():
    assert fusion_partitions(1) == ("1",)
    assert fusion_partitions(2) == ("2", "1+1")
    assert fusion_partitions(3) == ("3", "2+1", "1+2", "1+1+1")
    assert len(fusion_partitions(4)) == 8  # 2^(n-1) compositions


def test_program_rejects_non_chain_graphs(ad_app):
    reg = ad_app["prog"].registry
    with pytest.raises(ProgramError, match="not a chain edge"):
        StreamProgram(
            reg, ["Advect2D", "ReactDiffuse2D"],
            edges=[(1, 0)], width=W,
        )
    with pytest.raises(ProgramError, match="disconnected"):
        StreamProgram(reg, ["Advect2D", "ReactDiffuse2D"], edges=[],
                      width=W)


def test_stage_geometry(lbm_app, ad_app):
    # uLBM: collide+stream carries the 9-dir stencil (halo 1); the
    # boundary and moments stages are pointwise (halo 0).
    assert lbm_app["prog"].stage_geometry() == ((10, 1), (10, 0), (10, 0))
    assert ad_app["prog"].stage_geometry() == ((1, 1), (1, 1))


# --------------------------------------------------------------------------
# Bit-match matrix: every partition == the monolithic single-core kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("double_buffer", [True, False])
def test_lbm_partitions_bitwise_match_monolith(lbm_app, m, double_buffer):
    prog, state, regs = lbm_app["prog"], lbm_app["state"], lbm_app["regs"]
    ref = np.asarray(lbm_app["mono"].run_blocked(
        state, regs, steps=STEPS, m=m, block_h=8,
        double_buffer=double_buffer, interpret=True,
    ))
    for spec in fusion_partitions(prog.nstages):
        out = np.asarray(prog.kernel(spec).run_blocked(
            state, regs, steps=STEPS, m=m, block_h=8,
            double_buffer=double_buffer, interpret=True,
        ))
        assert np.array_equal(out, ref), (spec, m, double_buffer)


@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("double_buffer", [True, False])
def test_advdiff_partitions_bitwise_match_monolith(ad_app, m,
                                                   double_buffer):
    prog, state, regs = ad_app["prog"], ad_app["state"], ad_app["regs"]
    ref = np.asarray(ad_app["mono"].run_blocked(
        state, regs, steps=STEPS, m=m, block_h=8,
        double_buffer=double_buffer, interpret=True,
    ))
    for spec in fusion_partitions(prog.nstages):
        out = np.asarray(prog.kernel(spec).run_blocked(
            state, regs, steps=STEPS, m=m, block_h=8,
            double_buffer=double_buffer, interpret=True,
        ))
        assert np.array_equal(out, ref), (spec, m, double_buffer)


def test_advdiff_matches_jnp_oracle(ad_app):
    sim, prog = ad_app["sim"], ad_app["prog"]
    u0 = blob_init(H, W)
    want = np.asarray(advdiff_ref_run(
        u0, sim.vx, sim.vy, sim.alpha, sim.r, STEPS
    ))
    for spec in fusion_partitions(prog.nstages):
        got = np.asarray(sim.run(u0, STEPS, fusion=spec, m=2, block_h=8))
        np.testing.assert_allclose(got, want, rtol=0, atol=1e-5)


def test_partitions_match_reference_path(lbm_app, ad_app):
    """Every partition == the compiler's reference function (the
    CompiledCore.apply chain of the fused wrapper), bitwise."""
    for app in (lbm_app, ad_app):
        prog, state, regs = app["prog"], app["state"], app["regs"]
        ref = np.asarray(prog.kernel("").reference(state, regs, m=STEPS))
        for spec in fusion_partitions(prog.nstages):
            out = np.asarray(prog.kernel(spec).run_blocked(
                state, regs, steps=STEPS, m=2, block_h=8, interpret=True,
            ))
            assert np.array_equal(out, ref), spec


@_needs_devices(2)
@pytest.mark.parametrize("app_fixture", ["lbm_app", "ad_app"])
def test_partitions_bitwise_match_sharded(app_fixture, request):
    app = request.getfixturevalue(app_fixture)
    prog, state, regs = app["prog"], app["state"], app["regs"]
    for spec in fusion_partitions(prog.nstages):
        one = np.asarray(prog.kernel(spec).run_blocked(
            state, regs, steps=2, m=1, block_h=8, interpret=True, d=1,
        ))
        two = np.asarray(prog.kernel(spec).run_blocked(
            state, regs, steps=2, m=1, block_h=8, interpret=True, d=2,
        ))
        assert np.array_equal(one, two), spec


# --------------------------------------------------------------------------
# Pipelined clusters: intermediates stay on device
# --------------------------------------------------------------------------


def test_pipelined_intermediates_never_visit_host(ad_app):
    prog, state, regs = ad_app["prog"], ad_app["state"], ad_app["regs"]
    pk = prog.kernel("1+1")
    kwargs = dict(steps=2, m=1, block_h=8, interpret=True)
    pk.run_blocked(state, regs, **kwargs)  # warm-up compile
    # Device-to-host is the round-trip being asserted away (uploading
    # the launch's register scalars host-to-device is fine).
    with jax.transfer_guard_device_to_host("disallow"):
        out = pk.run_blocked(state, regs, **kwargs)
    # Materializing afterwards is the caller's (allowed) transfer.
    assert np.asarray(out).shape == state.shape


def test_unfused_baseline_does_round_trip(ad_app, monkeypatch):
    """The contrast path, by transfer count: run_unfused materializes
    every cluster's output on the host (the CPU backend's same-memory
    "transfer" is invisible to the guard, so count the crossings)."""
    prog, state, regs = ad_app["prog"], ad_app["state"], ad_app["regs"]
    pk = prog.kernel("1+1")
    crossings = []
    orig = np.asarray

    def spy(x, *args, **kwargs):
        if isinstance(x, jax.Array):
            crossings.append(x.shape)
        return orig(x, *args, **kwargs)

    monkeypatch.setattr(np, "asarray", spy)
    out = pk.run_unfused(state, regs, steps=2, block_h=8, interpret=True)
    # one host materialization per cluster per step
    assert len(crossings) >= 2 * len(pk.clusters)
    assert orig(out).shape == state.shape


# --------------------------------------------------------------------------
# Fusion legality: composed halos and summed cluster stripes
# --------------------------------------------------------------------------


def _clusters(stages, spec):
    sizes = parse_fusion(spec, len(stages))
    out, lo = [], 0
    for s in sizes:
        out.append(stages[lo:lo + s])
        lo += s
    return out


def test_legal_partitions_fit_vmem():
    stages = ((10, 1), (10, 0), (10, 0))  # the uLBM program geometry
    for spec in fusion_partitions(3):
        bh, m, db = program_blocking_plan(
            64, 16, 4, stages=stages, fusion=spec, width=128,
        )
        m_c = m if "+" not in spec else 1
        for c in _clusters(stages, spec):
            assert cluster_vmem_bytes(
                bh, m_c, 128, [w for w, _ in c], [h for _, h in c], db,
            ) <= VMEM_BYTES, (spec, c)


def test_unsourceable_composed_halo_names_cluster():
    # Fusing two halo-3 stages composes halo 6 > the 4-row shard.
    with pytest.raises(ValueError,
                       match=r"fusion cluster 0 of spec '2'.*composed "
                             r"stencil halo 6"):
        program_blocking_plan(4, 4, 1, stages=((1, 3), (1, 3)),
                              fusion="2", width=W)


def test_vmem_overflow_names_cluster_and_spec():
    with pytest.raises(ValueError,
                       match=r"fusion cluster \d+ of spec '1\+2'.*"
                             r"budget 4096 B"):
        program_blocking_plan(64, 16, 2, stages=((1, 1), (1, 1), (1, 1)),
                              fusion="1+2", width=4096, vmem_bytes=4096)


@given(
    st.lists(
        st.tuples(st.integers(1, 4), st.integers(0, 2)),
        min_size=1, max_size=4,
    ),
    st.integers(0, 63),
    st.integers(1, 8),
)
@settings(max_examples=30, deadline=None)
def test_partition_legality_property(stages, pick, m):
    """Any partition of any stage chain either yields a plan whose
    every cluster stripes within VMEM_BYTES, or raises naming the
    offending cluster."""
    stages = tuple(stages)
    specs = fusion_partitions(len(stages))
    spec = specs[pick % len(specs)]
    try:
        bh, m_res, db = program_blocking_plan(
            64, 16, m, stages=stages, fusion=spec, width=2048,
        )
    except ValueError as e:
        assert "fusion cluster" in str(e)
        assert repr(spec) in str(e)
        return
    m_c = m_res if "+" not in spec else 1
    assert 64 % bh == 0
    for c in _clusters(stages, spec):
        assert cluster_vmem_bytes(
            bh, m_c, 2048, [w for w, _ in c], [h for _, h in c], db,
        ) <= VMEM_BYTES


def test_cluster_vmem_is_sum_of_member_stripes():
    """Linearity in words at the composed halo — the §14 accounting."""
    one = cluster_vmem_bytes(16, 2, 128, [3], [2])
    two = cluster_vmem_bytes(16, 2, 128, [3, 3], [1, 1])
    assert two == 2 * one  # same composed halo, twice the fields


# --------------------------------------------------------------------------
# Plan identity: single-sourced tuple, drift-tested
# --------------------------------------------------------------------------


def test_plan_fields_single_source():
    from dataclasses import fields

    from repro.core import search

    assert tuple(f.name for f in fields(RunPlan)) == PLAN_FIELDS
    # mesh axis (DESIGN.md §15) appended after fusion, defaults last
    assert PLAN_FIELDS[-2:] == ("fusion", "dx")
    # the search package re-exports the one definition
    assert search.RunPlan is RunPlan
    assert search.PLAN_FIELDS is PLAN_FIELDS
    # every plan dimension lands in the executed-point schema
    assert set(PLAN_FIELDS) <= set(search.EXECUTED_POINT_FIELDS)


def test_run_plan_round_trip_and_back_compat():
    p = RunPlan(8, 2, 4, 1, 3, False, 2, "2+1")
    assert RunPlan.from_dict(p.as_dict()) == p
    assert p.key() == (8, 2, 4, 1, 3, False, 2, "2+1", 1)
    # records written before the fusion (and b, double_buffer, reps)
    # dimensions existed resolve to the legacy defaults
    old = RunPlan.from_dict({"block_h": 8, "m": 2, "steps": 4, "d": 1})
    assert (old.reps, old.double_buffer, old.b, old.fusion) == (
        1, True, 1, "",
    )


def test_cache_key_carries_fusion():
    from repro.core.measure import MeasurementCache

    base = ("fp", (H, W), (8, 1, 2, 1, 1, 1), "cpu", True, 1, 1)
    k_legacy = MeasurementCache.make_key(*base)
    k_fused = MeasurementCache.make_key(
        "fp", (H, W), (8, 1, 2, 1, 1, 1, "1+1"), "cpu", True, 1, 1,
    )
    k_other = MeasurementCache.make_key(
        "fp", (H, W), (8, 1, 2, 1, 1, 1, "2"), "cpu", True, 1, 1,
    )
    assert len({k_legacy, k_fused, k_other}) == 3


# --------------------------------------------------------------------------
# Stencil-inference memoization per (core, incoming extents)
# --------------------------------------------------------------------------


def test_stencil_summary_memoized_per_incoming_extents(ad_app):
    from repro.core.codegen import stencil_summary

    compiled = ad_app["prog"].stages[1].compiled  # ReactDiffuse2D
    plain = stencil_summary(compiled)
    shifted = stencil_summary(compiled, incoming=((1, 0),))
    assert plain.halo() == 1
    assert shifted.halo() == 2  # edge extent composes with the stencil
    # each variant is cached; asking again returns the same object
    assert stencil_summary(compiled) is plain
    assert stencil_summary(compiled, incoming=((1, 0),)) is shifted
    # the fused wrapper's kernel sees the composed reach end to end
    assert ad_app["prog"].cluster_kernel(0, 2).halo == 2


# --------------------------------------------------------------------------
# The fusion axis through sweep → search → executed points
# --------------------------------------------------------------------------


def test_fusion_axis_sweeps_and_executes(ad_app):
    from repro.core.search import EXECUTED_POINT_FIELDS, ExhaustiveSearch

    prog, state, regs = ad_app["prog"], ad_app["state"], ad_app["regs"]
    ex = prog.explorer(H * W, grid_w=W)
    sweep = ex.sweep_tpu(
        bh_values=(8, 16), m_values=(1, 2),
        fusion_values=fusion_partitions(prog.nstages),
    )
    assert sorted(set(map(str, sweep.data["fusion"]))) == ["1+1", "2"]
    res = ex.search(
        sweep, state, regs, strategy=ExhaustiveSearch(k=8),
        reps=1, calibrate=False, cache=False, interpret=True,
    )
    executed = res.executed
    assert executed, "exhaustive search executed nothing"
    assert {e.fusion for e in executed} == {"2", "1+1"}
    for e in executed:
        assert tuple(e.as_dict().keys()) == EXECUTED_POINT_FIELDS
        assert e.as_dict()["fusion"] in ("2", "1+1")
