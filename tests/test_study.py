"""Durable studies + TPE surrogate search (docs/pipeline.md §study,
DESIGN.md §11) on the deterministic harness of ``_search_harness.py``.

The load-bearing assertions (ISSUE 6 acceptance criteria):

* an interrupted study resumed by name replays completed trials into
  the runner's dedupe table and re-measures **zero** of them (a fully
  replayed resume spends 0 budget and makes 0 timer calls);
* a seeded TPESearch reproduces the identical trial sequence twice;
* seeded TPE matches >= 95% of the exhaustive best measured GFLOPS
  using <= half the exhaustive measurement count, for both the lbm and
  diffusion apps;
* warm-start from a pre-populated MeasurementCache skips every
  already-measured plan;
* two processes appending to one study journal (and merging one
  measurement cache) concurrently lose no records;
* one serialization schema: EXECUTED_POINT_FIELDS for every executed
  point (CLI --json, BENCH_dse.json, study trial records) and
  SEARCH_RESULT_FIELDS for every search result.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest
from _search_harness import ModelTimer, SearchHarness, _rf

from repro.core.dse import TPUModel
from repro.core.measure import MeasurementCache
from repro.core.search import (
    EXECUTED_POINT_FIELDS,
    SEARCH_RESULT_FIELDS,
    ExhaustiveSearch,
    SearchRunner,
    Study,
    TPESearch,
    default_study_dir,
)
from repro.core.search.study import TRIAL_CONTEXT_FIELDS


def _tpe(**kw):
    kw.setdefault("seed", 0)
    return TPESearch(**kw)


# ----------------------- one schema, everywhere -----------------------


def test_executed_point_schema_is_single_source(search_harness):
    """ISSUE 6 satellite: the CLI report, study trial records and BENCH
    search sections must all carry exactly EXECUTED_POINT_FIELDS /
    SEARCH_RESULT_FIELDS — schema drift is a test failure, not a
    downstream surprise."""
    hz = search_harness
    res = hz.search(hz.sweep(), strategy=_tpe(), budget=4,
                    study="schema", cache_tag="toy")
    d = res.as_dict()
    assert tuple(d.keys()) == SEARCH_RESULT_FIELDS
    assert d["study"] == "schema"
    for e in d["executed"]:
        assert tuple(e.keys()) == EXECUTED_POINT_FIELDS
    assert tuple(d["best"].keys()) == EXECUTED_POINT_FIELDS

    # the study journal's trial records carry the same point schema
    st = Study.resume("schema", hz.study_dir)
    trials = [r for r in st.records if r.get("point")]
    assert trials
    for rec in trials:
        # journal lines are dumped with sort_keys: same key *set*
        assert set(rec["point"]) == set(EXECUTED_POINT_FIELDS)
        for f in TRIAL_CONTEXT_FIELDS:
            assert f in rec


# ----------------------- resume: zero re-measurement -----------------------


def test_interrupted_study_resumes_with_zero_remeasurement(search_harness):
    """ISSUE 6 acceptance: interrupt a budgeted TPE search, resume by
    study name — every completed trial replays, none re-measures."""
    hz = search_harness
    t1 = hz.timer()
    first = hz.search(hz.sweep(), timer=t1, strategy=_tpe(), budget=4,
                      study="interrupted")
    assert first.budget_spent == 4 == len(t1.calls)  # cut off mid-study
    measured_plans = {p.key() for p in t1.calls}

    # Resume by name with room to continue: replays all completed
    # trials, then spends budget only on plans nobody measured yet.
    t2 = hz.timer()
    resumed = hz.search(hz.sweep(), timer=t2, strategy=_tpe(), budget=4,
                        study="interrupted")
    assert resumed.replayed == len(measured_plans)
    assert resumed.budget_spent <= 4
    assert {p.key() for p in t2.calls}.isdisjoint(measured_plans)

    # A resume whose max_trials the replayed trials already cover
    # spends exactly zero budget and zero timer calls.
    st = Study.resume("interrupted", hz.study_dir)
    n = len(st.records)
    t3 = hz.timer()
    done = hz.search(hz.sweep(), timer=t3,
                     strategy=_tpe(max_trials=n), budget=4,
                     study="interrupted")
    assert done.budget_spent == 0 and not t3.calls
    assert done.replayed == n


def test_replay_is_scoped_by_fingerprint_and_context(search_harness):
    """Trials replay only into a matching measurement context: another
    kernel's fingerprint (or an honest run vs an injected timer's
    namespaced walls) gets nothing."""
    hz = search_harness
    hz.search(hz.sweep(), strategy=_tpe(), budget=4,
              study="scoped", cache_tag="kern-a")
    st = Study.resume("scoped", hz.study_dir)

    def runner(tag, timer):
        return SearchRunner(
            workload=hz.workload, grid_shape=(hz.h, hz.w), run_factory=_rf,
            model=TPUModel(), fingerprint=tag, calibrate=False, cache=False,
            timer=timer, max_devices=1,
        )

    same = runner("kern-a", hz.timer())
    assert st.replay_into(same) > 0

    other = runner("kern-b", hz.timer())
    assert st.replay_into(other) == 0  # different kernel, no replay

    honest = runner("kern-a", None)  # timer=None: the honest namespace
    assert st.replay_into(honest) == 0  # synthetic walls never leak


# ----------------------- determinism -----------------------


def test_tpe_seed_reproduces_identical_trial_sequence(search_harness):
    """Same seed => the identical sequence of executed plans, twice."""
    hz = search_harness
    sweep = hz.sweep(d_values=(1,))

    def trial_seq(seed, study):
        t = hz.timer(noise=0.05)
        res = hz.search(sweep, timer=t, strategy=_tpe(seed=seed),
                        budget=8, study=study)
        return [(e.block_h, e.m, e.d, e.steps) for e in res.executed]

    a = trial_seq(7, "det-a")
    b = trial_seq(7, "det-b")
    assert a == b and len(a) == 8


# ----------------------- acceptance: TPE vs exhaustive -----------------------


def _app_harness(name, tmp):
    if name == "lbm":
        from repro.apps import lbm

        sim = lbm.LBMSimulation(lbm.LBMProblem(64, 64, mode="wrap"))
    else:
        from repro.apps import diffusion as dif

        sim = dif.DiffusionSimulation(64, 64, alpha=0.2)
    ex = sim.explorer()
    return SearchHarness(study_dir=tmp / "studies", workload=ex.workload,
                         explorer=ex)


@pytest.mark.parametrize("app", ["lbm", "diffusion"])
def test_tpe_matches_exhaustive_best_at_half_budget(app, tmp_path):
    """ISSUE 6 acceptance: seeded TPE >= 95% of the exhaustive best
    measured GFLOPS at <= half the exhaustive measurement count, on the
    deterministic ModelTimer harness, for both apps."""
    hz = _app_harness(app, tmp_path)
    sweep = hz.sweep()

    t_ex = hz.timer(noise=0.05)
    exhaustive = hz.search(
        sweep, timer=t_ex, strategy=ExhaustiveSearch(frontier_only=False)
    )
    assert exhaustive.budget_spent > 8  # wide enough to mean something
    best = exhaustive.best.measured_gflops

    t_tpe = hz.timer(noise=0.05)
    res = hz.search(sweep, timer=t_tpe, strategy=_tpe(),
                    budget=exhaustive.budget_spent // 2)
    assert res.budget_spent <= exhaustive.budget_spent // 2
    assert res.budget_spent == len(t_tpe.calls)
    assert res.best.measured_gflops >= 0.95 * best, app


# ----------------------- warm start from the cache -----------------------


def test_tpe_warm_starts_from_prepopulated_cache(search_harness, tmp_path):
    """A fresh TPE search over plans the persistent MeasurementCache
    already holds observes them for free — zero live timings."""
    hz = search_harness
    sweep = hz.sweep()
    cache = MeasurementCache(tmp_path / "m.json")

    t1 = hz.timer()
    full = hz.search(sweep, timer=t1,
                     strategy=ExhaustiveSearch(frontier_only=False),
                     cache=cache, cache_tag="toy")
    assert full.budget_spent == len(t1.calls) > 8

    t2 = hz.timer()
    res = hz.search(sweep, timer=t2, strategy=_tpe(), budget=4,
                    cache=cache, cache_tag="toy")
    assert res.budget_spent == 0 and not t2.calls  # all warm-started
    assert res.executed and all(e.cached for e in res.executed)


# ----------------------- violations: free, journaled -----------------------


def test_tpe_observes_violations_without_spending_budget(search_harness):
    """Candidates with no legal plan become continuous-violation
    observations: journaled to the study, charged zero budget."""
    hz = search_harness
    sweep = hz.sweep()
    st = Study("viol", hz.study_dir)
    timer = hz.timer()
    # width/words chosen so *every* stripe overflows VMEM: the whole
    # lattice is infeasible and TPE must spend nothing.
    runner = SearchRunner(
        workload=hz.workload, grid_shape=(hz.h, hz.w), run_factory=_rf,
        model=TPUModel(), fingerprint="toy", width=3_000_000, words=8,
        calibrate=False, cache=False, timer=timer, max_devices=1,
    )
    runner.study = st
    runner.study_meta = {"strategy": "tpe", "seed": 0}
    executed = _tpe().search(sweep, runner)
    assert executed == [] and runner.budget_spent == 0 and not timer.calls
    viols = st.violations_for(runner)
    assert viols and all(r["violation"] > 0.0 for r in viols)
    # (block_h, m, d, b): the batch axis joined the candidate lattice
    assert all(len(r["coords"]) == 4 for r in viols)


# ----------------------- concurrency: nothing lost -----------------------


_WRITER = r"""
import sys
from repro.core.measure import MeasurementCache
from repro.core.search.study import Study

tag, study_dir, cache_path, n = (
    sys.argv[1], sys.argv[2], sys.argv[3], int(sys.argv[4])
)


class _Ctx:  # the runner surface Study.record_* needs
    h, w = 64, 64
    backend = "test"
    interpret = True
    warmup = 1

    def study_fingerprint(self):
        return "concurrent"

    def cache_key(self, plan):
        return None


st = Study("shared", study_dir)
cache = MeasurementCache(cache_path)
ctx = _Ctx()
for i in range(n):
    st.record_violation(ctx, (int(tag), i, 1), 1.0 + i)
    cache.put(f"{tag}:{i}", {"wall_s": float(i)})
"""


def test_concurrent_study_appends_and_cache_merges_lose_nothing(tmp_path):
    """ISSUE 6 satellite: two processes appending trials to one study
    journal and putting into one MeasurementCache concurrently — every
    record from both writers survives."""
    n = 50
    study_dir, cache_path = tmp_path / "studies", tmp_path / "cache.json"
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _WRITER, tag, str(study_dir),
             str(cache_path), str(n)],
            env={**__import__("os").environ, "JAX_PLATFORMS": "cpu",
                 "PYTHONPATH": str(Path(__file__).parent.parent / "src")},
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        for tag in ("1", "2")
    ]
    for p in procs:
        _, err = p.communicate(timeout=240)
        assert p.returncode == 0, err.decode()

    st = Study("shared", study_dir)
    assert len(st.records) == 2 * n  # no torn/lost journal lines
    by_tag = {"1": 0, "2": 0}
    for rec in st.records:
        by_tag[str(rec["coords"][0])] += 1
    assert by_tag == {"1": n, "2": n}

    cache = MeasurementCache(cache_path)
    keys = {f"{tag}:{i}" for tag in ("1", "2") for i in range(n)}
    assert all(cache.peek(k) is not None for k in keys)  # merge lost none


# ----------------------- journal robustness + reporting ----------------------


def test_study_tolerates_torn_trailing_line(tmp_path):
    st = Study("torn", tmp_path)
    path = Path(st.path)
    path.parent.mkdir(parents=True, exist_ok=True)
    good = {"v": 1, "study": "torn", "fingerprint": "f", "grid": [4, 4],
            "backend": "b", "interpret": True, "warmup": 1,
            "coords": [1, 1, 1], "violation": 1.0, "point": None}
    path.write_text(json.dumps(good) + "\n" + '{"v": 1, "trunc',
                    encoding="utf-8")
    st = Study("torn", tmp_path)
    assert len(st.records) == 1  # the torn line is dropped, not fatal


def test_study_name_validation(tmp_path):
    for bad in ("", "../escape", ".hidden"):
        with pytest.raises(ValueError):
            Study(bad, tmp_path)
    assert default_study_dir()  # resolvable without env


def test_study_report_text_and_html(search_harness, tmp_path):
    hz = search_harness
    hz.search(hz.sweep(), strategy=_tpe(), budget=6, study="rep")
    st = Study.resume("rep", hz.study_dir)
    text = st.report_text()
    assert "best:" in text and "convergence" in text and "pareto" in text
    html = st.report_html()
    assert html.startswith("<!DOCTYPE html>")
    assert "<svg" in html and "Pareto front" in html
    assert "<script" not in html  # self-contained, no external assets

    out = st.report(out_dir=tmp_path, basename="rep")
    assert Path(out["text"]).read_text(encoding="utf-8").strip()
    assert "<svg" in Path(out["html"]).read_text(encoding="utf-8")
    # convergence is monotone nondecreasing by construction
    conv = st.convergence()
    assert all(b[1] >= a[1] for a, b in zip(conv, conv[1:]))
