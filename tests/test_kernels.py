"""Pallas kernels (interpret mode) vs pure-jnp oracles, swept over
shapes / dtypes / fusion depths / block sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lbm
from repro.kernels.flash_attention.ops import (
    attention,
    attention_chunked_ref,
    attention_ref,
    flash_attention,
)
from repro.kernels.lbm_stream.ops import (
    lbm_multistep,
    lbm_multistep_ref,
    lbm_run_blocked,
)

# ------------------------- lbm_stream -------------------------


@pytest.mark.parametrize("m,block_h", [(1, 8), (2, 8), (4, 16), (8, 8)])
@pytest.mark.parametrize("hw", [(32, 128), (16, 256)])
def test_lbm_kernel_matches_ref(m, block_h, hw):
    h, w = hw
    f, attr, _ = lbm.taylor_green_init(h, w)
    got = lbm_multistep(f, attr, 1 / 0.8, 0.0, m=m, block_h=block_h)
    want = lbm_multistep_ref(f, attr, 1 / 0.8, 0.0, m=m)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-7
    )


def test_lbm_kernel_walls_and_lid():
    f, attr = lbm.couette_init(24, 128)
    got = lbm_multistep(f, attr, 1 / 0.9, 0.07, m=4, block_h=8)
    want = lbm_multistep_ref(f, attr, 1 / 0.9, 0.07, m=4)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-7
    )


def test_lbm_kernel_multi_launch_equals_sequential():
    f, attr, _ = lbm.taylor_green_init(16, 128)
    got = lbm_run_blocked(f, attr, 1 / 0.8, steps=8, m=4, block_h=8)
    want = lbm_multistep_ref(f, attr, 1 / 0.8, 0.0, m=8)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-6
    )


def test_lbm_kernel_block_independence():
    """Result must not depend on the spatial block decomposition."""
    f, attr, _ = lbm.taylor_green_init(32, 128)
    a = lbm_multistep(f, attr, 1 / 0.8, 0.0, m=2, block_h=8)
    b = lbm_multistep(f, attr, 1 / 0.8, 0.0, m=2, block_h=16)
    c = lbm_multistep(f, attr, 1 / 0.8, 0.0, m=2, block_h=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-6)


def test_lbm_kernel_rejects_bad_blocks():
    f, attr, _ = lbm.taylor_green_init(16, 128)
    with pytest.raises(ValueError):
        lbm_multistep(f, attr, 1 / 0.8, m=4, block_h=5)  # 16 % 5 != 0
    with pytest.raises(ValueError):
        lbm_multistep(f, attr, 1 / 0.8, m=16, block_h=8)  # m > block_h


def test_lbm_kernel_physics_through_kernel():
    """Taylor-Green decay through the kernel path, not just vs ref."""
    import math

    h = w = 128
    tau = 0.8
    f, attr, ksq = lbm.taylor_green_init(h, w, u0=0.02)
    e0 = lbm.tgv_kinetic_energy(f)
    f2 = lbm_run_blocked(f, attr, 1 / tau, steps=40, m=8, block_h=16)
    e1 = lbm.tgv_kinetic_energy(f2)
    expected = e0 * math.exp(-2.0 * lbm.viscosity(tau) * ksq * 40)
    assert e1 == pytest.approx(expected, rel=0.02)


# ------------------------- flash_attention -------------------------


def _qkv(rng, b, hq, hkv, sq, sk, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)).astype(dtype))
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)).astype(dtype))
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,causal,window",
    [
        (1, 2, 2, 128, 128, True, 0),
        (2, 4, 1, 128, 128, True, 0),  # MQA
        (1, 4, 2, 64, 256, True, 0),  # GQA, decode-style prefix
        (1, 2, 2, 128, 128, False, 0),  # bidirectional (encoder)
        (1, 2, 2, 256, 256, True, 64),  # sliding window
    ],
)
def test_flash_matches_direct(b, hq, hkv, sq, sk, causal, window):
    rng = np.random.default_rng(42)
    q, k, v = _qkv(rng, b, hq, hkv, sq, sk, 128, np.float32)
    got = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64
    )
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 128, 128, np.float32)
    q, k, v = (x.astype(dtype) for x in (q, k, v))
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    assert got.dtype == q.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("blocks", [(32, 32), (64, 128), (128, 64)])
def test_flash_block_independence(blocks):
    bq, bk = blocks
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 256, 128, np.float32)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


def test_chunked_ref_matches_direct_long():
    rng = np.random.default_rng(5)
    q, k, v = _qkv(rng, 1, 2, 1, 512, 512, 64, np.float32)
    got = attention_chunked_ref(q, k, v, chunk=128)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )


def test_attention_dispatcher_cpu_path():
    rng = np.random.default_rng(9)
    q, k, v = _qkv(rng, 1, 2, 2, 128, 128, 64, np.float32)
    got = attention(q, k, v)  # CPU backend -> chunked ref
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3
    )
