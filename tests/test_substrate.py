"""Training/serving substrate tests: optimizer math, checkpoint fault
tolerance, data determinism, compression error feedback, loop restarts,
serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.configs.base import ShapeConfig
from repro.configs import get_arch
from repro.models import registry
from repro.parallel.compression import (
    CompressionConfig,
    compress_int8,
    compress_topk,
    payload_bytes,
)
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, MemmapTokens, Prefetcher, SyntheticTokens, write_corpus
from repro.train.loop import LoopConfig, run_with_restarts
from repro.train.optimizer import AdamWConfig, apply_updates, init_state, lr_at

# ----------------------------- optimizer -----------------------------


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=1, total_steps=10**9)
    params = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    grads = {"w": jnp.asarray([[0.5, 0.25]], jnp.float32)}
    state = init_state(cfg, params)
    p1, s1, _ = apply_updates(cfg, params, grads, state)
    g = np.asarray([[0.5, 0.25]])
    m = 0.1 * g
    v = 0.01 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    lr = float(lr_at(cfg, s1["step"] - 1))
    want = np.asarray([[1.0, -2.0]]) - lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_adamw_clipping_and_decay():
    cfg = AdamWConfig(lr=1e-2, clip_norm=0.1, weight_decay=0.5,
                      warmup_steps=1, total_steps=10**9)
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    grads = {"w": jnp.ones((4, 4), jnp.float32) * 100.0}
    state = init_state(cfg, params)
    _, _, metrics = apply_updates(cfg, params, grads, state)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0, rel=1e-4)


def test_adamw_bf16_states():
    cfg = AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = init_state(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    p1, s1, _ = apply_updates(cfg, params, {"w": jnp.ones((8,), jnp.bfloat16)},
                              state)
    assert s1["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(p1["w"].astype(jnp.float32)).all())


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup
    assert max(lrs) == pytest.approx(1.0, rel=0.01)
    assert lrs[-1] == pytest.approx(0.1, rel=0.1)  # cosine floor


# ----------------------------- checkpoint -----------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
                   "b16": jnp.asarray(rng.standard_normal(5), jnp.bfloat16)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 10, t, extra={"note": "x"})
    got = ckpt.restore_latest(str(tmp_path), t)
    assert got is not None
    step, tree, extra = got
    assert step == 10 and extra == {"note": "x"}
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_skips_corrupt(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 10, t)
    ckpt.save(str(tmp_path), 20, _tree(1))
    ckpt.corrupt_for_test(str(tmp_path), 20)
    step, tree, _ = ckpt.restore_latest(str(tmp_path), t)
    assert step == 10  # newest valid, not newest


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    other = {"params": {"w": jnp.zeros((2, 2)), "b16": jnp.zeros(5, jnp.bfloat16)},
             "opt": {"step": jnp.asarray(0, jnp.int32)}}
    assert ckpt.restore_latest(str(tmp_path), other) is None


def test_checkpoint_async(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    t = _tree()
    saver.save(3, t)
    saver.wait()
    assert ckpt.available_steps(str(tmp_path)) == [3]


def test_checkpoint_elastic_reshard(tmp_path):
    """Saved unsharded -> restoring under a different dp width is just a
    different slicing of the same arrays."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    _, tree, _ = ckpt.restore_latest(str(tmp_path), t)
    w = np.asarray(tree["params"]["w"])
    # dp=4 -> 4 slices; dp=2 -> 2 slices; content identical when recombined
    s4 = np.concatenate(np.split(w, 4, axis=0))
    s2 = np.concatenate(np.split(w, 2, axis=0))
    np.testing.assert_array_equal(s4, s2)


# ----------------------------- data -----------------------------


def test_synthetic_determinism_and_host_sharding():
    c0 = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1,
                    num_hosts=2, host_id=0)
    c1 = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1,
                    num_hosts=2, host_id=1)
    a = SyntheticTokens(c0).batch_at(5)
    b = SyntheticTokens(c0).batch_at(5)
    c = SyntheticTokens(c1).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, np.arange(10_000) % 251)
    cfg = DataConfig(vocab=251, seq_len=16, global_batch=4, path=path)
    src = MemmapTokens(cfg)
    b1 = src.batch_at(0)
    b2 = src.batch_at(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_prefetcher_orders_batches():
    cfg = DataConfig(vocab=50, seq_len=4, global_batch=2, seed=3)
    pf = Prefetcher(SyntheticTokens(cfg), start_step=7)
    try:
        s0, _ = pf.next()
        s1, _ = pf.next()
        assert (s0, s1) == (7, 8)
    finally:
        pf.close()


# ----------------------------- compression -----------------------------


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_error_feedback_reduces_bias(seed):
    """With EF, accumulated compressed updates track the true sum."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((64,)).astype(np.float32)) * 0.1
    r = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(20):
        (_, _), deq, r = compress_int8(g, r)
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc), np.asarray(20 * g),
                               atol=0.05 * float(jnp.abs(g).max()) + 1e-4)


def test_topk_keeps_largest():
    g = jnp.asarray(np.arange(100, dtype=np.float32))
    (vals, idx), deq, r = compress_topk(g, jnp.zeros_like(g), 0.1)
    assert set(np.asarray(idx).tolist()) == set(range(90, 100))
    np.testing.assert_allclose(np.asarray(deq)[90:], np.arange(90, 100))


def test_payload_bytes_accounting():
    params = {"w": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert payload_bytes(params, CompressionConfig("int8_ef")) == 1024 + 8
    assert payload_bytes(params, CompressionConfig("none")) == 2048
    topk = payload_bytes(params, CompressionConfig("topk_ef", topk_frac=0.01))
    assert topk == 8 * 10


# ----------------------------- loop + faults -----------------------------


def _tiny_training(tmp_path, fail_at=()):
    cfg = get_arch("xlstm-125m").reduced()
    import dataclasses

    cfg = dataclasses.replace(cfg, n_layers=2, d_model=64, vocab=64,
                              n_heads=2, n_kv_heads=2)
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    opt_state = init_state(opt_cfg, params)
    step = jax.jit(bundle.make_train_step(opt_cfg))

    def train_step(params, opt_state, batch):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return step(params, opt_state, b)

    loop_cfg = LoopConfig(
        total_steps=12, ckpt_dir=str(tmp_path / "ck"), ckpt_every=4,
        log_every=100, fail_at_steps=fail_at,
    )
    data_cfg = DataConfig(vocab=64, seq_len=16, global_batch=2, seed=0)
    return loop_cfg, data_cfg, train_step, params, opt_state


def test_loop_runs_clean(tmp_path):
    args = _tiny_training(tmp_path)
    params, opt, st = run_with_restarts(*args, log=lambda s: None)
    assert st.step == 12 and st.restarts == 0
    assert all(np.isfinite(st.losses))


def test_loop_restarts_after_fault_and_converges(tmp_path):
    """Inject faults; the supervisor must restore from checkpoint and the
    final state must be step-complete."""
    args = _tiny_training(tmp_path, fail_at=(6, 9))
    params, opt, st = run_with_restarts(*args, log=lambda s: None)
    assert st.restarts == 2
    assert st.step == 12
    # checkpoints exist and the newest is the final step
    steps = ckpt.available_steps(str(tmp_path / "ck"))
    assert steps[-1] == 12


def test_loop_fault_resumes_data_stream(tmp_path):
    """Restarted run must re-consume the same step indices (determinism)."""
    clean = _tiny_training(tmp_path / "a")
    p1, _, st1 = run_with_restarts(*clean, log=lambda s: None)
    faulty = _tiny_training(tmp_path / "b", fail_at=(6,))
    p2, _, st2 = run_with_restarts(*faulty, log=lambda s: None)
    # same final loss trajectory tail after recovery
    assert st1.losses[-1] == pytest.approx(st2.losses[-1], rel=1e-4)


# ----------------------------- serve engine -----------------------------


def test_serve_engine_batched_requests():
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3-8b").reduced(), n_layers=2, d_model=64, vocab=97,
        n_heads=2, n_kv_heads=2, head_dim=32,
    )
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(bundle, params, max_batch=3, max_seq=64)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert sorted(c.rid for c in done) == [0, 1, 2, 3, 4]
    assert all(len(c.tokens) == 4 for c in done)
    assert all(0 <= t < cfg.vocab for c in done for t in c.tokens)


def test_serve_greedy_matches_forward():
    """Engine greedy decode == argmax of teacher-forced forward logits."""
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3-8b").reduced(), n_layers=2, d_model=64, vocab=97,
        n_heads=2, n_kv_heads=2, head_dim=32,
    )
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    from repro.serve.engine import Request, ServeEngine

    prompt = [5, 17, 31]
    eng = ServeEngine(bundle, params, max_batch=1, max_seq=32)
    eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=3))
    done = eng.run_until_drained()
    toks = done[0].tokens
    seq = list(prompt)
    for t in toks:
        logits = bundle.forward(params, {"tokens": jnp.asarray([seq], jnp.int32)})
        want = int(jnp.argmax(logits[0, -1]))
        assert t == want
        seq.append(t)


def test_serve_run_until_drained_raises_instead_of_truncating():
    """Hitting max_ticks with requests still pending must raise naming
    the undrained rids, never silently return a partial list."""
    import dataclasses

    cfg = dataclasses.replace(
        get_arch("qwen3-8b").reduced(), n_layers=2, d_model=64, vocab=97,
        n_heads=2, n_kv_heads=2, head_dim=32,
    )
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    from repro.serve.engine import Request, ServeEngine

    eng = ServeEngine(bundle, params, max_batch=1, max_seq=64)
    eng.submit(Request(rid=3, prompt=[1, 2, 3], max_new_tokens=40))
    eng.submit(Request(rid=4, prompt=[4, 5], max_new_tokens=40))
    with pytest.raises(RuntimeError, match=r"undrained.*3"):
        eng.run_until_drained(max_ticks=2)
