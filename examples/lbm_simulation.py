"""End-to-end driver: lid-driven-cavity fluid simulation through the
SPD-compiled LBM pipeline, with checkpoint/restart and an (n, m)
design-space report — the paper's application, start to finish.

    PYTHONPATH=src python examples/lbm_simulation.py --steps 400 --m 4
"""

import argparse
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.apps import lbm
from repro.core.dse import FPGAModel, StreamWorkload, TPUModel, render_table
from repro.train import checkpoint as ckpt


def ascii_flow(ux, uy, rows=16, cols=32):
    """Terminal visualization of the velocity field."""
    h, w = ux.shape
    chars = " .:-=+*#%@"
    sy, sx = max(h // rows, 1), max(w // cols, 1)
    mag = np.sqrt(np.asarray(ux) ** 2 + np.asarray(uy) ** 2)
    mag = mag[::sy, ::sx]
    mx = mag.max() or 1.0
    lines = []
    for r in mag[::-1]:
        lines.append("".join(chars[min(int(v / mx * 9.99), 9)] for v in r))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=96)
    ap.add_argument("--width", type=int, default=96)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--m", type=int, default=4, help="temporal cascade depth")
    ap.add_argument("--tau", type=float, default=0.7)
    ap.add_argument("--u-lid", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lbm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    prob = lbm.LBMProblem(args.height, args.width, tau=args.tau,
                          u_lid=args.u_lid, mode="zero")
    sim = lbm.LBMSimulation(prob, m=args.m)
    rep = sim.hardware_report
    print(f"[lbm] SPD PE: {rep.flops} FP ops, depth {rep.depth}; "
          f"cascade m={args.m} -> depth {args.m * rep.depth}")

    f, attr = lbm.cavity_init(args.height, args.width)
    start = 0
    restored = ckpt.restore_latest(args.ckpt_dir, {"f": f})
    if restored:
        start, tree, _ = restored
        f = tree["f"]
        print(f"[lbm] restored checkpoint at step {start}")

    t0 = time.time()
    done = start
    while done < args.steps:
        n = min(args.ckpt_every, args.steps - done)
        n -= n % args.m or 0
        n = max(n, args.m)
        f = sim.run(f, attr, n)
        done += n
        ckpt.save(args.ckpt_dir, done, {"f": f})
        rho, ux, uy = lbm.macroscopics(f)
        print(f"[lbm] step {done}: mean|u|="
              f"{float(jnp.mean(jnp.sqrt(ux**2 + uy**2))):.5f} "
              f"mass={float(jnp.sum(rho)):.1f}")
    dt = time.time() - t0
    sites = args.height * args.width * (done - start)
    print(f"[lbm] {done - start} steps in {dt:.2f}s = "
          f"{sites / dt / 1e6:.2f} MLUPS (CPU)")

    rho, ux, uy = lbm.macroscopics(f)
    print("\n[lbm] cavity flow |u| field:")
    print(ascii_flow(ux, uy))

    # --- the DSE report for this workload ----------------------------------
    w = StreamWorkload.from_report(rep, elems=args.height * args.width,
                                   grid_w=args.width)
    print("\n[lbm] FPGA-target design space (paper model):")
    print(render_table(FPGAModel().explore(w)[:6]))
    print("\n[lbm] TPU-v5e-target temporal blocking:")
    print(render_table(TPUModel().explore(w)[:6]))


if __name__ == "__main__":
    main()
