"""Train a language model end-to-end with the production loop: deterministic
data pipeline, AdamW, async checkpointing, fault injection, straggler
tracking. Any assigned arch is selectable; by default a ~100M-param qwen3
variant sized for CPU.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --smoke
    PYTHONPATH=src python examples/train_lm.py --steps 50 --fail-at 20
"""

import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.models import registry
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, run_with_restarts
from repro.train.optimizer import AdamWConfig, init_state


def hundred_m_config():
    """~100M-parameter decoder (qwen3 family) that trains on CPU."""
    base = get_arch("qwen3-8b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=2048, vocab=32768, head_dim=64, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (reduced config); default 100M")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced() smoke config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject faults after these steps (restart demo)")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    if args.arch:
        cfg = get_arch(args.arch)
        cfg = cfg.reduced() if args.smoke else cfg
    else:
        cfg = hundred_m_config()
    print(f"[train] arch={cfg.name} params~{cfg.num_params()/1e6:.1f}M "
          f"family={cfg.family}")

    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps,
                          state_dtype=cfg.opt_state_dtype)
    opt_state = init_state(opt_cfg, params)
    step = jax.jit(bundle.make_train_step(opt_cfg, args.microbatches))

    import jax.numpy as jnp

    def train_step(p, o, batch):
        return step(p, o, {k: jnp.asarray(v) for k, v in batch.items()})

    loop_cfg = LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10, fail_at_steps=tuple(args.fail_at),
    )
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    params, opt_state, st = run_with_restarts(
        loop_cfg, data_cfg, train_step, params, opt_state
    )
    print(f"[train] done: {st.step} steps, {st.restarts} restarts, "
          f"{st.straggler_events} straggler events")
    print(f"[train] loss first5={['%.3f' % l for l in st.losses[:5]]} "
          f"last5={['%.3f' % l for l in st.losses[-5:]]}")


if __name__ == "__main__":
    main()
