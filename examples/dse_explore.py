"""Design-space exploration walkthrough — the paper's workflow as a tool:
compile an SPD workload, sweep (n, m) on the FPGA model, sweep temporal
blocking on the TPU model, and plan LM meshes with the same trade-off.

    PYTHONPATH=src python examples/dse_explore.py --arch kimi-k2-1t-a32b
"""

import argparse

from repro.apps import lbm
from repro.configs import ARCHS, get_arch
from repro.core.dse import FPGAModel, StreamWorkload, TPUModel, render_table
from repro.core.planner import ArchStats, plan, render_plans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    args = ap.parse_args()

    print("=" * 72)
    print("1) The paper's case study: LBM on the Stratix V model")
    print("=" * 72)
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    w = StreamWorkload.from_report(sim.hardware_report, elems=720 * 300,
                                   grid_w=720)
    print(render_table(FPGAModel().explore(w)))

    print()
    print("=" * 72)
    print("2) Hardware adaptation: temporal blocking on TPU v5e")
    print("=" * 72)
    print(render_table(TPUModel().explore(w)[:8]))

    print()
    print("=" * 72)
    print(f"3) The same trade on an LM fleet: {args.arch} on "
          f"{args.chips} chips")
    print("   (spatial n -> dp, temporal m -> pp, in-PE -> tp)")
    print("=" * 72)
    cfg = get_arch(args.arch)
    stats = ArchStats(
        name=cfg.name, params=cfg.num_params(),
        active_params=cfg.active_params(), n_layers=cfg.n_layers,
        d_model=cfg.d_model, global_batch=args.batch, seq_len=args.seq,
    )
    print(render_plans(plan(stats, args.chips), top=10))


if __name__ == "__main__":
    main()
