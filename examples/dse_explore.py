"""Design-space exploration walkthrough — the paper's workflow as a tool.

Compile the SPD LBM core, sweep the full (n, m) lattice on the FPGA model
and the (block_h, m) lattice on the TPU model in batched NumPy, extract
the Pareto frontiers, execute the TPU frontiers through real Pallas
kernels — the hand-written ``lbm_stream`` for LBM *and* the generic
SPD→Pallas codegen path for the 2-D diffusion app — and plan LM meshes
with the same spatial/temporal trade-off:

    PYTHONPATH=src python examples/dse_explore.py --arch granite-34b

or, after ``pip install -e .``, simply ``repro-explore``. Use
``--no-execute`` to skip the (host-speed) interpret-mode kernel runs,
``--topk`` to execute more frontier points, ``--devices N`` to sweep the
device axis d (multi-chip sharding with halo exchange; off-TPU force
host devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
so d > 1 frontier points actually run), ``--strategy refine|halving``
with ``--budget N`` to autotune measured-in-the-loop under a hard
measurement budget (docs/pipeline.md §search), and ``--json PATH`` to
dump the results — including strategy/budget accounting — for
scripting. The implementation lives in :mod:`repro.cli` so the
installed console script and this checkout script stay one code path.
"""

from repro.cli import explore_main

if __name__ == "__main__":
    explore_main()
