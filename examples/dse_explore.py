"""Design-space exploration walkthrough — the paper's workflow as a tool.

Compile the SPD LBM core, sweep the full (n, m) lattice on the FPGA model
and the (block_h, m) lattice on the TPU model in batched NumPy, extract
the Pareto frontiers, execute the TPU frontier through the real Pallas
kernel, and plan LM meshes with the same spatial/temporal trade-off:

    PYTHONPATH=src python examples/dse_explore.py --arch granite-34b

Use ``--no-execute`` to skip the (host-speed) interpret-mode kernel runs,
``--topk`` to execute more frontier points.
"""

import argparse

from repro.apps import lbm
from repro.configs import get_arch
from repro.core.explorer import execute_frontier, render_executed
from repro.core.planner import ArchStats, plan, render_plans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-34b")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--topk", type=int, default=2)
    ap.add_argument("--no-execute", action="store_true",
                    help="skip the interpret-mode Pallas runs")
    args = ap.parse_args()

    print("=" * 72)
    print("1) The paper's case study: LBM on the Stratix V model")
    print("=" * 72)
    sim = lbm.LBMSimulation(lbm.LBMProblem(300, 720, mode="wrap"))
    ex = sim.explorer()
    sweep = ex.sweep_fpga(n_values=(1, 2, 4, 8), m_values=(1, 2, 4, 8))
    print(sweep.table(k=10))
    print()
    print("Pareto frontier (max throughput, max perf/W, min resources):")
    print(sweep.table(frontier_only=True))
    best = sweep.best("perf_per_watt")
    print(f"-> best configuration: (n, m) = ({best.n}, {best.m})  "
          f"[paper §III: (1, 4)]")

    print()
    print("=" * 72)
    print("2) Hardware adaptation: temporal blocking on TPU v5e")
    print("=" * 72)
    tsweep = ex.sweep_tpu()
    print(tsweep.table(k=8))
    print()
    print("TPU Pareto frontier:")
    print(tsweep.table(frontier_only=True, k=6))

    if not args.no_execute:
        print()
        print("=" * 72)
        print(f"3) Model -> measurement: top-{args.topk} frontier points "
              f"through the Pallas kernel (interpret mode, 64x128)")
        print("=" * 72)
        mex = lbm.LBMSimulation(lbm.LBMProblem(64, 128, mode="wrap")).explorer()
        msweep = mex.sweep_tpu(bh_values=(8, 16, 32, 64),
                               m_values=(1, 2, 4, 8))
        f0, attr, _ = lbm.taylor_green_init(64, 128)
        runs = execute_frontier(msweep, f0, attr, one_tau=1 / 0.8,
                                k=args.topk, interpret=True)
        print(render_executed(runs))

    print()
    print("=" * 72)
    print(f"4) The same trade on an LM fleet: {args.arch} on "
          f"{args.chips} chips")
    print("   (spatial n -> dp, temporal m -> pp, in-PE -> tp)")
    print("=" * 72)
    cfg = get_arch(args.arch)
    stats = ArchStats(
        name=cfg.name, params=cfg.num_params(),
        active_params=cfg.active_params(), n_layers=cfg.n_layers,
        d_model=cfg.d_model, global_batch=args.batch, seq_len=args.seq,
    )
    print(render_plans(plan(stats, args.chips), top=10))


if __name__ == "__main__":
    main()
