"""Quickstart: write an SPD core (the paper's Fig. 4), compile it to JAX,
run a stream through it, inspect the hardware model, and apply the (n, m)
parallelism transforms.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import Registry, parse_spd, spatial_duplicate, temporal_cascade
from repro.core.dse import FPGAModel, StreamWorkload

SPD_SOURCE = """
Name  core;                         # the paper's Fig. 4 example
Main_In  {main_i::x1,x2,x3,x4};
Main_Out {main_o::z1,z2};
Brch_In  {brch_i::bin1};
Brch_Out {brch_o::bout1};
Param cnst = 123.456;
EQU Node1, t1 = x1 * x2;            # eq (5)
EQU Node2, t2 = x3 + x4;            # eq (6)
EQU Node3, z1 = t1 - t2 * bin1;     # eq (7)
EQU Node4, z2 = t1 / t2 + cnst;     # eq (8)
DRCT (bout1) = (t2);                # eq (9)
"""


def main():
    reg = Registry()
    core = reg.compile(parse_spd(SPD_SOURCE))

    # --- run a stream through the compiled dataflow ------------------------
    t = jnp.arange(8, dtype=jnp.float32)
    main_out, brch_out = core(
        {"x1": t, "x2": t + 1, "x3": t + 2, "x4": t + 3},
        {"bin1": jnp.ones_like(t)},
    )
    print("z1   =", np.asarray(main_out["z1"]))
    print("z2   =", np.asarray(main_out["z2"]))
    print("bout1=", np.asarray(brch_out["bout1"]))

    # --- the hardware model behind the same core ---------------------------
    rep = core.hardware_report
    print(f"\nhardware: {rep.flops} FP ops {rep.census}, "
          f"pipeline depth {rep.depth} cycles, "
          f"{rep.balance_regs} balance register-stages")

    # --- (n, m) parallelism transforms --------------------------------------
    pe = reg.compile(parse_spd("""
        Name PE;
        Main_In {mi::u};
        Main_Out {mo::u2};
        EQU N1, u2 = u + 0.25 * ( 1.0 - u * u );
    """))
    casc = temporal_cascade(pe, 4)   # m=4: one pass = 4 iterations
    dup = spatial_duplicate(pe, 2)   # n=2: two lanes per cycle
    print(f"\ntemporal cascade x4: depth {casc.hardware_report.depth} "
          f"(PE depth {pe.hardware_report.depth}), flops {casc.flops}")
    print(f"spatial duplicate x2: flops {dup.flops}, "
          f"depth {dup.hardware_report.depth}")

    x = jnp.linspace(0.0, 0.9, 6)
    (out4,) = casc.apply([x])
    seq = x
    for _ in range(4):
        (seq,) = pe.apply([seq])
    print("cascade == 4 sequential applications:",
          bool(jnp.allclose(out4, seq, rtol=1e-6)))

    # --- explore the design space with the paper's platform model ----------
    w = StreamWorkload.from_report(pe.hardware_report, elems=10_000, grid_w=100)
    for pt in FPGAModel().explore(w, n_values=(1, 2), m_values=(1, 4))[:3]:
        print(f"(n={pt.n}, m={pt.m}) -> {pt.sustained_gflops:.2f} GF/s, "
              f"{pt.perf_per_watt:.3f} GF/sW {'FEASIBLE' if pt.feasible else pt.limits}")


if __name__ == "__main__":
    main()
