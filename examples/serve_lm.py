"""Serve a small model with batched requests through the continuous-batching
engine (KV-cache decode path — the same code the decode_* dry-run shapes
lower).

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --max-batch 4
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch(args.arch).reduced(), n_layers=4, d_model=256, vocab=4096,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512,
    )
    bundle = registry.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, max_batch=args.max_batch,
                      max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 12)).tolist()
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.new_tokens,
                           temperature=args.temperature))
    done = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    for c in sorted(done, key=lambda c: c.rid):
        print(f"[serve] req {c.rid}: {c.tokens}")
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
          f"batch slots={args.max_batch})")


if __name__ == "__main__":
    main()
